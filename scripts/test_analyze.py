#!/usr/bin/env python3
"""Golden-fixture tests for the tomers-analyze static analyzer.

Per lint pass there is a minimal firing fixture and a clean twin under
scripts/analyze_fixtures/<pass>/{fire,clean}/src — the test proves the
pass fires on the trigger and stays silent on the twin, so a lint
regression (pass stops firing, or starts flagging idiomatic code) is
caught by verify.sh without cargo.

Also covered: allowlist schema strictness (bad version, unknown keys,
short justifications, stale entries), allowlist application, and the
ANALYZE_report.json shape.

Run: python3 scripts/test_analyze.py [-v]
"""

from __future__ import annotations

import json
import os
import sys
import tempfile
import unittest

_SCRIPTS = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, _SCRIPTS)

from analyze import PASS_IDS, AllowlistError, analyze_root  # noqa: E402
from findings import load_allowlist  # noqa: E402

_FIXTURES = os.path.join(_SCRIPTS, "analyze_fixtures")


def _pass_findings(fixture: str, which: str, pass_id: str):
    crate = os.path.join(_FIXTURES, fixture, which)
    report = analyze_root(crate, allow_path=None, rel_prefix="rust")
    return [f for f in report.findings if f.pass_id == pass_id]


class FixtureTests(unittest.TestCase):
    """Each pass fires on its trigger and stays silent on the twin."""

    def _check(self, pass_id: str):
        fire = _pass_findings(pass_id, "fire", pass_id)
        self.assertTrue(
            fire,
            f"{pass_id}: fire fixture produced no {pass_id} findings",
        )
        clean = _pass_findings(pass_id, "clean", pass_id)
        self.assertFalse(
            clean,
            f"{pass_id}: clean fixture still fires: "
            + "; ".join(f.message for f in clean),
        )

    def test_symbols(self):
        self._check("symbols")
        msgs = " ".join(
            f.message for f in _pass_findings("symbols", "fire", "symbols")
        )
        self.assertIn("arity mismatch", msgs)
        self.assertIn("unresolved call", msgs)

    def test_wiring(self):
        self._check("wiring")
        msgs = " ".join(
            f.message for f in _pass_findings("wiring", "fire", "wiring")
        )
        self.assertIn("no backing file", msgs)
        self.assertIn("orphan file", msgs)

    def test_concurrency(self):
        self._check("concurrency")
        syms = {f.symbol for f in
                _pass_findings("concurrency", "fire", "concurrency")}
        self.assertIn("mpsc::channel", syms)
        self.assertIn("join().unwrap", syms)

    def test_panics(self):
        self._check("panics")
        syms = {f.symbol for f in _pass_findings("panics", "fire", "panics")}
        self.assertIn("partial_cmp().unwrap", syms)
        self.assertIn("unwrap", syms)

    def test_configs(self):
        self._check("configs")

    def test_unsafe(self):
        self._check("unsafe")

    def test_deprecation(self):
        self._check("deprecation")

    def test_every_pass_has_fixtures(self):
        for pass_id in PASS_IDS:
            for which in ("fire", "clean"):
                d = os.path.join(_FIXTURES, pass_id, which, "src")
                self.assertTrue(
                    os.path.isdir(d), f"missing fixture dir {d}"
                )


class AllowlistSchemaTests(unittest.TestCase):
    """The allowlist only suppresses with a justified, live entry."""

    def _load(self, doc, known=frozenset({"rust/src/lib.rs"})):
        with tempfile.NamedTemporaryFile(
            "w", suffix=".json", delete=False
        ) as fh:
            json.dump(doc, fh)
            path = fh.name
        try:
            return load_allowlist(path, set(known))
        finally:
            os.unlink(path)

    def _entry(self, **over):
        e = {
            "pass": "panics",
            "file": "rust/src/lib.rs",
            "pattern": "unwrap",
            "justification": "a justification long enough to pass",
        }
        e.update(over)
        return e

    def test_valid_roundtrip(self):
        allows = self._load({"version": 1, "entries": [self._entry()]})
        self.assertEqual(len(allows), 1)
        self.assertEqual(allows[0].pass_id, "panics")

    def test_bad_version(self):
        with self.assertRaises(AllowlistError):
            self._load({"version": 2, "entries": []})

    def test_unknown_entry_key(self):
        with self.assertRaises(AllowlistError):
            self._load({
                "version": 1,
                "entries": [self._entry(extra="nope")],
            })

    def test_missing_justification(self):
        e = self._entry()
        del e["justification"]
        with self.assertRaises(AllowlistError):
            self._load({"version": 1, "entries": [e]})

    def test_short_justification(self):
        with self.assertRaises(AllowlistError):
            self._load({
                "version": 1,
                "entries": [self._entry(justification="because")],
            })

    def test_unknown_pass(self):
        with self.assertRaises(AllowlistError):
            self._load({
                "version": 1,
                "entries": [self._entry(**{"pass": "vibes"})],
            })

    def test_unknown_file(self):
        with self.assertRaises(AllowlistError):
            self._load({
                "version": 1,
                "entries": [self._entry(file="rust/src/ghost.rs")],
            })


class AllowlistApplicationTests(unittest.TestCase):
    """Entries suppress matching findings; stale entries are flagged."""

    def _analyze_fire(self, entries):
        crate = os.path.join(_FIXTURES, "panics", "fire")
        with tempfile.NamedTemporaryFile(
            "w", suffix=".json", delete=False
        ) as fh:
            json.dump({"version": 1, "entries": entries}, fh)
            path = fh.name
        try:
            return analyze_root(crate, allow_path=path, rel_prefix="rust")
        finally:
            os.unlink(path)

    def test_matching_entry_suppresses(self):
        # specific pattern first: entries match in order, and the broad
        # "unwrap" substring would otherwise claim the partial_cmp line
        # too, leaving the specific entry stale
        report = self._analyze_fire([
            {
                "pass": "panics",
                "file": "rust/src/lib.rs",
                "pattern": "partial_cmp().unwrap",
                "justification": "fixture: the NaN hazard is the trigger",
            },
            {
                "pass": "panics",
                "file": "rust/src/lib.rs",
                "pattern": "unwrap",
                "justification": "fixture: unwraps are the trigger here",
            },
        ])
        self.assertFalse(report.errors)
        panics_new = [
            f for f in report.new_findings if f.pass_id == "panics"
        ]
        self.assertFalse(panics_new)
        self.assertFalse(report.stale_allows)

    def test_stale_entry_fails(self):
        report = self._analyze_fire([
            {
                "pass": "panics",
                "file": "rust/src/lib.rs",
                "pattern": "this-matches-nothing-at-all",
                "justification": "stale on purpose for the test",
            },
        ])
        self.assertTrue(report.stale_allows)
        self.assertFalse(report.ok)

    def test_unallowed_finding_fails_report(self):
        report = self._analyze_fire([])
        self.assertFalse(report.ok)
        self.assertTrue(report.new_findings)


class ReportShapeTests(unittest.TestCase):
    """ANALYZE_report.json carries per-pass counts and every finding."""

    def test_report_json_shape(self):
        crate = os.path.join(_FIXTURES, "panics", "fire")
        report = analyze_root(crate, allow_path=None, rel_prefix="rust")
        doc = report.to_json()
        self.assertEqual(doc["version"], 1)
        self.assertIn("ok", doc)
        self.assertIn("files_scanned", doc)
        self.assertEqual(set(doc["passes"]), set(PASS_IDS))
        for row in doc["passes"].values():
            self.assertEqual(
                set(row), {"findings", "allowlisted", "new"}
            )
        for f in doc["findings"]:
            self.assertLessEqual(
                {"pass", "file", "line", "symbol", "message"}, set(f)
            )

    def test_summary_table_lists_all_passes(self):
        crate = os.path.join(_FIXTURES, "symbols", "clean")
        report = analyze_root(crate, allow_path=None, rel_prefix="rust")
        table = report.summary_table()
        for pass_id in PASS_IDS:
            self.assertIn(pass_id, table)


if __name__ == "__main__":
    unittest.main()
