"""Pass (d) `panics` — panic paths in non-test src must be justified.

`unwrap()`, `expect(…)`, `panic!(…)`, `unreachable!(…)`, `todo!` /
`unimplemented!`, and the `partial_cmp(…).unwrap()` NaN hazard (PR 1's
top-r bug class) are flagged in `rust/src` outside `#[cfg(test)]`
scopes.  Every hit must either be removed or allowlisted with a
one-line justification of why the invariant can't fail (or why failing
fast is the correct behavior there).

Tests, benches and examples are exempt: a panic there fails the harness
loudly, which is exactly what those contexts want.
"""

from __future__ import annotations

import re

from findings import Finding
from index import CrateIndex

PASS_ID = "panics"

_PATTERNS = [
    # partial_cmp first so the more specific symbol wins on shared lines
    (re.compile(r"\.partial_cmp\s*\([^)]*\)\s*\.\s*unwrap\s*\(\)"),
     "partial_cmp().unwrap",
     "`partial_cmp().unwrap()` panics on NaN (the PR 1 top-r hazard class)"
     " — use `total_cmp` or handle the None"),
    (re.compile(r"\.unwrap\s*\(\)"), "unwrap",
     "`unwrap()` on a serving path turns a recoverable error into a panic"),
    (re.compile(r"\.expect\s*\("), "expect",
     "`expect()` on a serving path turns a recoverable error into a panic"),
    (re.compile(r"\bpanic!\s*[\(\[{]"), "panic!",
     "explicit `panic!` in library code"),
    (re.compile(r"\bunreachable!\s*[\(\[{]"), "unreachable!",
     "`unreachable!` is a panic if the reasoning ever rots"),
    (re.compile(r"\btodo!\s*[\(\[{]"), "todo!", "`todo!` must not ship"),
    (re.compile(r"\bunimplemented!\s*[\(\[{]"), "unimplemented!",
     "`unimplemented!` must not ship"),
]


def run(ix: CrateIndex) -> list[Finding]:
    out: list[Finding] = []
    for path, fi in ix.files.items():
        if fi.kind != "src":
            continue
        code = fi.sf.code
        # a file may define its own method named `expect`/`unwrap` (the
        # JSON parser's `self.expect(b'{')` is a Result-returning token
        # check, not Option::expect) — exempt `self.<name>(` there
        own_methods = {
            name for name in ("expect", "unwrap")
            if any(fd.file == path and fd.has_self
                   for fd in ix.fns.get(name, []))
        }
        seen_spans: list[tuple[int, int]] = []
        for rx, symbol, why in _PATTERNS:
            for m in rx.finditer(code):
                if any(s <= m.start() < e for s, e in seen_spans):
                    continue  # already claimed by a more specific pattern
                if own_methods and symbol in own_methods and \
                        code[: m.start()].endswith("self"):
                    continue
                gates = ix.gates_at(path, m.start()) | fi.file_gates
                if "test" in gates:
                    continue
                seen_spans.append((m.start(), m.end()))
                line = fi.sf.line_of(m.start())
                out.append(Finding(
                    PASS_ID, path, line, symbol,
                    f"{why} — allowlist with a justification or remove",
                    fi.sf.line_text(line).strip()))
    return out
