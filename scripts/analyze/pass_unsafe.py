"""Pass (f) `unsafe` — unsafe confinement and SAFETY comments.

The crate's contract: `unsafe` lives in `merging/simd.rs` only, each
occurrence inside a `cfg(target_arch)`-gated scope (the intrinsic
modules and the dispatch match arms), and every occurrence carries a
`// SAFETY:` comment (or `# Safety` doc section for unsafe fns) within
the preceding lines stating the alignment / length / feature-gate
preconditions.  Unsafe anywhere else — today that's the worker pool's
type-erased task cell — must be allowlisted with its invariant.
"""

from __future__ import annotations

import re

from findings import Finding
from index import CrateIndex

PASS_ID = "unsafe"

_UNSAFE_RE = re.compile(r"\bunsafe\b")
_SAFETY_RE = re.compile(r"(//\s*SAFETY:|#\s*Safety)", re.IGNORECASE)
_ALLOWED_FILE_SUFFIX = "merging/simd.rs"
_COMMENT_LOOKBACK_LINES = 8


def run(ix: CrateIndex) -> list[Finding]:
    out: list[Finding] = []
    for path, fi in ix.files.items():
        if fi.kind == "vendor":
            continue
        code = fi.sf.code
        for m in _UNSAFE_RE.finditer(code):
            line = fi.sf.line_of(m.start())
            snippet = fi.sf.line_text(line).strip()
            in_simd = path.replace("\\", "/").endswith(_ALLOWED_FILE_SUFFIX)
            gates = ix.gates_at(path, m.start()) | fi.file_gates
            if not in_simd:
                out.append(Finding(
                    PASS_ID, path, line, "unsafe",
                    "`unsafe` outside merging/simd.rs — the kernel ISA "
                    "module is the only sanctioned unsafe surface; "
                    "allowlist with the invariant this block relies on",
                    snippet))
                continue
            if "target_arch" not in gates and not _arch_attr_nearby(fi, line):
                out.append(Finding(
                    PASS_ID, path, line, "unsafe-ungated",
                    "`unsafe` in simd.rs outside any #[cfg(target_arch)] "
                    "scope — intrinsics must be arch-gated", snippet))
                continue
            if not _has_safety_comment(fi, line):
                out.append(Finding(
                    PASS_ID, path, line, "unsafe-no-safety-comment",
                    f"`unsafe` at {path}:{line} lacks a `// SAFETY:` "
                    f"comment within {_COMMENT_LOOKBACK_LINES} lines "
                    f"stating its preconditions", snippet))
    return out


_ARCH_ATTR_RE = re.compile(r"#\[cfg\((?:any\()?target_arch")


def _arch_attr_nearby(fi, line: int) -> bool:
    """Match-arm `#[cfg(target_arch = …)]` attributes gate the arm, not
    an item, so the region map can't see them — accept a textual
    attribute within the lookback window."""
    lo = max(1, line - _COMMENT_LOOKBACK_LINES)
    for ln in range(lo, line + 1):
        if _ARCH_ATTR_RE.search(fi.sf.line_text(ln)):
            return True
    return False


def _has_safety_comment(fi, line: int) -> bool:
    """Look back through the *raw* text (comments were scrubbed from
    `code`) for a SAFETY marker within the lookback window, and also
    accept one on the same line (trailing comment)."""
    lo = max(1, line - _COMMENT_LOOKBACK_LINES)
    for ln in range(lo, line + 1):
        if _SAFETY_RE.search(fi.sf.line_text(ln)):
            return True
    return False
