"""Pass (a) `symbols` — whole-crate interface resolution.

Mechanizes the manual review every toolchain-less PR has relied on:
every call site, method receiver and struct-literal field set must
resolve to a definition with a matching shape *somewhere* in the crate
(or the curated std knowledge base, `stdlib.py`).

Checked, per expression position:

* path calls `a::b::f(x, y)` — `f` must be a known fn / tuple-struct /
  tuple-variant / macro-less callable with matching arity (UFCS
  `Type::method(recv, …)` accepted at arity+1);
* method calls `recv.m(x)` — `m` must be a crate method with matching
  arity or a known std method (std is name-only: overload sets across
  std types make arity checking there meaningless without inference);
* macro calls `m!(…)` — `m` must be a crate `macro_rules!` or std macro;
* struct literals / struct patterns `Name { f1: …, f2, .. }` — the
  field names must be a subset of the definition's fields, and exactly
  equal when no `..` rest appears.

Resolution is name-global by design (the "grep the call against its
definition" bar), so renames, arity drift, and field drift — the actual
failure modes of review-only PRs — are caught, while type-level
mistakes remain the (documented) residual for the day `cargo check`
lands.
"""

from __future__ import annotations

from findings import Finding
from index import CrateIndex, FileInfo
from lexer import Tok, match_delim, match_angle
from stdlib import (
    PRELUDE_CALLABLES,
    STD_MACROS,
    STD_METHODS,
    STD_PATH_FNS,
    STD_ROOTS,
    STD_TYPES,
    is_intrinsic,
)

PASS_ID = "symbols"

# Idents that look like calls but are control flow / syntax.
_NOT_CALLS = {
    "if", "while", "for", "match", "return", "loop", "fn", "in", "as",
    "where", "move", "mut", "ref", "let", "else", "break", "continue",
    "impl", "dyn", "use", "pub", "unsafe", "async", "await", "box",
    "const", "static", "type", "union", "extern",
    # closure-trait bounds in type position, not calls
    "Fn", "FnMut", "FnOnce",
}


def run(ix: CrateIndex) -> list[Finding]:
    out: list[Finding] = []
    for path, fi in ix.files.items():
        if fi.kind == "vendor":
            continue
        out.extend(_scan_file(ix, fi))
    return out


def _attr_token_mask(toks: list[Tok]) -> list[bool]:
    """True for every token inside a `#[…]` / `#![…]` attribute —
    attribute bodies (`derive(…)`, `allow(…)`, `cfg(…)`) are meta-syntax,
    not call expressions."""
    mask = [False] * len(toks)
    i = 0
    while i < len(toks):
        if toks[i].val == "#" and toks[i].kind == "punct":
            j = i + 1
            if j < len(toks) and toks[j].val == "!" and toks[j].kind == "punct":
                j += 1
            if j < len(toks) and toks[j].kind == "open" and toks[j].val == "[":
                end = match_delim(toks, j)
                for k in range(i, end + 1):
                    mask[k] = True
                i = end + 1
                continue
        i += 1
    return mask


def _scan_file(ix: CrateIndex, fi: FileInfo) -> list[Finding]:
    toks = fi.toks
    out: list[Finding] = []
    n = len(toks)
    in_attr = _attr_token_mask(toks)
    for i, t in enumerate(toks):
        if t.kind != "open" or in_attr[i] or fi.in_decl(t.off):
            continue
        if t.val == "(":
            f = _check_call(ix, fi, i)
            if f:
                out.append(f)
        elif t.val == "{":
            f = _check_struct_literal(ix, fi, i)
            if f:
                out.append(f)
    return out


def _path_before(toks: list[Tok], i: int) -> tuple[list[str], int, bool]:
    """Collect the `::`-path ending just before index i (exclusive).
    Returns (segments, index_before_path, is_macro).  Empty segments
    means: not a call position."""
    j = i - 1
    is_macro = False
    if j >= 0 and toks[j].val == "!" and toks[j].kind == "punct":
        is_macro = True
        j -= 1
    if j < 0 or toks[j].kind != "ident":
        return [], j, is_macro
    segs = [toks[j].val]
    j -= 1
    while j >= 1:
        if toks[j].val == "::" and toks[j].kind == "punct":
            k = j - 1
            # turbofish `::<…>::` — the `<…>` sits *after* a `::`; here we
            # walk backwards so a `>` just before `::` means a generics
            # group we must skip
            if toks[k].val == ">" and toks[k].kind == "punct":
                depth = 1
                k -= 1
                while k >= 0 and depth:
                    if toks[k].val == ">":
                        depth += 1
                    elif toks[k].val == "<":
                        depth -= 1
                    elif toks[k].val == ">>":
                        depth += 2
                    elif toks[k].val == "<<":
                        depth -= 2
                    k -= 1
                # expect another `::` before the turbofish
                if k >= 0 and toks[k].val == "::":
                    k -= 1
                else:
                    break
            if k >= 0 and toks[k].kind == "ident":
                segs.append(toks[k].val)
                j = k - 1
                continue
            if k >= 0 and toks[k].kind == "close" and toks[k].val == ">":
                break
        break
    segs.reverse()
    return segs, j, is_macro


def _count_args(toks: list[Tok], open_i: int, close_i: int) -> tuple[int, bool]:
    """Count top-level commas between ( ) — with closure-literal and
    turbofish awareness.  Second return: True when the arg list contains
    a `..` rest pattern (arity check must be skipped)."""
    if close_i == open_i + 1:
        return 0, False
    args = 1
    has_rest = False
    trailing_comma = False
    j = open_i + 1
    while j < close_i:
        t = toks[j]
        if t.kind == "open":
            j = match_delim(toks, j) + 1
            trailing_comma = False
            continue
        if t.val == "|" and t.kind == "punct":
            prev = toks[j - 1]
            if prev.val in ("(", ",", "=", "move", "=>", "&", "&&") or (
                prev.kind == "ident" and prev.val == "move"
            ):
                # closure literal: skip its parameter list
                k = j + 1
                while k < close_i and not (
                    toks[k].val == "|" and toks[k].kind == "punct"
                ):
                    if toks[k].kind == "open":
                        k = match_delim(toks, k)
                    k += 1
                j = k + 1
                trailing_comma = False
                continue
        if t.val == "<" and t.kind == "punct" and j > open_i + 1 \
                and toks[j - 1].val == "::":
            k = match_angle(toks, j)
            if k > j:
                j = k + 1
                trailing_comma = False
                continue
        if t.val == ".." or t.val == "..=":
            has_rest = True
        if t.val == "," and t.kind == "punct":
            args += 1
            trailing_comma = True
        else:
            trailing_comma = False
        j += 1
    if trailing_comma:
        args -= 1
    return max(args, 0), has_rest


def _is_trusted_path(ix: CrateIndex, fi: FileInfo, segs: list[str]) -> bool:
    """True when the path's root resolves into std/core/alloc (directly
    or through this file's imports)."""
    root = segs[0]
    if root in STD_ROOTS:
        return True
    imp = fi.imports.get(root)
    if imp and imp[0] in STD_ROOTS:
        return True
    return False


def _crate_arity_ok(arities: set[int], n: int, ufcs_arities: set[int]) -> bool:
    return n in arities or n in ufcs_arities


def _check_call(ix: CrateIndex, fi: FileInfo, open_i: int) -> Finding | None:
    toks = fi.toks
    segs, before_i, is_macro = _path_before(toks, open_i)
    if not segs:
        return None
    name = segs[-1]
    prev = toks[before_i] if before_i >= 0 else None
    # fn definitions, not calls:
    if prev is not None and prev.kind == "ident" and prev.val == "fn":
        return None
    close_i = match_delim(toks, open_i)
    nargs, has_rest = _count_args(toks, open_i, close_i)
    line = fi.sf.line_of(toks[open_i].off)
    snippet = fi.sf.line_text(line).strip()

    is_method = prev is not None and prev.val == "." and len(segs) == 1

    if is_macro:
        if name in _NOT_CALLS:
            return None  # `if !(cond)` — unary negation, not a macro
        if name in ix.macros or name in STD_MACROS:
            return None
        return Finding(PASS_ID, fi.sf.path, line, name,
                       f"unresolved macro `{name}!` — not defined in the "
                       f"crate and not a known std macro", snippet)

    if name in _NOT_CALLS or (len(segs) == 1 and name in ("self", "Self")):
        return None

    if is_method:
        return _check_method(ix, fi, name, nargs, has_rest, line, snippet)

    if len(segs) > 1 and _is_trusted_path(ix, fi, segs):
        return None
    # keyword-rooted paths are crate paths; strip the root markers
    core = [s for s in segs if s not in ("crate", "self", "super")]
    if not core:
        return None
    name = core[-1]

    # a single-segment lowercase name shadowed by a local binding is a
    # closure / fn-pointer call — not resolvable by name, skip
    if len(segs) == 1:
        locals_ = ix.fn_locals(fi.sf.path, toks[open_i].off)
        if locals_ and name in locals_:
            return None

    # qualifier disambiguation: `Qual::name(…)` — if Qual is a crate type
    # only its own assoc fns count; if Qual is a std container/primitive,
    # trust the std knowledge base (name collisions with crate impls like
    # `MergeScratch::with_capacity` must not shadow `Vec::with_capacity`)
    qual = core[-2] if len(core) >= 2 else None
    qual_is_type = qual is not None and (
        qual in ix.structs or qual in ix.enums or qual in ix.traits
    )
    if qual is not None and not qual_is_type and qual in STD_TYPES:
        return None

    candidates: set[int] = set()
    ufcs: set[int] = set()
    known = False
    for fd in ix.fns.get(name, []):
        if qual_is_type and fd.owner != qual:
            continue
        known = True
        if fd.has_self:
            ufcs.add(fd.arity + 1)
        else:
            candidates.add(fd.arity)
    for sd in ix.structs.get(name, []):
        if sd.kind == "tuple":
            if qual_is_type and sd.name != qual:
                continue
            known = True
            candidates.add(sd.arity)
    for vd in ix.variants.get(name, []):
        if vd.kind == "tuple":
            if qual_is_type and vd.enum != qual:
                continue
            known = True
            candidates.add(vd.arity)
    if known:
        if has_rest or _crate_arity_ok(candidates, nargs, ufcs):
            return None
        shapes = sorted(candidates | ufcs)
        return Finding(
            PASS_ID, fi.sf.path, line, name,
            f"arity mismatch: `{name}` called with {nargs} argument(s) but "
            f"defined with {shapes}", snippet)

    # not in the crate: prelude/std fallbacks
    if name in PRELUDE_CALLABLES:
        want = PRELUDE_CALLABLES[name]
        if want is None or want == nargs or has_rest:
            return None
        return Finding(PASS_ID, fi.sf.path, line, name,
                       f"`{name}` takes {want} argument(s), called with "
                       f"{nargs}", snippet)
    if name in STD_PATH_FNS or name in STD_METHODS or is_intrinsic(name):
        return None
    if len(segs) > 1 and (segs[-2] in ix.enums or segs[-2] in ix.structs
                          or segs[-2] in ix.traits):
        # Assoc item of a known type that we failed to index (blanket
        # impls, derive-generated) — resolve the *type*, tolerate the
        # member.  Derived ctors don't exist, so this stays narrow.
        return None
    if name[0].isupper() and len(segs) == 1:
        # tuple-struct/variant from std (e.g. `Duration`, `Reverse(…)`)
        # imported via use: trust if the import resolves to std
        imp = fi.imports.get(name)
        if imp and imp[0] in STD_ROOTS:
            return None
    return Finding(PASS_ID, fi.sf.path, line, name,
                   f"unresolved call `{'::'.join(segs)}({nargs} args)` — no "
                   f"definition in crate, vendor, or std knowledge base",
                   snippet)


def _check_method(
    ix: CrateIndex, fi: FileInfo, name: str, nargs: int, has_rest: bool,
    line: int, snippet: str,
) -> Finding | None:
    crate_arities: set[int] = set()
    for fd in ix.fns.get(name, []):
        if fd.has_self:
            crate_arities.add(fd.arity)
    if crate_arities:
        if nargs in crate_arities or has_rest:
            return None
        if name in STD_METHODS:
            # same name exists in std (e.g. `get`, `len`): the receiver
            # may be a std type — name-only pass
            return None
        return Finding(
            PASS_ID, fi.sf.path, line, name,
            f"method arity mismatch: `.{name}({nargs} args)` but crate "
            f"definitions take {sorted(crate_arities)} argument(s) and no "
            f"std method of that name exists", snippet)
    if name in STD_METHODS or is_intrinsic(name):
        return None
    return Finding(PASS_ID, fi.sf.path, line, name,
                   f"unresolved method `.{name}()` — no crate method and "
                   f"not a known std method", snippet)


# ---------------------------------------------------------------------------
# Struct literals / patterns


def _check_struct_literal(
    ix: CrateIndex, fi: FileInfo, open_i: int
) -> Finding | None:
    toks = fi.toks
    segs, before_i, is_macro = _path_before(toks, open_i)
    if not segs or is_macro:
        return None
    name = segs[-1]
    prev = toks[before_i] if before_i >= 0 else None
    if prev is not None and prev.kind == "ident" and prev.val in (
        "struct", "enum", "union", "trait", "impl", "mod", "fn", "for",
        "in", "use", "match", "while", "if", "loop", "else", "return",
        "unsafe", "move", "dyn", "where", "as",
    ):
        # `match X {`, `impl X {` … are blocks, not literals — but
        # `match` / `if` / `for` / `while` / `return` heads can *contain*
        # literals only inside parens, which Rust forbids bare; safe to
        # skip the ident directly preceded by these keywords.
        if prev.val in ("struct", "enum", "union", "trait", "impl", "mod",
                        "fn", "for", "dyn", "use", "where", "as", "in",
                        "match", "while", "if", "loop", "else", "return",
                        "move", "unsafe"):
            return None

    # resolve definition: struct with named fields, enum struct-variant,
    # or `Self` inside an impl
    fields_def: set[str] | None = None
    kinds: list[tuple[str, set[str]]] = []
    if name == "Self":
        return None  # owner tracking for Self literals: resolved at impls
    if len(segs) >= 2 and segs[-2] in ix.enums:
        for vd in ix.variants.get(name, []):
            if vd.enum == segs[-2] and vd.kind == "named":
                kinds.append((f"{vd.enum}::{vd.name}", set(vd.fields)))
        if not kinds:
            # tuple/unit variant followed by a block (match arm body …)
            return None
    else:
        for sd in ix.structs.get(name, []):
            if sd.kind == "named":
                kinds.append((sd.name, set(sd.fields)))
        for vd in ix.variants.get(name, []):
            if vd.kind == "named":
                kinds.append((f"{vd.enum}::{vd.name}", set(vd.fields)))
    if not kinds:
        return None
    close_i = match_delim(toks, open_i)
    lit = _literal_fields(toks, open_i, close_i)
    if lit is None:
        return None
    used, has_rest, has_exprs = lit
    if not used and not has_rest:
        return None
    line = fi.sf.line_of(toks[open_i].off)
    snippet = fi.sf.line_text(line).strip()
    best: tuple[int, str, set[str]] | None = None
    for label, fields in kinds:
        missing = fields - used if not has_rest else set()
        unknown = used - fields
        score = len(missing) + len(unknown)
        if score == 0:
            return None
        if best is None or score < best[0]:
            best = (score, label, fields)
    assert best is not None
    _score, label, fields = best
    unknown = sorted(used - fields)
    missing = sorted(fields - used) if not has_rest else []
    parts = []
    if unknown:
        parts.append(f"unknown field(s) {unknown}")
    if missing:
        parts.append(f"missing field(s) {missing} without `..`")
    return Finding(PASS_ID, fi.sf.path, line, name,
                   f"struct literal `{label}` field mismatch: "
                   + "; ".join(parts), snippet)


def _literal_fields(
    toks: list[Tok], open_i: int, close_i: int
) -> tuple[set[str], bool, bool] | None:
    """Parse `{ f1: e, f2, ..rest }`.  Returns (field_names, has_rest,
    has_exprs) or None when the braces clearly aren't a field list."""
    used: set[str] = set()
    has_rest = False
    j = open_i + 1
    expect_field = True
    while j < close_i:
        t = toks[j]
        if t.val in ("..", "..="):
            has_rest = True
            # `..Default::default()` — skip the tail expression
            j += 1
            while j < close_i and toks[j].val != ",":
                if toks[j].kind == "open":
                    j = match_delim(toks, j)
                j += 1
            continue
        if t.val == ",":
            expect_field = True
            j += 1
            continue
        if expect_field:
            if t.kind != "ident":
                return None
            if t.val in ("mut", "ref"):
                j += 1
                continue
            nxt = toks[j + 1] if j + 1 < close_i + 1 else None
            if nxt is not None and nxt.val == ":" and nxt.kind == "punct":
                used.add(t.val)
                expect_field = False
                # skip the value expression up to the next top-level comma
                j += 2
                while j < close_i and toks[j].val != ",":
                    if toks[j].kind == "open":
                        j = match_delim(toks, j)
                    j += 1
                continue
            elif nxt is not None and (
                nxt.val == "," or (nxt.kind == "close" and j + 1 == close_i)
            ):
                used.add(t.val)  # shorthand
                expect_field = False
                j += 1
                continue
            elif nxt is not None and nxt.val == "::":
                return None  # `Enum::Variant` expression in a block
            else:
                return None   # statements: this is a block, not a literal
        j += 1
    return used, has_rest, False
