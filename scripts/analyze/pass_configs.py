"""Pass (e) `configs` — strict-config convention.

Every JSON config-block parser must carry the unknown-key-rejection
pattern (`reject_unknown_keys`, config.rs): a parser that reads two or
more distinct literal keys from a `Json` value without rejecting
unknown keys silently ignores typos — the exact failure mode the
crate's config discipline exists to kill (a `"thresold"` that defaults
instead of erroring).

Heuristic: a fn body (non-test, src only) that contains >= 2 distinct
`.get("…")` / `.req("…")` literal-key reads and no
`reject_unknown_keys(` call (directly, or via a `*_from_json` helper it
delegates every read to) is flagged.  Report-*writers* (`Json::obj`
construction) don't match because they don't `.get`.
"""

from __future__ import annotations

import re

from findings import Finding
from index import CrateIndex

PASS_ID = "configs"

_KEY_READ_RE = re.compile(r"\.\s*(?:get|req)\s*\(\s*\"([^\"]*)\"\s*\)")
_REJECT_RE = re.compile(r"\breject_unknown_keys\s*\(")
_DELEGATE_RE = re.compile(r"\b([a-z_]+_from_json)\s*\(")
_MIN_KEYS = 2


def run(ix: CrateIndex) -> list[Finding]:
    # fns that themselves call reject_unknown_keys — delegation targets
    strict_fns: set[str] = set()
    for path, fi in ix.files.items():
        for start, end, fn_name, _gates in fi.fn_spans:
            if _REJECT_RE.search(fi.sf.text_nc[start:end]):
                strict_fns.add(fn_name)
    out: list[Finding] = []
    for path, fi in ix.files.items():
        if fi.kind != "src":
            continue
        for start, end, fn_name, gates in fi.fn_spans:
            all_gates = set(gates) | set(ix.gates_at(path, start)) \
                | set(fi.file_gates)
            if "test" in all_gates:
                continue
            body = fi.sf.text_nc[start:end]
            keys = set(_KEY_READ_RE.findall(body))
            if len(keys) < _MIN_KEYS:
                continue
            if _REJECT_RE.search(body):
                continue
            if fn_name in strict_fns:
                continue
            delegates = set(_DELEGATE_RE.findall(body))
            if delegates & strict_fns:
                # reads a couple of discriminator keys, then hands the
                # block to a strict parser — the strictness holds
                continue
            line = fi.sf.line_of(start)
            out.append(Finding(
                PASS_ID, path, line, fn_name,
                f"fn `{fn_name}` reads {len(keys)} literal JSON keys "
                f"({sorted(keys)[:6]}…) without `reject_unknown_keys` — "
                f"unknown/typo'd keys would be silently ignored",
                fi.sf.line_text(line).strip()))
    return out
