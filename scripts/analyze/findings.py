"""Finding model, allowlist (strict schema), and the JSON report.

The allowlist (`scripts/analyze_allow.json`) is the only way to ship a
finding: every entry names the pass, the file, a match pattern, and a
non-empty justification.  Entries that stop matching anything are
*errors* ("stale allow"), so the list can only shrink with the code —
it never accumulates dead exemptions.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

PASS_IDS = (
    "symbols",       # (a) call/method/struct-literal resolution + arity
    "wiring",        # (b) mod/file agreement, use resolution, feature gates
    "concurrency",   # (c) bare joins, unbounded channels, lock order
    "panics",        # (d) unwrap/expect/panic! on non-test src paths
    "configs",       # (e) strict unknown-key rejection in config parsers
    "unsafe",        # (f) unsafe confined to simd.rs + SAFETY comments
    "deprecation",   # (g) no non-test callers of #[deprecated] items
)


@dataclass
class Finding:
    pass_id: str
    file: str
    line: int
    symbol: str       # the symbol/pattern the finding is about
    message: str
    snippet: str = ""
    allowed_by: int | None = None   # index into allowlist entries

    def key(self) -> str:
        return f"{self.pass_id}:{self.file}:{self.line}:{self.symbol}"

    def to_json(self) -> dict:
        d = {
            "pass": self.pass_id,
            "file": self.file,
            "line": self.line,
            "symbol": self.symbol,
            "message": self.message,
            "snippet": self.snippet,
        }
        if self.allowed_by is not None:
            d["allowed_by"] = self.allowed_by
        return d


@dataclass
class AllowEntry:
    pass_id: str
    file: str
    pattern: str        # substring of the offending line, or exact symbol
    justification: str
    index: int
    hits: int = 0

    def matches(self, f: Finding) -> bool:
        if self.pass_id != f.pass_id or self.file != f.file:
            return False
        return self.pattern == f.symbol or self.pattern in f.snippet


class AllowlistError(Exception):
    pass


_ENTRY_KEYS = {"pass", "file", "pattern", "justification"}
_TOP_KEYS = {"version", "entries"}


def load_allowlist(path: str | None, known_files: set[str]) -> list[AllowEntry]:
    """Parse + validate the allowlist.  Schema violations raise
    AllowlistError — a malformed allowlist must fail the gate, not
    silently allow nothing (or everything)."""
    if path is None:
        return []
    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except FileNotFoundError:
        return []
    except json.JSONDecodeError as e:
        raise AllowlistError(f"{path}: not valid JSON: {e}") from e
    if not isinstance(doc, dict):
        raise AllowlistError(f"{path}: top level must be an object")
    extra = set(doc) - _TOP_KEYS
    if extra:
        raise AllowlistError(
            f"{path}: unknown top-level key(s) {sorted(extra)} — "
            f"accepted: {sorted(_TOP_KEYS)}"
        )
    if doc.get("version") != 1:
        raise AllowlistError(f"{path}: version must be 1")
    entries = doc.get("entries")
    if not isinstance(entries, list):
        raise AllowlistError(f"{path}: entries must be an array")
    out: list[AllowEntry] = []
    for i, e in enumerate(entries):
        where = f"{path}: entries[{i}]"
        if not isinstance(e, dict):
            raise AllowlistError(f"{where}: must be an object")
        extra = set(e) - _ENTRY_KEYS
        if extra:
            raise AllowlistError(
                f"{where}: unknown key(s) {sorted(extra)} — "
                f"accepted: {sorted(_ENTRY_KEYS)}"
            )
        missing = _ENTRY_KEYS - set(e)
        if missing:
            raise AllowlistError(f"{where}: missing key(s) {sorted(missing)}")
        if e["pass"] not in PASS_IDS:
            raise AllowlistError(
                f"{where}: unknown pass {e['pass']!r} — one of {PASS_IDS}"
            )
        for k in ("file", "pattern", "justification"):
            if not isinstance(e[k], str) or not e[k].strip():
                raise AllowlistError(f"{where}: {k} must be a non-empty string")
        if len(e["justification"].strip()) < 10:
            raise AllowlistError(
                f"{where}: justification too short — explain *why* this "
                f"finding is acceptable, not just that it is"
            )
        if known_files and e["file"] not in known_files:
            raise AllowlistError(
                f"{where}: file {e['file']!r} is not part of the analyzed set"
            )
        out.append(
            AllowEntry(
                pass_id=e["pass"], file=e["file"], pattern=e["pattern"],
                justification=e["justification"], index=i,
            )
        )
    return out


@dataclass
class Report:
    findings: list[Finding] = field(default_factory=list)
    allows: list[AllowEntry] = field(default_factory=list)
    files_scanned: int = 0
    errors: list[str] = field(default_factory=list)

    def apply_allowlist(self) -> None:
        for f in self.findings:
            for a in self.allows:
                if a.matches(f):
                    f.allowed_by = a.index
                    a.hits += 1
                    break

    @property
    def new_findings(self) -> list[Finding]:
        return [f for f in self.findings if f.allowed_by is None]

    @property
    def stale_allows(self) -> list[AllowEntry]:
        return [a for a in self.allows if a.hits == 0]

    @property
    def ok(self) -> bool:
        return not self.new_findings and not self.stale_allows and not self.errors

    def per_pass(self) -> dict[str, dict[str, int]]:
        out = {p: {"findings": 0, "allowlisted": 0, "new": 0} for p in PASS_IDS}
        for f in self.findings:
            row = out[f.pass_id]
            row["findings"] += 1
            if f.allowed_by is None:
                row["new"] += 1
            else:
                row["allowlisted"] += 1
        return out

    def to_json(self) -> dict:
        return {
            "version": 1,
            "ok": self.ok,
            "files_scanned": self.files_scanned,
            "passes": self.per_pass(),
            "findings": [f.to_json() for f in self.findings],
            "stale_allows": [
                {"index": a.index, "pass": a.pass_id, "file": a.file,
                 "pattern": a.pattern}
                for a in self.stale_allows
            ],
            "errors": self.errors,
        }

    def summary_table(self) -> str:
        rows = self.per_pass()
        w = max(len(p) for p in PASS_IDS)
        lines = [f"{'pass'.ljust(w)}  findings  allowlisted  new"]
        for p in PASS_IDS:
            r = rows[p]
            lines.append(
                f"{p.ljust(w)}  {r['findings']:8d}  {r['allowlisted']:11d}  "
                f"{r['new']:3d}"
            )
        tot = {"findings": 0, "allowlisted": 0, "new": 0}
        for r in rows.values():
            for k in tot:
                tot[k] += r[k]
        lines.append(
            f"{'TOTAL'.ljust(w)}  {tot['findings']:8d}  "
            f"{tot['allowlisted']:11d}  {tot['new']:3d}"
        )
        return "\n".join(lines)
