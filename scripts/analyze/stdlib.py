"""Curated std/core/alloc knowledge base for the symbol pass.

The analyzer runs without a toolchain, so it cannot ask rustc what the
standard library exports.  Instead it carries this curated set of the
std surface the crate actually touches: method names (checked by name
only — std methods are overloaded across dozens of types, so arity
checking there would need real type inference), macros, prelude
callables, and trusted path roots.

Curation rule (DESIGN.md §14): adding a name here is a reviewed change,
just like adding an allowlist entry — a typo'd method call that happens
to collide with a real std name is the residual risk, and keeping this
list tight (instead of "any ident is fine") is what keeps the pass
meaningful.  Names are grouped by where they come from so a reviewer
can spot-check against the std docs.
"""

# Path roots that are always trusted (resolution stops at the root).
STD_ROOTS = {"std", "core", "alloc", "proc_macro"}

# Macros from std/core (called as `name!`).
STD_MACROS = {
    "println", "print", "eprintln", "eprint", "write", "writeln", "format",
    "format_args", "vec", "assert", "assert_eq", "assert_ne", "debug_assert",
    "debug_assert_eq", "debug_assert_ne", "panic", "unreachable", "todo",
    "unimplemented", "matches", "include_str", "include_bytes", "concat",
    "stringify", "env", "option_env", "file", "line", "column", "cfg",
    "compile_error", "dbg", "thread_local",
}

# Architecture feature-probe macros (std::arch).
STD_MACROS |= {"is_x86_feature_detected", "is_aarch64_feature_detected"}

# Prelude / ubiquitous callables: enum variant constructors and free or
# associated fns callable without an explicit std path.
PRELUDE_CALLABLES = {
    "Some": 1, "Ok": 1, "Err": 1,
    "Box": None, "Vec": None, "String": None, "Default": None, "drop": 1,
}

# std container / primitive type names usable as path qualifiers
# (`Vec::with_capacity`, `u32::from_str_radix`).  When the qualifier is
# one of these — and the crate does not define a type of the same name —
# the assoc-fn call is trusted without arity checking (overload sets
# across std types need inference).  Crate types always win the name.
STD_TYPES = {
    # containers & smart pointers
    "Vec", "VecDeque", "String", "Box", "Rc", "Arc", "Cow", "Cell",
    "RefCell", "HashMap", "HashSet", "BTreeMap", "BTreeSet", "BinaryHeap",
    "Option", "Result",
    # sync / time / thread
    "Mutex", "RwLock", "Condvar", "Once", "OnceLock", "Barrier",
    "AtomicBool", "AtomicUsize", "AtomicU32", "AtomicU64", "AtomicI64",
    "Instant", "Duration", "SystemTime", "Thread", "JoinHandle",
    # io / fs / net
    "File", "OpenOptions", "Path", "PathBuf", "OsStr", "OsString",
    "Cursor", "BufReader", "BufWriter", "TcpStream", "TcpListener",
    "UdpSocket", "SocketAddr", "SocketAddrV4", "Ipv4Addr", "IpAddr",
    "Command", "Stdio",
    # channel error enums (variants used in match arms as path calls)
    "TrySendError", "SendError", "TryRecvError", "RecvTimeoutError",
    "RecvError",
    # cmp / num / marker
    "Ordering", "Reverse", "Wrapping", "PhantomData", "NonZeroUsize",
    "NonZeroU32", "NonZeroU64", "RangeInclusive", "Range",
    # conversion / iteration traits used as qualifiers
    "Default", "Clone", "From", "Into", "TryFrom", "TryInto", "Iterator",
    "IntoIterator", "FromIterator", "ToString", "ToOwned", "AsRef", "Ord",
    "PartialOrd", "Hash", "Error", "Display", "Debug", "Write", "Read",
    "Seek", "BufRead", "Drop", "Send", "Sync",
    # primitives (assoc fns/consts: `u32::from_str_radix`, `f64::MAX`)
    "u8", "u16", "u32", "u64", "u128", "usize",
    "i8", "i16", "i32", "i64", "i128", "isize",
    "f32", "f64", "bool", "char", "str",
}

# Free fns & assoc fns reached via imported std modules/types
# (`use std::sync::mpsc;` then `mpsc::channel()`), checked by name only.
STD_PATH_FNS = {
    # mem / ptr / iter / cmp / fmt ...
    "swap", "replace", "take", "transmute", "size_of", "size_of_val",
    "min", "max", "min_by", "max_by", "abs", "sqrt",
    "from", "try_from", "into", "try_into", "default", "new", "with_capacity",
    "catch_unwind", "panic_any", "available_parallelism", "current",
    "spawn", "sleep", "yield_now", "channel", "sync_channel",
    "once", "repeat", "empty", "successors", "from_fn", "var", "var_os",
    "args", "temp_dir", "create", "open", "read_to_string", "write",
    "read", "remove_file", "create_dir_all", "metadata", "canonicalize",
    "now", "elapsed", "duration_since", "from_secs", "from_secs_f64",
    "from_millis", "from_micros", "from_nanos", "exit", "id", "hostname",
    "copy_nonoverlapping", "null", "null_mut", "identity", "zeroed",
    "from_str_radix", "resume_unwind", "read_dir",
}

# Method names on std types (name-only check).  Grouped by provenance.
STD_METHODS = set()

# Option / Result
STD_METHODS |= {
    "unwrap", "unwrap_or", "unwrap_or_else", "unwrap_or_default", "expect",
    "expect_err", "unwrap_err", "ok", "err", "is_some", "is_none", "is_ok",
    "is_err", "map", "map_err", "map_or", "map_or_else", "and_then", "or_else",
    "ok_or", "ok_or_else", "filter", "take", "replace", "get_or_insert_with",
    "as_ref", "as_mut", "as_deref", "as_deref_mut", "cloned", "copied",
    "transpose", "flatten", "zip", "and", "or", "is_some_and", "is_none_or",
    "is_ok_and", "inspect", "inspect_err",
}

# Iterator / IntoIterator
STD_METHODS |= {
    "iter", "iter_mut", "into_iter", "next", "next_back", "peekable", "peek",
    "count", "last", "nth", "step_by", "chain", "rev", "enumerate", "skip",
    "skip_while", "take_while", "scan", "flat_map", "fuse", "by_ref",
    "collect", "partition", "fold", "try_fold", "reduce", "all", "any",
    "find", "find_map", "position", "rposition", "max_by_key", "min_by_key",
    "sum", "product", "cycle", "unzip", "windows", "chunks", "chunks_exact",
    "chunks_mut", "chunks_exact_mut", "rchunks", "split_first", "split_last",
    "array_chunks", "map_while", "dedup", "dedup_by_key", "filter_map",
    "for_each", "partition_point", "copy_within", "extend_from_within",
    "front", "back", "front_mut", "back_mut",
}

# slice / Vec / VecDeque / arrays
STD_METHODS |= {
    "len", "is_empty", "push", "pop", "insert", "remove", "clear", "truncate",
    "resize", "resize_with", "extend", "extend_from_slice", "append", "drain",
    "retain", "split_off", "split_at", "split_at_mut", "swap_remove",
    "first", "first_mut", "last_mut", "get", "get_mut", "contains",
    "starts_with", "ends_with", "fill", "fill_with", "sort", "sort_by",
    "sort_by_key", "sort_unstable", "sort_unstable_by", "sort_unstable_by_key",
    "select_nth_unstable_by", "select_nth_unstable", "binary_search",
    "binary_search_by", "reverse", "concat", "join", "to_vec", "swap",
    "rotate_left", "rotate_right", "copy_from_slice", "clone_from_slice",
    "push_back", "push_front", "pop_back", "pop_front", "make_contiguous",
    "capacity", "reserve", "shrink_to_fit", "as_slice", "as_mut_slice",
    "as_ptr", "as_mut_ptr", "to_owned", "leak", "splice",
}

# HashMap / BTreeMap / sets
STD_METHODS |= {
    "keys", "values", "values_mut", "entry", "or_insert", "or_insert_with",
    "or_default", "contains_key", "get_key_value", "remove_entry", "range",
    "pop_first", "pop_last", "first_key_value", "last_key_value",
    "and_modify", "difference", "intersection", "union", "symmetric_difference",
    "into_mut", "get_or_insert", "key",
}

# String / str / char / fmt
STD_METHODS |= {
    "to_string", "push_str", "chars", "char_indices", "bytes", "as_bytes",
    "as_str", "split", "splitn", "rsplit", "split_whitespace", "lines",
    "trim", "trim_start", "trim_end", "trim_start_matches", "trim_end_matches",
    "strip_prefix", "strip_suffix", "to_lowercase", "to_uppercase",
    "to_ascii_lowercase", "to_ascii_uppercase", "eq_ignore_ascii_case",
    "parse", "repeat", "replace", "replacen", "rfind",
    "is_ascii_digit", "is_ascii_alphanumeric", "is_alphabetic", "is_numeric",
    "is_whitespace", "to_digit", "fmt", "width", "precision", "pad",
    "write_str", "write_fmt", "write_char", "escape_debug", "escape_default",
}

# numeric / float / int / cmp / ops
STD_METHODS |= {
    "min", "max", "clamp", "abs", "signum", "powi", "powf", "sqrt", "exp",
    "ln", "log2", "log10", "sin", "cos", "tan", "sin_cos", "atan2", "hypot",
    "floor", "ceil", "round", "trunc", "fract", "recip", "to_bits",
    "from_bits", "is_nan", "is_finite", "is_infinite", "is_sign_negative",
    "is_sign_positive", "total_cmp", "partial_cmp", "cmp", "eq", "ne", "lt",
    "le", "gt", "ge", "max_by", "min_by", "checked_add", "checked_sub",
    "checked_mul", "checked_div", "saturating_add", "saturating_sub",
    "saturating_mul", "wrapping_add", "wrapping_sub", "wrapping_mul",
    "overflowing_add", "overflowing_sub", "rem_euclid", "div_euclid",
    "pow", "isqrt", "leading_zeros", "trailing_zeros", "count_ones",
    "rotate_left", "rotate_right", "to_le_bytes", "to_be_bytes",
    "from_le_bytes", "from_be_bytes", "to_ne_bytes", "then", "then_some",
    "then_with", "reverse", "is_eq", "is_lt", "is_gt", "is_le", "is_ge",
    "mul_add", "midpoint", "next_power_of_two", "ilog2", "cast", "exp2",
    "unsigned_abs", "is_power_of_two",
}

# Clone / Hash / conversion traits
STD_METHODS |= {
    "clone", "clone_from", "hash", "into", "try_into", "as_any", "borrow",
    "borrow_mut", "to_le", "to_be", "deref", "deref_mut",
}

# sync / thread / atomics / time
STD_METHODS |= {
    "lock", "try_lock", "read", "write", "try_read", "try_write", "wait",
    "wait_timeout", "wait_while", "wait_timeout_while", "notify_one",
    "notify_all", "load", "store", "fetch_add", "fetch_sub", "fetch_and",
    "fetch_or", "fetch_xor", "compare_exchange", "compare_exchange_weak",
    "fetch_update", "swap", "into_inner", "get_mut", "join", "is_finished",
    "thread", "name", "send", "recv", "try_send", "try_recv", "recv_timeout",
    "try_iter", "park", "unpark", "checked_duration_since", "as_secs",
    "as_secs_f64", "as_millis", "as_micros", "as_nanos", "saturating_duration_since",
    "checked_sub", "checked_add", "get_or_init", "get_or_try_init", "set",
    "as_secs_f32", "subsec_nanos", "abs_diff", "elapsed", "saturating_duration",
    "duration_since",
}

# io / net / fs / process
STD_METHODS |= {
    "read_exact", "read_to_end", "read_line", "write_all", "flush", "seek",
    "bytes", "lines", "accept", "incoming", "connect", "local_addr",
    "peer_addr", "set_nonblocking", "set_nodelay", "set_read_timeout",
    "set_write_timeout", "shutdown", "try_clone", "take_error", "kind",
    "raw_os_error", "path", "file_name", "file_stem", "extension", "exists",
    "is_file", "is_dir", "to_path_buf", "display", "components",
    "with_extension", "parent", "to_str", "to_string_lossy", "status",
    "success", "stdout", "stderr", "stdin", "wait_with_output", "arg",
    "current_dir", "spawn", "output", "metadata", "set_len", "sync_all",
    "read_dir",
}

# Any / Box / Rc / Arc / Cow
STD_METHODS |= {
    "downcast", "downcast_ref", "downcast_mut", "is", "type_id",
    "strong_count", "weak_count", "upgrade", "downgrade", "get_ref",
    "as_any_mut", "into_owned", "into_boxed_slice", "into_vec", "into_string",
    "make_mut", "ptr_eq",
}

# x86/aarch64 intrinsics are resolved via the `std::arch` trusted root,
# but the NEON path imports them unqualified via `use std::arch::aarch64::*`
# — the symbol pass treats `_mm*`/`v*q_*` prefixed idents specially
# instead of listing every intrinsic here.
INTRINSIC_PREFIXES = ("_mm", "_mm256", "_mm512", "v")


def is_intrinsic(name: str) -> bool:
    if name.startswith(("_mm", "_mm512")):
        return True
    # NEON intrinsics: vaddq_f64, vld1q_f32, vgetq_lane_f64, vcvt_f64_f32 …
    return bool(
        name.startswith("v")
        and ("_" in name)
        and name.split("_")[0][1:].rstrip("q").isalnum()
        and any(
            name.endswith(suf)
            for suf in ("_f32", "_f64", "_s8", "_s16", "_s32", "_s64",
                        "_u8", "_u16", "_u32", "_u64", "_p64")
        )
    )
