"""tomers-analyze: toolchain-free whole-crate static analysis.

`analyze_root(crate_dir)` loads every `.rs` file under the crate's
`src/`, `tests/`, `benches/` and `examples/` directories (plus
`vendor/` for definitions only), builds the `CrateIndex`, runs the
seven passes, applies the allowlist, and returns a `Report`.

See DESIGN.md §14 for the contract and scripts/analyze.py for the CLI.
"""

from __future__ import annotations

import os
import re
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)

from findings import (  # noqa: E402
    AllowlistError, Finding, PASS_IDS, Report, load_allowlist,
)
from index import CrateIndex, build_index  # noqa: E402
import pass_symbols  # noqa: E402
import pass_wiring  # noqa: E402
import pass_concurrency  # noqa: E402
import pass_panics  # noqa: E402
import pass_configs  # noqa: E402
import pass_unsafe  # noqa: E402
import pass_deprecation  # noqa: E402

__all__ = ["analyze_root", "Report", "Finding", "PASS_IDS", "AllowlistError"]

_KIND_DIRS = (
    ("src", "src"),
    ("tests", "test"),
    ("benches", "bench"),
    ("examples", "example"),
)


def _collect_files(crate_dir: str, rel_prefix: str) -> list[tuple[str, str, str]]:
    out: list[tuple[str, str, str]] = []
    for sub, kind in _KIND_DIRS:
        base = os.path.join(crate_dir, sub)
        if not os.path.isdir(base):
            continue
        for dirpath, _dirs, files in os.walk(base):
            for fn in sorted(files):
                if not fn.endswith(".rs"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, crate_dir)
                with open(full, encoding="utf-8") as fh:
                    raw = fh.read()
                out.append((os.path.join(rel_prefix, rel), kind, raw))
    vendor = os.path.join(crate_dir, "vendor")
    if os.path.isdir(vendor):
        for dirpath, _dirs, files in os.walk(vendor):
            for fn in sorted(files):
                if not fn.endswith(".rs"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, crate_dir)
                with open(full, encoding="utf-8") as fh:
                    raw = fh.read()
                out.append((os.path.join(rel_prefix, rel), "vendor", raw))
    return out


def _pjrt_examples(crate_dir: str) -> set[str]:
    """Example basenames whose Cargo.toml entry requires the pjrt
    feature — exempt from the default-build gate check."""
    manifest = os.path.join(crate_dir, "Cargo.toml")
    out: set[str] = set()
    if not os.path.exists(manifest):
        return out
    with open(manifest, encoding="utf-8") as fh:
        text = fh.read()
    for block in re.split(r"\[\[example\]\]", text)[1:]:
        name = re.search(r'name\s*=\s*"([^"]+)"', block)
        feats = re.search(r'required-features\s*=\s*\[([^\]]*)\]', block)
        if name and feats and "pjrt" in feats.group(1):
            out.add(name.group(1) + ".rs")
    return out


def analyze_root(
    crate_dir: str,
    allow_path: str | None = None,
    rel_prefix: str = "rust",
) -> Report:
    report = Report()
    file_set = _collect_files(crate_dir, rel_prefix)
    ix = build_index(file_set)
    report.files_scanned = sum(
        1 for _p, k, _r in file_set if k != "vendor"
    )
    try:
        known = {p for p, k, _ in file_set if k != "vendor"}
        report.allows = load_allowlist(allow_path, known)
    except AllowlistError as e:
        report.errors.append(str(e))
        return report
    pjrt_ex = _pjrt_examples(crate_dir)
    src_root = os.path.join(crate_dir, "src")
    report.findings.extend(pass_symbols.run(ix))
    report.findings.extend(pass_wiring.run(ix, src_root, pjrt_ex))
    report.findings.extend(pass_concurrency.run(ix))
    report.findings.extend(pass_panics.run(ix))
    report.findings.extend(pass_configs.run(ix))
    report.findings.extend(pass_unsafe.run(ix))
    report.findings.extend(pass_deprecation.run(ix))
    report.findings.sort(key=lambda f: (f.pass_id, f.file, f.line))
    report.apply_allowlist()
    return report
