"""Pass (b) `wiring` — module/file agreement, `use` resolution, and
feature-gate discipline.

* every `mod name;` declaration must have `name.rs` or `name/mod.rs`
  next to its declaring file, and every `.rs` file under `src/` must be
  reachable from some `mod` declaration (no orphan files silently
  excluded from the build);
* every `use crate::…` path must resolve: the module path must exist
  and the leaf name must be an item, re-export, or glob-covered name of
  that module;
* no default-build reference to `#[cfg(feature = "pjrt")]`-only items:
  a use/path whose target lives behind the pjrt gate is an error unless
  the referencing site is itself pjrt-gated (file, region, or — for
  examples — a Cargo.toml `required-features` entry).
"""

from __future__ import annotations

import os
import re

from findings import Finding
from index import CrateIndex

PASS_ID = "wiring"


def run(ix: CrateIndex, src_root: str, pjrt_examples: set[str]) -> list[Finding]:
    out: list[Finding] = []
    out.extend(_mod_file_agreement(ix, src_root))
    out.extend(_use_resolution(ix))
    out.extend(_pjrt_discipline(ix, pjrt_examples))
    return out


def _mod_file_agreement(ix: CrateIndex, src_root: str) -> list[Finding]:
    out: list[Finding] = []
    declared_files: set[str] = set()
    for name, decls in ix.mods.items():
        for d in decls:
            if d.inline:
                continue
            base = os.path.dirname(d.file)
            # mod decls in lib.rs/main.rs/mod.rs resolve next to the file;
            # in `foo.rs` they resolve under `foo/`
            stem = os.path.basename(d.file)
            if stem not in ("lib.rs", "main.rs", "mod.rs"):
                base = os.path.join(base, stem[:-3])
            cand = [
                os.path.join(base, f"{name}.rs"),
                os.path.join(base, name, "mod.rs"),
            ]
            hit = next((c for c in cand if c in ix.files), None)
            if hit is None:
                out.append(Finding(
                    PASS_ID, d.file, d.line, name,
                    f"`mod {name};` has no backing file ({cand[0]} or "
                    f"{cand[1]})"))
            else:
                declared_files.add(hit)
    # orphan check: every src file (other than crate roots) must be declared
    roots = {"lib.rs", "main.rs"}
    for path, fi in ix.files.items():
        if fi.kind != "src":
            continue
        base = os.path.basename(path)
        if base in roots:
            continue
        if path not in declared_files:
            out.append(Finding(
                PASS_ID, path, 1, base,
                f"orphan file: {path} is not declared by any `mod` — it is "
                f"silently excluded from the build"))
    return out


def _module_exists(ix: CrateIndex, mods: list[str]) -> bool:
    """Does the module path (e.g. ['merging', 'simd']) exist?"""
    if not mods:
        return True
    joined = "::".join(mods)
    if joined in ix.module_items:
        return True
    # a path may denote a type with assoc items rather than a module
    leaf = mods[-1]
    return (
        leaf in ix.enums or leaf in ix.structs or leaf in ix.traits
        or leaf in ix.mods
    )


def _name_in_module(ix: CrateIndex, module: str, name: str) -> bool:
    if name in ix.module_items.get(module, set()):
        return True
    if name in ix.module_reexports.get(module, set()):
        return True
    if module in ix.module_globs:
        # glob re-export: fall back to crate-global name existence
        return _name_anywhere(ix, name)
    return False


def _name_anywhere(ix: CrateIndex, name: str) -> bool:
    return (
        name in ix.fns or name in ix.structs or name in ix.enums
        or name in ix.traits or name in ix.consts or name in ix.types
        or name in ix.macros or name in ix.mods or name in ix.variants
    )


def _use_resolution(ix: CrateIndex) -> list[Finding]:
    out: list[Finding] = []
    crate_name = "tomers"
    for ud in ix.uses:
        fi = ix.files.get(ud.file)
        if fi is None or fi.kind == "vendor":
            continue
        segs = list(ud.path)
        if not segs:
            continue
        root = segs[0]
        if root in ("std", "core", "alloc", "proc_macro"):
            continue
        if root == crate_name:
            segs = ["crate"] + segs[1:]
            root = "crate"
        if root in ("self", "super"):
            # relative: resolve against the declaring module
            base = fi.module.split("::") if fi.module else []
            rest = segs[1:]
            if root == "super" and base:
                base = base[:-1]
            segs = ["crate"] + base + rest
            root = "crate"
        if root != "crate":
            # bare-root use (`use merging::…` in tests via the crate name,
            # or a vendored crate like `anyhow`)
            if root in ("anyhow", "xla"):
                continue
            if root in ix.mods or _name_anywhere(ix, root):
                segs = ["crate"] + segs
            else:
                out.append(Finding(
                    PASS_ID, ud.file, ud.line, root,
                    f"use path root `{root}` is neither a crate module, a "
                    f"vendored crate, nor std",
                    "::".join(ud.path)))
                continue
        body = segs[1:]
        if not body:
            continue
        leaf = body[-1]
        mods = body[:-1]
        if leaf == "*":
            if not _module_exists(ix, mods):
                out.append(Finding(
                    PASS_ID, ud.file, ud.line, "::".join(mods),
                    f"glob import of nonexistent module "
                    f"`{'::'.join(mods)}`", "::".join(ud.path)))
            continue
        if not _module_exists(ix, mods):
            out.append(Finding(
                PASS_ID, ud.file, ud.line, "::".join(mods) or leaf,
                f"use path `{'::'.join(ud.path)}` names a nonexistent "
                f"module `{'::'.join(mods)}`", "::".join(ud.path)))
            continue
        module = "::".join(mods)
        if module and not _name_in_module(ix, module, leaf):
            # items re-exported deeper or assoc items of types — accept if
            # the name exists anywhere (name-global bar, symbols-pass style)
            if not _name_anywhere(ix, leaf):
                out.append(Finding(
                    PASS_ID, ud.file, ud.line, leaf,
                    f"use path `{'::'.join(ud.path)}` — `{leaf}` is not an "
                    f"item of `{module}` (or anywhere in the crate)",
                    "::".join(ud.path)))
        elif not module and not _name_anywhere(ix, leaf) and leaf not in ix.mods:
            out.append(Finding(
                PASS_ID, ud.file, ud.line, leaf,
                f"use path `{'::'.join(ud.path)}` — `{leaf}` not found in "
                f"the crate root", "::".join(ud.path)))
    return out


def _pjrt_discipline(ix: CrateIndex, pjrt_examples: set[str]) -> list[Finding]:
    """References to pjrt-gated modules/items from default-build code."""
    out: list[Finding] = []
    if not ix.pjrt_modules and not ix.pjrt_items:
        return out
    pjrt_mod_leaves = {m.split("::")[-1] for m in ix.pjrt_modules}
    for ud in ix.uses:
        fi = ix.files.get(ud.file)
        if fi is None or fi.kind == "vendor":
            continue
        if fi.kind == "example" and os.path.basename(ud.file) in pjrt_examples:
            continue
        gates = ix.gates_at(ud.file, 0) | ud.gates | fi.file_gates
        if "pjrt" in gates:
            continue
        segs = [s for s in ud.path if s not in ("crate", "self", "super",
                                                "tomers")]
        # does the path traverse a pjrt-only module?
        for k in range(1, len(segs) + 1):
            prefix = "::".join(segs[:k])
            if prefix in ix.pjrt_modules:
                out.append(Finding(
                    PASS_ID, ud.file, ud.line, prefix,
                    f"default-build use of pjrt-gated module `{prefix}` "
                    f"(declared #[cfg(feature = \"pjrt\")]) from an ungated "
                    f"context", "::".join(ud.path)))
                break
        else:
            leaf = segs[-1] if segs else ""
            if leaf in ix.pjrt_items and leaf not in pjrt_mod_leaves \
                    and not _defined_ungated_somewhere(ix, leaf):
                out.append(Finding(
                    PASS_ID, ud.file, ud.line, leaf,
                    f"default-build use of pjrt-gated item `{leaf}` from an "
                    f"ungated context", "::".join(ud.path)))
    # expression-position references to pjrt-gated module roots
    mod_re = re.compile(
        r"\b(" + "|".join(re.escape(m.split("::")[-1])
                          for m in ix.pjrt_modules) + r")::"
    ) if ix.pjrt_modules else None
    if mod_re is None:
        return out
    for path, fi in ix.files.items():
        if fi.kind == "vendor":
            continue
        if fi.kind == "example" and os.path.basename(path) in pjrt_examples:
            continue
        if "pjrt" in fi.file_gates:
            continue
        for m in mod_re.finditer(fi.sf.code):
            leaf = m.group(1)
            full = next((pm for pm in ix.pjrt_modules
                         if pm.split("::")[-1] == leaf), leaf)
            gates = ix.gates_at(path, m.start())
            if "pjrt" in gates:
                continue
            # `use` lines were already checked above; skip duplicates by
            # requiring expression context (preceding char not part of a
            # use statement) — cheap check: line does not start with `use`
            line = fi.sf.line_of(m.start())
            text = fi.sf.line_text(line).lstrip()
            if text.startswith("use ") or text.startswith("pub use "):
                continue
            out.append(Finding(
                PASS_ID, path, line, full,
                f"default-build reference to pjrt-gated module `{full}` "
                f"outside any #[cfg(feature = \"pjrt\")] scope",
                text.strip()))
    return out


def _defined_ungated_somewhere(ix: CrateIndex, name: str) -> bool:
    """An item name may be defined twice (pjrt and not); only flag names
    that exist *exclusively* behind the gate."""
    for fd in ix.fns.get(name, []):
        if "pjrt" not in fd.gates:
            return True
    for sd in ix.structs.get(name, []):
        if "pjrt" not in sd.gates:
            return True
    return False
