"""Crate index: a lightweight, offset-preserving Rust item parser.

Walks the token stream of every scrubbed file and records the items the
passes need — functions (with arity and receiver-ness), structs (field
sets, tuple arities), enums (variant shapes), traits, impl blocks,
macros, consts/statics/type aliases, `mod` declarations, `use` imports
and `pub use` re-exports — together with the attribute gates active at
every item (`#[cfg(test)]`, `#[cfg(feature = "pjrt")]`,
`#[cfg(target_arch = …)]`, `#[deprecated]`, `#[allow(deprecated)]`).

This is NOT a Rust parser; it is the mechanized version of "grep the
call site against its definition".  It is deliberately name-global:
a symbol resolves if *some* definition with that name and a matching
shape exists in the crate (or the std knowledge base), which is exactly
the bar the manual interface review applied — and it never needs a
toolchain.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from lexer import ScrubbedFile, Tok, match_delim, match_angle, tokenize, KEYWORDS


@dataclass
class FnDef:
    name: str
    file: str
    line: int
    arity: int          # parameter count, excluding any self receiver
    has_self: bool
    module: str         # crate-relative module path ("merging::simd")
    owner: str | None   # impl/trait type name for associated fns
    gates: frozenset[str]
    deprecated: bool = False


@dataclass
class StructDef:
    name: str
    file: str
    line: int
    kind: str                 # "named" | "tuple" | "unit"
    fields: tuple[str, ...]   # named fields (kind == "named")
    arity: int                # tuple arity (kind == "tuple")
    module: str = ""
    gates: frozenset[str] = frozenset()
    deprecated: bool = False


@dataclass
class VariantDef:
    enum: str
    name: str
    kind: str                 # "named" | "tuple" | "unit"
    fields: tuple[str, ...]
    arity: int


@dataclass
class ModDecl:
    name: str
    file: str        # file containing the `mod name;` declaration
    line: int
    inline: bool     # `mod name { … }` vs `mod name;`
    gates: frozenset[str]


@dataclass
class UseDecl:
    file: str
    line: int
    path: tuple[str, ...]     # full path segments, alias resolved away
    alias: str                # name brought into scope
    is_pub: bool
    gates: frozenset[str]


@dataclass
class Region:
    """A gated byte range of a file (attribute scope), used to answer
    `gates_at(file, offset)` for expression-level scanning."""
    start: int
    end: int
    gates: frozenset[str]
    inner: bool = False   # came from a `#![…]` inner attribute


@dataclass
class FileInfo:
    sf: ScrubbedFile
    toks: list[Tok]
    module: str               # module path of the file root
    kind: str                 # "src" | "test" | "bench" | "example" | "vendor"
    file_gates: frozenset[str]
    regions: list[Region] = field(default_factory=list)
    imports: dict[str, tuple[str, ...]] = field(default_factory=dict)
    fn_spans: list[tuple[int, int, str, frozenset]] = field(default_factory=list)
    # (start_off, end_off, fn_name, gates) for every fn body
    decl_spans: list[tuple[int, int]] = field(default_factory=list)
    # byte spans of type *declaration* bodies (enum/struct blocks) —
    # variant/field declarations there must not be scanned as call sites

    def in_decl(self, off: int) -> bool:
        return any(s <= off < e for s, e in self.decl_spans)


class CrateIndex:
    def __init__(self) -> None:
        self.files: dict[str, FileInfo] = {}
        self.fns: dict[str, list[FnDef]] = {}
        self.structs: dict[str, list[StructDef]] = {}
        self.variants: dict[str, list[VariantDef]] = {}
        self.enums: set[str] = set()
        self.traits: set[str] = set()
        self.macros: set[str] = set()
        self.consts: set[str] = set()
        self.types: set[str] = set()          # type aliases
        self.mods: dict[str, list[ModDecl]] = {}
        self.uses: list[UseDecl] = []
        self.module_items: dict[str, set[str]] = {}   # module path -> names
        self.module_reexports: dict[str, set[str]] = {}
        self.module_globs: set[str] = set()           # modules with `pub use …::*`
        self.deprecated: set[str] = set()
        self.pjrt_modules: set[str] = set()           # module paths gated on pjrt
        self.pjrt_items: set[str] = set()             # item names gated on pjrt

    # -- queries -----------------------------------------------------------

    def gates_at(self, path: str, off: int) -> frozenset[str]:
        fi = self.files[path]
        gates = set(fi.file_gates)
        for r in fi.regions:
            if r.start <= off < r.end:
                gates |= r.gates
        return frozenset(gates)

    def fn_locals(self, path: str, off: int) -> set[str] | None:
        """Set of local binding names for the innermost fn containing
        `off` (computed lazily, cached on the span tuple's name key)."""
        fi = self.files[path]
        best = None
        for start, end, name, _gates in fi.fn_spans:
            if start <= off < end and (best is None or start > best[0]):
                best = (start, end, name)
        if best is None:
            return None
        key = (path, best[0], best[1])
        cached = _LOCALS_CACHE.get(key)
        if cached is None:
            cached = _collect_locals(fi.sf.code[best[0] : best[1]])
            _LOCALS_CACHE[key] = cached
        return cached


_LOCALS_CACHE: dict[tuple, set[str]] = {}

_LET_RE = re.compile(r"\blet\s+(?:mut\s+)?(?:ref\s+)?([A-Za-z_][A-Za-z0-9_]*)")
_TUPLE_LET_RE = re.compile(r"\blet\s*\(([^)]*)\)")
_CLOSURE_RE = re.compile(r"(?<=[\(\{,=])\s*(?:move\s*)?\|([^|\n]*)\|")
_PARAM_NAME_RE = re.compile(r"(?:^|[,(])\s*(?:mut\s+)?([A-Za-z_][A-Za-z0-9_]*)\s*[:,)|]")
_FOR_RE = re.compile(r"\bfor\s+(?:mut\s+)?\(?([A-Za-z_][A-Za-z0-9_, ]*?)\)?\s+in\b")
_IFLET_BIND_RE = re.compile(r"\b(?:Some|Ok|Err)\s*\(\s*(?:mut\s+)?([A-Za-z_][A-Za-z0-9_]*)\s*\)")


def _collect_locals(body: str) -> set[str]:
    out: set[str] = set()
    if body.startswith("("):
        # fn param list precedes the body block — bind its names too
        depth = 0
        for k, ch in enumerate(body):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        params = body[: k + 1]
        for m in _PARAM_NAME_RE.finditer(params):
            out.add(m.group(1))
    out.update(_LET_RE.findall(body))
    for grp in _TUPLE_LET_RE.findall(body):
        out.update(re.findall(r"[A-Za-z_][A-Za-z0-9_]*", grp))
    for grp in _CLOSURE_RE.findall(body):
        for m in _PARAM_NAME_RE.finditer(grp + ","):
            out.add(m.group(1))
        out.update(re.findall(r"^\s*([A-Za-z_][A-Za-z0-9_]*)\s*$", grp))
    for grp in _FOR_RE.findall(body):
        out.update(re.findall(r"[A-Za-z_][A-Za-z0-9_]*", grp))
    out.update(_IFLET_BIND_RE.findall(body))
    out.discard("mut")
    out.discard("ref")
    return out


# ---------------------------------------------------------------------------
# Attribute parsing


def _attr_gates(attr_text: str) -> frozenset[str]:
    """Map one `#[…]` attribute body to the gate set it implies."""
    gates: set[str] = set()
    if re.search(r"\bcfg\s*\(", attr_text) or attr_text.lstrip().startswith("cfg("):
        if re.search(r"\btest\b", attr_text):
            gates.add("test")
        if re.search(r"feature\s*=\s*\"pjrt\"", attr_text):
            gates.add("pjrt")
        if re.search(r"\btarget_arch\b", attr_text):
            gates.add("target_arch")
        if re.search(r"\bnot\s*\(\s*feature\s*=\s*\"pjrt\"", attr_text):
            gates.discard("pjrt")
            gates.add("not_pjrt")
    if re.match(r"\s*test\b", attr_text):
        gates.add("test")
    if re.match(r"\s*deprecated\b", attr_text):
        gates.add("deprecated")
    if re.search(r"\ballow\s*\(\s*deprecated", attr_text):
        gates.add("allow_deprecated")
    if re.search(r"\ballow\s*\(", attr_text):
        # scoped lint allows are recorded generically: "allow:<lint>"
        for name in re.findall(r"allow\s*\(([^)]*)\)", attr_text):
            for lint in re.findall(r"[A-Za-z_:]+", name):
                gates.add(f"allow:{lint.split('::')[-1]}")
    return frozenset(gates)


# ---------------------------------------------------------------------------
# The item walker


class _Walker:
    def __init__(self, index: CrateIndex, fi: FileInfo) -> None:
        self.ix = index
        self.fi = fi
        self.toks = fi.toks
        self.path = fi.sf.path

    def line(self, off: int) -> int:
        return self.fi.sf.line_of(off)

    def walk(self) -> None:
        self._items(0, len(self.toks), self.fi.module, self.fi.file_gates, None)

    # -- item-level scan over toks[i:end) ---------------------------------

    def _items(
        self,
        i: int,
        end: int,
        module: str,
        gates: frozenset[str],
        owner: str | None,
    ) -> None:
        toks = self.toks
        pending: set[str] = set()
        while i < end:
            t = toks[i]
            if t.kind == "punct" and t.val == "#":
                # attribute: #[…] or #![…]
                j = i + 1
                if j < end and toks[j].val == "!":
                    j += 1
                if j < end and toks[j].kind == "open" and toks[j].val == "[":
                    close = match_delim(toks, j)
                    attr_body = self.fi.sf.code[toks[j].off + 1 : toks[close].off]
                    g = _attr_gates(attr_body)
                    if toks[i + 1].val == "!":
                        # inner attribute: gates the whole remaining scope
                        if g:
                            self.fi.regions.append(
                                Region(t.off, self.toks[end - 1].off + 1, g,
                                       inner=True)
                            )
                            gates = frozenset(gates | g)
                    else:
                        pending |= g
                    i = close + 1
                    continue
            if t.kind == "ident":
                item_gates = frozenset(gates | pending)
                nxt = self._item(i, end, module, item_gates, owner, t)
                if nxt is not None:
                    pending = set()
                    i = nxt
                    continue
                if t.val not in ("pub", "unsafe", "extern", "default", "async"):
                    pending = set()
            if t.kind == "open":
                i = match_delim(toks, i) + 1
                continue
            i += 1

    def _item(
        self,
        i: int,
        end: int,
        module: str,
        gates: frozenset[str],
        owner: str | None,
        t: Tok,
    ) -> int | None:
        """Try to parse an item starting at the keyword toks[i]; return
        the index to continue from, or None if not an item keyword."""
        toks = self.toks
        kw = t.val
        if kw == "fn":
            return self._fn(i, module, gates, owner)
        if kw in ("struct", "union"):
            return self._struct(i, module, gates)
        if kw == "enum":
            return self._enum(i, module, gates)
        if kw == "trait":
            return self._trait(i, end, module, gates)
        if kw == "impl":
            return self._impl(i, end, module, gates)
        if kw == "mod":
            return self._mod(i, end, module, gates)
        if kw == "use":
            return self._use(i, module, gates)
        if kw in ("const", "static"):
            # `const NAME: …` (skip `const fn`, handled via fn kw later)
            if i + 1 < end and toks[i + 1].val == "fn":
                return None
            if i + 1 < end and toks[i + 1].kind == "ident":
                name = toks[i + 1].val
                self.ix.consts.add(name)
                self._record_module_item(module, name, gates)
            return self._skip_to_semi_or_block(i)
        if kw == "type":
            if i + 1 < end and toks[i + 1].kind == "ident":
                name = toks[i + 1].val
                self.ix.types.add(name)
                self._record_module_item(module, name, gates)
            return self._skip_to_semi_or_block(i)
        if kw == "macro_rules":
            if i + 2 < end and toks[i + 1].val == "!":
                name = toks[i + 2].val
                self.ix.macros.add(name)
                self._record_module_item(module, name, gates)
                j = i + 3
                while j < end and toks[j].kind != "open":
                    j += 1
                return match_delim(toks, j) + 1 if j < end else end
        return None

    # -- helpers -----------------------------------------------------------

    def _record_module_item(
        self, module: str, name: str, gates: frozenset[str]
    ) -> None:
        self.ix.module_items.setdefault(module, set()).add(name)
        if "pjrt" in gates:
            self.ix.pjrt_items.add(name)
        if "deprecated" in gates:
            self.ix.deprecated.add(name)

    def _skip_to_semi_or_block(self, i: int) -> int:
        toks = self.toks
        j = i
        while j < len(toks):
            if toks[j].val == ";":
                return j + 1
            if toks[j].kind == "open":
                if toks[j].val == "{":
                    return match_delim(toks, j) + 1
                j = match_delim(toks, j) + 1
                continue
            if toks[j].val == "=" and toks[j].kind == "punct":
                pass  # const X: T = expr;  keep scanning to `;`
            j += 1
        return j

    def _generics_end(self, j: int) -> int:
        """If toks[j] is `<`, return index after matching `>`."""
        if j < len(self.toks) and self.toks[j].val == "<":
            k = match_angle(self.toks, j)
            if k > j:
                return k + 1
        return j

    def _fn(
        self, i: int, module: str, gates: frozenset[str], owner: str | None
    ) -> int:
        toks = self.toks
        j = i + 1
        if j >= len(toks) or toks[j].kind != "ident":
            return i + 1
        name = toks[j].val
        j = self._generics_end(j + 1)
        if j >= len(toks) or not (toks[j].kind == "open" and toks[j].val == "("):
            return j
        close = match_delim(toks, j)
        arity, has_self = self._count_params(j, close)
        fd = FnDef(
            name=name,
            file=self.path,
            line=self.line(toks[i].off),
            arity=arity,
            has_self=has_self,
            module=module,
            owner=owner,
            gates=gates,
            deprecated="deprecated" in gates,
        )
        self.ix.fns.setdefault(name, []).append(fd)
        if not has_self:
            self._record_module_item(module, name, gates)
        elif "deprecated" in gates:
            self.ix.deprecated.add(name)
        # find the body (or `;` for trait-required methods)
        k = close + 1
        while k < len(toks) and not (
            toks[k].val == ";" or (toks[k].kind == "open" and toks[k].val == "{")
        ):
            if toks[k].kind == "open":
                k = match_delim(toks, k) + 1
                continue
            if toks[k].val == "<":
                nk = match_angle(toks, k)
                if nk > k:
                    k = nk + 1
                    continue
            k += 1
        if k < len(toks) and toks[k].kind == "open":
            body_close = match_delim(toks, k)
            # span starts at the param list so fn parameters land in the
            # locals set (callable params like `mut f: F` shadow fn names)
            self.fi.fn_spans.append(
                (toks[j].off, toks[body_close].off + 1, name, gates)
            )
            if gates:
                self.fi.regions.append(
                    Region(toks[i].off, toks[body_close].off + 1, gates)
                )
            # nested items (incl. #[cfg(test)] mod tests inside fns is
            # not a thing, but closures/fns can nest): walk the body for
            # nested fn/struct/use items only when one is present
            self._nested_items(k + 1, body_close, module, gates)
            return body_close + 1
        if gates and k < len(toks):
            self.fi.regions.append(Region(toks[i].off, toks[k].off + 1, gates))
        return k + 1

    def _nested_items(
        self, i: int, end: int, module: str, gates: frozenset[str]
    ) -> None:
        """Record fns/structs defined inside a fn body (rare but real)."""
        toks = self.toks
        j = i
        while j < end:
            t = toks[j]
            if t.kind == "ident" and t.val == "fn":
                j = self._fn(j, module, gates, None)
                continue
            if t.kind == "ident" and t.val in ("struct", "enum") and j + 1 < end \
                    and toks[j + 1].kind == "ident":
                j = (
                    self._struct(j, module, gates)
                    if t.val == "struct"
                    else self._enum(j, module, gates)
                )
                continue
            j += 1

    def _count_params(self, open_i: int, close_i: int) -> tuple[int, bool]:
        """Count top-level commas in a param list; detect a self receiver."""
        toks = self.toks
        depth_paren = 0
        depth_angle = 0
        parts = 1 if close_i > open_i + 1 else 0
        has_self = False
        first_part = True
        trailing_comma = False
        j = open_i + 1
        while j < close_i:
            t = toks[j]
            if t.kind == "open":
                j = match_delim(toks, j) + 1
                trailing_comma = False
                continue
            if t.val == "<" and t.kind == "punct":
                k = match_angle(toks, j)
                if k > j:
                    j = k + 1
                    trailing_comma = False
                    continue
            if t.val == "," and depth_paren == 0 and depth_angle == 0:
                parts += 1
                first_part = False
                trailing_comma = True
            else:
                trailing_comma = False
                if t.kind == "ident" and t.val == "self" and first_part:
                    has_self = True
            j += 1
        if trailing_comma:
            parts -= 1
        if has_self:
            parts -= 1
        return max(parts, 0), has_self

    def _struct(self, i: int, module: str, gates: frozenset[str]) -> int:
        toks = self.toks
        j = i + 1
        if j >= len(toks) or toks[j].kind != "ident":
            return i + 1
        name = toks[j].val
        line = self.line(toks[i].off)
        j = self._generics_end(j + 1)
        # skip a where clause
        while j < len(toks) and toks[j].val not in (";",) and toks[j].kind != "open":
            j += 1
        if j >= len(toks) or toks[j].val == ";":
            self._add_struct(StructDef(name, self.path, line, "unit", (), 0,
                                       module, gates))
            return j + 1
        close = match_delim(toks, j)
        self.fi.decl_spans.append((toks[j].off, toks[close].off + 1))
        if toks[j].val == "(":
            arity, _ = self._count_params(j, close)
            self._add_struct(StructDef(name, self.path, line, "tuple", (), arity,
                                       module, gates))
            # tuple struct decl ends with `;`
            k = close + 1
            while k < len(toks) and toks[k].val != ";":
                k += 1
            return k + 1
        fields = self._named_fields(j, close)
        self._add_struct(StructDef(name, self.path, line, "named", fields, 0,
                                   module, gates))
        return close + 1

    def _add_struct(self, sd: StructDef) -> None:
        self.ix.structs.setdefault(sd.name, []).append(sd)
        self._record_module_item(sd.module, sd.name, sd.gates)

    def _named_fields(self, open_i: int, close_i: int) -> tuple[str, ...]:
        """Field names: idents at top level followed by `:` (skipping
        attributes and `pub` modifiers)."""
        toks = self.toks
        fields: list[str] = []
        j = open_i + 1
        expect_name = True
        while j < close_i:
            t = toks[j]
            if t.kind == "punct" and t.val == "#":
                if j + 1 < close_i and toks[j + 1].kind == "open":
                    j = match_delim(toks, j + 1) + 1
                    continue
            if t.kind == "open":
                j = match_delim(toks, j) + 1
                continue
            if t.val == "<" and t.kind == "punct":
                k = match_angle(toks, j)
                if k > j:
                    j = k + 1
                    continue
            if t.val == ",":
                expect_name = True
            elif expect_name and t.kind == "ident" and t.val != "pub":
                if j + 1 < close_i and toks[j + 1].val == ":" \
                        and toks[j + 1].kind == "punct":
                    fields.append(t.val)
                    expect_name = False
                elif t.val in ("crate", "super", "in"):
                    pass  # pub(crate) visibility innards
                else:
                    expect_name = False
            j += 1
        return tuple(fields)

    def _enum(self, i: int, module: str, gates: frozenset[str]) -> int:
        toks = self.toks
        j = i + 1
        if j >= len(toks) or toks[j].kind != "ident":
            return i + 1
        name = toks[j].val
        self.ix.enums.add(name)
        self._record_module_item(module, name, gates)
        j = self._generics_end(j + 1)
        while j < len(toks) and not (toks[j].kind == "open" and toks[j].val == "{"):
            j += 1
        if j >= len(toks):
            return j
        close = match_delim(toks, j)
        self.fi.decl_spans.append((toks[j].off, toks[close].off + 1))
        k = j + 1
        expect_variant = True
        while k < close:
            t = toks[k]
            if t.kind == "punct" and t.val == "#" and k + 1 < close \
                    and toks[k + 1].kind == "open":
                k = match_delim(toks, k + 1) + 1
                continue
            if t.val == ",":
                expect_variant = True
                k += 1
                continue
            if expect_variant and t.kind == "ident":
                vname = t.val
                if k + 1 < close and toks[k + 1].kind == "open":
                    vclose = match_delim(toks, k + 1)
                    if toks[k + 1].val == "(":
                        arity, _ = self._count_params(k + 1, vclose)
                        vd = VariantDef(name, vname, "tuple", (), arity)
                    else:
                        flds = self._named_fields(k + 1, vclose)
                        vd = VariantDef(name, vname, "named", flds, 0)
                    k = vclose + 1
                else:
                    vd = VariantDef(name, vname, "unit", (), 0)
                    k += 1
                self.ix.variants.setdefault(vname, []).append(vd)
                expect_variant = False
                continue
            if t.kind == "open":
                k = match_delim(toks, k) + 1
                continue
            k += 1
        return close + 1

    def _trait(
        self, i: int, end: int, module: str, gates: frozenset[str]
    ) -> int:
        toks = self.toks
        j = i + 1
        if j >= len(toks) or toks[j].kind != "ident":
            return i + 1
        name = toks[j].val
        self.ix.traits.add(name)
        self._record_module_item(module, name, gates)
        while j < len(toks) and not (toks[j].kind == "open" and toks[j].val == "{"):
            if toks[j].val == ";":
                return j + 1
            j += 1
        if j >= len(toks):
            return j
        close = match_delim(toks, j)
        self._items(j + 1, close, module, gates, name)
        return close + 1

    def _impl(self, i: int, end: int, module: str, gates: frozenset[str]) -> int:
        toks = self.toks
        j = self._generics_end(i + 1)
        # collect the (possibly `Trait for Type`) head up to `{`
        segs: list[str] = []
        owner = None
        while j < len(toks):
            t = toks[j]
            if t.kind == "open" and t.val == "{":
                break
            if t.val == ";":
                return j + 1
            if t.kind == "ident" and t.val == "for":
                segs = []  # what follows `for` is the type
            elif t.kind == "ident" and t.val == "where":
                break
            elif t.kind == "ident" and t.val not in KEYWORDS:
                segs.append(t.val)
            elif t.val == "<":
                k = match_angle(toks, j)
                if k > j:
                    j = k + 1
                    continue
            j += 1
        while j < len(toks) and not (toks[j].kind == "open" and toks[j].val == "{"):
            j += 1
        if j >= len(toks):
            return j
        owner = segs[-1] if segs else None
        close = match_delim(toks, j)
        if gates:
            self.fi.regions.append(Region(toks[i].off, toks[close].off + 1, gates))
        self._items(j + 1, close, module, gates, owner)
        return close + 1

    def _mod(self, i: int, end: int, module: str, gates: frozenset[str]) -> int:
        toks = self.toks
        j = i + 1
        if j >= len(toks) or toks[j].kind != "ident":
            return i + 1
        name = toks[j].val
        line = self.line(toks[i].off)
        sub = f"{module}::{name}" if module else name
        if j + 1 < len(toks) and toks[j + 1].val == ";":
            self.ix.mods.setdefault(name, []).append(
                ModDecl(name, self.path, line, False, gates)
            )
            if "pjrt" in gates:
                self.ix.pjrt_modules.add(sub)
            self._record_module_item(module, name, gates)
            return j + 2
        if j + 1 < len(toks) and toks[j + 1].kind == "open":
            close = match_delim(toks, j + 1)
            self.ix.mods.setdefault(name, []).append(
                ModDecl(name, self.path, line, True, gates)
            )
            if "pjrt" in gates:
                self.ix.pjrt_modules.add(sub)
            if gates:
                self.fi.regions.append(
                    Region(toks[i].off, toks[close].off + 1, gates)
                )
            self._record_module_item(module, name, gates)
            self._items(j + 2, close, sub, gates, None)
            return close + 1
        return j + 1

    def _use(self, i: int, module: str, gates: frozenset[str]) -> int:
        toks = self.toks
        # find `;`, collecting the subtree textually (brace-aware)
        j = i + 1
        start_off = toks[j].off if j < len(toks) else toks[i].off
        depth = 0
        while j < len(toks):
            if toks[j].kind == "open":
                depth += 1
            elif toks[j].kind == "close":
                depth -= 1
            elif toks[j].val == ";" and depth == 0:
                break
            j += 1
        end_off = toks[j].off if j < len(toks) else len(self.fi.sf.code)
        text = self.fi.sf.code[start_off:end_off]
        is_pub = i > 0 and toks[i - 1].val in ("pub", ")")
        line = self.line(toks[i].off)
        for path, alias in _expand_use(text):
            ud = UseDecl(self.path, line, tuple(path), alias, is_pub, gates)
            self.ix.uses.append(ud)
            self.fi.imports[alias] = tuple(path)
            if is_pub:
                if alias == "*":
                    self.ix.module_globs.add(module)
                else:
                    self.ix.module_reexports.setdefault(module, set()).add(alias)
        return j + 1


def _expand_use(text: str) -> list[tuple[list[str], str]]:
    """Expand a use-tree body (`a::b::{c, d as e, f::*}`) into
    (path_segments, alias) pairs."""
    text = text.strip()
    out: list[tuple[list[str], str]] = []

    def rec(prefix: list[str], t: str) -> None:
        t = t.strip()
        if not t:
            return
        brace = t.find("{")
        if brace != -1 and t.endswith("}"):
            head = t[:brace].strip().rstrip(":")
            pre = prefix + [s for s in head.split("::") if s]
            body = t[brace + 1 : -1]
            for part in _split_top(body):
                rec(pre, part)
            return
        m = re.match(r"^(.*?)\s+as\s+([A-Za-z_][A-Za-z0-9_]*)$", t)
        alias = None
        if m:
            t, alias = m.group(1).strip(), m.group(2)
        segs = prefix + [s for s in t.split("::") if s]
        if not segs:
            return
        if segs[-1] == "self":
            segs = segs[:-1]  # `use a::b::{self, c}` — self IS the module
            if not segs:
                return
        out.append((segs, alias or segs[-1]))

    rec([], text)
    return out


def _split_top(body: str) -> list[str]:
    parts, depth, cur = [], 0, []
    for ch in body:
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


# ---------------------------------------------------------------------------
# Crate loading


def module_of(rel: str) -> str:
    """Map a src-relative path (`merging/simd.rs`) to its module path."""
    p = rel[:-3] if rel.endswith(".rs") else rel
    parts = p.split("/")
    if parts[-1] in ("mod", "lib", "main"):
        parts = parts[:-1]
    return "::".join(parts)


def build_index(file_set: list[tuple[str, str, str]]) -> CrateIndex:
    """file_set: (report_path, kind, raw_text) triples.

    kind: "src" | "test" | "bench" | "example" | "vendor".  Vendor files
    contribute definitions only; they are never scanned by passes.
    """
    from lexer import scrub

    ix = CrateIndex()
    for path, kind, raw in file_set:
        sf = scrub(path, raw)
        toks = tokenize(sf.code)
        rel = path
        for marker in ("src/", "tests/", "benches/", "examples/"):
            pos = rel.rfind(marker)
            if pos != -1:
                rel = rel[pos + len(marker):]
                break
        module = module_of(rel) if kind == "src" else ""
        file_gates: set[str] = set()
        if kind == "test":
            file_gates.add("test")
        fi = FileInfo(
            sf=sf, toks=toks, module=module, kind=kind,
            file_gates=frozenset(file_gates),
        )
        ix.files[path] = fi
        w = _Walker(ix, fi)
        w.walk()
        # inner `#![cfg(…)]` attributes recorded as whole-file regions —
        # promote them to file gates so path checks see them
        for r in fi.regions:
            if r.inner and toks and r.end >= toks[-1].off:
                fi.file_gates = frozenset(fi.file_gates | r.gates)
    return ix
