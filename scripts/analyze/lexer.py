"""Rust source scrubbing and tokenization (dependency-free).

The analyzer never sees a real Rust parser; it sees this: a scrubber
that blanks comments and string/char-literal *contents* while keeping
every byte offset identical to the original file, and a tokenizer over
the scrubbed text.  Offset preservation is the load-bearing property —
every downstream pass reports `file:line` positions computed directly
from scrubbed offsets, and the unsafe-audit pass looks back into the
*raw* text for `// SAFETY:` comments at the same offsets.

Two scrubbed renditions are produced per file:

* ``code``    — comments AND string contents blanked (symbol passes:
                an identifier inside a format string must not look like
                a call site);
* ``text_nc`` — comments blanked, strings kept (the strict-config pass
                counts *distinct* literal keys like ``.get("shards")``).

Rust specifics handled: nested ``/* */`` block comments, raw strings
``r"…"`` / ``r#"…"#`` (any hash depth), byte strings, char literals vs
lifetimes (``'a`` is a lifetime, ``'a'`` a char), escape sequences.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field


def _blank(text: str, start: int, end: int, keep: str = "") -> list[str]:
    """Replace text[start:end] with spaces, preserving newlines (so line
    numbers derived from offsets stay correct)."""
    out = []
    for ch in text[start:end]:
        out.append(ch if ch == "\n" or ch in keep else " ")
    return out


@dataclass
class ScrubbedFile:
    path: str           # path as reported in findings (repo-relative)
    raw: str            # original text
    code: str           # comments + string contents blanked
    text_nc: str        # comments blanked, strings kept
    line_starts: list[int] = field(default_factory=list)

    def line_of(self, offset: int) -> int:
        """1-based line number for a byte offset (binary search)."""
        lo, hi = 0, len(self.line_starts) - 1
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.line_starts[mid] <= offset:
                lo = mid
            else:
                hi = mid - 1
        return lo + 1

    def line_text(self, lineno: int) -> str:
        start = self.line_starts[lineno - 1]
        end = (
            self.line_starts[lineno]
            if lineno < len(self.line_starts)
            else len(self.raw)
        )
        return self.raw[start:end].rstrip("\n")


def scrub(path: str, raw: str) -> ScrubbedFile:
    n = len(raw)
    code = list(raw)
    nc = list(raw)
    i = 0
    while i < n:
        ch = raw[i]
        nxt = raw[i + 1] if i + 1 < n else ""
        if ch == "/" and nxt == "/":
            j = raw.find("\n", i)
            j = n if j == -1 else j
            code[i:j] = _blank(raw, i, j)
            nc[i:j] = _blank(raw, i, j)
            i = j
        elif ch == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if raw.startswith("/*", j):
                    depth += 1
                    j += 2
                elif raw.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            code[i:j] = _blank(raw, i, j)
            nc[i:j] = _blank(raw, i, j)
            i = j
        elif ch == '"' or (ch in "br" and _is_string_start(raw, i)):
            j, is_raw = _string_end(raw, i)
            # keep the delimiters in `code` so tokenization sees a
            # string token; blank only the contents
            body_start = raw.find('"', i) + 1
            body_end = j - 1 if not is_raw else raw.rfind('"', body_start, j)
            if body_end > body_start:
                code[body_start:body_end] = _blank(raw, body_start, body_end)
            i = j
        elif ch == "'":
            j = _char_or_lifetime_end(raw, i)
            if j > i + 1 and raw[j - 1] == "'":  # char literal
                if j - 1 > i + 1:
                    code[i + 1 : j - 1] = _blank(raw, i + 1, j - 1)
                    nc[i + 1 : j - 1] = _blank(raw, i + 1, j - 1)
            i = j
        else:
            i += 1
    line_starts = [0] + [m.end() for m in re.finditer("\n", raw)]
    return ScrubbedFile(
        path=path,
        raw=raw,
        code="".join(code),
        text_nc="".join(nc),
        line_starts=line_starts,
    )


def _is_string_start(raw: str, i: int) -> bool:
    """True at `b"`, `r"`, `br"`, `r#"`, `br#"` — only when not part of
    an identifier (e.g. the `r` in `for` or a var named `b`)."""
    if i > 0 and (raw[i - 1].isalnum() or raw[i - 1] == "_"):
        return False
    m = re.match(r'(?:b?r#*"|b")', raw[i : i + 8])
    return m is not None


def _string_end(raw: str, i: int) -> tuple[int, bool]:
    """Offset one past the closing quote; second item: is-raw-string."""
    n = len(raw)
    m = re.match(r'(b?r)(#*)"', raw[i : i + 8])
    if m:  # raw string: ends at `"` + same number of hashes, no escapes
        hashes = m.group(2)
        close = '"' + hashes
        j = raw.find(close, i + m.end())
        return (n if j == -1 else j + len(close)), True
    # ordinary (possibly byte) string with escapes
    j = raw.find('"', i) + 1
    while j < n:
        if raw[j] == "\\":
            j += 2
        elif raw[j] == '"':
            return j + 1, False
        else:
            j += 1
    return n, False


def _char_or_lifetime_end(raw: str, i: int) -> int:
    """Given raw[i] == "'", return end offset of the char literal, or
    i+1 if this is a lifetime/label (leaving the ident to the lexer)."""
    n = len(raw)
    # lifetime: 'ident NOT followed by closing quote
    m = re.match(r"'([A-Za-z_][A-Za-z0-9_]*)", raw[i : i + 64])
    if m and (i + m.end() >= n or raw[i + m.end()] != "'"):
        return i + 1
    # char literal: handle '\'' and '\\' and multi-byte escapes
    j = i + 1
    if j < n and raw[j] == "\\":
        j += 2
        while j < n and raw[j] != "'":
            j += 1
        return min(j + 1, n)
    while j < n and raw[j] != "'":
        j += 1
    return min(j + 1, n)


# ---------------------------------------------------------------------------
# Tokenizer


@dataclass
class Tok:
    kind: str   # ident | num | str | lifetime | punct | open | close
    val: str
    off: int

    def __repr__(self) -> str:  # debugging aid
        return f"{self.kind}:{self.val}@{self.off}"


_PUNCTS = [
    "::", "->", "=>", "..=", "..", "&&", "||", "<<=", ">>=", "==", "!=",
    "<=", ">=", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
]

_TOKEN_RE = re.compile(
    r"""
      (?P<ident>[A-Za-z_][A-Za-z0-9_]*)
    | (?P<num>\d[\dA-Za-z_.]*)
    | (?P<str>b?r?\#*"(?:[^"\\]|\\.)*"\#*)
    | (?P<lifetime>'[A-Za-z_][A-Za-z0-9_]*)
    | (?P<punct>%s)
    | (?P<open>[([{])
    | (?P<close>[)\]}])
    | (?P<single>[^\s])
    """
    % "|".join(re.escape(p) for p in _PUNCTS),
    re.VERBOSE,
)

KEYWORDS = {
    "as", "async", "await", "break", "const", "continue", "crate", "dyn",
    "else", "enum", "extern", "false", "fn", "for", "if", "impl", "in",
    "let", "loop", "match", "mod", "move", "mut", "pub", "ref", "return",
    "self", "Self", "static", "struct", "super", "trait", "true", "type",
    "union", "unsafe", "use", "where", "while",
}


def tokenize(code: str) -> list[Tok]:
    toks: list[Tok] = []
    for m in _TOKEN_RE.finditer(code):
        kind = m.lastgroup
        if kind == "single":
            kind = "punct"
        toks.append(Tok(kind=kind, val=m.group(), off=m.start()))
    return toks


def match_delim(toks: list[Tok], i: int) -> int:
    """toks[i] is an `open` token; return index of its matching close."""
    assert toks[i].kind == "open", toks[i]
    depth = 0
    for j in range(i, len(toks)):
        if toks[j].kind == "open":
            depth += 1
        elif toks[j].kind == "close":
            depth -= 1
            if depth == 0:
                return j
    return len(toks) - 1


def match_angle(toks: list[Tok], i: int) -> int:
    """toks[i] is `<` in a generics position; return index of matching
    `>` (treating `>>` as two closes).  Gives up (returns i) when the
    run looks like a comparison rather than generics."""
    depth = 0
    j = i
    limit = min(len(toks), i + 4096)
    while j < limit:
        t = toks[j]
        if t.val == "<" and t.kind == "punct":
            depth += 1
        elif t.val == "<<":
            depth += 2
        elif t.val == ">" and t.kind == "punct":
            depth -= 1
            if depth == 0:
                return j
        elif t.val == ">>":
            depth -= 2
            if depth <= 0:
                return j
        elif t.val in (";", "{") or t.kind == "open" and t.val == "{":
            return i  # statement boundary: not generics after all
        j += 1
    return i
