"""Pass (g) `deprecation` — no non-test callers of `#[deprecated]` items.

The pre-PR 3 one-shot merge wrappers (`merge_fixed_r`, `merge_dynamic`,
`match_tokens`, `merge_batch`) stay in the crate as bit-pinned
compatibility shims, and the differential suite calls them under a
scoped `#[allow(deprecated)]`.  Nothing else may: a new call site in
src/benches/examples reintroduces the untyped API the `MergeSpec`
redesign removed.  (This mirrors verify.sh's `clippy -D deprecated`
gate, which has never been able to run here.)
"""

from __future__ import annotations

import re

from findings import Finding
from index import CrateIndex

PASS_ID = "deprecation"


def run(ix: CrateIndex) -> list[Finding]:
    if not ix.deprecated:
        return []
    rx = re.compile(
        r"\b(" + "|".join(re.escape(n) for n in sorted(ix.deprecated))
        + r")\s*(?:::<[^>]*>)?\s*\("
    )
    out: list[Finding] = []
    def_sites = _definition_lines(ix)
    for path, fi in ix.files.items():
        if fi.kind == "vendor":
            continue
        for m in rx.finditer(fi.sf.code):
            name = m.group(1)
            line = fi.sf.line_of(m.start())
            if (path, line) in def_sites:
                continue
            gates = ix.gates_at(path, m.start()) | fi.file_gates
            if "test" in gates or "allow_deprecated" in gates \
                    or "allow:deprecated" in gates:
                continue
            # the deprecated wrappers delegate to each other inside the
            # deprecated region itself — a caller that is *itself*
            # deprecated is the shim's own body
            if "deprecated" in gates:
                continue
            # skip fn definitions of the deprecated item
            text = fi.sf.line_text(line)
            if re.search(rf"\bfn\s+{re.escape(name)}\b", text):
                continue
            out.append(Finding(
                PASS_ID, path, line, name,
                f"non-test call of #[deprecated] `{name}` — build a "
                f"MergeSpec / MergePlan instead (deprecation note)",
                text.strip()))
    return out


def _definition_lines(ix: CrateIndex) -> set[tuple[str, int]]:
    out: set[tuple[str, int]] = set()
    for name in ix.deprecated:
        for fd in ix.fns.get(name, []):
            out.add((fd.file, fd.line))
    return out
