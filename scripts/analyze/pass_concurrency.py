"""Pass (c) `concurrency` — thread/channel/lock discipline.

* bare `.join().unwrap()` / `.join().expect(…)` on thread handles is
  forbidden outside `util::join_annotated` (the crate-wide idiom that
  preserves panic payloads — DESIGN.md §10); non-test code only;
* unbounded `mpsc::channel(` is forbidden in non-test code — bounded
  `sync_channel` is the crate contract for every queue that can grow
  with traffic (DESIGN.md §10's bounded-memory guarantee).  One-shot
  rendezvous response channels are the known exception and must be
  *allowlisted with that justification*, not silently skipped;
* a function body that acquires locks on two or more distinct fields is
  flagged as a lock-order hazard: nested `Mutex` acquisition across
  fields is how deadlocks are born, and each such site must carry a
  justification (ordering argument) in the allowlist.
"""

from __future__ import annotations

import re

from findings import Finding
from index import CrateIndex

PASS_ID = "concurrency"

_JOIN_RE = re.compile(r"\.join\(\)\s*\.\s*(unwrap|expect)\s*\(")
_CHANNEL_RE = re.compile(r"\bmpsc::channel\s*\(|\bchannel::<[^>]*>\s*\(\)")
_LOCK_RE = re.compile(
    r"(?:lock_ignore_poison\s*\(\s*&(?P<a>[A-Za-z_][\w.]*)\s*\)"
    r"|(?P<b>[A-Za-z_][\w.]*)\s*\.\s*lock\s*\(\))"
)


def run(ix: CrateIndex) -> list[Finding]:
    out: list[Finding] = []
    for path, fi in ix.files.items():
        if fi.kind == "vendor":
            continue
        code = fi.sf.code
        in_util = path.endswith("util.rs")
        for m in _JOIN_RE.finditer(code):
            gates = ix.gates_at(path, m.start()) | fi.file_gates
            if "test" in gates:
                continue
            if in_util:
                continue  # join_annotated's own implementation site
            line = fi.sf.line_of(m.start())
            out.append(Finding(
                PASS_ID, path, line, "join().unwrap",
                "bare `.join().unwrap()/.expect()` discards the panic "
                "payload — route through `util::join_annotated`",
                fi.sf.line_text(line).strip()))
        for m in _CHANNEL_RE.finditer(code):
            gates = ix.gates_at(path, m.start()) | fi.file_gates
            if "test" in gates:
                continue
            line = fi.sf.line_of(m.start())
            out.append(Finding(
                PASS_ID, path, line, "mpsc::channel",
                "unbounded `mpsc::channel()` — the crate contract is a "
                "bounded `sync_channel` for anything that can grow with "
                "traffic (DESIGN.md §10); one-shot response channels must "
                "be allowlisted with that justification",
                fi.sf.line_text(line).strip()))
        out.extend(_lock_order(ix, path, fi))
    return out


def _lock_order(ix: CrateIndex, path: str, fi) -> list[Finding]:
    out: list[Finding] = []
    for start, end, fn_name, gates in fi.fn_spans:
        all_gates = set(gates) | set(ix.gates_at(path, start)) | set(fi.file_gates)
        if "test" in all_gates:
            continue
        body = fi.sf.code[start:end]
        receivers: dict[str, int] = {}
        for m in _LOCK_RE.finditer(body):
            recv = (m.group("a") or m.group("b") or "").strip()
            if not recv or recv in ("m",):  # util::lock_ignore_poison param
                continue
            # normalize: drop leading `self.` so `self.x` == `x` never
            # collides across different objects but stays stable per field
            receivers.setdefault(recv, start + m.start())
        if len(receivers) >= 2:
            first_off = min(receivers.values())
            line = fi.sf.line_of(first_off)
            fields = sorted(receivers)
            out.append(Finding(
                PASS_ID, path, line, f"lock-order:{fn_name}",
                f"fn `{fn_name}` acquires locks on {len(fields)} distinct "
                f"receivers {fields} — nested Mutex acquisition across "
                f"fields is a lock-order hazard; allowlist with the "
                f"ordering argument if intentional",
                fi.sf.line_text(line).strip()))
    return out
