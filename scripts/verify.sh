#!/usr/bin/env bash
# Tier-1 verification + merging-kernel perf smoke.
#
# Runs:
#   1. cargo build --release          (offline, default features)
#   2. cargo test  -q                 (unit + property + differential tests)
#   3. cargo bench --bench merging    (quick mode: acceptance case only)
#   4. asserts BENCH_merging.json reports speedup_batched >= MIN_SPEEDUP
#      on the t=8192 d=64 k=16 case (the acceptance criterion is the
#      batched warm-scratch path), so kernel perf regressions fail loudly.
#      The single-thread speedup is printed for trend-watching.
#
# Usage: scripts/verify.sh [--no-bench]
set -euo pipefail

cd "$(dirname "$0")/../rust"

MIN_SPEEDUP="${MIN_SPEEDUP:-3.0}"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: cargo not found on PATH — install a Rust toolchain (>= 1.70)." >&2
    echo "The build is fully offline: all dependencies are vendored under rust/vendor/." >&2
    exit 1
fi

echo "== tier-1: cargo build --release =="
cargo build --release --offline

echo "== tier-1: cargo test -q =="
cargo test -q --offline

if [[ "${1:-}" == "--no-bench" ]]; then
    echo "OK (bench smoke skipped)"
    exit 0
fi

echo "== perf smoke: merging bench (quick) =="
TOMERS_BENCH_QUICK=1 cargo bench --offline --bench merging

if [[ ! -f BENCH_merging.json ]]; then
    echo "ERROR: bench did not write BENCH_merging.json" >&2
    exit 1
fi

if command -v python3 >/dev/null 2>&1; then
    python3 - "$MIN_SPEEDUP" <<'EOF'
import json, sys
min_speedup = float(sys.argv[1])
report = json.load(open("BENCH_merging.json"))
cases = [c for c in report["cases"] if c["t"] == 8192 and c["d"] == 64 and c["k"] == 16]
if not cases:
    sys.exit("ERROR: acceptance case t=8192 d=64 k=16 missing from BENCH_merging.json")
batched = min(c["speedup_batched"] for c in cases)
single = min(c["speedup_optimized"] for c in cases)
print(f"acceptance case: speedup_batched={batched:.2f}x (gated) speedup_optimized={single:.2f}x (trend)")
if batched < min_speedup:
    sys.exit(f"ERROR: batched kernel speedup regressed below {min_speedup}x")
print("OK: merging kernel speedup gate passed")
EOF
else
    echo "WARN: python3 unavailable — skipping the numeric speedup gate" >&2
fi

echo "verify: all green"
