#!/usr/bin/env bash
# Tier-1 verification + lint gates + merging/serving perf smoke.
#
# Runs:
#   0. static analysis (toolchain-independent, FIRST — needs only python3):
#      scripts/analyze.py lints the whole crate source (symbols, wiring,
#      concurrency, panics, configs, unsafe, deprecation) against the
#      strict allowlist scripts/analyze_allow.json and writes
#      ANALYZE_report.json; scripts/test_analyze.py runs the
#      golden-fixture suite that pins each lint pass.
#      Skip: TOMERS_SKIP_ANALYZE=1 (mirrors TOMERS_SKIP_LINT).
#   0b. python crosschecks (toolchain-independent, before anything cargo):
#      scripts/crosscheck_kernel.py pins the SIMD kernel semantics,
#      scripts/crosscheck_net.py pins the net-layer goldens (splitmix64
#      mixer, consistent-hash routing table, frame header layout, ledger
#      merge identity), and scripts/crosscheck_obs.py pins the
#      observability substrate (log-linear histogram bucketing, the
#      percentile relative-error bound, lossless histogram merge) against
#      independent Python reimplementations
#   1. cargo fmt --check              (style gate; skip: TOMERS_SKIP_LINT=1)
#   2. cargo clippy -- -D warnings    (lint gate; skip: TOMERS_SKIP_LINT=1)
#   2b. cargo miri test (kernel + differential subsets) — UB gate over the
#      unsafe SIMD surface and the incremental-vs-batch differentials;
#      runs only when the miri component is installed, otherwise skips
#      with a loud WARN (it is a nightly component, not baked into every
#      toolchain).
#   2c. extended clippy (leftover-debris lints, hard -D: dbg_macro,
#      todo, unimplemented) — runs when cargo-clippy is present, same
#      toolchain detection as 2b.
#   3. cargo build --release          (offline, default features)
#   4. cargo check --features pjrt    (the stubbed PJRT surface must keep compiling)
#   5. cargo check --features pjrt --examples (the walkthrough examples under
#      rust/examples/ — the pjrt-gated ones included — must keep compiling)
#   6. cargo doc --no-deps            (rustdoc warnings are errors: the public
#                                      MergeSpec/MergePlan API stays documented)
#   7. cargo test  -q                 (unit + property + differential + pool tests)
#   8. cargo build --example stream_sessions (the offline streaming demo
#      must keep compiling in the default build)
#   9. streaming-serve smoke: `tomers stream` (univariate and d=3) must
#      drive the decode scheduler — gated on decode_steps >= 1 in the
#      metrics report (the same staged machinery `tomers serve` wires
#      when a "streaming" config block is present)
#  10. fault-injection smoke: `tomers serve-sim --fault-rate 0.2 --seed 7`
#      drives the dual serving loop through the seeded FaultPlan — gated
#      on every request reaching a terminal outcome (non_terminal=0) and
#      the delivery monitor's ledger balancing ("delivery accounting
#      consistent"), the liveness + accounting pins of DESIGN.md §10
#  10b. net smoke: `tomers serve-net --shards 2` + `tomers client` over
#      loopback TCP (DESIGN.md §12) — gated on wire-level liveness
#      (non_terminal=0), per-shard routing counts summing to the total,
#      the summed delivery ledger balancing, and the server draining with
#      the merged per-shard report
#  11. cargo bench --bench merging    (quick mode: acceptance cases only)
#      asserts BENCH_merging.json reports speedup_batched >= MIN_SPEEDUP on
#      the t=8192 d=64 k=16 case (pool-backed batched path), zero
#      post-warmup thread spawns, and pool p50 <= thread::scope p50 at b=32;
#      PR 7: also gates simd_vs_scalar >= MIN_SIMD_SPEEDUP (default 1.5)
#      on the t=4096 d=64 case when a SIMD ISA is dispatched, with a loud
#      WARN skip on scalar-only hosts.
#  12. cargo bench --bench coordinator (quick) -> BENCH_serving.json;
#      asserts staged (merge-while-execute) throughput beats the serial
#      loop on the balanced row.
#  13. cargo bench --bench streaming (quick) -> BENCH_streaming.json;
#      asserts the incremental causal append path is >= MIN_STREAM_RATIO x
#      faster than full recompute at t=4096, n=16.
#  14. cargo bench --bench obs (quick) -> BENCH_obs.json; asserts the span
#      recorder + stage histograms cost <= OBS_MAX_OVERHEAD % (default 2)
#      of loopback serving throughput (DESIGN.md §13 budget).
#
# Usage: scripts/verify.sh [--no-bench]
set -euo pipefail

SCRIPTS_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPTS_DIR/../rust"

MIN_SPEEDUP="${MIN_SPEEDUP:-3.0}"
MIN_STREAM_RATIO="${MIN_STREAM_RATIO:-5.0}"
MIN_SIMD_SPEEDUP="${MIN_SIMD_SPEEDUP:-1.5}"
OBS_MAX_OVERHEAD="${OBS_MAX_OVERHEAD:-2.0}"

# Always-on toolchain-independent gates, ordered cheapest-signal-first.
#
# Gate 0 — whole-crate static analysis. scripts/analyze.py re-derives the
# crate's interface graph (call arity, struct literals, mod/file wiring)
# and enforces the concurrency/config/unsafe/panic conventions of
# DESIGN.md §14 against the strict allowlist scripts/analyze_allow.json.
# It needs only the Python stdlib, so it runs — and can fail the build —
# even on hosts with no Rust toolchain at all.
if [[ "${TOMERS_SKIP_ANALYZE:-0}" != "1" ]]; then
    if command -v python3 >/dev/null 2>&1; then
        echo "== analyze: scripts/analyze.py (toolchain-free static analysis) =="
        if ! python3 "$SCRIPTS_DIR/analyze.py" --json; then
            echo "ERROR: static analysis found unallowlisted findings — fix them or" >&2
            echo "add a justified entry to scripts/analyze_allow.json" >&2
            echo "(or TOMERS_SKIP_ANALYZE=1 to bypass; report: ANALYZE_report.json)" >&2
            exit 1
        fi
        echo "== analyze self-test: scripts/test_analyze.py (golden fixtures) =="
        if ! python3 "$SCRIPTS_DIR/test_analyze.py" 2>&1 | tail -n 3; then
            echo "ERROR: analyzer fixture suite failed — a lint pass regressed" >&2
            exit 1
        fi
    else
        echo "WARN: python3 unavailable — skipping the static-analysis gate" >&2
    fi
else
    echo "(static-analysis gate skipped: TOMERS_SKIP_ANALYZE=1)"
fi

# Gate 0b — the Python transliteration crosschecks pin the SIMD kernel
# semantics and the net-layer goldens (splitmix64 mixer, consistent-hash
# routing table, frame header layout, ledger merge identity) against
# independent reimplementations — they run before anything cargo-dependent
# so a missing Rust toolchain cannot mask a semantic drift.
if command -v python3 >/dev/null 2>&1; then
    echo "== crosscheck: scripts/crosscheck_kernel.py =="
    python3 "$SCRIPTS_DIR/crosscheck_kernel.py"
    echo "== crosscheck: scripts/crosscheck_net.py =="
    python3 "$SCRIPTS_DIR/crosscheck_net.py"
    echo "== crosscheck: scripts/crosscheck_obs.py =="
    python3 "$SCRIPTS_DIR/crosscheck_obs.py"
else
    echo "WARN: python3 unavailable — skipping the kernel/net/obs crosscheck gates" >&2
fi

if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: cargo not found on PATH — install a Rust toolchain (>= 1.70)." >&2
    echo "The build is fully offline: all dependencies are vendored under rust/vendor/." >&2
    exit 1
fi

if [[ "${TOMERS_SKIP_LINT:-0}" != "1" ]]; then
    echo "== lint: cargo fmt --check =="
    if ! cargo fmt --check; then
        echo "ERROR: formatting drift — run 'cargo fmt' (or TOMERS_SKIP_LINT=1 to bypass)" >&2
        exit 1
    fi

    echo "== lint: cargo clippy -D warnings -D deprecated =="
    # -D deprecated explicitly: calls into the pre-PR 3 one-shot merge
    # wrappers must not creep back in (the differential suite opts in
    # with a scoped allow(deprecated); nothing else may).
    if ! cargo clippy --offline --all-targets -- -D warnings -D deprecated; then
        echo "ERROR: clippy findings — fix them (or TOMERS_SKIP_LINT=1 to bypass)" >&2
        exit 1
    fi
else
    echo "(lint gates skipped: TOMERS_SKIP_LINT=1)"
fi

# Gate 2b — miri UB gate over the two surfaces where it earns its keep:
# the unsafe SIMD kernels (merging_dispatch exercises every ISA arm that
# compiles on the host) and the scoped fork-join pool (runtime_pool's
# raw-pointer task handoff). Miri is a nightly rustup component, so the
# gate is toolchain-detected: present → hard gate, absent → loud WARN so
# the skip never reads as a pass.
if [[ "${TOMERS_SKIP_LINT:-0}" != "1" ]]; then
    if cargo miri --version >/dev/null 2>&1; then
        echo "== sanitize: cargo miri test (SIMD kernels + pool handoff) =="
        # -Zmiri-disable-isolation: the pool tests read the host clock
        if ! MIRIFLAGS="-Zmiri-disable-isolation" \
            cargo miri test --offline --test merging_dispatch --test runtime_pool; then
            echo "ERROR: miri found undefined behaviour in the unsafe surface" >&2
            exit 1
        fi
    else
        echo "=========================================================================="
        echo "WARN: cargo miri unavailable (nightly component not installed) —"
        echo "WARN: skipping the UB gate over merging/simd.rs and runtime/pool.rs."
        echo "WARN: install with: rustup +nightly component add miri"
        echo "=========================================================================="
    fi

    # Gate 2c — leftover-debris lints beyond -D warnings: these never
    # belong in committed code, so they are hard denies, but they ride
    # the same clippy binary detection as the base lint gate.
    if cargo clippy --version >/dev/null 2>&1; then
        echo "== lint: extended clippy (dbg_macro / todo / unimplemented) =="
        if ! cargo clippy --offline --all-targets -- \
            -D clippy::dbg_macro -D clippy::todo -D clippy::unimplemented; then
            echo "ERROR: leftover debug/placeholder macros in the tree" >&2
            exit 1
        fi
    else
        echo "WARN: cargo-clippy unavailable — skipping the extended lint tier" >&2
    fi
fi

echo "== tier-1: cargo build --release =="
cargo build --release --offline

echo "== feature gate: cargo check --features pjrt =="
cargo check --offline --features pjrt

echo "== example gate: cargo check --features pjrt --examples =="
cargo check --offline --features pjrt --examples

echo "== docs gate: cargo doc --no-deps (rustdoc warnings as errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --offline --no-deps --quiet

echo "== tier-1: cargo test -q =="
cargo test -q --offline

echo "== example gate: cargo build --example stream_sessions =="
cargo build --offline --release --example stream_sessions

echo "== stream smoke: tomers stream must drive the decode scheduler =="
STREAM_OUT=$(cargo run --offline --release --quiet -- stream \
    --sessions 8 --rounds 6 --points 8 --batch 4 --m 32 2>&1)
echo "$STREAM_OUT" | tail -n 3
if ! echo "$STREAM_OUT" | grep -Eq "streaming: decode_steps=[1-9]"; then
    echo "ERROR: tomers stream produced no decode steps — the wired streaming path is dead" >&2
    exit 1
fi
MULTI_OUT=$(cargo run --offline --release --quiet -- stream \
    --sessions 6 --rounds 5 --points 8 --batch 4 --m 32 --d 3 2>&1)
if ! echo "$MULTI_OUT" | grep -Eq "streaming: decode_steps=[1-9]"; then
    echo "ERROR: multivariate (--d 3) tomers stream produced no decode steps" >&2
    exit 1
fi
echo "OK: stream smoke (univariate + d=3) passed"

echo "== fault smoke: tomers serve-sim under 20% injected faults =="
FAULT_OUT=$(cargo run --offline --release --quiet -- serve-sim \
    --fault-rate 0.2 --seed 7 2>&1)
echo "$FAULT_OUT" | grep -E "batch:|delivery|injected" || true
if ! echo "$FAULT_OUT" | grep -q "non_terminal=0"; then
    echo "ERROR: serve-sim left requests without a terminal outcome under faults" >&2
    exit 1
fi
if ! echo "$FAULT_OUT" | grep -q "delivery accounting consistent"; then
    echo "ERROR: serve-sim delivery ledger did not balance under faults" >&2
    exit 1
fi
# observability threading (DESIGN.md §13): the report must show the prep
# stage's merge-efficiency telemetry and the per-stage latency histograms
if ! echo "$FAULT_OUT" | grep -q "compression="; then
    echo "ERROR: serve-sim report lacks merge-efficiency telemetry (compression=)" >&2
    exit 1
fi
if ! echo "$FAULT_OUT" | grep -q "stage: "; then
    echo "ERROR: serve-sim report lacks per-stage latency histograms (stage:)" >&2
    exit 1
fi
echo "OK: fault smoke passed (liveness + delivery accounting under injected faults)"

echo "== trace smoke: tomers trace-dump exports a parseable Chrome trace =="
TRACE_OUT_FILE=$(mktemp --suffix=.json)
TRACE_OUT=$(cargo run --offline --release --quiet -- trace-dump \
    --out "$TRACE_OUT_FILE" 2>&1)
echo "$TRACE_OUT" | tail -n 1
if ! echo "$TRACE_OUT" | grep -Eq "complete_chains=[1-9]"; then
    echo "ERROR: trace-dump recorded no complete prep->exec->respond span chain" >&2
    exit 1
fi
if command -v python3 >/dev/null 2>&1; then
    python3 - "$TRACE_OUT_FILE" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
events = doc["traceEvents"]
assert events, "trace must contain span events"
for e in events:
    assert e["ph"] == "X" and e["ts"] >= 0 and e["dur"] >= 0, e
names = {e["name"] for e in events}
assert "prep" in names and "exec" in names, f"stage spans missing: {sorted(names)}"
print(f"OK: Chrome trace parses ({len(events)} spans, stages={sorted(names)})")
EOF
fi
rm -f "$TRACE_OUT_FILE"
echo "OK: trace smoke passed (span chains + Chrome trace_event export)"

echo "== net smoke: serve-net + client loopback over real TCP =="
# ephemeral-ish port in the dynamic range, seeded by PID to dodge collisions
NET_PORT=$(( 20000 + $$ % 20000 ))
NET_LOG=$(mktemp)
cargo run --offline --release --quiet -- serve-net \
    --shards 2 --addr "127.0.0.1:${NET_PORT}" --fault-rate 0.2 --seed 7 \
    --exit-after 1 >"$NET_LOG" 2>&1 &
NET_PID=$!
NET_CLIENT_OUT=$(cargo run --offline --release --quiet -- client \
    --addr "127.0.0.1:${NET_PORT}" --shards 2 --metrics 2>&1) || {
    echo "$NET_CLIENT_OUT"
    echo "--- server log ---"; cat "$NET_LOG"
    kill "$NET_PID" 2>/dev/null || true
    echo "ERROR: tomers client failed against the sharded net front" >&2
    exit 1
}
echo "$NET_CLIENT_OUT" | grep -E "batch:|routing:|delivery" || true
if ! echo "$NET_CLIENT_OUT" | grep -q "non_terminal=0"; then
    echo "ERROR: net front left requests without a terminal outcome over the wire" >&2
    kill "$NET_PID" 2>/dev/null || true
    exit 1
fi
if ! echo "$NET_CLIENT_OUT" | grep -q "delivery accounting consistent"; then
    echo "ERROR: summed per-shard delivery ledger did not balance over the wire" >&2
    kill "$NET_PID" 2>/dev/null || true
    exit 1
fi
if ! echo "$NET_CLIENT_OUT" | grep -Eq "routing: shard0=[0-9]+ shard1=[0-9]+ total="; then
    echo "ERROR: per-shard routing counts missing from the client report" >&2
    kill "$NET_PID" 2>/dev/null || true
    exit 1
fi
# the wire metrics request must answer and render as Prometheus text
if ! echo "$NET_CLIENT_OUT" | grep -Eq "tomers_served_total [0-9]+"; then
    echo "ERROR: client --metrics did not print the Prometheus metrics exposition" >&2
    kill "$NET_PID" 2>/dev/null || true
    exit 1
fi
if ! wait "$NET_PID"; then
    echo "--- server log ---"; cat "$NET_LOG"
    echo "ERROR: serve-net did not drain cleanly after the client disconnected" >&2
    exit 1
fi
if ! grep -q "process: shards=2" "$NET_LOG"; then
    echo "--- server log ---"; cat "$NET_LOG"
    echo "ERROR: serve-net shutdown did not print the merged per-shard report" >&2
    exit 1
fi
rm -f "$NET_LOG"
echo "OK: net smoke passed (wire liveness + routing + merged delivery ledger)"

if [[ "${1:-}" == "--no-bench" ]]; then
    echo "OK (bench smoke skipped)"
    exit 0
fi

echo "== perf smoke: merging bench (quick) =="
TOMERS_BENCH_QUICK=1 cargo bench --offline --bench merging

if [[ ! -f BENCH_merging.json ]]; then
    echo "ERROR: bench did not write BENCH_merging.json" >&2
    exit 1
fi

echo "== perf smoke: coordinator bench (quick) =="
TOMERS_BENCH_QUICK=1 cargo bench --offline --bench coordinator

if [[ ! -f BENCH_serving.json ]]; then
    echo "ERROR: bench did not write BENCH_serving.json" >&2
    exit 1
fi

echo "== perf smoke: streaming bench (quick) =="
TOMERS_BENCH_QUICK=1 cargo bench --offline --bench streaming

if [[ ! -f BENCH_streaming.json ]]; then
    echo "ERROR: bench did not write BENCH_streaming.json" >&2
    exit 1
fi

echo "== perf smoke: obs overhead bench (quick) =="
TOMERS_BENCH_QUICK=1 cargo bench --offline --bench obs

if [[ ! -f BENCH_obs.json ]]; then
    echo "ERROR: bench did not write BENCH_obs.json" >&2
    exit 1
fi

if command -v python3 >/dev/null 2>&1; then
    python3 - "$MIN_SPEEDUP" "$MIN_STREAM_RATIO" "$MIN_SIMD_SPEEDUP" "$OBS_MAX_OVERHEAD" <<'EOF'
import json, sys
min_speedup = float(sys.argv[1])
min_stream_ratio = float(sys.argv[2])
min_simd = float(sys.argv[3])
obs_max_overhead = float(sys.argv[4])

report = json.load(open("BENCH_merging.json"))
cases = [c for c in report["cases"] if c["t"] == 8192 and c["d"] == 64 and c["k"] == 16]
if not cases:
    sys.exit("ERROR: acceptance case t=8192 d=64 k=16 missing from BENCH_merging.json")
batched = min(c["speedup_batched"] for c in cases)
single = min(c["speedup_optimized"] for c in cases)
print(f"acceptance case: speedup_batched={batched:.2f}x (gated) speedup_optimized={single:.2f}x (trend)")
if batched < min_speedup:
    sys.exit(f"ERROR: batched (pool) kernel speedup regressed below {min_speedup}x")
spawns = report.get("post_warmup_spawns", -1)
print(f"pool post-warmup thread spawns: {spawns} (gated == 0)")
if spawns != 0:
    sys.exit("ERROR: the worker pool spawned threads after warmup")
b32 = [c for c in cases if c["batch"] == 32]
if not b32:
    sys.exit("ERROR: pool-vs-scope acceptance case (b=32) missing")
pool_p50, scope_p50 = b32[0]["batched_p50_ms"], b32[0]["batched_scope_p50_ms"]
print(f"b=32 p50: pool={pool_p50:.3f}ms scope={scope_p50:.3f}ms (gated pool <= scope)")
# 5% allowance: at b=32 the per-call spawn saving is small relative to the
# merge work, so an exact <= would flake on scheduler noise; a real
# regression (re-introducing per-call spawns) shows up far above 5%.
if pool_p50 > scope_p50 * 1.05:
    sys.exit("ERROR: pool-backed merge_batch lost to the thread::scope baseline at b=32")

# SIMD dispatch gate (schema v4): the explicit-SIMD kernel must beat its
# own forced-scalar path on the t=4096 d=64 acceptance shape — unless the
# host has no SIMD path at all, in which case both timings are the same
# code and the gate is meaningless.
isa = report.get("isa", "unknown")
simd_cases = [c for c in report["cases"] if c["t"] == 4096 and c["d"] == 64]
if not simd_cases:
    sys.exit("ERROR: acceptance case t=4096 d=64 missing from BENCH_merging.json")
if isa == "scalar":
    print("=" * 72)
    print(f"WARN: kernel dispatched to the SCALAR path (isa={isa}, "
          f"cpu_features={report.get('cpu_features', '?')}) —")
    print(f"WARN: skipping the simd_vs_scalar >= {min_simd}x gate on this host.")
    print("=" * 72)
else:
    x_simd = min(c["simd_vs_scalar"] for c in simd_cases)
    print(f"simd dispatch (isa={isa}): simd_vs_scalar={x_simd:.2f}x at t=4096 d=64 "
          f"(gated >= {min_simd}x)")
    if x_simd < min_simd:
        sys.exit(f"ERROR: explicit-SIMD kernel speedup fell below {min_simd}x vs forced scalar")
    x_blk = min(c["blocked_vs_streaming"] for c in simd_cases)
    print(f"cache blocking: blocked_vs_streaming={x_blk:.2f}x at t=4096 d=64 (trend, ungated)")
print("OK: merging kernel gates passed")

serving = json.load(open("BENCH_serving.json"))
balanced = [r for r in serving["rows"] if abs(r["ratio"] - 1.0) < 1e-9]
if not balanced:
    sys.exit("ERROR: balanced (ratio=1) row missing from BENCH_serving.json")
row = balanced[0]
print(f"serving: serial={row['serial_rps']:.1f} req/s staged={row['staged_rps']:.1f} req/s "
      f"(overlap {row['overlap_gain'] * 100:+.1f}%, gated staged > serial)")
if row["staged_rps"] <= row["serial_rps"]:
    sys.exit("ERROR: staged pipeline did not beat the serial loop — overlap is broken")
print("OK: serving overlap gate passed")

streaming = json.load(open("BENCH_streaming.json"))
acceptance = [c for c in streaming["cases"] if c["t"] == 4096 and c["n"] == 16]
if not acceptance:
    sys.exit("ERROR: acceptance case t=4096 n=16 missing from BENCH_streaming.json")
for c in acceptance:
    if "incremental_ratio" not in c:
        sys.exit("ERROR: BENCH_streaming.json case lacks the incremental_ratio field")
ratio = min(c["incremental_ratio"] for c in acceptance)
print(f"streaming: incremental append {ratio:.1f}x faster than full recompute "
      f"at t=4096 n=16 (gated >= {min_stream_ratio}x)")
if ratio < min_stream_ratio:
    sys.exit(f"ERROR: incremental append path fell below {min_stream_ratio}x vs recompute")
aps = streaming.get("sessions", {}).get("appends_per_sec", 0.0)
print(f"streaming sessions steady state: {aps:.0f} appends/s")
print("OK: streaming gates passed")

obs = json.load(open("BENCH_obs.json"))
pct = obs["overhead_pct"]
print(f"obs: recorder on {obs['rps_on']:.1f} req/s vs off {obs['rps_off']:.1f} req/s "
      f"-> overhead {pct:+.2f}% (gated <= {obs_max_overhead}%)")
if pct > obs_max_overhead:
    sys.exit(f"ERROR: observability overhead {pct:.2f}% exceeds the "
             f"{obs_max_overhead}% budget (DESIGN.md §13)")
print("OK: obs overhead gate passed")
EOF
else
    echo "WARN: python3 unavailable — skipping the numeric gates" >&2
fi

echo "verify: all green"
