//! panics/fire: unwrap + the partial_cmp().unwrap() NaN hazard in
//! non-test src.

pub fn largest(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v.last().copied().unwrap()
}
