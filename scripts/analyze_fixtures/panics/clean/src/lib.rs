//! panics/clean: total_cmp + handled Option; test-gated unwrap is
//! exempt by contract.

pub fn largest(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    v.last().copied().unwrap_or(f64::NEG_INFINITY)
}

#[cfg(test)]
mod tests {
    use super::largest;

    #[test]
    fn test_largest() {
        let xs = vec![1.0, 3.0, 2.0];
        assert_eq!(largest(&xs), xs.iter().copied().last().unwrap());
    }
}
