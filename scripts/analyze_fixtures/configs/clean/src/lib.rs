//! configs/clean: same parser, but unknown keys are rejected first.

pub struct Json;

impl Json {
    pub fn get(&self, _key: &str) -> Option<f64> {
        None
    }
}

pub struct Config {
    pub alpha: f64,
    pub beta: f64,
}

pub fn reject_unknown_keys(_v: &Json, _path: &str, _allowed: &[&str]) -> Result<(), String> {
    Ok(())
}

pub fn parse(v: &Json) -> Result<Config, String> {
    reject_unknown_keys(v, "cfg", &["alpha", "beta"])?;
    let alpha = v.get("alpha").unwrap_or(1.0);
    let beta = v.get("beta").unwrap_or(0.0);
    Ok(Config { alpha, beta })
}
