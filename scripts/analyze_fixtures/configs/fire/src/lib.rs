//! configs/fire: a parser reading two literal keys with no
//! unknown-key rejection — a typo'd key would silently default.

pub struct Json;

impl Json {
    pub fn get(&self, _key: &str) -> Option<f64> {
        None
    }
}

pub struct Config {
    pub alpha: f64,
    pub beta: f64,
}

pub fn parse(v: &Json) -> Config {
    let alpha = v.get("alpha").unwrap_or(1.0);
    let beta = v.get("beta").unwrap_or(0.0);
    Config { alpha, beta }
}
