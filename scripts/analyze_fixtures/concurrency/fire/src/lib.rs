//! concurrency/fire: unbounded channel + bare join().unwrap().

use std::sync::mpsc;
use std::thread;

pub fn run() -> u32 {
    let (tx, rx) = mpsc::channel::<u32>();
    let h = thread::spawn(move || {
        let _ = tx.send(1);
    });
    h.join().unwrap();
    rx.recv().unwrap_or(0)
}
