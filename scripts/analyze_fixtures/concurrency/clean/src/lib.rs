//! concurrency/clean: bounded sync_channel, join without unwrap.

use std::sync::mpsc;
use std::thread;

pub fn run() -> u32 {
    let (tx, rx) = mpsc::sync_channel::<u32>(8);
    let h = thread::spawn(move || {
        let _ = tx.send(1);
    });
    let _ = h.join();
    rx.recv().unwrap_or(0)
}
