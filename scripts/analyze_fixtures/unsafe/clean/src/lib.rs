//! unsafe/clean: unsafe confined to merging/simd.rs, arch-gated and
//! SAFETY-commented.

pub mod merging;

pub fn sum(a: &[f32]) -> f64 {
    merging::simd::dispatch(a)
}
