pub mod simd;
