//! The one sanctioned unsafe surface: ISA kernels behind arch gates.

pub fn dispatch(a: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY: kern reads only in-bounds lanes of `a`; the arch gate
        // guarantees the target supports the baseline ISA it uses.
        return unsafe { kern(a) };
    }
    #[allow(unreachable_code)]
    scalar(a)
}

fn scalar(a: &[f32]) -> f64 {
    a.iter().map(|x| *x as f64).sum()
}

/// # Safety
/// Caller must ensure the arch gate's ISA baseline is available.
#[cfg(target_arch = "x86_64")]
unsafe fn kern(a: &[f32]) -> f64 {
    scalar(a)
}
