//! unsafe/fire: an unsafe block outside merging/simd.rs.

pub fn peek(xs: &[u8]) -> u8 {
    unsafe { *xs.as_ptr() }
}
