//! deprecation/clean: callers use the replacement; the shim is only
//! exercised under #[allow(deprecated)] in tests.

#[deprecated(note = "use new_api")]
pub fn old_api(x: usize) -> usize {
    new_api(x)
}

pub fn new_api(x: usize) -> usize {
    x
}

pub fn caller(x: usize) -> usize {
    new_api(x)
}

#[cfg(test)]
mod tests {
    #[test]
    #[allow(deprecated)]
    fn shim_matches_replacement() {
        assert_eq!(super::old_api(3), super::new_api(3));
    }
}
