//! deprecation/fire: a non-test caller of a #[deprecated] wrapper.

#[deprecated(note = "use new_api")]
pub fn old_api(x: usize) -> usize {
    new_api(x)
}

pub fn new_api(x: usize) -> usize {
    x
}

pub fn caller(x: usize) -> usize {
    old_api(x)
}
