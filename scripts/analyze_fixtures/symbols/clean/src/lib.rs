//! symbols/clean: every call resolves with matching arity.

pub fn helper(x: usize) -> usize {
    x + 1
}

pub fn caller() -> usize {
    let doubled: Vec<usize> = (0..4).map(|i| helper(i)).collect();
    helper(1) + doubled.len()
}
