//! symbols/fire: one arity mismatch, one unresolved call.

pub fn helper(x: usize) -> usize {
    x + 1
}

pub fn caller() -> usize {
    helper(1, 2) + missing_fn(3)
}
