//! wiring/clean: mod declaration matches its file, use path resolves.

mod sub;

pub use sub::answer;

pub fn touch() -> usize {
    answer()
}
