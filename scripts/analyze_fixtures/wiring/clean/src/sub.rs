pub fn answer() -> usize {
    42
}
