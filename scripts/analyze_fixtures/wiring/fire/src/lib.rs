//! wiring/fire: a `mod` with no backing file, plus an orphan file.

mod nothere;

pub fn touch() -> usize {
    1
}
