//! Never declared by any `mod` — silently excluded from the build.

pub fn lonely() -> usize {
    2
}
