#!/usr/bin/env python3
"""tomers-analyze — toolchain-free whole-crate static analysis gate.

Runs seven passes over rust/{src,tests,benches,examples} (vendor/ is
indexed for definitions only) without needing cargo, rustc, or any
non-stdlib Python package:

  symbols      (a) every call site / method / struct literal resolves
               to a definition with matching arity or field set
  wiring       (b) mod/file agreement, `use` path resolution, no
               default-build reference to pjrt-gated items
  concurrency  (c) no bare `.join().unwrap()`, no unbounded
               `mpsc::channel`, lock-order hazards flagged
  panics       (d) unwrap/expect/panic! in non-test src need a
               justification
  configs      (e) JSON config parsers must reject unknown keys
  unsafe       (f) unsafe confined to merging/simd.rs + SAFETY comments
  deprecation  (g) no non-test callers of #[deprecated] wrappers

Findings are suppressed only via scripts/analyze_allow.json (strict
schema, justification required; stale entries are errors).  Exit code
0 = clean; 1 = new findings, stale allows, or schema errors.

Usage:
  scripts/analyze.py [--crate DIR] [--allow FILE] [--json [PATH]]
                     [--verbose]

  --json writes ANALYZE_report.json (default: next to the crate dir)
  with per-pass counts (findings / allowlisted / new) and every finding.

See DESIGN.md §14 for the analysis contract and how to add a lint.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_SCRIPTS = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, _SCRIPTS)

from analyze import analyze_root  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--crate",
        default=os.path.join(_SCRIPTS, "..", "rust"),
        help="crate directory containing src/ (default: ../rust)",
    )
    ap.add_argument(
        "--allow",
        default=os.path.join(_SCRIPTS, "analyze_allow.json"),
        help="allowlist path (default: scripts/analyze_allow.json)",
    )
    ap.add_argument(
        "--json",
        nargs="?",
        const="",
        default=None,
        metavar="PATH",
        help="write the JSON report (default path: <repo>/ANALYZE_report.json)",
    )
    ap.add_argument(
        "--verbose", action="store_true",
        help="also print allowlisted findings",
    )
    args = ap.parse_args(argv)

    crate = os.path.abspath(args.crate)
    if not os.path.isdir(os.path.join(crate, "src")):
        print(f"ERROR: {crate} has no src/ directory", file=sys.stderr)
        return 2
    report = analyze_root(crate, allow_path=args.allow)

    for err in report.errors:
        print(f"ALLOWLIST ERROR: {err}", file=sys.stderr)

    shown = report.findings if args.verbose else report.new_findings
    for f in shown:
        tag = "allow" if f.allowed_by is not None else "NEW"
        print(f"[{f.pass_id}][{tag}] {f.file}:{f.line}: {f.message}")
        if f.snippet:
            print(f"    | {f.snippet}")
    for a in report.stale_allows:
        print(
            f"STALE ALLOW: entries[{a.index}] (pass={a.pass_id}, "
            f"file={a.file}, pattern={a.pattern!r}) matches nothing — "
            f"remove it", file=sys.stderr,
        )

    print()
    print(report.summary_table())
    print(
        f"\nanalyze: {report.files_scanned} files, "
        f"{len(report.findings)} findings "
        f"({len(report.findings) - len(report.new_findings)} allowlisted, "
        f"{len(report.new_findings)} new), "
        f"{len(report.stale_allows)} stale allow(s)"
    )

    if args.json is not None:
        path = args.json or os.path.abspath(
            os.path.join(crate, "..", "ANALYZE_report.json")
        )
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(report.to_json(), fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"report written: {path}")

    if not report.ok:
        print(
            "analyze: FAIL — fix the findings or add a justified "
            "allowlist entry (scripts/analyze_allow.json)",
            file=sys.stderr,
        )
        return 1
    print("analyze: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
