#!/usr/bin/env python3
"""Transliteration cross-check for the net subsystem (DESIGN.md §12).

Executable verification of the pieces of `rust/src/net/` whose behaviour
is a *wire contract* — values that, if they drifted, would strand state
on the wrong shard or desynchronize framing between old and new builds:

  1. `router.rs::mix64` — the SplitMix64 finalizer, bit-for-bit
     (reference values are also pinned by the Rust unit tests);
  2. `router.rs::ShardRouter` — ring construction + lookup: the golden
     (shards, id) -> shard table embedded in the Rust
     `hash_stability_golden_pins` test must match this transliteration
     exactly, and the ring must be roughly balanced;
  3. `frame.rs` — the length-prefixed frame layout: header encoding and
     the oversized-reject bound;
  4. `metrics.rs::merged_report` ledger arithmetic — summing per-shard
     delivery ledgers preserves the identity
     enqueued == acked + expired_undelivered + dropped_overflow + pending.

All integer arithmetic is explicitly wrapped to 64 bits, so every op is
the op the Rust code performs.  Run: python3 scripts/crosscheck_net.py
"""

import bisect
import struct
import sys

MASK = (1 << 64) - 1


def mix64(x: int) -> int:
    """rust/src/net/router.rs::mix64 (SplitMix64 finalizer)."""
    z = (x + 0x9E3779B97F4A7C15) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return z ^ (z >> 31)


def build_ring(shards: int, vnodes: int = 64):
    """rust/src/net/router.rs::ShardRouter::with_vnodes."""
    ring = []
    for shard in range(shards):
        for vnode in range(vnodes):
            point = mix64(mix64(shard) ^ ((vnode * 0xA24BAED4963EE407) & MASK))
            ring.append((point, shard))
    ring.sort()
    return ring


def shard_for(ring, ident: int) -> int:
    """rust partition_point(point < h) + wrap — bisect_left((h,)) is the
    first index whose (point, shard) tuple is >= (h,)."""
    h = mix64(ident)
    idx = bisect.bisect_left(ring, (h,))
    return ring[idx % len(ring)][1]


def check_mixer():
    # the reference constants the Rust mixer_golden_pins test asserts
    expect = {
        0: 0xE220A8397B1DCDAF,  # canonical splitmix64(seed=0) first output
        1: 0x910A2DEC89025CC1,  # canonical splitmix64(seed=0) second output
        0xDEADBEEF: 0x4ADFB90F68C9EB9B,
    }
    got = {k: mix64(k) for k in expect}
    for k, e in expect.items():
        if got[k] != e:
            sys.exit(f"ERROR: mix64({k:#x}) = {got[k]:#x}, expected {e:#x} — "
                     "not the SplitMix64 finalizer the router pins")
    print(f"mixer: mix64(0)={got[0]:#018x} mix64(1)={got[1]:#018x} "
          f"mix64(0xDEADBEEF)={got[0xDEADBEEF]:#018x}")
    return got


# The golden table `rust/tests` + `router.rs::hash_stability_golden_pins`
# assert: rows are shard counts 2/3/4, columns the ids below.
GOLDEN_IDS = [0, 1, 2, 3, 7, 42, 1_000_003, (1 << 64) - 1 >> 13]
GOLDEN_TABLE = {
    2: [0, 1, 0, 1, 1, 1, 0, 0],
    3: [0, 1, 0, 2, 2, 1, 2, 2],
    4: [3, 1, 0, 2, 2, 1, 3, 2],
}


def check_router():
    print("router golden table (ids = %s):" % GOLDEN_IDS)
    table = {}
    for shards in (2, 3, 4):
        ring = build_ring(shards)
        row = [shard_for(ring, i) for i in GOLDEN_IDS]
        table[shards] = row
        print(f"  shards={shards}: {row}")
        expected = GOLDEN_TABLE[shards]
        if expected is not None and row != expected:
            sys.exit(f"ERROR: golden drift at shards={shards}: {row} != {expected}")
    # balance: 4 shards x 64 vnodes over 40k sequential ids
    ring = build_ring(4)
    counts = [0, 0, 0, 0]
    for i in range(40_000):
        counts[shard_for(ring, i)] += 1
    print(f"  balance over 40k ids at shards=4: {counts}")
    if not all(4_000 <= c <= 20_000 for c in counts):
        sys.exit("ERROR: ring badly imbalanced — vnode hashing broken")
    # growth moves a bounded fraction (the consistent-hashing property)
    r3, r4 = build_ring(3), build_ring(4)
    moved = sum(1 for i in range(40_000) if shard_for(r3, i) != shard_for(r4, i))
    print(f"  moved 3->4 shards: {moved}/40000")
    if moved >= 20_000:
        sys.exit("ERROR: growing the ring reshuffled >= half the ids")
    return table


def check_framing():
    """frame.rs: u32 big-endian length + UTF-8 payload."""
    payload = b'{"type":"report"}'
    frame = struct.pack(">I", len(payload)) + payload
    if frame[:4] != bytes([0, 0, 0, 17]) or len(frame) != 21:
        sys.exit("ERROR: frame layout drifted from u32-BE length + payload")
    # the reject bound: a header declaring max_frame_bytes+1 must be seen
    # as oversized by an instance configured with that max
    max_frame = 64
    declared = struct.unpack(">I", struct.pack(">I", max_frame + 1))[0]
    if not declared > max_frame:
        sys.exit("ERROR: oversized-header arithmetic broken")
    print(f"framing: header BE-u32 ok, oversize bound ok (example frame {len(frame)}B)")


def check_ledger_merge():
    """metrics.rs::merged_report — summed ledgers keep the identity."""
    shards = [
        dict(enqueued=10, acked=4, redelivered=1, expired=2, dropped=1, pending=3),
        dict(enqueued=7, acked=7, redelivered=0, expired=0, dropped=0, pending=0),
        dict(enqueued=0, acked=0, redelivered=0, expired=0, dropped=0, pending=0),
    ]
    for i, s in enumerate(shards):
        if s["enqueued"] != s["acked"] + s["expired"] + s["dropped"] + s["pending"]:
            sys.exit(f"ERROR: test fixture shard {i} ledger does not balance")
    tot = {k: sum(s[k] for s in shards) for k in shards[0]}
    if tot["enqueued"] != tot["acked"] + tot["expired"] + tot["dropped"] + tot["pending"]:
        sys.exit("ERROR: ledger identity not preserved under summation")
    print(f"ledger merge: sum {tot} balances")


def main():
    check_mixer()
    check_router()
    check_framing()
    check_ledger_merge()
    print("OK: net crosscheck passed")


if __name__ == "__main__":
    main()
