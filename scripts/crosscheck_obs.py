#!/usr/bin/env python3
"""Transliteration crosscheck for the observability histograms
(rust/src/obs/hist.rs) — runs without the Rust toolchain.

The serving metrics replace their unbounded per-request vectors with
bounded log-linear histograms: one bucket per 1/16th of an octave
(SUB_BITS = 4 linear sub-buckets per power of two), extracted straight
from the f64 bit pattern, plus an underflow bucket (index 0) and an
overflow bucket (last index).  This script reimplements, independently
of the Rust code:

  * bucket indexing from the IEEE-754 bit layout (exponent + top 4
    mantissa bits), pinned against a hand-computed golden table;
  * bucket bounds / midpoint representatives and the documented
    relative-error bound (<= 1/32 = 3.125% for in-range values);
  * nearest-rank percentile readout (the same rule as
    `tomers::util::percentile`), checked against a sorted-vector oracle
    on pseudorandom data within the documented bound;
  * histogram merge: exact count/sum identities, commutativity, and
    associativity (on dyadic-exact values, where f64 addition is exact).

Any drift between this file and rust/src/obs/hist.rs is a semantic
regression in one of them.  scripts/verify.sh runs this as a first
gate, before anything cargo-dependent.
"""

import math
import struct
import sys

SUB_BITS = 4
SUB = 1 << SUB_BITS  # 16 linear sub-buckets per octave

# Default latency histogram bounds (seconds): 2^-20 (~0.95us) .. 2^7 (128s).
LAT_MIN_EXP = -20
LAT_MAX_EXP = 7


def bucket_count(min_exp, max_exp):
    return (max_exp - min_exp) * SUB + 2


def index(v, min_exp, max_exp):
    """Bucket index of value v: 0 = underflow (incl. <= 0 and NaN),
    last = overflow, else 1 + (exponent - min_exp) * SUB + sub."""
    n = bucket_count(min_exp, max_exp)
    if not (v >= 2.0 ** min_exp):  # NaN compares false -> underflow
        return 0
    if v >= 2.0 ** max_exp:
        return n - 1
    bits = struct.unpack(">Q", struct.pack(">d", v))[0]
    e = ((bits >> 52) & 0x7FF) - 1023
    sub = (bits >> (52 - SUB_BITS)) & (SUB - 1)
    return 1 + (e - min_exp) * SUB + sub


def bounds(i, min_exp):
    """[lower, lower + width) of in-range bucket i (1 <= i <= n-2)."""
    k = i - 1
    e = min_exp + k // SUB
    sub = k % SUB
    lower = (2.0 ** e) * (1.0 + sub / SUB)
    width = (2.0 ** e) / SUB
    return lower, width


def representative(i, min_exp):
    lower, width = bounds(i, min_exp)
    return lower + width / 2.0


def nearest_rank(p, n):
    """0-based nearest-rank index, matching tomers::util::percentile:
    round-half-away-from-zero of p/100 * (n-1)."""
    return int(math.floor(p / 100.0 * (n - 1) + 0.5))


class Hist:
    def __init__(self, min_exp=LAT_MIN_EXP, max_exp=LAT_MAX_EXP):
        self.min_exp, self.max_exp = min_exp, max_exp
        self.buckets = [0] * bucket_count(min_exp, max_exp)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, v):
        self.buckets[index(v, self.min_exp, self.max_exp)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def merge(self, other):
        assert (self.min_exp, self.max_exp) == (other.min_exp, other.max_exp)
        for i, c in enumerate(other.buckets):
            self.buckets[i] += c
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def percentile(self, p):
        if self.count == 0:
            return 0.0
        rank = nearest_rank(p, self.count)
        cum = 0
        last = len(self.buckets) - 1
        for i, c in enumerate(self.buckets):
            cum += c
            if cum > rank:
                if i == 0:
                    rep = self.min
                elif i == last:
                    rep = self.max
                else:
                    rep = representative(i, self.min_exp)
                return min(max(rep, self.min), self.max)
        return self.max


# Golden bucket indices at the default latency bounds (-20 .. 7), each
# hand-derived from the IEEE-754 layout.  Pinned verbatim in
# rust/src/obs/hist.rs (test `golden_bucket_indices`).
GOLDEN = [
    (0.0, 0),        # <= 0 underflows
    (float("nan"), 0),
    (2.0 ** -21, 0),  # below 2^min_exp underflows
    (2.0 ** -20, 1),  # first in-range bucket
    (0.001, 161),    # e = -10, sub = 0
    (0.0015, 169),   # e = -10, sub = 8
    (1.0, 321),      # e = 0, sub = 0
    (1.5, 329),      # e = 0, sub = 8
    (64.0, 417),     # e = 6, sub = 0
    (127.9999, 432), # last in-range bucket
    (128.0, 433),    # 2^max_exp overflows
    (1e9, 433),
]


def lcg(seed):
    state = seed
    while True:
        state = (state * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        yield state >> 33


def check_goldens():
    n = bucket_count(LAT_MIN_EXP, LAT_MAX_EXP)
    if n != 434:
        sys.exit(f"ERROR: default latency histogram has {n} buckets, expected 434")
    for v, want in GOLDEN:
        got = index(v, LAT_MIN_EXP, LAT_MAX_EXP)
        if got != want:
            sys.exit(f"ERROR: index({v!r}) = {got}, golden table says {want}")
    print(f"goldens: {len(GOLDEN)} pinned bucket indices OK (n={n})")


def check_bounds_and_error():
    rng = lcg(7)
    checked = 0
    for _ in range(4000):
        # spread across the full in-range span
        e = LAT_MIN_EXP + next(rng) % (LAT_MAX_EXP - LAT_MIN_EXP)
        frac = 1.0 + (next(rng) % 10_000) / 10_000.0  # [1, 2)
        v = (2.0 ** e) * min(frac, 1.9999)
        i = index(v, LAT_MIN_EXP, LAT_MAX_EXP)
        if i == 0 or i == bucket_count(LAT_MIN_EXP, LAT_MAX_EXP) - 1:
            sys.exit(f"ERROR: in-range value {v} landed in an edge bucket")
        lower, width = bounds(i, LAT_MIN_EXP)
        if not (lower <= v < lower + width * (1 + 1e-12)):
            sys.exit(f"ERROR: value {v} outside its bucket [{lower}, {lower + width})")
        rel = abs(representative(i, LAT_MIN_EXP) - v) / v
        if rel > 1.0 / 32.0 + 1e-12:
            sys.exit(f"ERROR: representative error {rel:.5f} exceeds 1/32 at {v}")
        checked += 1
    # indexing is monotone in the value
    vals = sorted((2.0 ** LAT_MIN_EXP) * (1.0 + k / 997.0) * 2.0 ** (k % 27) for k in range(997))
    idxs = [index(v, LAT_MIN_EXP, LAT_MAX_EXP) for v in vals]
    if any(a > b for a, b in zip(idxs, idxs[1:])):
        sys.exit("ERROR: bucket index is not monotone in the value")
    print(f"bounds: {checked} sampled values inside their bucket, error <= 1/32, monotone")


def check_percentile_oracle():
    rng = lcg(21)
    values = []
    for _ in range(5000):
        # latencies spread over ~6 decades: 2us .. 2s
        e = -19 + next(rng) % 21
        frac = 1.0 + (next(rng) % 10_000) / 10_000.0
        values.append((2.0 ** e) * frac)
    h = Hist()
    for v in values:
        h.record(v)
    s = sorted(values)
    for p in (0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0):
        oracle = s[nearest_rank(p, len(s))]
        got = h.percentile(p)
        rel = abs(got - oracle) / oracle
        if rel > 1.0 / 32.0 + 1e-12:
            sys.exit(
                f"ERROR: p{p}: histogram {got:.6g} vs oracle {oracle:.6g} "
                f"(rel {rel:.5f} > 1/32)"
            )
    # degenerate cases
    if Hist().percentile(50.0) != 0.0:
        sys.exit("ERROR: empty histogram percentile must be 0")
    one = Hist()
    one.record(0.25)
    for p in (0.0, 50.0, 100.0):
        if abs(one.percentile(p) - 0.25) > 0.25 / 32.0:
            sys.exit("ERROR: single-value percentile off its value")
    print("percentile: p0..p100 within 1/32 of the sorted-vector oracle (n=5000)")


def check_merge_identities():
    # dyadic-exact values: f64 addition is exact, so sum identities and
    # associativity hold bit-for-bit (the Rust test uses the same set)
    sets = [
        [0.5, 0.25, 1.0, 2.0, 0.125],
        [4.0, 0.5, 0.5, 8.0],
        [1.5, 0.75, 0.0078125, 32.0, 2.0, 2.0],
    ]
    hs = []
    for vs in sets:
        h = Hist()
        for v in vs:
            h.record(v)
        hs.append(h)
    # commutativity: a+b == b+a
    ab, ba = Hist(), Hist()
    ab.merge(hs[0]); ab.merge(hs[1])
    ba.merge(hs[1]); ba.merge(hs[0])
    if ab.buckets != ba.buckets or ab.sum != ba.sum or ab.count != ba.count:
        sys.exit("ERROR: histogram merge is not commutative")
    # associativity: (a+b)+c == a+(b+c)
    left = Hist(); left.merge(hs[0]); left.merge(hs[1]); left.merge(hs[2])
    bc = Hist(); bc.merge(hs[1]); bc.merge(hs[2])
    right = Hist(); right.merge(hs[0]); right.merge(bc)
    if left.buckets != right.buckets or left.sum != right.sum:
        sys.exit("ERROR: histogram merge is not associative on dyadic values")
    # exact identities vs recording everything into one histogram
    direct = Hist()
    for vs in sets:
        for v in vs:
            direct.record(v)
    if left.count != direct.count or left.count != sum(len(vs) for vs in sets):
        sys.exit("ERROR: merged count identity broken")
    if left.sum != direct.sum:
        sys.exit("ERROR: merged sum identity broken on exact values")
    if left.buckets != direct.buckets:
        sys.exit("ERROR: merged buckets differ from direct recording")
    if left.min != direct.min or left.max != direct.max:
        sys.exit("ERROR: merged min/max identity broken")
    print("merge: commutative + associative, exact count/sum/min/max identities")


def main():
    check_goldens()
    check_bounds_and_error()
    check_percentile_oracle()
    check_merge_identities()
    print("OK: obs crosscheck passed")


if __name__ == "__main__":
    main()
