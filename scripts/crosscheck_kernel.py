#!/usr/bin/env python3
"""Transliteration cross-check for the PR 7 merge-kernel changes.

When the container has no Rust toolchain, this script is the executable
half of the review: it transliterates the exact arithmetic of
`rust/src/merging/simd.rs` (lane/index layout of the scalar and vector
reduction models), `kernel.rs::match_tokens_scratch_tiled` (cache-blocked
walk + norm watermark) and `batch.rs::chunk_lens` (balanced splitter)
into Python — where every float op is the same IEEE-754 binary64 op Rust
performs — and checks the properties the Rust test suite asserts:

  1. vector lane models (AVX2 4x f64, NEON 2x2 f64) are *bitwise* equal
     to the 4-lane chunked scalar reduction, for dot and sumsq, across
     the remainder-edge length sweep;
  2. the tiled matching walk is bitwise equal to the one-tile streaming
     walk for every tile size, and the norm watermark never lets a score
     read an unfilled norm (sentinel-checked);
  3. at d < 4 the kernel scores are bitwise equal to the reference
     transliteration (serial dot + mirrored chunked sumsq), and the
     norms are bitwise-shared at every d — the documented contract;
  4. top-r selection under the total order (score desc, index asc)
     selects the same *set* as the reference's stable descending sort;
  5. chunk_lens invariants: sums to b, min(slots, b) chunks, no empty
     chunk, sizes differ by at most one;
  6. matching_tile clamp pins.

Inputs are f32-rounded (struct round-trip), so the f64 accumulation here
is op-for-op what the Rust f64 paths compute.  Run: python3 scripts/crosscheck_kernel.py
"""

import math
import random
import struct
import sys

FAILURES = []


def check(name, ok, detail=""):
    if ok:
        print(f"PASS  {name}")
    else:
        print(f"FAIL  {name}  {detail}")
        FAILURES.append(name)


def f32(x):
    """Round a Python float through IEEE binary32 (Rust `as f32`)."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def rand_vec(rng, n):
    return [f32(rng.gauss(0.0, 1.0)) for _ in range(n)]


# ---------------------------------------------------------------------------
# 1. simd.rs reduction models (f64 paths; Python float == IEEE binary64)


def dot_f64_scalar(a, b):
    """simd.rs::dot_f64_scalar — 4 strided lanes, (s0+s1)+(s2+s3)+tail."""
    n = len(a)
    chunks = n // 4
    s = [0.0, 0.0, 0.0, 0.0]
    for c in range(chunks):
        i = 4 * c
        for l in range(4):
            s[l] += a[i + l] * b[i + l]
    tail = 0.0
    for i in range(4 * chunks, n):
        tail += a[i] * b[i]
    return (s[0] + s[1]) + (s[2] + s[3]) + tail


def sumsq_f64_scalar(a):
    """simd.rs::sumsq_f64_scalar — same lane layout as the dot."""
    n = len(a)
    chunks = n // 4
    s = [0.0, 0.0, 0.0, 0.0]
    for c in range(chunks):
        i = 4 * c
        for l in range(4):
            x = a[i + l]
            s[l] += x * x
    tail = 0.0
    for i in range(4 * chunks, n):
        tail += a[i] * a[i]
    return (s[0] + s[1]) + (s[2] + s[3]) + tail


def dot_f64_avx2_model(a, b):
    """avx2::dot_f64 — one 4-wide accumulator: lane l sees exactly the ops
    acc[l] = acc[l] + (a[4c+l] * b[4c+l]) (cvtps_pd exact, mul rounds once,
    add rounds once — no FMA), reduced (l0+l1)+(l2+l3)+tail."""
    n = len(a)
    chunks = n // 4
    acc = [0.0, 0.0, 0.0, 0.0]
    for c in range(chunks):
        i = 4 * c
        va = a[i:i + 4]          # _mm_loadu_ps + _mm256_cvtps_pd (exact)
        vb = b[i:i + 4]
        prod = [va[l] * vb[l] for l in range(4)]      # _mm256_mul_pd
        acc = [acc[l] + prod[l] for l in range(4)]    # _mm256_add_pd
    tail = 0.0
    for i in range(4 * chunks, n):
        tail += a[i] * b[i]
    return (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail


def dot_f64_neon_model(a, b):
    """neon::dot_f64 — two float64x2_t accumulators holding lanes (0,1)
    and (2,3); vcvt exact, vmulq then vaddq (no vfmaq)."""
    n = len(a)
    chunks = n // 4
    acc01 = [0.0, 0.0]
    acc23 = [0.0, 0.0]
    for c in range(chunks):
        i = 4 * c
        lo = [a[i] * b[i], a[i + 1] * b[i + 1]]           # vmulq_f64 low
        hi = [a[i + 2] * b[i + 2], a[i + 3] * b[i + 3]]   # vmulq_f64 high
        acc01 = [acc01[0] + lo[0], acc01[1] + lo[1]]      # vaddq_f64
        acc23 = [acc23[0] + hi[0], acc23[1] + hi[1]]
    tail = 0.0
    for i in range(4 * chunks, n):
        tail += a[i] * b[i]
    return (acc01[0] + acc01[1]) + (acc23[0] + acc23[1]) + tail


def sumsq_f64_vector_model(a, two_regs):
    n = len(a)
    chunks = n // 4
    acc = [0.0, 0.0, 0.0, 0.0]
    for c in range(chunks):
        i = 4 * c
        v = a[i:i + 4]
        prod = [v[l] * v[l] for l in range(4)]
        acc = [acc[l] + prod[l] for l in range(4)]
    tail = 0.0
    for i in range(4 * chunks, n):
        tail += a[i] * a[i]
    # two_regs (NEON) vs one 4-wide reg (AVX2): identical lane contents,
    # identical reduction expression
    return (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail


def bits(x):
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def check_lane_models():
    rng = random.Random(22)
    lens = [0, 1, 2, 3, 4, 5, 7, 8, 9, 63, 64, 257]
    ok = True
    detail = ""
    for n in lens:
        for _ in range(8):
            a, b = rand_vec(rng, n), rand_vec(rng, n)
            s = dot_f64_scalar(a, b)
            for name, model in [("avx2", dot_f64_avx2_model(a, b)),
                                ("neon", dot_f64_neon_model(a, b))]:
                if bits(model) != bits(s):
                    ok, detail = False, f"dot {name} n={n}: {model!r} != {s!r}"
            ss = sumsq_f64_scalar(a)
            for tr in (False, True):
                if bits(sumsq_f64_vector_model(a, tr)) != bits(ss):
                    ok, detail = False, f"sumsq two_regs={tr} n={n}"
            # every index consumed exactly once by the lane partition
            used = sorted(list(range(0, 4 * (n // 4))) + list(range(4 * (n // 4), n)))
            if used != list(range(n)):
                ok, detail = False, f"index coverage n={n}"
    check("simd lane models bitwise == 4-lane chunked scalar (dot, sumsq)", ok, detail)


# ---------------------------------------------------------------------------
# 2–3. kernel.rs tiled matching walk + reference comparison


def match_tiled(tokens, t, d, k, tile):
    """kernel.rs::match_tokens_scratch_tiled, with sentinel norms: any
    score reading an unfilled norm raises (the watermark proof)."""
    te = t - (t % 2)
    t2 = te // 2
    k = max(1, min(k, max(t2, 1)))
    norms = [None] * te          # sentinel: None == not yet filled
    scores = [float("-inf")] * t2
    best = [0] * t2
    if t2 == 0:
        return scores, best, norms
    tile = max(tile, 1)
    filled = 0
    i0 = 0
    while i0 < t2:
        i1 = min(i0 + tile, t2)
        need = 2 * min(i1 - 1 + (k - 1), t2 - 1) + 2
        assert need <= te, f"watermark overrun: need={need} te={te}"
        while filled < need:
            row = tokens[filled * d:(filled + 1) * d]
            norms[filled] = math.sqrt(sumsq_f64_scalar(row))
            filled += 1
        for i in range(i0, i1):
            a = tokens[(2 * i) * d:(2 * i + 1) * d]
            na = norms[2 * i]
            assert na is not None, f"A-norm read before fill: i={i}"
            lo = max(i - (k - 1), 0)
            hi = min(i + k - 1, t2 - 1)
            best_score = float("-inf")
            best_j = 0
            for j in range(lo, hi + 1):
                nb = norms[2 * j + 1]
                assert nb is not None, f"B-norm read before fill: i={i} j={j}"
                b = tokens[(2 * j + 1) * d:(2 * j + 2) * d]
                s = dot_f64_scalar(a, b) / (na * nb + 1e-8)
                if s > best_score:
                    best_score = s
                    best_j = j
            scores[i] = best_score
            best[i] = best_j
        i0 = i1
    return scores, best, norms


def match_reference(tokens, t, d, k):
    """reference.rs matching: serial-index-order dot, chunked sumsq (the
    PR 7 mirror), same band/tie-break semantics."""
    te = t - (t % 2)
    t2 = te // 2
    k = max(1, min(k, max(t2, 1)))
    scores = [float("-inf")] * t2
    best = [0] * t2
    for i in range(t2):
        a = tokens[(2 * i) * d:(2 * i + 1) * d]
        lo = max(i - (k - 1), 0)
        hi = min(i + k - 1, t2 - 1)
        for j in range(lo, hi + 1):
            b = tokens[(2 * j + 1) * d:(2 * j + 2) * d]
            dot = 0.0
            for x, y in zip(a, b):
                dot += x * y
            s = dot / (math.sqrt(sumsq_f64_scalar(a)) * math.sqrt(sumsq_f64_scalar(b)) + 1e-8)
            if s > scores[i]:
                scores[i] = s
                best[i] = j
    return scores, best


SHAPES = [(130, 7, 9), (127, 64, 16), (64, 257, 4), (33, 1, 40), (8, 3, 1),
          (64, 8, 4), (97, 3, 16), (33, 1, 33), (128, 64, 1), (7, 2, 3), (1, 4, 1), (0, 4, 1)]
TILES = [1, 2, 3, 5, 7, 16, 63, 64, 65, 4096]


def check_tiled_walk():
    rng = random.Random(7)
    ok = True
    detail = ""
    for (t, d, k) in SHAPES:
        tokens = rand_vec(rng, t * d)
        s_stream, b_stream, n_stream = match_tiled(tokens, t, d, k, 10 ** 9)
        for tile in TILES:
            s_blk, b_blk, n_blk = match_tiled(tokens, t, d, k, tile)
            if [bits(x) for x in s_blk] != [bits(x) for x in s_stream] or b_blk != b_stream:
                ok, detail = False, f"t={t} d={d} k={k} tile={tile}"
            if None in n_blk or [bits(x) for x in n_blk] != [bits(x) for x in n_stream]:
                ok, detail = False, f"norms t={t} d={d} k={k} tile={tile}"
    check("tiled walk bitwise == streaming walk; watermark never under-fills", ok, detail)

    ok = True
    detail = ""
    for (t, d, k) in SHAPES:
        tokens = rand_vec(rng, t * d)
        s_k, b_k, n_k = match_tiled(tokens, t, d, k, 64)
        s_r, b_r = match_reference(tokens, t, d, k)
        if d < 4:
            # chunked dot has no 4-chunks at d < 4: serial tail only, so
            # kernel scores are bitwise the reference scores
            if [bits(x) for x in s_k] != [bits(x) for x in s_r] or b_k != b_r:
                ok, detail = False, f"d<4 bitwise t={t} d={d} k={k}"
        else:
            # norms stay bitwise-shared at every d (mirrored sumsq); the
            # dots differ only in summation order, so matches agree up to
            # near-ties — require score agreement within reassociation noise
            for x, y in zip(s_k, s_r):
                if abs(x - y) > 1e-12 * max(1.0, abs(x)):
                    ok, detail = False, f"score drift t={t} d={d} k={k}: {x!r} vs {y!r}"
    check("kernel == reference: bitwise at d<4, reassociation-only drift at d>=4", ok, detail)


def check_selection():
    rng = random.Random(9)
    ok = True
    detail = ""
    for trial in range(200):
        t2 = rng.randrange(1, 40)
        # coarse scores force ties, exercising the tie-break
        scores = [rng.randrange(0, 6) / 4.0 for _ in range(t2)]
        r = rng.randrange(1, t2 + 1)
        # kernel: total order (score desc, index asc), top r
        kernel_sel = set(sorted(range(t2), key=lambda i: (-scores[i], i))[:r])
        # reference: stable descending sort by score, first r
        ref_sel = set(sorted(range(t2), key=lambda i: -scores[i])[:r])
        if kernel_sel != ref_sel:
            ok, detail = False, f"trial={trial} r={r} {scores}"
    check("top-r total order selects the same set as stable descending sort", ok, detail)


# ---------------------------------------------------------------------------
# 4. batch.rs::chunk_lens


def chunk_lens(b, n_slots):
    n_chunks = min(n_slots, b)
    base = b // n_chunks if n_chunks else 0
    extra = b % n_chunks if n_chunks else 0
    return [base + 1 if c < extra else base for c in range(n_chunks)]


def check_splitter():
    ok = True
    detail = ""
    for n_slots in range(1, 41):
        for b in range(0, 201):
            lens = chunk_lens(b, n_slots)
            if sum(lens) != b or len(lens) != min(n_slots, b):
                ok, detail = False, f"b={b} slots={n_slots} {lens}"
            if b and (min(lens) < 1 or max(lens) - min(lens) > 1):
                ok, detail = False, f"b={b} slots={n_slots} {lens}"
    # the regression the PR fixes: ceil-div at b=9, slots=8 used 5 slots
    old_style = -(-9 // 8)  # ceil
    assert old_style == 2 and -(-9 // old_style) == 5
    if chunk_lens(9, 8) != [2, 1, 1, 1, 1, 1, 1, 1]:
        ok, detail = False, f"b=9 slots=8 -> {chunk_lens(9, 8)}"
    check("chunk_lens: sums to b, min(slots,b) chunks, non-empty, max-min<=1", ok, detail)


def check_matching_tile():
    def matching_tile(d):
        return min(max(32 * 1024 // (8 * max(d, 1)), 64), 4096)
    pins = {1: 4096, 8: 512, 64: 64, 4096: 64, 0: 4096, 2: 2048, 16: 256}
    bad = {d: (matching_tile(d), want) for d, want in pins.items() if matching_tile(d) != want}
    check("matching_tile(d) clamp pins", not bad, str(bad))


def main():
    check_lane_models()
    check_tiled_walk()
    check_selection()
    check_splitter()
    check_matching_tile()
    if FAILURES:
        print(f"\n{len(FAILURES)} check(s) FAILED")
        return 1
    print("\nall crosschecks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
