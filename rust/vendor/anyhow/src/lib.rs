//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build is fully offline (no crates.io access), so the error-handling
//! surface the crate actually uses is reimplemented here: `Error`,
//! `Result<T>`, the `anyhow!` / `bail!` / `ensure!` macros and the
//! `Context` extension trait for `Result` and `Option`.  Errors carry a
//! pre-rendered message chain (context frames prepended, sources appended),
//! which is all the callers ever format (`{e}`, `{e:#}`, `{e:?}`).
//!
//! Not implemented (unused by this repo): downcasting, backtraces,
//! `Error::chain`, custom error types via `#[derive(Error)]`.

use std::fmt;

/// A rendered error: the full message chain as one string.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context frame, anyhow-style (`context: cause`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts into `Error`, with its source chain flattened into
// the message (this is what powers `?` on io/parse/utf8 errors).  `Error`
// itself deliberately does not implement `std::error::Error`, so this
// blanket impl cannot conflict with the identity `From<Error>`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut source = e.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`, mirroring anyhow's.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (inline captures work because the
/// literal arm forwards to `format!`) or from any displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path/3141").context("reading config")?;
        Ok(())
    }

    #[test]
    fn question_mark_and_context() {
        let e = fails_io().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "), "{e}");
    }

    #[test]
    fn macros_format() {
        let x = 7;
        assert_eq!(anyhow!("x = {x}").to_string(), "x = 7");
        assert_eq!(anyhow!("x = {}", x + 1).to_string(), "x = 8");
        fn b() -> Result<()> {
            bail!("boom {}", 2)
        }
        assert_eq!(b().unwrap_err().to_string(), "boom 2");
        fn e(v: usize) -> Result<usize> {
            ensure!(v < 10, "too big: {v}");
            Ok(v)
        }
        assert_eq!(e(3).unwrap(), 3);
        assert_eq!(e(30).unwrap_err().to_string(), "too big: 30");
    }

    #[test]
    fn option_context() {
        let v: Option<usize> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }
}
