//! API-compatible stub of the `xla` PJRT binding used by `tomers::runtime`.
//!
//! The build environment is fully offline, so the real PJRT binding (which
//! needs a libxla build) cannot be fetched.  This stub provides the exact
//! type/method surface `runtime::engine` compiles against; every entry
//! point fails at *runtime* with a clear message, so `cargo build
//! --features pjrt` and `cargo test --features pjrt` link fine and the
//! engine-dependent paths report "PJRT unavailable" instead of breaking the
//! build.
//!
//! To run against real hardware, replace this directory with the actual
//! binding (same package name) or patch it in `rust/Cargo.toml`:
//!
//! ```toml
//! [dependencies]
//! xla = { git = "https://github.com/LaurentMazare/xla-rs", optional = true }
//! ```

use std::path::Path;

/// Stub error: a plain message, `Debug`-formatted by the engine.
#[derive(Debug, Clone)]
pub struct Error(pub String);

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: xla stub build — replace rust/vendor/xla with a real PJRT binding"
    )))
}

/// Element types the engine dispatches on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U8,
    F64,
    Pred,
}

/// Marker for host buffer element types accepted by PJRT transfers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for u8 {}
impl NativeType for f64 {}

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Shape;

impl Shape {
    pub fn is_tuple(&self) -> bool {
        false
    }
}

pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }

    pub fn ty(&self) -> ElementType {
        ElementType::F32
    }
}

pub struct Literal;

impl Literal {
    pub fn shape(&self) -> Result<Shape, Error> {
        unavailable("Literal::shape")
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        unavailable("Literal::array_shape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}
