//! Routing-decision cost benchmarks: the merge-policy planner runs once
//! per incoming request on the serving executor thread, so its cost must
//! stay far below one model execution (~10ms+).
//!
//! Compares the legacy uncached full-context decide against the
//! bounded-prefix + memoized `decide_cached` path the server now uses.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use tomers::coordinator::policy::Variant;
use tomers::coordinator::{EntropyCache, MergePolicy};
use tomers::util::{bench, Rng};

fn main() {
    println!("== bench: merge-policy routing decision ==");
    let policy = MergePolicy::uniform(
        vec![
            Variant::fixed("chronos_s__r0", 0),
            Variant::fixed("chronos_s__r32", 32),
            Variant::fixed("chronos_s__r128", 128),
        ],
        3.0,
        7.5,
    );
    let mut rng = Rng::new(2);
    println!(
        "{:<10} {:>14} {:>16} {:>14}",
        "context", "uncached", "prefix(no-memo)", "memo-hit"
    );
    for &n in &[512usize, 1000, 4096, 16000] {
        let ctx: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

        // legacy: full-length FFT per request (Bluestein for non-pow2)
        let (full_s, _) = bench(5, 50, || {
            let _ = policy.decide(&ctx);
        });

        // bounded prefix, memoization disabled (capacity 0): the cost of a
        // cache miss
        let mut miss_cache = EntropyCache::new(0, 512);
        let (miss_s, _) = bench(5, 50, || {
            let _ = policy.decide_cached(&mut miss_cache, &ctx);
        });

        // warm cache: the steady-state serving cost for repeated contexts
        let mut hit_cache = EntropyCache::new(64, 512);
        let _ = policy.decide_cached(&mut hit_cache, &ctx);
        let (hit_s, _) = bench(5, 200, || {
            let _ = policy.decide_cached(&mut hit_cache, &ctx);
        });

        println!(
            "n={:<8} {:>12.1}us {:>14.1}us {:>12.1}us",
            n,
            full_s * 1e6,
            miss_s * 1e6,
            hit_s * 1e6
        );
    }
    println!("\nexpected shape: prefix decide is flat in n (bounded FFT); memo-hit is");
    println!("hash-only. uncached grows with n and spikes on non-power-of-two lengths.");
}
