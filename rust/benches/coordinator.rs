//! Coordinator benchmarks: batcher + policy hot paths and the served
//! throughput of the full stack (policy -> batch -> PJRT -> dequantize).

use std::time::Duration;

use tomers::coordinator::{
    self, policy::Variant, BatcherConfig, DynamicBatcher, ForecastRequest, MergePolicy,
    ServerConfig,
};
use tomers::data;
use tomers::util::{bench, Rng};

fn main() {
    println!("== bench: coordinator ==");

    // policy decision cost (spectral entropy on one 512-context)
    let policy = MergePolicy::uniform(
        vec![
            Variant { name: "chronos_s__r0".into(), r: 0 },
            Variant { name: "chronos_s__r32".into(), r: 32 },
            Variant { name: "chronos_s__r128".into(), r: 128 },
        ],
        3.0,
        7.5,
    );
    let series = data::generate(data::profile("ettm1").unwrap(), 512, 7).column(0);
    let (mean, std) = bench(10, 100, || {
        let _ = policy.decide(&series);
    });
    println!("policy.decide(512)          {:>10.1}us {:>8.1}us", mean * 1e6, std * 1e6);

    // batcher push/drain throughput
    let (mean, _) = bench(3, 20, || {
        let mut b: DynamicBatcher<u64> = DynamicBatcher::new(BatcherConfig {
            capacity: 8,
            max_wait: Duration::from_millis(1000),
            max_queue: 100_000,
        });
        for i in 0..10_000u64 {
            let _ = b.push(i);
            if b.ready(std::time::Instant::now()) {
                let _ = b.drain_batch();
            }
        }
        while !b.is_empty() {
            let _ = b.drain_batch();
        }
    });
    println!("batcher 10k push+drain      {:>10.2}ms", mean * 1e3);

    // full serving stack throughput (needs artifacts)
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("chronos_s__r0.hlo.txt").exists() {
        println!("serving bench: SKIP (run `make artifacts`)");
        return;
    }
    let handle = coordinator::server::serve(ServerConfig {
        artifact_dir: dir,
        policy,
        max_wait: Duration::from_millis(10),
        max_queue: 8192,
    })
    .expect("server");
    let client = handle.client();
    let mut rng = Rng::new(11);
    let n = 160;
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = (0..n as u64)
        .map(|id| {
            let profile = if id % 2 == 0 { "weather" } else { "ettm1" };
            let s = data::generate(data::profile(profile).unwrap(), 512, rng.next_u64());
            client.submit(ForecastRequest { id, context: s.column(0) }).unwrap()
        })
        .collect();
    for rx in pending {
        let _ = rx.recv();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("served {n} requests in {:.2}s ({:.1} req/s)", dt, n as f64 / dt);
    println!("{}", client.metrics_report().unwrap());
    handle.shutdown().unwrap();
}
