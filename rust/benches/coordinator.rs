//! Coordinator benchmarks: batcher + policy hot paths, the staged
//! merge-while-execute pipeline vs the PR 1 serial loop, and (with the
//! `pjrt` feature + artifacts) the served throughput of the full stack.
//!
//! The staged-pipeline section drives the *real* serving machinery
//! (`coordinator::pipeline::run_stages`: prep thread, double-buffered
//! slabs, pool-backed premerge) with a synthetic device stage — a
//! deterministic arithmetic spin standing in for `model.execute` — so the
//! host-merge/device-execute overlap is measurable in the default offline
//! build.  The serial baseline runs the identical prep + execute work on
//! one thread.  Writes `BENCH_serving.json`:
//!
//! ```json
//! {
//!   "schema_version": 1, "bench": "serving", "quick": false,
//!   "pool_workers": 2, "capacity": 8, "m": 512, "ctx_len": 2048,
//!   "rows": [
//!     { "ratio": 1.0,          // target exec:prep cost ratio
//!       "reps": 80,            // spin reps realizing it
//!       "prep_ms": 0.0, "exec_ms": 0.0,     // measured single-shot costs
//!       "requests": 320, "serial_s": 0.0, "staged_s": 0.0,
//!       "serial_rps": 0.0, "staged_rps": 0.0,
//!       "overlap_gain": 0.0 }  // staged_rps / serial_rps - 1
//!   ]
//! }
//! ```
//!
//! Acceptance (scripts/verify.sh): the balanced row (`ratio == 1`) must
//! show `staged_rps > serial_rps` — if overlapping prep with execution is
//! not faster than alternating them, the pipeline is broken.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use tomers::coordinator::pipeline::{self, Pending, PrepJob, VariantMeta};
use tomers::coordinator::{
    policy::Variant, BatcherConfig, DynamicBatcher, FaultContext, ForecastOutcome,
    ForecastRequest, ForecastResponse, MergePolicy, Metrics,
};
use tomers::data;
use tomers::json::Json;
use tomers::merging::MergeSpec;
use tomers::runtime::WorkerPool;
use tomers::util::{bench, Rng};

const VARIANT: &str = "sim__r0";
const HORIZON: usize = 64;

/// Deterministic stand-in for `model.execute`: `reps` passes of a
/// multiply-accumulate over the slab.
fn device_work(slab: &[f32], reps: usize) -> f32 {
    let mut acc = 0.0f32;
    for rep in 0..reps {
        let scale = 1.0 + (rep % 7) as f32 * 1e-3;
        let mut s = 0.0f32;
        for (i, &v) in slab.iter().enumerate() {
            s += v * (((i & 63) as f32) * 1e-2 + scale);
        }
        acc += s;
    }
    std::hint::black_box(acc)
}

/// `n_batches` full batches of premerge-length contexts, plus the response
/// receivers to drain afterwards.
fn make_jobs(
    n_batches: usize,
    capacity: usize,
    ctx_len: usize,
    seed: u64,
) -> (Vec<PrepJob>, Vec<mpsc::Receiver<ForecastResponse>>) {
    let mut rng = Rng::new(seed);
    let mut jobs = Vec::with_capacity(n_batches);
    let mut receivers = Vec::with_capacity(n_batches * capacity);
    let mut id = 0u64;
    for _ in 0..n_batches {
        let mut batch: Vec<Pending> = Vec::with_capacity(capacity);
        for _ in 0..capacity {
            let profile = if id % 2 == 0 { "weather" } else { "ettm1" };
            let series = data::generate(data::profile(profile).unwrap(), ctx_len, rng.next_u64());
            let (rtx, rrx) = mpsc::channel();
            batch.push((
                ForecastRequest { id, context: series.column(0) },
                Instant::now(),
                rtx,
            ));
            receivers.push(rrx);
            id += 1;
        }
        jobs.push(PrepJob { variant: VARIANT.to_string(), batch });
    }
    (jobs, receivers)
}

fn forecast_rows(rows: usize) -> Vec<Vec<f32>> {
    (0..rows).map(|_| vec![0.0f32; HORIZON]).collect()
}

fn staged_vs_serial(
    pool: &'static WorkerPool,
    meta: &VariantMeta,
    merge_cfg: &MergeSpec,
    ctx_len: usize,
    n_batches: usize,
    reps: usize,
    seed: u64,
) -> (f64, f64) {
    let metas: BTreeMap<String, VariantMeta> =
        [(VARIANT.to_string(), meta.clone())].into_iter().collect();

    // -- serial baseline: prep and execute alternate on one thread, with
    // the same pool-backed premerge parallelism production uses ----------
    let (jobs, receivers) = make_jobs(n_batches, meta.capacity, ctx_len, seed);
    let mut hp = pipeline::HostPrep::new(pool.workers(), merge_cfg.clone());
    let mut slab = Vec::new();
    let t0 = Instant::now();
    for job in jobs {
        hp.prep_into(pool, &job.batch, meta, &mut slab).expect("serial prep");
        device_work(&slab, reps);
        let rows = forecast_rows(job.batch.len());
        for ((req, tq, rtx), forecast) in job.batch.into_iter().zip(rows) {
            let _ = rtx.send(ForecastResponse {
                id: req.id,
                forecast,
                variant: VARIANT.to_string(),
                latency: tq.elapsed().as_secs_f64(),
                batch_size: meta.capacity,
                outcome: ForecastOutcome::Delivered,
            });
        }
    }
    let serial_s = t0.elapsed().as_secs_f64();
    let served = receivers.iter().filter(|rx| rx.recv().is_ok()).count();
    assert_eq!(served, n_batches * meta.capacity, "serial run dropped requests");

    // -- staged: identical work through run_stages (prep overlaps exec) --
    let (jobs, receivers) = make_jobs(n_batches, meta.capacity, ctx_len, seed);
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(2);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let t0 = Instant::now();
    let feeder = std::thread::spawn(move || {
        for job in jobs {
            if jobs_tx.send(job).is_err() {
                return;
            }
        }
    });
    pipeline::run_stages(
        jobs_rx,
        metas,
        merge_cfg.clone(),
        pool.workers(), // prep parallelism as the real server configures it
        pool,
        Arc::clone(&metrics),
        FaultContext::default(),
        |ready| {
            device_work(&ready.slab, reps);
            Ok(forecast_rows(ready.rows))
        },
    )
    .expect("staged run");
    let staged_s = t0.elapsed().as_secs_f64();
    tomers::util::join_annotated(feeder, "bench feeder").expect("feeder");
    let served = receivers.iter().filter(|rx| rx.recv().is_ok()).count();
    assert_eq!(served, n_batches * meta.capacity, "staged run dropped requests");

    (serial_s, staged_s)
}

fn main() {
    let quick = std::env::var("TOMERS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let out_path = std::env::var("TOMERS_BENCH_SERVING_OUT")
        .unwrap_or_else(|_| "BENCH_serving.json".to_string());
    println!("== bench: coordinator ==");

    // policy decision cost (spectral entropy on one 512-context)
    let policy = MergePolicy::uniform(
        vec![
            Variant::fixed("chronos_s__r0", 0),
            Variant::fixed("chronos_s__r32", 32),
            Variant::fixed("chronos_s__r128", 128),
        ],
        3.0,
        7.5,
    );
    let series = data::generate(data::profile("ettm1").unwrap(), 512, 7).column(0);
    let (mean, std) = bench(10, 100, || {
        let _ = policy.decide(&series);
    });
    println!("policy.decide(512)          {:>10.1}us {:>8.1}us", mean * 1e6, std * 1e6);

    // batcher push/drain throughput
    let (mean, _) = bench(3, 20, || {
        let mut b: DynamicBatcher<u64> = DynamicBatcher::new(BatcherConfig {
            capacity: 8,
            max_wait: Duration::from_millis(1000),
            max_queue: 100_000,
        });
        for i in 0..10_000u64 {
            let _ = b.push(i);
            if b.ready(std::time::Instant::now()) {
                let _ = b.drain_batch();
            }
        }
        while !b.is_empty() {
            let _ = b.drain_batch();
        }
    });
    println!("batcher 10k push+drain      {:>10.2}ms", mean * 1e3);

    // -- staged pipeline vs serial loop (synthetic device) ---------------
    let pool = WorkerPool::global();
    let meta = VariantMeta { capacity: 8, m: 512 };
    let merge_cfg = MergeSpec::fixed_r(Vec::new(), 8); // schedule derived per shape
    let ctx_len = 2048; // premerged 2048 -> 1024 -> 512 on the pool
    let n_batches = if quick { 8 } else { 40 };

    // Calibrate the synthetic device against the measured prep cost
    // (pool-parallel premerge, exactly as the measured runs do it).
    let (cal_jobs, _cal_rx) = make_jobs(1, meta.capacity, ctx_len, 99);
    let mut hp = pipeline::HostPrep::new(pool.workers(), merge_cfg.clone());
    let mut slab = Vec::new();
    let (prep_s, _) = bench(2, if quick { 5 } else { 15 }, || {
        hp.prep_into(pool, &cal_jobs[0].batch, &meta, &mut slab).expect("cal prep");
    });
    let (one_rep_s, _) = bench(2, if quick { 5 } else { 15 }, || {
        device_work(&slab, 1);
    });
    println!(
        "prep(8x{ctx_len}->512)        {:>10.2}ms   device rep {:>8.1}us",
        prep_s * 1e3,
        one_rep_s * 1e6
    );

    let ratios: &[f64] = if quick { &[1.0] } else { &[1.0, 4.0] };
    let mut rows = Vec::new();
    for &ratio in ratios {
        let reps = ((prep_s * ratio / one_rep_s.max(1e-9)).round() as usize).max(1);
        let (serial_s, staged_s) =
            staged_vs_serial(pool, &meta, &merge_cfg, ctx_len, n_batches, reps, 17);
        let requests = (n_batches * meta.capacity) as f64;
        let serial_rps = requests / serial_s.max(1e-9);
        let staged_rps = requests / staged_s.max(1e-9);
        let gain = staged_rps / serial_rps.max(1e-9) - 1.0;
        println!(
            "serving ratio={ratio:<4} reps={reps:<5} serial {serial_rps:>8.1} req/s   staged \
             {staged_rps:>8.1} req/s   overlap {:+.1}%",
            gain * 100.0
        );
        rows.push(Json::obj(vec![
            ("ratio", Json::num(ratio)),
            ("reps", Json::num(reps as f64)),
            ("prep_ms", Json::num(prep_s * 1e3)),
            ("exec_ms", Json::num(one_rep_s * reps as f64 * 1e3)),
            ("requests", Json::num(requests)),
            ("serial_s", Json::num(serial_s)),
            ("staged_s", Json::num(staged_s)),
            ("serial_rps", Json::num(serial_rps)),
            ("staged_rps", Json::num(staged_rps)),
            ("overlap_gain", Json::num(gain)),
        ]));
    }
    let report = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("bench", Json::str("serving")),
        ("quick", Json::Bool(quick)),
        ("pool_workers", Json::num(pool.workers() as f64)),
        ("capacity", Json::num(meta.capacity as f64)),
        ("m", Json::num(meta.m as f64)),
        ("ctx_len", Json::num(ctx_len as f64)),
        ("rows", Json::arr(rows)),
    ]);
    match std::fs::write(&out_path, report.to_string_pretty()) {
        Ok(()) => println!("serving record -> {out_path}"),
        Err(e) => eprintln!("WARN: could not write {out_path}: {e}"),
    }
    println!("expected shape: staged > serial at ratio 1 (full overlap headroom);");
    println!("the gain shrinks as the device dominates (ratio 4).");

    // -- full serving stack throughput (needs pjrt + artifacts) ----------
    #[cfg(feature = "pjrt")]
    real_stack(policy);
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = policy;
        println!("real serving stack: SKIP (built without the pjrt feature)");
    }
}

#[cfg(feature = "pjrt")]
fn real_stack(policy: MergePolicy) {
    use tomers::coordinator::{self, ServerConfig};

    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("chronos_s__r0.hlo.txt").exists() {
        println!("real serving stack: SKIP (run `make artifacts`)");
        return;
    }
    let handle = coordinator::server::serve(ServerConfig {
        artifact_dir: dir,
        policy,
        max_wait: Duration::from_millis(10),
        max_queue: 8192,
        merge_workers: 0,
        merge: tomers::coordinator::default_host_merge(),
        streaming: None,
        prefer_manifest_spec: true,
        faults: tomers::coordinator::FaultPolicy::default(),
    })
    .expect("server");
    let client = handle.client();
    let mut rng = Rng::new(11);
    let n = 160;
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = (0..n as u64)
        .map(|id| {
            let profile = if id % 2 == 0 { "weather" } else { "ettm1" };
            let s = data::generate(data::profile(profile).unwrap(), 512, rng.next_u64());
            client.submit(ForecastRequest { id, context: s.column(0) }).unwrap()
        })
        .collect();
    for rx in pending {
        let _ = rx.recv();
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("served {n} requests in {:.2}s ({:.1} req/s)", dt, n as f64 / dt);
    println!("{}", client.metrics_report().unwrap());
    handle.shutdown().unwrap();
}
