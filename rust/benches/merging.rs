//! Merging kernel benchmarks: legacy scalar reference vs the optimized
//! zero-allocation kernel vs the batched [`MergePlan`] path — with the
//! plan measured twice: on the persistent [`WorkerPool`] (the production
//! path, `run_batch_into`) and through the PR 1 `thread::scope` fan-out
//! (`run_batch_into_scoped`, the baseline the pool must beat or match,
//! since it does strictly less work per call).
//!
//! PR 7 adds two single-thread contrasts per case:
//! * `simd_vs_scalar` — the dispatched explicit-SIMD kernel vs the same
//!   kernel forced through the scalar path (`simd::force_scalar`), p50
//!   over p50.  On scalar-only hosts both runs take the same path, so
//!   the ratio sits at ~1.0 and verify.sh skips its gate with a WARN.
//! * `blocked_vs_streaming` — the cache-blocked matching walk at the
//!   default [`matching_tile`] vs `tile = usize::MAX` (the pre-blocking
//!   two-pass norms-then-scores walk over the whole slab).
//!
//! Offline build: hand-rolled harness (no criterion crate available);
//! run with `cargo bench --offline --bench merging`.
//!
//! Writes a machine-readable `BENCH_merging.json` (schema v4, documented
//! in `src/merging/mod.rs`) so the kernel's perf trajectory accumulates
//! across PRs; `scripts/verify.sh` gates on the acceptance case
//! `t=8192 d=64 k=16` keeping `speedup_batched >= 3` (the pool-backed
//! plan), on `post_warmup_spawns == 0` — the pool's entire point is
//! that steady state spawns no threads — and on the `t=4096 d=64` case
//! keeping `simd_vs_scalar >= MIN_SIMD_SPEEDUP` when a SIMD ISA is
//! dispatched.
//!
//! Env knobs:
//! * `TOMERS_BENCH_QUICK=1` — few iterations, acceptance cases only
//!   (the CI smoke used by scripts/verify.sh)
//! * `TOMERS_BENCH_OUT=path` — where to write the JSON (default
//!   `BENCH_merging.json` in the package root)

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use tomers::json::Json;
use tomers::merging::kernel::{match_tokens_scratch_tiled, matching_tile, merge_fixed_r_scratch};
use tomers::merging::simd;
use tomers::merging::{
    reference, Accum, MergeResult, MergeScratch, MergeSpec, PipelineResult,
};
use tomers::runtime::WorkerPool;
use tomers::util::{bench, bench_samples, percentile, Rng};

struct Case {
    t: usize,
    d: usize,
    k: usize,
    batch: usize,
    iters: usize,
}

fn main() {
    let quick = std::env::var("TOMERS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("TOMERS_BENCH_OUT").unwrap_or_else(|_| "BENCH_merging.json".to_string());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let pool = WorkerPool::global();
    let isa = simd::active_isa();

    // The verify.sh acceptance cases — t=8192 d=64 k=16 b=4 (kernel
    // speedup), same shape b=32 (pool vs scope), and t=4096 d=64 k=16
    // (simd_vs_scalar) — are always present.
    let cases: Vec<Case> = if quick {
        vec![
            Case { t: 8192, d: 64, k: 16, batch: 4, iters: 3 },
            // more samples: the pool-vs-scope p50 gate needs a stable median
            Case { t: 8192, d: 64, k: 16, batch: 32, iters: 7 },
            // the MIN_SIMD_SPEEDUP acceptance shape; the single-thread
            // simd-vs-scalar p50 gate also wants a stable median
            Case { t: 4096, d: 64, k: 16, batch: 4, iters: 7 },
        ]
    } else {
        vec![
            Case { t: 512, d: 64, k: 1, batch: 8, iters: 20 },
            Case { t: 2048, d: 64, k: 16, batch: 8, iters: 10 },
            Case { t: 4096, d: 64, k: 16, batch: 4, iters: 7 },
            Case { t: 8192, d: 64, k: 16, batch: 8, iters: 5 },
            Case { t: 8192, d: 64, k: 16, batch: 32, iters: 5 },
            Case { t: 8192, d: 64, k: 1, batch: 8, iters: 5 },
            Case { t: 16000, d: 64, k: 16, batch: 4, iters: 3 },
        ]
    };

    println!(
        "== bench: merging (legacy vs optimized vs MergePlan pool/scope; {threads} threads, \
         pool={} workers, isa={} [{}]) ==",
        pool.workers(),
        isa.name(),
        simd::cpu_features()
    );
    println!(
        "{:<22} {:>11} {:>11} {:>11} {:>11} {:>7} {:>7} {:>7} {:>7}",
        "case", "legacy", "optimized", "pool", "scope", "x-opt", "x-pool", "x-simd", "x-blk"
    );

    let mut rng = Rng::new(1);
    let mut rows: Vec<Json> = Vec::new();

    // Warm the pool once, then require zero spawns across all timed work.
    pool.run((0..pool.workers()).map(|_| || {}).collect::<Vec<_>>());
    let spawns_before = pool.spawned_threads();

    for case in &cases {
        let (t, d, k, b) = (case.t, case.d, case.k, case.batch);
        let r = t / 4;
        let spec = MergeSpec::single(r, k);
        let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
        let sizes = vec![1.0f32; b * t];

        // legacy scalar path over the whole batch
        let (legacy_s, _) = bench(1, case.iters, || {
            for i in 0..b {
                let _ = reference::merge_fixed_r_reference(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                );
            }
        });

        // optimized kernel, warm scratch, single thread (the plan's inner
        // loop, measured without the batching layer)
        let mut scratch = MergeScratch::with_capacity(t, d);
        let mut out = MergeResult::default();
        let mut single_batch = |scr: &mut MergeScratch, res: &mut MergeResult| {
            for i in 0..b {
                merge_fixed_r_scratch(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                    scr,
                    res,
                );
            }
        };
        let (opt_s, _) = bench(1, case.iters, || single_batch(&mut scratch, &mut out));

        // the same single-thread work, dispatched ISA vs forced scalar
        // (identical code both runs — only the dispatch differs)
        let mut simd_samples =
            bench_samples(1, case.iters, || single_batch(&mut scratch, &mut out));
        simd::force_scalar(true);
        let mut scalar_samples =
            bench_samples(1, case.iters, || single_batch(&mut scratch, &mut out));
        simd::force_scalar(false);
        let simd_p50 = percentile(&mut simd_samples, 50.0);
        let scalar_p50 = percentile(&mut scalar_samples, 50.0);
        let x_simd = scalar_p50 / simd_p50.max(1e-12);

        // matching stage only: cache-blocked default tile vs the
        // pre-blocking streaming walk (tile = MAX, bitwise identical)
        let mut blocked_samples = bench_samples(1, case.iters, || {
            for i in 0..b {
                match_tokens_scratch_tiled(
                    &tokens[i * t * d..(i + 1) * t * d],
                    t,
                    d,
                    k,
                    &mut scratch,
                    Accum::F64,
                    matching_tile(d),
                );
            }
        });
        let mut streaming_samples = bench_samples(1, case.iters, || {
            for i in 0..b {
                match_tokens_scratch_tiled(
                    &tokens[i * t * d..(i + 1) * t * d],
                    t,
                    d,
                    k,
                    &mut scratch,
                    Accum::F64,
                    usize::MAX,
                );
            }
        });
        let blocked_p50 = percentile(&mut blocked_samples, 50.0);
        let streaming_p50 = percentile(&mut streaming_samples, 50.0);
        let x_blk = streaming_p50 / blocked_p50.max(1e-12);

        // compiled plan, batched on the persistent pool (production path)
        let mut plan = spec
            .compile(t, d)
            .expect("bench spec compiles")
            .with_default_parallelism();
        let mut outs: Vec<PipelineResult> = Vec::new();
        let mut pool_samples = bench_samples(1, case.iters, || {
            plan.run_batch_into(pool, &tokens, &sizes, b, &mut outs);
        });
        let pool_s = pool_samples.iter().sum::<f64>() / pool_samples.len() as f64;
        let pool_p50 = percentile(&mut pool_samples, 50.0);

        // the same plan through the PR 1 thread::scope fan-out (baseline)
        let mut scope_samples = bench_samples(1, case.iters, || {
            plan.run_batch_into_scoped(&tokens, &sizes, b, &mut outs);
        });
        let scope_s = scope_samples.iter().sum::<f64>() / scope_samples.len() as f64;
        let scope_p50 = percentile(&mut scope_samples, 50.0);

        let x_opt = legacy_s / opt_s.max(1e-12);
        let x_pool = legacy_s / pool_s.max(1e-12);
        println!(
            "t={:<6} k={:<4} b={:<3} {:>9.3}ms {:>9.3}ms {:>9.3}ms {:>9.3}ms {:>6.2}x {:>6.2}x {:>6.2}x {:>6.2}x",
            t,
            k,
            b,
            legacy_s * 1e3,
            opt_s * 1e3,
            pool_s * 1e3,
            scope_s * 1e3,
            x_opt,
            x_pool,
            x_simd,
            x_blk,
        );

        rows.push(Json::obj(vec![
            ("t", Json::num(t as f64)),
            ("d", Json::num(d as f64)),
            ("k", Json::num(k as f64)),
            ("r", Json::num(r as f64)),
            ("batch", Json::num(b as f64)),
            ("legacy_ms", Json::num(legacy_s * 1e3)),
            ("optimized_ms", Json::num(opt_s * 1e3)),
            ("batched_ms", Json::num(pool_s * 1e3)),
            ("batched_p50_ms", Json::num(pool_p50 * 1e3)),
            ("batched_scope_ms", Json::num(scope_s * 1e3)),
            ("batched_scope_p50_ms", Json::num(scope_p50 * 1e3)),
            ("speedup_optimized", Json::num(x_opt)),
            ("speedup_batched", Json::num(x_pool)),
            ("simd_p50_ms", Json::num(simd_p50 * 1e3)),
            ("scalar_p50_ms", Json::num(scalar_p50 * 1e3)),
            ("simd_vs_scalar", Json::num(x_simd)),
            ("blocked_p50_ms", Json::num(blocked_p50 * 1e3)),
            ("streaming_p50_ms", Json::num(streaming_p50 * 1e3)),
            ("blocked_vs_streaming", Json::num(x_blk)),
        ]));
    }

    let post_warmup_spawns = pool.spawned_threads() - spawns_before;
    println!(
        "\npool: workers={} post-warmup spawns={} steals={} tasks={}",
        pool.workers(),
        post_warmup_spawns,
        pool.steals(),
        pool.tasks_executed()
    );
    println!("kernel: {}", simd::dispatch_report());

    let report = Json::obj(vec![
        ("schema_version", Json::num(4.0)),
        ("bench", Json::str("merging")),
        ("quick", Json::Bool(quick)),
        ("threads", Json::num(threads as f64)),
        ("pool_workers", Json::num(pool.workers() as f64)),
        ("isa", Json::str(isa.name())),
        ("cpu_features", Json::str(&simd::cpu_features())),
        ("post_warmup_spawns", Json::num(post_warmup_spawns as f64)),
        ("pool_steals", Json::num(pool.steals() as f64)),
        ("cases", Json::arr(rows)),
    ]);
    match std::fs::write(&out_path, report.to_string_pretty()) {
        Ok(()) => println!("\nperf record -> {out_path}"),
        Err(e) => eprintln!("\nWARN: could not write {out_path}: {e}"),
    }
    println!("expected shape: optimized >= 3x legacy on the banded cases; pool p50 <=");
    println!("scope p50 at b=32 (no per-call spawns); simd >= 1.5x forced-scalar at");
    println!("t=4096 d=64 on SIMD hosts; local k=1 ~linear in t, global ~t^2.");
}
