//! Merging kernel benchmarks: legacy scalar reference vs the optimized
//! zero-allocation kernel vs the batched [`MergePlan`] path — with the
//! plan measured twice: on the persistent [`WorkerPool`] (the production
//! path, `run_batch_into`) and through the PR 1 `thread::scope` fan-out
//! (`run_batch_into_scoped`, the baseline the pool must beat or match,
//! since it does strictly less work per call).
//!
//! Offline build: hand-rolled harness (no criterion crate available);
//! run with `cargo bench --offline --bench merging`.
//!
//! Writes a machine-readable `BENCH_merging.json` (schema v3, documented
//! in `src/merging/mod.rs`) so the kernel's perf trajectory accumulates
//! across PRs; `scripts/verify.sh` gates on the acceptance case
//! `t=8192 d=64 k=16` keeping `speedup_batched >= 3` (the pool-backed
//! plan) and on `post_warmup_spawns == 0` — the pool's entire point is
//! that steady state spawns no threads.
//!
//! Env knobs:
//! * `TOMERS_BENCH_QUICK=1` — few iterations, acceptance cases only
//!   (the CI smoke used by scripts/verify.sh)
//! * `TOMERS_BENCH_OUT=path` — where to write the JSON (default
//!   `BENCH_merging.json` in the package root)

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use tomers::json::Json;
use tomers::merging::kernel::merge_fixed_r_scratch;
use tomers::merging::{
    reference, MergeResult, MergeScratch, MergeSpec, PipelineResult,
};
use tomers::runtime::WorkerPool;
use tomers::util::{bench, bench_samples, percentile, Rng};

struct Case {
    t: usize,
    d: usize,
    k: usize,
    batch: usize,
    iters: usize,
}

fn main() {
    let quick = std::env::var("TOMERS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("TOMERS_BENCH_OUT").unwrap_or_else(|_| "BENCH_merging.json".to_string());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let pool = WorkerPool::global();

    // The verify.sh acceptance case (t=8192, d=64, k=16, b=4) and the
    // pool-vs-scope acceptance case (same shape, b=32) are always present.
    let cases: Vec<Case> = if quick {
        vec![
            Case { t: 8192, d: 64, k: 16, batch: 4, iters: 3 },
            // more samples: the pool-vs-scope p50 gate needs a stable median
            Case { t: 8192, d: 64, k: 16, batch: 32, iters: 7 },
        ]
    } else {
        vec![
            Case { t: 512, d: 64, k: 1, batch: 8, iters: 20 },
            Case { t: 2048, d: 64, k: 16, batch: 8, iters: 10 },
            Case { t: 8192, d: 64, k: 16, batch: 8, iters: 5 },
            Case { t: 8192, d: 64, k: 16, batch: 32, iters: 5 },
            Case { t: 8192, d: 64, k: 1, batch: 8, iters: 5 },
            Case { t: 16000, d: 64, k: 16, batch: 4, iters: 3 },
        ]
    };

    println!(
        "== bench: merging (legacy vs optimized vs MergePlan pool/scope; {threads} threads, \
         pool={} workers) ==",
        pool.workers()
    );
    println!(
        "{:<22} {:>11} {:>11} {:>11} {:>11} {:>7} {:>7} {:>13}",
        "case", "legacy", "optimized", "pool", "scope", "x-opt", "x-pool", "sim-ops(eq.2)"
    );

    let mut rng = Rng::new(1);
    let mut rows: Vec<Json> = Vec::new();

    // Warm the pool once, then require zero spawns across all timed work.
    pool.run((0..pool.workers()).map(|_| || {}).collect::<Vec<_>>());
    let spawns_before = pool.spawned_threads();

    for case in &cases {
        let (t, d, k, b) = (case.t, case.d, case.k, case.batch);
        let r = t / 4;
        let spec = MergeSpec::single(r, k);
        let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
        let sizes = vec![1.0f32; b * t];

        // legacy scalar path over the whole batch
        let (legacy_s, _) = bench(1, case.iters, || {
            for i in 0..b {
                let _ = reference::merge_fixed_r_reference(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                );
            }
        });

        // optimized kernel, warm scratch, single thread (the plan's inner
        // loop, measured without the batching layer)
        let mut scratch = MergeScratch::with_capacity(t, d);
        let mut out = MergeResult::default();
        let (opt_s, _) = bench(1, case.iters, || {
            for i in 0..b {
                merge_fixed_r_scratch(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                    &mut scratch,
                    &mut out,
                );
            }
        });

        // compiled plan, batched on the persistent pool (production path)
        let mut plan = spec
            .compile(t, d)
            .expect("bench spec compiles")
            .with_default_parallelism();
        let mut outs: Vec<PipelineResult> = Vec::new();
        let mut pool_samples = bench_samples(1, case.iters, || {
            plan.run_batch_into(pool, &tokens, &sizes, b, &mut outs);
        });
        let pool_s = pool_samples.iter().sum::<f64>() / pool_samples.len() as f64;
        let pool_p50 = percentile(&mut pool_samples, 50.0);

        // the same plan through the PR 1 thread::scope fan-out (baseline)
        let mut scope_samples = bench_samples(1, case.iters, || {
            plan.run_batch_into_scoped(&tokens, &sizes, b, &mut outs);
        });
        let scope_s = scope_samples.iter().sum::<f64>() / scope_samples.len() as f64;
        let scope_p50 = percentile(&mut scope_samples, 50.0);

        let x_opt = legacy_s / opt_s.max(1e-12);
        let x_pool = legacy_s / pool_s.max(1e-12);
        println!(
            "t={:<6} k={:<4} b={:<3} {:>9.3}ms {:>9.3}ms {:>9.3}ms {:>9.3}ms {:>6.2}x {:>6.2}x {:>13}",
            t,
            k,
            b,
            legacy_s * 1e3,
            opt_s * 1e3,
            pool_s * 1e3,
            scope_s * 1e3,
            x_opt,
            x_pool,
            spec.similarity_cost(t)
        );

        rows.push(Json::obj(vec![
            ("t", Json::num(t as f64)),
            ("d", Json::num(d as f64)),
            ("k", Json::num(k as f64)),
            ("r", Json::num(r as f64)),
            ("batch", Json::num(b as f64)),
            ("legacy_ms", Json::num(legacy_s * 1e3)),
            ("optimized_ms", Json::num(opt_s * 1e3)),
            ("batched_ms", Json::num(pool_s * 1e3)),
            ("batched_p50_ms", Json::num(pool_p50 * 1e3)),
            ("batched_scope_ms", Json::num(scope_s * 1e3)),
            ("batched_scope_p50_ms", Json::num(scope_p50 * 1e3)),
            ("speedup_optimized", Json::num(x_opt)),
            ("speedup_batched", Json::num(x_pool)),
        ]));
    }

    let post_warmup_spawns = pool.spawned_threads() - spawns_before;
    println!(
        "\npool: workers={} post-warmup spawns={} steals={} tasks={}",
        pool.workers(),
        post_warmup_spawns,
        pool.steals(),
        pool.tasks_executed()
    );

    let report = Json::obj(vec![
        ("schema_version", Json::num(3.0)),
        ("bench", Json::str("merging")),
        ("quick", Json::Bool(quick)),
        ("threads", Json::num(threads as f64)),
        ("pool_workers", Json::num(pool.workers() as f64)),
        ("post_warmup_spawns", Json::num(post_warmup_spawns as f64)),
        ("pool_steals", Json::num(pool.steals() as f64)),
        ("cases", Json::arr(rows)),
    ]);
    match std::fs::write(&out_path, report.to_string_pretty()) {
        Ok(()) => println!("\nperf record -> {out_path}"),
        Err(e) => eprintln!("\nWARN: could not write {out_path}: {e}"),
    }
    println!("expected shape: optimized >= 3x legacy on the banded cases; pool p50 <=");
    println!("scope p50 at b=32 (no per-call spawns); local k=1 ~linear in t, global ~t^2.");
}
