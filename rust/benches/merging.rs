//! Merging kernel benchmarks: legacy scalar reference vs the optimized
//! zero-allocation kernel vs the thread-scoped batched path, plus the
//! eq. 2 local/global complexity crossover the paper's §5.4 overhead
//! numbers come from.
//!
//! Offline build: hand-rolled harness (no criterion crate available);
//! run with `cargo bench --offline --bench merging`.
//!
//! Writes a machine-readable `BENCH_merging.json` (schema documented in
//! `src/merging/mod.rs`) so the kernel's perf trajectory accumulates
//! across PRs; `scripts/verify.sh` gates on the acceptance case
//! `t=8192 d=64 k=16` keeping `speedup_batched >= 3` (the single-thread
//! `speedup_optimized` is printed for trend-watching, not gated).
//!
//! Env knobs:
//! * `TOMERS_BENCH_QUICK=1` — few iterations, acceptance case only
//!   (the CI smoke used by scripts/verify.sh)
//! * `TOMERS_BENCH_OUT=path` — where to write the JSON (default
//!   `BENCH_merging.json` in the package root)

use tomers::json::Json;
use tomers::merging::{reference, similarity_complexity, BatchMerger, MergeResult, MergeScratch};
use tomers::merging::kernel::merge_fixed_r_scratch;
use tomers::util::{bench, Rng};

struct Case {
    t: usize,
    d: usize,
    k: usize,
    batch: usize,
    iters: usize,
}

fn main() {
    let quick = std::env::var("TOMERS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("TOMERS_BENCH_OUT").unwrap_or_else(|_| "BENCH_merging.json".to_string());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // The acceptance case (t=8192, d=64, k=16) is always present.
    let cases: Vec<Case> = if quick {
        vec![Case { t: 8192, d: 64, k: 16, batch: 4, iters: 3 }]
    } else {
        vec![
            Case { t: 512, d: 64, k: 1, batch: 8, iters: 20 },
            Case { t: 2048, d: 64, k: 16, batch: 8, iters: 10 },
            Case { t: 8192, d: 64, k: 16, batch: 8, iters: 5 },
            Case { t: 8192, d: 64, k: 1, batch: 8, iters: 5 },
            Case { t: 16000, d: 64, k: 16, batch: 4, iters: 3 },
        ]
    };

    println!("== bench: merging (legacy scalar vs optimized vs batched; {threads} threads) ==");
    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>8} {:>8} {:>14}",
        "case", "legacy", "optimized", "batched", "x-opt", "x-batch", "sim-ops(eq.2)"
    );

    let mut rng = Rng::new(1);
    let mut rows: Vec<Json> = Vec::new();

    for case in &cases {
        let (t, d, k, b) = (case.t, case.d, case.k, case.batch);
        let r = t / 4;
        let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
        let sizes = vec![1.0f32; b * t];

        // legacy scalar path over the whole batch
        let (legacy_s, _) = bench(1, case.iters, || {
            for i in 0..b {
                let _ = reference::merge_fixed_r_reference(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                );
            }
        });

        // optimized kernel, warm scratch, single thread
        let mut scratch = MergeScratch::with_capacity(t, d);
        let mut out = MergeResult::default();
        let (opt_s, _) = bench(1, case.iters, || {
            for i in 0..b {
                merge_fixed_r_scratch(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                    &mut scratch,
                    &mut out,
                );
            }
        });

        // batched path: thread::scope across the batch, warm per-worker scratch
        let mut merger = BatchMerger::with_default_parallelism();
        let mut outs: Vec<MergeResult> = Vec::new();
        let (batch_s, _) = bench(1, case.iters, || {
            merger.merge_batch_into(&tokens, &sizes, b, t, d, r, k, &mut outs);
        });

        let x_opt = legacy_s / opt_s.max(1e-12);
        let x_batch = legacy_s / batch_s.max(1e-12);
        println!(
            "t={:<6} k={:<4} b={:<3} {:>10.3}ms {:>10.3}ms {:>10.3}ms {:>7.2}x {:>7.2}x {:>14}",
            t,
            k,
            b,
            legacy_s * 1e3,
            opt_s * 1e3,
            batch_s * 1e3,
            x_opt,
            x_batch,
            similarity_complexity(t, k)
        );

        rows.push(Json::obj(vec![
            ("t", Json::num(t as f64)),
            ("d", Json::num(d as f64)),
            ("k", Json::num(k as f64)),
            ("r", Json::num(r as f64)),
            ("batch", Json::num(b as f64)),
            ("legacy_ms", Json::num(legacy_s * 1e3)),
            ("optimized_ms", Json::num(opt_s * 1e3)),
            ("batched_ms", Json::num(batch_s * 1e3)),
            ("speedup_optimized", Json::num(x_opt)),
            ("speedup_batched", Json::num(x_batch)),
        ]));
    }

    let report = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("bench", Json::str("merging")),
        ("quick", Json::Bool(quick)),
        ("threads", Json::num(threads as f64)),
        ("cases", Json::arr(rows)),
    ]);
    match std::fs::write(&out_path, report.to_string_pretty()) {
        Ok(()) => println!("\nperf record -> {out_path}"),
        Err(e) => eprintln!("\nWARN: could not write {out_path}: {e}"),
    }
    println!("expected shape: optimized >= 3x legacy on the banded cases; batched");
    println!("scales further with cores. local k=1 stays ~linear in t, global ~t^2.");
}
