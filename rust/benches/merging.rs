//! Microbenchmarks for the Rust merging reference: the eq. 2 complexity
//! crossover (local k=1 linear vs global quadratic) measured in wall-clock,
//! matching the paper's §5.4 overhead observation (local merging adds ~14%
//! per Hyena block, global ~68%).
//!
//! Offline build: hand-rolled harness (no criterion crate available);
//! run with `cargo bench --offline`.

use tomers::merging::{merge_fixed_r, similarity_complexity};
use tomers::util::{bench, Rng};

fn main() {
    println!("== bench: merging (eq. 2 complexity in wall-clock) ==");
    println!(
        "{:<26} {:>12} {:>12} {:>14}",
        "case", "mean", "std", "sim-ops(eq.2)"
    );
    let mut rng = Rng::new(1);
    let d = 64;
    for &t in &[512usize, 2048, 8192, 16000] {
        let tokens: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let sizes = vec![1.0f32; t];
        let r = t / 4;
        for &(label, k) in &[("local k=1", 1usize), ("band k=16", 16), ("global", t / 2)] {
            // global merging at t=16000 is the quadratic case the paper
            // calls out as unusable for long sequences — keep iters low.
            let iters = if k > 1000 { 3 } else { 10 };
            let (mean, std) = bench(1, iters, || {
                let _ = merge_fixed_r(&tokens, &sizes, t, d, r, k);
            });
            println!(
                "t={:<6} {:<16} {:>10.3}ms {:>10.3}ms {:>14}",
                t,
                label,
                mean * 1e3,
                std * 1e3,
                similarity_complexity(t, k)
            );
        }
    }
    println!("\nexpected shape: local stays ~linear in t; global grows ~t^2 —");
    println!("the gap is the paper's motivation for local merging in SSMs.");
}
