//! Microbenchmarks for the signal substrate used on the serving hot path:
//! the merge-policy planner calls `spectral_entropy` per request, so its
//! cost must stay well below one model execution (~10ms+).

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use tomers::signal::{autocorrelation, gaussian_filter, power_spectrum, spectral_entropy, thd};
use tomers::util::{bench, Rng};

fn main() {
    println!("== bench: signal substrate ==");
    println!("{:<28} {:>12} {:>12}", "case", "mean", "std");
    let mut rng = Rng::new(2);
    for &n in &[512usize, 1000, 4096, 16000] {
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let cases: Vec<(&str, Box<dyn Fn()>)> = vec![
            ("power_spectrum", Box::new({
                let x = x.clone();
                move || {
                    let _ = power_spectrum(&x);
                }
            })),
            ("spectral_entropy", Box::new({
                let x = x.clone();
                move || {
                    let _ = spectral_entropy(&x);
                }
            })),
            ("thd(8)", Box::new({
                let x = x.clone();
                move || {
                    let _ = thd(&x, 8);
                }
            })),
            ("gaussian(sigma=2)", Box::new({
                let x = x.clone();
                move || {
                    let _ = gaussian_filter(&x, 2.0);
                }
            })),
            ("autocorr(64)", Box::new({
                let x = x.clone();
                move || {
                    let _ = autocorrelation(&x, 64);
                }
            })),
        ];
        for (label, f) in cases {
            let (mean, std) = bench(2, 10, || f());
            println!(
                "n={:<6} {:<20} {:>10.3}ms {:>10.3}ms",
                n,
                label,
                mean * 1e3,
                std * 1e3
            );
        }
    }
    println!("\nplanner budget: spectral_entropy at n=512 must be << 1ms.");
}
