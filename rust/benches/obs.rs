//! Observability overhead bench (DESIGN.md §13): the loopback serving
//! path from `benches/net.rs`, run twice — span recorder + stage
//! histograms ON (the default) vs the recorder disabled — plus the
//! microbenches of the two primitives that sit on every request
//! (histogram record, span record).  Writes `BENCH_obs.json`:
//!
//! * `rps_on` / `rps_off` — pipelined loopback requests/sec with the
//!   recorder enabled / disabled
//! * `overhead_pct` — `(rps_off - rps_on) / rps_off * 100`; the §13
//!   budget is <= 2% and `scripts/verify.sh` gates on it
//!   (`OBS_MAX_OVERHEAD`, default 2.0)
//!
//! Env: `TOMERS_BENCH_QUICK=1` for few iterations,
//! `TOMERS_BENCH_OBS_OUT=path` to redirect the JSON (default
//! `BENCH_obs.json` in the package root).

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::collections::BTreeMap;
use std::time::Duration;

use tomers::coordinator::{
    default_host_merge, DecodeStep, FaultPolicy, MergePolicy, ReadyBatch, Variant, VariantMeta,
};
use tomers::json::Json;
use tomers::net::{
    serve_net, NetClient, NetConfig, Request, Response, ShardSpec, DEFAULT_MAX_FRAME_BYTES,
};
use tomers::obs::{recorder, Histogram, ObsConfig, Stage};
use tomers::runtime::WorkerPool;
use tomers::streaming::StreamingConfig;
use tomers::util::bench;

const M: usize = 32;
const HORIZON: usize = 8;

/// One loopback serving run (the `benches/net.rs` end-to-end shape):
/// pipeline `n` forecasts through a 2-shard server with an instant
/// device, return requests/sec.
fn loopback_rps(n: u64) -> f64 {
    let spec = ShardSpec {
        policy: MergePolicy::fixed(Variant::fixed("v", 0)),
        metas: BTreeMap::from([("v".to_string(), VariantMeta { capacity: 4, m: M })]),
        merge: default_host_merge(),
        prep_slots: 2,
        stream_meta: VariantMeta { capacity: 4, m: 16 },
        stream_cfg: StreamingConfig { min_new: 4, d: 1, ..Default::default() },
        max_wait: Duration::from_millis(1),
        max_queue: 4096,
        faults: FaultPolicy::default(),
        obs: ObsConfig::default(),
    };
    let handle = serve_net(
        &NetConfig { shards: 2, ..NetConfig::default() },
        &spec,
        WorkerPool::global(),
        |_| {
            |ready: &mut ReadyBatch| -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(vec![vec![0.0; HORIZON]; ready.rows])
            }
        },
        |_| {
            |step: &mut DecodeStep| -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(vec![vec![0.0; HORIZON]; step.rows])
            }
        },
    )
    .expect("bench server");
    let mut c = NetClient::connect_retry(&handle.addr().to_string(), DEFAULT_MAX_FRAME_BYTES, 20)
        .expect("loopback connect");
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let context: Vec<f32> = (0..M).map(|j| ((i as usize + j) % 7) as f32 * 0.1).collect();
        c.send(&Request::Forecast { id: i, context }).unwrap();
    }
    let mut done = 0u64;
    while done < n {
        match c.recv().expect("liveness") {
            Response::Forecast { .. } => done += 1,
            other => panic!("unexpected response: {other:?}"),
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    drop(c);
    handle.shutdown().expect("drain");
    n as f64 / dt.max(1e-9)
}

fn main() {
    let quick = std::env::var("TOMERS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let out_path =
        std::env::var("TOMERS_BENCH_OBS_OUT").unwrap_or_else(|_| "BENCH_obs.json".to_string());
    println!("== bench: obs ==");

    // primitive: one histogram record (sits on every request + stage)
    let mut h = Histogram::new(-20, 7).unwrap();
    let (mean, _) = bench(5, if quick { 200 } else { 2000 }, || {
        for i in 0..1000u32 {
            h.record(1e-4 * (1.0 + i as f64));
        }
        std::hint::black_box(h.count());
    });
    println!("hist.record x1000           {:>10.2}us", mean * 1e6);

    // primitive: one span record into the global ring (sampled path)
    let cfg = ObsConfig::default();
    cfg.apply();
    let t0 = std::time::Instant::now();
    let (mean, _) = bench(5, if quick { 200 } else { 2000 }, || {
        for i in 0..1000u64 {
            recorder().record(i, Stage::Exec, 0, t0, Duration::from_micros(50), 4);
        }
    });
    println!("span.record x1000           {:>10.2}us", mean * 1e6);

    // end-to-end: the same loopback serving run, recorder on vs off.
    // Interleave a warmup so thread-pool and allocator state is identical
    // for both measured runs.
    let n: u64 = if quick { 400 } else { 2000 };
    let _ = loopback_rps(n.min(200)); // warmup
    cfg.apply(); // recorder enabled, default ring
    let rps_on = loopback_rps(n);
    recorder().configure(cfg.trace_ring, cfg.sample_every, false);
    let rps_off = loopback_rps(n);
    recorder().configure(cfg.trace_ring, cfg.sample_every, true);
    let overhead_pct = (rps_off - rps_on) / rps_off.max(1e-9) * 100.0;
    println!("loopback recorder on        {rps_on:>10.1} req/s");
    println!("loopback recorder off       {rps_off:>10.1} req/s");
    println!("recorder overhead           {overhead_pct:>10.2}%");

    let report = Json::obj(vec![
        ("bench", Json::str("obs")),
        ("schema", Json::num(1.0)),
        ("quick", Json::Bool(quick)),
        ("requests", Json::num(n as f64)),
        ("rps_on", Json::num(rps_on)),
        ("rps_off", Json::num(rps_off)),
        ("overhead_pct", Json::num(overhead_pct)),
    ]);
    match std::fs::write(&out_path, report.to_string_pretty()) {
        Ok(()) => println!("obs record -> {out_path}"),
        Err(e) => eprintln!("WARN: could not write {out_path}: {e}"),
    }
}
