//! Network-front benchmarks (DESIGN.md §12): the wire hot paths in
//! isolation — frame encode/decode and the consistent-hash router — plus
//! the end-to-end loopback throughput of the sharded server with an
//! instant synthetic device, so the wire + routing + intake overhead is
//! measurable apart from model execution.
//!
//! Expected shape: framing and routing are sub-microsecond per op (they
//! sit on every request); loopback serving lands within a small factor of
//! the in-process pipeline benches (`coordinator.rs`) — the gap *is* the
//! wire cost.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::collections::BTreeMap;
use std::time::Duration;

use tomers::coordinator::{
    default_host_merge, DecodeStep, FaultPolicy, MergePolicy, ReadyBatch, Variant, VariantMeta,
};
use tomers::net::{
    parse_request, request_to_json, serve_net, FrameDecoder, NetClient, NetConfig, Request,
    Response, ShardRouter, ShardSpec, DEFAULT_MAX_FRAME_BYTES,
};
use tomers::net::write_frame;
use tomers::obs::ObsConfig;
use tomers::runtime::WorkerPool;
use tomers::streaming::StreamingConfig;
use tomers::util::bench;

const M: usize = 32;
const HORIZON: usize = 8;

fn main() {
    let quick = std::env::var("TOMERS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    println!("== bench: net ==");

    // frame encode: serialize + length-prefix one forecast request
    let req = Request::Forecast { id: 42, context: (0..M).map(|i| i as f32 * 0.1).collect() };
    let payload = request_to_json(&req).to_string();
    let (mean, _) = bench(5, if quick { 200 } else { 2000 }, || {
        let mut buf = Vec::with_capacity(payload.len() + 4);
        write_frame(&mut buf, &payload, DEFAULT_MAX_FRAME_BYTES).unwrap();
        std::hint::black_box(&buf);
    });
    println!("frame encode ({}B)         {:>10.2}us", payload.len(), mean * 1e6);

    // frame decode + parse: the server's per-request read path
    let mut framed = Vec::new();
    write_frame(&mut framed, &payload, DEFAULT_MAX_FRAME_BYTES).unwrap();
    let (mean, _) = bench(5, if quick { 200 } else { 2000 }, || {
        let mut dec = FrameDecoder::new(DEFAULT_MAX_FRAME_BYTES);
        dec.push(&framed).unwrap();
        let p = dec.next().unwrap();
        std::hint::black_box(parse_request(&p).unwrap());
    });
    println!("frame decode+parse          {:>10.2}us", mean * 1e6);

    // router: shard_for over a 4-shard ring (binary search on 256 points)
    let router = ShardRouter::new(4).unwrap();
    let (mean, _) = bench(5, if quick { 50 } else { 500 }, || {
        let mut acc = 0usize;
        for id in 0..1000u64 {
            acc += router.shard_for(id);
        }
        std::hint::black_box(acc);
    });
    println!("router.shard_for x1000      {:>10.2}us", mean * 1e6);

    // end-to-end loopback: pipelined forecasts through 2 shards with an
    // instant device — wire + routing + intake + batching overhead
    let spec = ShardSpec {
        policy: MergePolicy::fixed(Variant::fixed("v", 0)),
        metas: BTreeMap::from([("v".to_string(), VariantMeta { capacity: 4, m: M })]),
        merge: default_host_merge(),
        prep_slots: 2,
        stream_meta: VariantMeta { capacity: 4, m: 16 },
        stream_cfg: StreamingConfig { min_new: 4, d: 1, ..Default::default() },
        max_wait: Duration::from_millis(1),
        max_queue: 4096,
        faults: FaultPolicy::default(),
        obs: ObsConfig::default(),
    };
    let handle = serve_net(
        &NetConfig { shards: 2, ..NetConfig::default() },
        &spec,
        WorkerPool::global(),
        |_| {
            |ready: &mut ReadyBatch| -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(vec![vec![0.0; HORIZON]; ready.rows])
            }
        },
        |_| {
            |step: &mut DecodeStep| -> anyhow::Result<Vec<Vec<f32>>> {
                Ok(vec![vec![0.0; HORIZON]; step.rows])
            }
        },
    )
    .expect("bench server");
    let n: u64 = if quick { 400 } else { 2000 };
    let mut c = NetClient::connect_retry(&handle.addr().to_string(), DEFAULT_MAX_FRAME_BYTES, 20)
        .expect("loopback connect");
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let context: Vec<f32> = (0..M).map(|j| ((i as usize + j) % 7) as f32 * 0.1).collect();
        c.send(&Request::Forecast { id: i, context }).unwrap();
    }
    let mut done = 0u64;
    while done < n {
        match c.recv().expect("liveness") {
            Response::Forecast { .. } => done += 1,
            other => panic!("unexpected response: {other:?}"),
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "loopback 2-shard serving    {:>10.1} req/s ({n} pipelined requests in {dt:.2}s)",
        n as f64 / dt
    );
    drop(c);
    handle.shutdown().expect("drain");
}
