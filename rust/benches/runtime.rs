//! End-to-end execution benchmarks over the compiled artifacts — the
//! numbers behind every "Accel." column in the paper tables.  One row per
//! (model family, merge variant): wall-clock per batch, derived
//! throughput, and the acceleration against that family's r0 baseline.
//!
//! Requires `make artifacts`.  Gracefully skips missing variants.

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use tomers::runtime::Engine;
use tomers::tensor::Tensor;
use tomers::util::{bench, Rng};

fn main() {
    let Ok(engine) = Engine::new("artifacts") else {
        eprintln!("SKIP: PJRT engine unavailable");
        return;
    };
    if engine.available().map(|a| a.is_empty()).unwrap_or(true) {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    }
    println!("== bench: end-to-end artifact execution ==");
    println!(
        "{:<28} {:>12} {:>14} {:>8}",
        "artifact", "ms/batch", "samples/s", "accel"
    );
    let mut rng = Rng::new(3);

    let families: &[(&str, &[&str])] = &[
        ("fc_transformer_L2", &["r0", "r16", "r32"]),
        ("fc_transformer_L4", &["r0", "r16", "r32"]),
        ("fc_nonstationary_L4", &["r0", "r32"]),
        ("chronos_s", &["r0", "r32", "r64", "r128"]),
        ("chronos_m", &["r0", "r128"]),
        ("chronos_l", &["r0", "r128"]),
        ("hyena_L4", &["r0", "r128_k1", "r128_kglobal"]),
        ("mamba_L4", &["r0", "r128_k1", "r128_kglobal"]),
        ("patchtst_L2", &["r0", "r8"]),
    ];
    for (identity, tags) in families {
        let mut base: Option<f64> = None;
        for tag in *tags {
            let name = format!("{identity}__{tag}");
            let Ok(model) = engine.load_with_weights(&name) else {
                println!("{name:<28} (missing)");
                continue;
            };
            let spec = &model.manifest.inputs[0];
            let input = if spec.dtype == "i32" {
                Tensor::from_i32(
                    &spec.shape,
                    (0..spec.elements()).map(|_| rng.below(5) as i32).collect(),
                )
                .unwrap()
            } else {
                Tensor::from_f32(
                    &spec.shape,
                    (0..spec.elements()).map(|_| rng.normal() as f32).collect(),
                )
                .unwrap()
            };
            let (mean, _) = bench(2, 6, || {
                model.execute(&[input.clone()]).unwrap();
            });
            let b = model.manifest.batch() as f64;
            let accel = base.map(|t0: f64| t0 / mean).unwrap_or(1.0);
            if base.is_none() {
                base = Some(mean);
            }
            println!(
                "{:<28} {:>10.2}ms {:>12.1}/s {:>7.2}x",
                name,
                mean * 1e3,
                b / mean,
                accel
            );
        }
    }
    println!("\nexpected shape (paper table 1/B.1): accel grows with depth L and r.");
}
