//! Streaming decode benchmarks: the incremental causal append path
//! against full recompute, plus steady-state session-manager throughput.
//!
//! Writes `BENCH_streaming.json`:
//!
//! ```json
//! {
//!   "schema_version": 1, "bench": "streaming", "quick": false,
//!   "cases": [
//!     { "t": 4096, "n": 16, "d": 1, "threshold": 0.9,
//!       "incremental_us": 0.0,       // one n-point append, incremental
//!       "recompute_us": 0.0,         // one n-point append via full recompute
//!       "incremental_ratio": 0.0,    // recompute_us / incremental_us
//!       "appends_per_sec": 0.0 }     // incremental steady state
//!   ],
//!   "sessions": { "sessions": 256, "points_per_append": 16,
//!                 "appends_per_sec": 0.0, "decode_steps": 0 }
//! }
//! ```
//!
//! Acceptance (scripts/verify.sh): the `t = 4096, n = 16` case must show
//! `incremental_ratio >= 5` — if maintaining the merged state is not
//! clearly cheaper than recomputing it, the streaming subsystem has no
//! reason to exist.  (The analytic expectation is ~t/n = 256x; 5x is the
//! regression floor, far above noise.)

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::sync::{Arc, Mutex};
use std::time::Instant;

use tomers::coordinator::{run_stream_stages, FaultPolicy, Metrics, StreamEvent, VariantMeta};
use tomers::json::Json;
use tomers::merging::{IncrementalMerge, MergeSpec, PipelineResult};
use tomers::runtime::WorkerPool;
use tomers::streaming::StreamingConfig;
use tomers::util::{bench, lock_ignore_poison as lock, Rng};

/// Time one n-point append against a warm incremental state vs. a full
/// causal recompute of the same history, at history length ~t.
fn append_vs_recompute(t: usize, n: usize, threshold: f64, iters: usize) -> (f64, f64) {
    let spec = MergeSpec::dynamic(threshold, 1).with_causal();
    let mut rng = Rng::new(97);
    let history: Vec<f32> = (0..t).map(|_| rng.normal() as f32).collect();
    let fresh: Vec<f32> = (0..n * iters.max(1)).map(|_| rng.normal() as f32).collect();

    // incremental: state warmed with the history, then timed appends let
    // it grow (t drifts by n per iteration — irrelevant, the append path
    // is O(n) by construction, which is exactly what this measures)
    let mut inc = IncrementalMerge::new(spec.clone(), 1).unwrap();
    inc.append(&history);
    let mut i = 0usize;
    let (inc_s, _) = bench(2.min(iters), iters, || {
        let chunk = &fresh[(i % iters) * n..((i % iters) + 1) * n];
        inc.append(chunk);
        i += 1;
    });

    // recompute: the same append serviced by recompiling + rerunning the
    // full causal plan over the whole history (what a system without
    // incremental state must do); fixed t per iteration for a stable
    // denominator
    let mut full_hist = history.clone();
    full_hist.extend_from_slice(&fresh[..n]);
    let sizes = vec![1.0f32; full_hist.len()];
    let mut out = PipelineResult::default();
    let mut plan = spec.compile(full_hist.len(), 1).unwrap();
    let (rec_s, _) = bench(2.min(iters), iters, || {
        plan.run_into(&full_hist, &sizes, &mut out);
    });
    (inc_s, rec_s)
}

fn main() {
    let quick = std::env::var("TOMERS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let out_path = std::env::var("TOMERS_BENCH_STREAMING_OUT")
        .unwrap_or_else(|_| "BENCH_streaming.json".to_string());
    println!("== bench: streaming ==");

    let iters = if quick { 200 } else { 2000 };
    let case_list: &[(usize, usize, f64)] = if quick {
        &[(4096, 16, 0.9)]
    } else {
        &[(1024, 16, 0.9), (4096, 16, 0.9), (4096, 64, 0.9), (16384, 16, 0.9), (4096, 16, 0.0)]
    };
    let mut cases = Vec::new();
    for &(t, n, threshold) in case_list {
        let (inc_s, rec_s) = append_vs_recompute(t, n, threshold, iters);
        let ratio = rec_s / inc_s.max(1e-12);
        let aps = 1.0 / inc_s.max(1e-12);
        println!(
            "append t={t:<6} n={n:<3} th={threshold:<4} incremental {:>9.2}us   \
             recompute {:>10.2}us   ratio {:>8.1}x",
            inc_s * 1e6,
            rec_s * 1e6,
            ratio
        );
        cases.push(Json::obj(vec![
            ("t", Json::num(t as f64)),
            ("n", Json::num(n as f64)),
            ("d", Json::num(1.0)),
            ("threshold", Json::num(threshold)),
            ("incremental_us", Json::num(inc_s * 1e6)),
            ("recompute_us", Json::num(rec_s * 1e6)),
            ("incremental_ratio", Json::num(ratio)),
            ("appends_per_sec", Json::num(aps)),
        ]));
    }

    // -- steady-state session-manager + scheduler throughput -------------
    let sessions = if quick { 64 } else { 256 };
    let rounds = if quick { 10 } else { 40 };
    let points = 16usize;
    let (tx, rx) = std::sync::mpsc::channel();
    let mut rng = Rng::new(11);
    for round in 0..rounds {
        for s in 0..sessions as u64 {
            let pts: Vec<f32> = (0..points)
                .map(|i| {
                    if s % 2 == 0 {
                        let t = (round * points + i) as f64;
                        (2.0 * std::f64::consts::PI * t / 64.0).sin() as f32
                    } else {
                        rng.normal() as f32
                    }
                })
                .collect();
            tx.send(StreamEvent::Append { session: s, points: pts }).unwrap();
        }
    }
    drop(tx);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let t0 = Instant::now();
    run_stream_stages(
        rx,
        VariantMeta { capacity: 16, m: 512 },
        StreamingConfig { max_sessions: sessions, ..StreamingConfig::default() },
        WorkerPool::global(),
        Arc::clone(&metrics),
        FaultPolicy::default(),
        |step| {
            let mut acc = 0.0f32;
            for &v in step.slab.iter() {
                acc += v * 1e-3;
            }
            std::hint::black_box(acc);
            Ok(vec![vec![0.0f32; 16]; step.rows])
        },
        |_, _| {},
    )
    .expect("stream stages");
    let dt = t0.elapsed().as_secs_f64();
    let total_appends = (sessions * rounds) as f64;
    let session_aps = total_appends / dt.max(1e-9);
    let (decode_steps, decode_rows) = {
        let mx = lock(&metrics);
        (mx.decode_steps(), mx.decode_rows())
    };
    println!(
        "sessions={sessions} rounds={rounds}: {session_aps:.0} appends/s, \
         {decode_steps} decode steps ({decode_rows} rows)"
    );

    let report = Json::obj(vec![
        ("schema_version", Json::num(1.0)),
        ("bench", Json::str("streaming")),
        ("quick", Json::Bool(quick)),
        ("cases", Json::arr(cases)),
        (
            "sessions",
            Json::obj(vec![
                ("sessions", Json::num(sessions as f64)),
                ("points_per_append", Json::num(points as f64)),
                ("appends_per_sec", Json::num(session_aps)),
                ("decode_steps", Json::num(decode_steps as f64)),
                ("decode_rows", Json::num(decode_rows as f64)),
            ]),
        ),
    ]);
    match std::fs::write(&out_path, report.to_string_pretty()) {
        Ok(()) => println!("streaming record -> {out_path}"),
        Err(e) => eprintln!("WARN: could not write {out_path}: {e}"),
    }
    println!("expected shape: incremental_ratio ~ t/n (O(n) append vs O(t) recompute);");
    println!("the verify gate holds it above 5x at t=4096, n=16.");
}
