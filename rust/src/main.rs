//! `tomers` CLI — the Layer-3 entrypoint.
//!
//! Subcommands:
//!   artifacts                     list compiled artifacts + manifests
//!   train    <identity> <dataset> train a model via its __train artifact
//!   eval     <artifact> <dataset> evaluate one artifact
//!   serve    [--requests N]       run the forecast-serving demo workload
//!   stream   [--sessions N]       run the streaming-decode demo workload
//!                                 (session-managed incremental merging;
//!                                 PJRT-free — synthetic device stage)
//!   serve-sim [--fault-rate R]    fault-injection run of the dual serving
//!                                 loop (DESIGN.md §10): seeded device
//!                                 faults, terminal-outcome and delivery
//!                                 accounting checked at exit (PJRT-free)
//!   serve-net [--shards N]        sharded TCP serving front (DESIGN.md
//!                                 §12): N independent dual serve loops
//!                                 behind a consistent-hash router and a
//!                                 length-prefixed JSON wire (PJRT-free —
//!                                 synthetic per-shard devices)
//!   client   --addr HOST:PORT     loopback driver for serve-net: pipelines
//!                                 forecasts + stream sessions over the
//!                                 wire and checks the liveness, routing
//!                                 and delivery-ledger invariants
//!                                 (--metrics also fetches the structured
//!                                 metrics and prints Prometheus text)
//!   trace-dump [--out trace.json] run a small in-process serving workload
//!                                 and export the per-stage span ring as
//!                                 Chrome trace_event JSON (DESIGN.md §13)
//!   bench    <experiment>         regenerate a paper table/figure (or `all`)
//!
//! Offline build: argument parsing is hand-rolled (no clap in the vendored
//! dependency set).

#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use tomers::bench::{self, BenchCtx};
#[cfg(feature = "pjrt")]
use tomers::coordinator::{self, policy::Variant, FaultPolicy, MergePolicy};
use tomers::coordinator::ServerConfig;
#[cfg(feature = "pjrt")]
use tomers::data::Split;
use tomers::merging::MergeSpec;
#[cfg(feature = "pjrt")]
use tomers::runtime::{Engine, WeightStore};
#[cfg(feature = "pjrt")]
use tomers::util::Rng;

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let value = if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    it.next().unwrap()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), value);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

const USAGE: &str = "\
tomers — token merging for time series (ICML 2025 reproduction)

USAGE:
  tomers artifacts [--dir artifacts]
  tomers train <identity> <dataset> [--steps N] [--dir artifacts]
  tomers eval <artifact> <dataset> [--windows N] [--dir artifacts]
  tomers serve [--requests N] [--merge-workers N] [--merge-mode off|fixed]
               [--merge-k N] [--config serve.json] [--write-config serve.json]
               (a "streaming" config block wires stream sessions into the
                serving loop; see DESIGN.md §9)
  tomers stream [--sessions N] [--rounds N] [--points N] [--batch N] [--m N]
                [--d N] [--merge-workers N] [--config serve.json]
  tomers serve-sim [--requests N] [--sessions N] [--rounds N]
                   [--fault-rate R] [--seed N]
                   (deterministic fault injection over the dual serving
                    loop; exits non-zero if any request fails to reach a
                    terminal outcome or delivery accounting is off)
  tomers serve-net [--shards N] [--addr HOST:PORT] [--max-conns N]
                   [--max-frame-bytes N] [--max-queue N] [--fault-rate R]
                   [--seed N] [--exit-after N] [--config serve.json]
                   (sharded TCP front over N dual serve loops; --exit-after
                    drains after N connections close, 0 = serve forever;
                    a "net" config block sets the same knobs)
  tomers client --addr HOST:PORT [--requests N] [--sessions N] [--rounds N]
                [--shards N] [--metrics]
                (serve-net loopback driver; exits non-zero unless every
                 request reaches a terminal outcome, sessions stay pinned
                 to the shard the client's own router predicts, and the
                 summed delivery ledger balances; --metrics also fetches
                 the merged structured metrics and prints Prometheus text)
  tomers trace-dump [--out trace.json] [--requests N]
                (run a small in-process dual-loop workload and export the
                 per-stage span ring as Chrome trace_event JSON; prints
                 span and complete-chain counts)
  tomers bench <table1|fig2|table2|table3|table4|table5|table8|fig4|fig5|fig6|fig7|fig8|fig9|fig15|fig16|fig19|ablation_k|deconly|ablation_bound|all> [--quick] [--dir artifacts]

Datasets: etth1 ettm1 weather electricity traffic (synthetic, DESIGN.md §7)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse();
    let dir = PathBuf::from(args.flag("dir").unwrap_or("artifacts"));
    match args.positional.first().map(|s| s.as_str()) {
        Some("artifacts") => cmd_artifacts(&dir),
        Some("train") => {
            let identity = args.positional.get(1).context("missing <identity>")?.clone();
            let ds = args.positional.get(2).context("missing <dataset>")?.clone();
            let steps: usize = args.flag("steps").unwrap_or("300").parse()?;
            cmd_train(&dir, &identity, &ds, steps)
        }
        Some("eval") => {
            let artifact = args.positional.get(1).context("missing <artifact>")?.clone();
            let ds = args.positional.get(2).context("missing <dataset>")?.clone();
            let windows: usize = args.flag("windows").unwrap_or("64").parse()?;
            cmd_eval(&dir, &artifact, &ds, windows)
        }
        Some("serve") => {
            if args.has("write-config") {
                let path = args.flag("write-config").unwrap_or("serve.json");
                std::fs::write(path, tomers::config::ServeFileConfig::example())?;
                println!("wrote example config -> {path}");
                return Ok(());
            }
            let requests: usize = args.flag("requests").unwrap_or("200").parse()?;
            // size the process-wide worker pool before anything touches it
            let merge_workers: usize = args.flag("merge-workers").unwrap_or("0").parse()?;
            let merge_flags = host_merge_from_flags(&args)?;
            if let Some(cfg_path) = args.flag("config") {
                let mut cfg =
                    tomers::config::ServeFileConfig::load(std::path::Path::new(cfg_path))?;
                if merge_workers > 0 {
                    cfg.merge_workers = merge_workers; // CLI overrides the file
                }
                if let Some(spec) = merge_flags {
                    cfg.merge = spec; // CLI merge flags override the file too
                }
                return cmd_serve_config(cfg.into_server_config(), requests);
            }
            let merge = merge_flags.unwrap_or_else(tomers::coordinator::default_host_merge);
            cmd_serve(&dir, requests, merge_workers, merge)
        }
        Some("stream") => cmd_stream(&args),
        Some("serve-sim") => cmd_serve_sim(&args),
        Some("serve-net") => cmd_serve_net(&args),
        Some("client") => cmd_client(&args),
        Some("trace-dump") => cmd_trace_dump(&args),
        Some("bench") => {
            let which = args.positional.get(1).context("missing experiment id")?.clone();
            let ctx = BenchCtx::new(&dir, args.has("quick"))?;
            bench::run(&ctx, &which)
        }
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// Build the host-premerge [`MergeSpec`] from `--merge-mode` and
/// `--merge-k`; `None` when no merge flag was given (caller falls back
/// to the config file or the default).  Only `off` and the schedule-free
/// `fixed` template are meaningful for serving (the premerge schedule is
/// derived per request shape), so a bad flag fails here, before any
/// serving thread starts.
fn host_merge_from_flags(args: &Args) -> Result<Option<MergeSpec>> {
    let mode = args.flag("merge-mode");
    let k_flag = args.flag("merge-k");
    if mode.is_none() && k_flag.is_none() {
        return Ok(None);
    }
    let k: usize = match k_flag {
        Some(s) => s.parse().context("--merge-k")?,
        None => MergeSpec::DEFAULT_K,
    };
    let spec = match mode.unwrap_or("fixed") {
        "off" => {
            // mirror the config parser: a key the chosen mode would never
            // read is an error, not a silent no-op
            ensure!(k_flag.is_none(), "--merge-k has no effect with --merge-mode off");
            MergeSpec::off()
        }
        "fixed" => MergeSpec::fixed_r(Vec::new(), k),
        other => bail!(
            "unknown --merge-mode {other:?} — host premerge supports off | fixed \
             (the schedule is derived per request shape; dynamic-threshold merging \
             is a per-variant config-file setting)"
        ),
    };
    spec.validate()?;
    Ok(Some(spec))
}

/// The streaming-decode demo workload: session-managed continuous
/// batching over the incremental causal merge state (DESIGN.md §9).
/// Deliberately PJRT-free — the decode steps run against a synthetic
/// device stage, so the subsystem is exercisable in the default offline
/// build; the staged machinery (`coordinator::run_stream_stages`) is the
/// same one a real device closure would drive.
fn cmd_stream(args: &Args) -> Result<()> {
    use std::sync::{Arc, Mutex};
    use std::time::Instant;
    use tomers::coordinator::{run_stream_stages, FaultPolicy, Metrics, StreamEvent, VariantMeta};
    use tomers::streaming::StreamingConfig;
    use tomers::util::lock_ignore_poison as lock;

    let sessions: usize = args.flag("sessions").unwrap_or("32").parse()?;
    let rounds: usize = args.flag("rounds").unwrap_or("40").parse()?;
    let points: usize = args.flag("points").unwrap_or("8").parse()?;
    let capacity: usize = args.flag("batch").unwrap_or("8").parse()?;
    let m: usize = args.flag("m").unwrap_or("256").parse()?;
    ensure!(
        sessions >= 1 && rounds >= 1 && points >= 1 && capacity >= 1 && m >= 1,
        "--sessions/--rounds/--points/--batch/--m must all be >= 1"
    );
    let merge_workers: usize = args.flag("merge-workers").unwrap_or("0").parse()?;
    if merge_workers > 0 {
        tomers::runtime::WorkerPool::init_global(merge_workers);
    }
    let mut cfg = match args.flag("config") {
        Some(path) => tomers::config::ServeFileConfig::load(std::path::Path::new(path))?
            .streaming
            .unwrap_or_default(),
        None => StreamingConfig::default(),
    };
    if let Some(d) = args.flag("d") {
        cfg.d = d.parse().context("--d")?;
        ensure!(cfg.d >= 1, "--d must be >= 1");
    }
    let d = cfg.d;
    let horizon = 16usize;

    // Mixed workload, half clean half noisy, streamed as append events
    // of `points` d-channel frames: sine sessions should probe into the
    // conservative bands, noise sessions into the aggressive ones
    // (visible in the reroute/probe counters and each session's merge
    // compression).
    let (tx, rx) = std::sync::mpsc::channel();
    let mut rng = tomers::util::Rng::new(17);
    for round in 0..rounds {
        for s in 0..sessions as u64 {
            let mut pts = Vec::with_capacity(points * d);
            for i in 0..points {
                let t = (round * points + i) as f64;
                for _ in 0..d {
                    if s % 2 == 0 {
                        pts.push((2.0 * std::f64::consts::PI * t / 64.0).sin() as f32);
                    } else {
                        pts.push(rng.normal() as f32);
                    }
                }
            }
            tx.send(StreamEvent::Append { session: s, points: pts })
                .expect("unbounded channel");
        }
    }
    drop(tx);

    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let delivered = Arc::new(Mutex::new(0u64));
    let sink = Arc::clone(&delivered);
    let total_points = (sessions * rounds * points) as f64;
    println!(
        "streaming {sessions} sessions x {rounds} rounds x {points} frames \
         (batch {capacity}, m {m}, d {d}, synthetic device) ..."
    );
    let t0 = Instant::now();
    let row_len = m * d;
    run_stream_stages(
        rx,
        VariantMeta { capacity, m },
        cfg,
        tomers::runtime::WorkerPool::global(),
        Arc::clone(&metrics),
        FaultPolicy::default(),
        move |step| {
            // synthetic device: one pass over the slab, "forecast" = the
            // session's most recent merged value repeated over the horizon
            let mut spin = 0.0f32;
            for &v in step.slab.iter() {
                spin += v * 1e-3;
            }
            std::hint::black_box(spin);
            Ok((0..step.rows)
                .map(|r| vec![step.slab[(r + 1) * row_len - 1]; horizon])
                .collect())
        },
        move |_session, _forecast| *lock(&sink) += 1,
    )?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "streamed {total_points:.0} points in {dt:.2}s ({:.0} points/s), {} rolling forecasts",
        total_points / dt.max(1e-9),
        lock(&delivered),
    );
    println!("{}", lock(&metrics).report());
    Ok(())
}

/// `tomers serve-sim` — deterministic fault-injection run of the dual
/// serving loop (DESIGN.md §10), PJRT-free so the default offline build
/// can gate on it (`scripts/verify.sh` does): synthetic batch and stream
/// devices behind a seeded [`FaultPlan`], the real
/// `coordinator::run_serve_stages` in between, and the fault-tolerance
/// invariants checked at exit — every submitted request reaches exactly
/// one terminal outcome (no hung receivers), per-session forecast order
/// holds, and the delivery monitor's ledger balances
/// (`enqueued == acked + expired_undelivered + dropped_overflow` once
/// everything unacked is expired).
fn cmd_serve_sim(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use std::sync::{mpsc, Arc, Mutex};
    use std::time::{Duration, Instant};
    use tomers::coordinator::{
        call_with_retry, default_host_merge, run_serve_stages, DeliveryMonitor, FaultContext,
        FaultPlan, FaultPolicy, ForecastOutcome, ForecastRequest, Metrics, PrepJob, StreamEvent,
        VariantMeta,
    };
    use tomers::streaming::StreamingConfig;
    use tomers::util::{join_annotated, lock_ignore_poison as lock};

    let requests: usize = args.flag("requests").unwrap_or("200").parse()?;
    let sessions: usize = args.flag("sessions").unwrap_or("20").parse()?;
    let rounds: usize = args.flag("rounds").unwrap_or("6").parse()?;
    let fault_rate: f64 = args.flag("fault-rate").unwrap_or("0.2").parse()?;
    let seed: u64 = args.flag("seed").unwrap_or("7").parse()?;
    ensure!(
        requests >= 1 && sessions >= 1 && rounds >= 1,
        "--requests/--sessions/--rounds must all be >= 1"
    );
    ensure!((0.0..=1.0).contains(&fault_rate), "--fault-rate must be within [0, 1]");

    // serving-shaped policy with sim-speed backoff; a small outbox so the
    // overflow accounting is actually exercised at default scale
    let policy = FaultPolicy {
        backoff_base: Duration::from_micros(200),
        backoff_max: Duration::from_millis(2),
        request_deadline: Some(Duration::from_secs(30)),
        step_deadline: Some(Duration::from_millis(100)),
        outbox_cap: 4,
        ..FaultPolicy::default()
    };
    let (capacity, m) = (4usize, 32usize);
    let metas: BTreeMap<String, VariantMeta> =
        [("v".to_string(), VariantMeta { capacity, m })].into();

    // batch side: every request's response receiver is kept — liveness is
    // "each of these yields exactly one terminal response"
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(requests);
    let mut receivers = Vec::with_capacity(requests);
    let mut batch = Vec::new();
    for id in 0..requests as u64 {
        let (rtx, rrx) = mpsc::channel();
        // alternate per batch (batches must be length-uniform): exact-m
        // contexts go straight through, 2m contexts exercise the prep
        // stage's host premerge — so the report's per-variant
        // compression telemetry shows both ratios
        let len = if (id / capacity as u64) % 2 == 0 { m } else { 2 * m };
        let context: Vec<f32> =
            (0..len).map(|i| ((id as usize + i) % 7) as f32 * 0.1).collect();
        batch.push((ForecastRequest { id, context }, Instant::now(), rtx));
        receivers.push(rrx);
        if batch.len() == capacity {
            jobs_tx.send(PrepJob { variant: "v".into(), batch: std::mem::take(&mut batch) })?;
        }
    }
    if !batch.is_empty() {
        jobs_tx.send(PrepJob { variant: "v".into(), batch })?;
    }
    drop(jobs_tx);

    // stream side: a *bounded* intake fed through try_send + bounded
    // retry, so sustained backpressure surfaces as an error instead of
    // blocking the producer forever
    let scfg = StreamingConfig { max_sessions: sessions, min_new: 4, d: 1, ..Default::default() };
    let frames = scfg.min_new;
    let (ev_tx, ev_rx) = mpsc::sync_channel::<StreamEvent>(64);
    let intake_policy = FaultPolicy {
        max_retries: 500,
        backoff_base: Duration::from_micros(500),
        backoff_max: Duration::from_millis(5),
        ..FaultPolicy::default()
    };
    let n_sessions = sessions as u64;
    let feeder = std::thread::spawn(move || -> Result<()> {
        for round in 0..rounds {
            for s in 0..n_sessions {
                let mut ev = Some(StreamEvent::Append {
                    session: s,
                    points: (0..frames)
                        .map(|i| ((round * frames + i) as f32 * 0.05).sin())
                        .collect(),
                });
                let out = call_with_retry(
                    &intake_policy,
                    Some(Instant::now() + Duration::from_secs(10)),
                    "stream intake",
                    || {
                        let e = ev.take().expect("retaken only after a full queue");
                        match ev_tx.try_send(e) {
                            Ok(()) => Ok(()),
                            Err(mpsc::TrySendError::Full(e)) => {
                                ev = Some(e);
                                anyhow::bail!("intake queue full")
                            }
                            Err(mpsc::TrySendError::Disconnected(e)) => {
                                ev = Some(e);
                                anyhow::bail!("serving loop gone")
                            }
                        }
                    },
                );
                out.result?;
            }
        }
        Ok(())
    });

    let delivery =
        Arc::new(Mutex::new(DeliveryMonitor::new(policy.outbox_cap, policy.forecast_ttl)));
    let plan = Arc::new(Mutex::new(FaultPlan::new(seed, fault_rate)));
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let faults = FaultContext::new(policy.clone());

    let horizon = 8usize;
    let stream_meta = VariantMeta { capacity: 4, m: 16 };
    let row = stream_meta.m * scfg.d;
    let bplan = Arc::clone(&plan);
    let splan = Arc::clone(&plan);
    let sink = Arc::clone(&delivery);
    println!(
        "serve-sim: {requests} batch requests + {sessions} stream sessions x {rounds} rounds, \
         fault rate {fault_rate}, seed {seed} ..."
    );
    run_serve_stages(
        jobs_rx,
        ev_rx,
        metas,
        default_host_merge(),
        2,
        stream_meta,
        scfg,
        tomers::runtime::WorkerPool::global(),
        Arc::clone(&metrics),
        faults,
        move |ready| {
            FaultPlan::gate(&bplan)?;
            Ok((0..ready.rows).map(|r| vec![ready.slab[(r + 1) * m - 1]; horizon]).collect())
        },
        move |step| {
            FaultPlan::gate(&splan)?;
            Ok((0..step.rows).map(|r| vec![step.slab[(r + 1) * row - 1]; horizon]).collect())
        },
        move |session, forecast| {
            lock(&sink).offer(session, forecast, Instant::now());
        },
    )?;
    join_annotated(feeder, "stream feeder")??;

    // liveness: every request answered with exactly one terminal outcome
    let (mut delivered, mut timeouts, mut failed, mut non_terminal) = (0usize, 0usize, 0usize, 0usize);
    for rrx in receivers {
        match rrx.recv() {
            Ok(resp) => match resp.outcome {
                ForecastOutcome::Delivered => delivered += 1,
                ForecastOutcome::DeadlineExceeded => timeouts += 1,
                ForecastOutcome::Failed(_) => failed += 1,
            },
            Err(_) => non_terminal += 1,
        }
    }
    println!(
        "batch: delivered={delivered} timeouts={timeouts} failed={failed} \
         non_terminal={non_terminal}"
    );
    ensure!(non_terminal == 0, "liveness violated: {non_terminal} request(s) never answered");
    ensure!(
        delivered + timeouts + failed == requests,
        "terminal outcomes must cover every request"
    );

    // delivery accounting: collect everything, ack half the sessions,
    // expire the rest — the ledger must balance exactly
    let mut d = lock(&delivery);
    let mut collected = 0usize;
    for s in 0..n_sessions {
        let got = d.collect(s);
        ensure!(
            got.windows(2).all(|w| w[0].0 < w[1].0),
            "session {s}: forecast sequence order violated"
        );
        collected += got.len();
        if s % 2 == 0 {
            if let Some(&(last, _)) = got.last() {
                d.ack(s, last, Instant::now());
            }
        }
    }
    ensure!(d.max_outbox_depth() <= d.cap(), "outbox depth exceeded its bound");
    let pending = d.total_pending();
    let expired = d.expire(Instant::now() + policy.forecast_ttl + Duration::from_secs(1));
    ensure!(
        expired == pending && d.total_pending() == 0,
        "expiry must settle every unacked forecast ({expired} expired, {pending} were pending)"
    );
    let st = d.stats();
    ensure!(
        st.enqueued == st.acked + st.expired_undelivered + st.dropped_overflow,
        "delivery ledger must balance: {st:?}"
    );
    drop(d);
    println!(
        "stream: collected={collected} enqueued={} acked={} redelivered={} \
         expired_undelivered={} dropped_overflow={}",
        st.enqueued, st.acked, st.redelivered, st.expired_undelivered, st.dropped_overflow
    );
    println!("delivery accounting consistent");
    {
        let p = lock(&plan);
        println!(
            "injected: {} fault(s) over {} device calls (errors={} delays={} panics={})",
            p.injected(),
            p.calls(),
            p.injected_errors,
            p.injected_delays,
            p.injected_panics
        );
    }
    let mut mx = lock(&metrics);
    mx.set_delivery(st);
    println!("{}", mx.report());
    Ok(())
}

/// `tomers serve-net` — the sharded TCP serving front (DESIGN.md §12):
/// `--shards N` independent dual serve loops behind one acceptor, each
/// with its own synthetic device pair gated by a per-shard seeded
/// [`FaultPlan`] (PJRT-free, so the offline build's loopback smoke gate
/// in `scripts/verify.sh` can drive it).  The serving shape mirrors
/// `serve-sim`, so the two commands exercise the same stages — one
/// in-process, one over the wire.
fn cmd_serve_net(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use std::sync::{Arc, Mutex};
    use std::time::Duration;
    use tomers::coordinator::{
        default_host_merge, policy::Variant, DecodeStep, FaultPlan, FaultPolicy, MergePolicy,
        ReadyBatch, VariantMeta,
    };
    use tomers::net::{serve_net, NetConfig, ShardSpec};
    use tomers::obs::ObsConfig;
    use tomers::streaming::StreamingConfig;

    // config-file "net" + "obs" blocks first; CLI flags override the net
    // fields field by field
    let (mut net, obs) = match args.flag("config") {
        Some(path) => {
            let cfg = tomers::config::ServeFileConfig::load(std::path::Path::new(path))?;
            (cfg.net.unwrap_or_default(), cfg.obs)
        }
        None => (NetConfig::default(), ObsConfig::default()),
    };
    if let Some(s) = args.flag("shards") {
        net.shards = s.parse().context("--shards")?;
    }
    if let Some(a) = args.flag("addr") {
        net.addr = a.to_string();
    }
    if let Some(c) = args.flag("max-conns") {
        net.max_conns = c.parse().context("--max-conns")?;
    }
    if let Some(b) = args.flag("max-frame-bytes") {
        net.max_frame_bytes = b.parse().context("--max-frame-bytes")?;
    }
    net.validate()?;
    let fault_rate: f64 = args.flag("fault-rate").unwrap_or("0.0").parse()?;
    ensure!((0.0..=1.0).contains(&fault_rate), "--fault-rate must be within [0, 1]");
    let seed: u64 = args.flag("seed").unwrap_or("7").parse()?;
    let exit_after: usize = args.flag("exit-after").unwrap_or("0").parse()?;
    let max_queue: usize = args.flag("max-queue").unwrap_or("256").parse()?;
    ensure!(max_queue >= 1, "--max-queue must be >= 1");

    // serve-sim's serving shape: one variant, sim-speed fault policy, a
    // small outbox so overflow accounting is exercised at default scale
    let faults = FaultPolicy {
        backoff_base: Duration::from_micros(200),
        backoff_max: Duration::from_millis(2),
        request_deadline: Some(Duration::from_secs(30)),
        step_deadline: Some(Duration::from_millis(100)),
        outbox_cap: 4,
        ..FaultPolicy::default()
    };
    let (capacity, m) = (4usize, 32usize);
    let stream_cfg = StreamingConfig { min_new: 4, d: 1, ..Default::default() };
    let stream_meta = VariantMeta { capacity: 4, m: 16 };
    let horizon = 8usize;
    let row = stream_meta.m * stream_cfg.d;
    let spec = ShardSpec {
        policy: MergePolicy::fixed(Variant::fixed("v", 0)),
        metas: BTreeMap::from([("v".to_string(), VariantMeta { capacity, m })]),
        merge: default_host_merge(),
        prep_slots: 2,
        stream_meta,
        stream_cfg,
        max_wait: Duration::from_millis(5),
        max_queue,
        faults,
        obs,
    };

    let handle = serve_net(
        &net,
        &spec,
        tomers::runtime::WorkerPool::global(),
        |i| {
            // per-shard seeds: shards fault independently but reproducibly
            let plan =
                Arc::new(Mutex::new(FaultPlan::new(seed.wrapping_add(i as u64), fault_rate)));
            move |ready: &mut ReadyBatch| -> Result<Vec<Vec<f32>>> {
                FaultPlan::gate(&plan)?;
                Ok((0..ready.rows)
                    .map(|r| vec![ready.slab[(r + 1) * m - 1]; horizon])
                    .collect())
            }
        },
        |i| {
            let plan = Arc::new(Mutex::new(FaultPlan::new(
                seed.wrapping_add(0x9E37_79B9).wrapping_add(i as u64),
                fault_rate,
            )));
            move |step: &mut DecodeStep| -> Result<Vec<Vec<f32>>> {
                FaultPlan::gate(&plan)?;
                Ok((0..step.rows).map(|r| vec![step.slab[(r + 1) * row - 1]; horizon]).collect())
            }
        },
    )?;
    println!(
        "serve-net: listening on {} shards={} fault_rate={fault_rate} seed={seed}",
        handle.addr(),
        net.shards
    );
    if exit_after == 0 {
        println!("serve-net: serving until killed (--exit-after 0)");
        loop {
            std::thread::sleep(Duration::from_secs(1));
        }
    }
    while handle.connections_closed() < exit_after {
        std::thread::sleep(Duration::from_millis(50));
    }
    let report = handle.shutdown()?;
    println!("serve-net: drained after {exit_after} connection(s)");
    print!("{report}");
    Ok(())
}

/// `tomers client` — loopback driver for `serve-net`: pipelines batch
/// forecasts and stream-session appends over one connection, then checks
/// the wire-level invariants the in-process `serve-sim` checks locally —
/// every forecast reaches exactly one terminal outcome, sessions stay
/// pinned to the shard the client's own [`ShardRouter`] predicts, and the
/// summed delivery ledger balances.  Exits non-zero on any violation
/// (`scripts/verify.sh` greps the two gate lines).
fn cmd_client(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use std::time::Duration;
    use tomers::coordinator::ForecastOutcome;
    use tomers::net::{NetClient, Request, Response, ShardRouter, DEFAULT_MAX_FRAME_BYTES};

    let addr = args.flag("addr").context("--addr HOST:PORT is required (see serve-net)")?;
    let requests: usize = args.flag("requests").unwrap_or("200").parse()?;
    let sessions: u64 = args.flag("sessions").unwrap_or("20").parse()?;
    let rounds: usize = args.flag("rounds").unwrap_or("4").parse()?;
    let shards: usize = args.flag("shards").unwrap_or("2").parse()?;
    ensure!(requests >= 1 && sessions >= 1 && rounds >= 1, "--requests/--sessions/--rounds >= 1");
    let router = ShardRouter::new(shards)?; // must mirror the server's
    let m = 32usize; // context length of serve-net's synthetic variant

    let mut c = NetClient::connect_retry(addr, DEFAULT_MAX_FRAME_BYTES, 40)?;
    c.set_timeout(Some(Duration::from_secs(10)))?;

    // pipeline everything: forecasts first, then the stream appends —
    // responses come back in server order, tallied by type below
    let base = 10_000u64; // keep forecast ids and session ids disjoint
    for i in 0..requests as u64 {
        let context: Vec<f32> = (0..m).map(|j| ((i as usize + j) % 7) as f32 * 0.1).collect();
        c.send(&Request::Forecast { id: base + i, context })?;
    }
    let appends = sessions as usize * rounds;
    for round in 0..rounds {
        for s in 0..sessions {
            let points: Vec<f32> =
                (0..4).map(|j| ((round * 4 + j) as f32 * 0.05).sin()).collect();
            c.send(&Request::Append { session: s, points })?;
        }
    }

    // drain until every pipelined request is answered; a read timeout
    // means the server broke the liveness contract
    let (mut delivered, mut timeouts, mut failed) = (0usize, 0usize, 0usize);
    let mut appended = 0usize;
    let mut append_errors = 0usize;
    let mut per_shard: Vec<usize> = vec![0; shards];
    let mut session_shard: BTreeMap<u64, usize> = BTreeMap::new();
    let mut forecast_seen = 0usize;
    let mut append_seen = 0usize;
    let mut drain_error = None;
    while forecast_seen < requests || append_seen < appends {
        let resp = match c.recv() {
            Ok(r) => r,
            Err(e) => {
                drain_error = Some(e);
                break;
            }
        };
        match resp {
            Response::Forecast { id, outcome, shard, .. } => {
                forecast_seen += 1;
                ensure!(shard == router.shard_for(id), "forecast {id} routed off-ring");
                per_shard[shard] += 1;
                match outcome {
                    ForecastOutcome::Delivered => delivered += 1,
                    ForecastOutcome::DeadlineExceeded => timeouts += 1,
                    ForecastOutcome::Failed(_) => failed += 1,
                }
            }
            Response::Appended { session, shard } => {
                append_seen += 1;
                appended += 1;
                ensure!(shard == router.shard_for(session), "session {session} routed off-ring");
                // pinning: every append of a session must land on one shard
                let prev = session_shard.entry(session).or_insert(shard);
                ensure!(*prev == shard, "session {session} moved shards: {prev} -> {shard}");
            }
            Response::Error { context, reason } => {
                // stream backpressure surfaces here; anything else is fatal
                ensure!(
                    context == "append" && reason.contains("backpressure"),
                    "unexpected error frame: {context}: {reason}"
                );
                append_seen += 1;
                append_errors += 1;
            }
            other => bail!("unexpected response while draining: {other:?}"),
        }
    }
    let non_terminal = requests - forecast_seen;
    println!(
        "batch: delivered={delivered} timeouts={timeouts} failed={failed} \
         non_terminal={non_terminal}"
    );
    println!("stream: appended={appended} backpressure_errors={append_errors}");
    if let Some(e) = drain_error {
        return Err(e.context(format!(
            "drain stalled with {non_terminal} forecast(s) and {} append(s) unanswered",
            appends - append_seen
        )));
    }
    ensure!(non_terminal == 0, "liveness violated: {non_terminal} request(s) never answered");
    let shard_line = per_shard
        .iter()
        .enumerate()
        .map(|(i, n)| format!("shard{i}={n}"))
        .collect::<Vec<_>>()
        .join(" ");
    ensure!(
        per_shard.iter().sum::<usize>() == requests,
        "per-shard forecast counts must sum to the total"
    );
    println!("routing: {shard_line} total={requests}");

    // give in-flight decode steps a beat to land in the outboxes, then
    // collect + ack every session (strictly synchronous exchanges now —
    // nothing else is in flight on this connection)
    std::thread::sleep(Duration::from_millis(200));
    let mut collected = 0usize;
    for s in 0..sessions {
        let (shard, entries) = match c.call(&Request::Collect { session: s })? {
            Response::Collected { session, shard, entries } => {
                ensure!(session == s, "collect answered for the wrong session");
                (shard, entries)
            }
            other => bail!("expected a collected response, got {other:?}"),
        };
        ensure!(shard == router.shard_for(s), "collect for session {s} routed off-ring");
        ensure!(
            entries.windows(2).all(|w| w[0].0 < w[1].0),
            "session {s}: forecast sequence order violated"
        );
        collected += entries.len();
        if let Some(&(last, _)) = entries.last() {
            match c.call(&Request::Ack { session: s, upto: last })? {
                Response::Acked { session, .. } => {
                    ensure!(session == s, "ack answered for the wrong session")
                }
                other => bail!("expected an acked response, got {other:?}"),
            }
        }
    }
    println!("stream: collected={collected}");

    // the summed per-shard ledger must balance exactly (DESIGN.md §11)
    let (text, d) = match c.call(&Request::Report)? {
        Response::Report { text, delivery } => (text, delivery),
        other => bail!("expected a report response, got {other:?}"),
    };
    ensure!(
        d.enqueued == d.acked + d.expired_undelivered + d.dropped_overflow + d.pending,
        "delivery ledger must balance: {d:?}"
    );
    println!("delivery accounting consistent");
    print!("{text}");

    // --metrics: fetch the merged structured metrics (DESIGN.md §13) and
    // render them as Prometheus text — the scrape-shaped view of the same
    // counters the human report above prints
    if args.has("metrics") {
        let metrics = match c.call(&Request::Metrics)? {
            Response::Metrics { metrics } => metrics,
            other => bail!("expected a metrics response, got {other:?}"),
        };
        print!("{}", tomers::obs::prometheus_text(&metrics));
    }
    Ok(())
}

/// `tomers trace-dump` — run a small in-process dual-loop workload (the
/// `serve-sim` shape at fault rate 0) with the global span recorder on,
/// then export the ring as Chrome `trace_event` JSON (load the file at
/// `chrome://tracing` or https://ui.perfetto.dev).  The printed
/// `complete_chains` count is the number of request ids whose
/// prep → exec → respond edges all landed in the ring — `verify.sh`
/// greps it as the tracing smoke gate.
fn cmd_trace_dump(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use std::sync::{mpsc, Arc, Mutex};
    use std::time::Instant;
    use tomers::coordinator::{
        default_host_merge, run_serve_stages, FaultContext, FaultPolicy, ForecastRequest,
        Metrics, PrepJob, StreamEvent, VariantMeta,
    };
    use tomers::obs::{complete_chains, recorder, ObsConfig};
    use tomers::streaming::StreamingConfig;

    let out = args.flag("out").unwrap_or("trace.json").to_string();
    let requests: usize = args.flag("requests").unwrap_or("32").parse()?;
    ensure!(requests >= 1, "--requests must be >= 1");
    let obs = ObsConfig::default();
    obs.apply();

    // the serve-sim serving shape, faults off: 2m contexts so the prep
    // stage premerges and the trace shows real per-stage compression work
    let (capacity, m) = (4usize, 32usize);
    let metas = BTreeMap::from([("v".to_string(), VariantMeta { capacity, m })]);
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(requests);
    let mut receivers = Vec::with_capacity(requests);
    let mut batch = Vec::new();
    for id in 0..requests as u64 {
        let (rtx, rrx) = mpsc::channel();
        let context: Vec<f32> =
            (0..2 * m).map(|i| ((id as usize + i) % 7) as f32 * 0.1).collect();
        batch.push((ForecastRequest { id, context }, Instant::now(), rtx));
        receivers.push(rrx);
        if batch.len() == capacity {
            jobs_tx.send(PrepJob {
                variant: "v".to_string(),
                batch: std::mem::take(&mut batch),
            })?;
        }
    }
    if !batch.is_empty() {
        jobs_tx.send(PrepJob { variant: "v".to_string(), batch })?;
    }
    drop(jobs_tx);

    let (ev_tx, ev_rx) = mpsc::sync_channel::<StreamEvent>(256);
    for round in 0..3 {
        for s in 0..4u64 {
            ev_tx.send(StreamEvent::Append {
                session: s,
                points: (0..4).map(|i| ((round * 4 + i) as f32 * 0.05).sin()).collect(),
            })?;
        }
    }
    drop(ev_tx);

    let stream_cfg = StreamingConfig { min_new: 4, d: 1, ..Default::default() };
    let stream_meta = VariantMeta { capacity: 4, m: 16 };
    let row = stream_meta.m * stream_cfg.d;
    let horizon = 8usize;
    let metrics = Arc::new(Mutex::new(Metrics::with_obs(&obs)));
    run_serve_stages(
        jobs_rx,
        ev_rx,
        metas,
        default_host_merge(),
        2,
        stream_meta,
        stream_cfg,
        tomers::runtime::WorkerPool::global(),
        Arc::clone(&metrics),
        FaultContext::new(FaultPolicy::default()),
        move |ready| {
            Ok((0..ready.rows).map(|r| vec![ready.slab[(r + 1) * m - 1]; horizon]).collect())
        },
        move |step| {
            Ok((0..step.rows).map(|r| vec![step.slab[(r + 1) * row - 1]; horizon]).collect())
        },
        |_session, _forecast| {},
    )?;
    for rrx in receivers {
        let _ = rrx.recv();
    }

    let (spans, dropped) = recorder().snapshot();
    let chains = complete_chains(&spans);
    std::fs::write(&out, recorder().export_chrome().to_string_pretty())
        .with_context(|| format!("writing {out}"))?;
    println!(
        "trace: spans={} complete_chains={chains} dropped={dropped} out={out}",
        spans.len()
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str = "this subcommand executes compiled artifacts, but the binary was built \
without the `pjrt` feature; rebuild with `cargo build --features pjrt` (and a real PJRT \
binding in rust/vendor/xla — see the header of rust/vendor/xla/src/lib.rs)";

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts(_dir: &PathBuf) -> Result<()> {
    anyhow::bail!(NO_PJRT)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_dir: &PathBuf, _identity: &str, _ds: &str, _steps: usize) -> Result<()> {
    anyhow::bail!(NO_PJRT)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_eval(_dir: &PathBuf, _artifact: &str, _ds: &str, _windows: usize) -> Result<()> {
    anyhow::bail!(NO_PJRT)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(
    _dir: &PathBuf,
    _requests: usize,
    _merge_workers: usize,
    _merge: MergeSpec,
) -> Result<()> {
    anyhow::bail!(NO_PJRT)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve_config(_config: ServerConfig, _requests: usize) -> Result<()> {
    anyhow::bail!(NO_PJRT)
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts(dir: &PathBuf) -> Result<()> {
    let engine = Engine::new(dir)?;
    println!("platform: {}", engine.platform());
    for name in engine.available()? {
        let manifest = tomers::runtime::Manifest::load(&dir.join(format!("{name}.json")))?;
        println!(
            "{:<34} {:<16} params={:<4} in={:?} out={:?}",
            name,
            manifest.family,
            manifest.params.len(),
            manifest.inputs.iter().map(|s| format!("{:?}", s.shape)).collect::<Vec<_>>(),
            manifest.outputs.iter().map(|s| format!("{:?}", s.shape)).collect::<Vec<_>>(),
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train(dir: &PathBuf, identity: &str, ds: &str, steps: usize) -> Result<()> {
    let ctx = BenchCtx::new(dir, false)?;
    let engine = Engine::new(dir)?;
    let univariate = identity.starts_with("chronos");
    let ws = bench::forecast_suite::train_or_load(
        &ctx, &engine, identity, &format!("{identity}__train"), ds, steps, univariate,
    )?;
    let out = ctx.trained_weights_path(identity, ds);
    ws.save(&out)?;
    println!("trained weights -> {}", out.display());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_eval(dir: &PathBuf, artifact: &str, ds_name: &str, windows: usize) -> Result<()> {
    let ctx = BenchCtx::new(dir, false)?;
    let engine = Engine::new(dir)?;
    let identity = artifact.split("__").next().unwrap_or(artifact);
    let mut model = engine.load(artifact)?;
    // prefer trained weights when present
    let trained = ctx.trained_weights_path(identity, ds_name);
    let mixture = ctx.trained_weights_path(identity, "mixture");
    let ws = if trained.exists() {
        WeightStore::load(&trained)?
    } else if mixture.exists() {
        WeightStore::load(&mixture)?
    } else {
        WeightStore::load(&dir.join(format!("{identity}.weights.bin")))?
    };
    model.bind_weights(&ws)?;
    let m = model.manifest.config_usize("m").unwrap_or(192);
    let p = model.manifest.config_usize("p").unwrap_or(96);
    let test = bench::forecast_suite::dataset(ds_name, 6000, m, p, Split::Test, 2024);
    let (mse, thr) = if model.manifest.family.starts_with("chronos") {
        bench::chronos_suite::eval_chronos(&model, &test, windows)?
    } else {
        bench::forecast_suite::eval_forecast(&model, &test, windows)?
    };
    println!("{artifact} on {ds_name}: MSE={mse:.4} throughput={thr:.1}/s");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve_config(config: ServerConfig, requests: usize) -> Result<()> {
    let streaming = config.streaming.clone();
    let mut handle = coordinator::server::serve(config)?;
    let client = handle.client();
    println!("serving {requests} mixed-workload requests (config file) ...");
    let mut rng = Rng::new(7);
    let mut pending = Vec::new();
    for id in 0..requests as u64 {
        let prof_name = if id % 2 == 0 { "weather" } else { "ettm1" };
        let prof = tomers::data::profile(prof_name).unwrap();
        let series = tomers::data::generate(prof, 512, rng.next_u64());
        pending.push(client.submit(coordinator::ForecastRequest { id, context: series.column(0) })?);
    }
    for rx in pending {
        let _ = rx.recv();
    }
    // A configured "streaming" block is live: demo it alongside the batch
    // workload — a few sessions streaming d-channel frames through the
    // same device thread, rolling forecasts collected + acked through the
    // delivery monitor (at-least-once; see DESIGN.md §10).
    if let Some(scfg) = streaming {
        let stream = handle.stream_client().expect("streaming configured");
        let stream_sessions = 4u64.min(requests.max(1) as u64);
        let frames = scfg.min_new.max(4);
        println!(
            "streaming {stream_sessions} demo sessions x {frames} frames x 8 rounds \
             (d {}) through the serving loop ...",
            scfg.d
        );
        for _round in 0..8 {
            for s in 0..stream_sessions {
                let pts: Vec<f32> =
                    (0..frames * scfg.d).map(|_| rng.normal() as f32).collect();
                stream.append(s, pts)?;
            }
        }
        // the server keeps serving while we poll; a settle window lets the
        // decode deadline flush partial batches before the last collect
        let mut rolling = 0usize;
        let mut idle_rounds = 0usize;
        while idle_rounds < 3 {
            std::thread::sleep(Duration::from_millis(100));
            let mut got = 0usize;
            for s in 0..stream_sessions {
                let batch = stream.collect(s);
                if let Some(&(last, _)) = batch.last() {
                    stream.ack(s, last);
                }
                got += batch.len();
            }
            rolling += got;
            idle_rounds = if got == 0 { idle_rounds + 1 } else { 0 };
        }
        println!("{rolling} rolling forecasts delivered and acked");
    }
    println!("{}", client.metrics_report()?);
    handle.shutdown()?;
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(dir: &PathBuf, requests: usize, merge_workers: usize, merge: MergeSpec) -> Result<()> {
    // entropy-driven merge-policy over the chronos_s variants
    let variants = vec![
        Variant::fixed("chronos_s__r0", 0),
        Variant::fixed("chronos_s__r32", 32),
        Variant::fixed("chronos_s__r128", 128),
    ];
    let policy = MergePolicy::uniform(variants, 3.0, 7.5);
    let handle = coordinator::server::serve(ServerConfig {
        artifact_dir: dir.clone(),
        policy,
        max_wait: Duration::from_millis(25),
        max_queue: 4096,
        merge_workers,
        merge,
        streaming: None,
        prefer_manifest_spec: true,
        faults: FaultPolicy::default(),
    })?;
    let client = handle.client();
    println!("serving {requests} mixed-workload requests ...");
    let mut rng = Rng::new(7);
    let mut pending = Vec::new();
    for id in 0..requests as u64 {
        // mixed workload: alternate clean and noisy series
        let prof_name = if id % 2 == 0 { "weather" } else { "ettm1" };
        let prof = tomers::data::profile(prof_name).unwrap();
        let series = tomers::data::generate(prof, 512, rng.next_u64());
        let context = series.column(0);
        pending.push(client.submit(coordinator::ForecastRequest { id, context })?);
    }
    let (mut ok, mut terminal_errors) = (0usize, 0usize);
    for rx in pending {
        match rx.recv() {
            Ok(resp) if resp.outcome.is_delivered() => ok += 1,
            Ok(_) => terminal_errors += 1,
            Err(_) => {}
        }
    }
    println!("completed {ok}/{requests} ({terminal_errors} terminal error responses)");
    println!("{}", client.metrics_report()?);
    handle.shutdown()?;
    Ok(())
}
