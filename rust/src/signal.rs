//! Signal-processing substrate (paper §6.2 predictors + fig. 6 baseline).
//!
//! Provides the dataset statistics the paper uses to *predict* token-merging
//! benefit — **spectral entropy** and **total harmonic distortion** — plus
//! the Gaussian low-pass filter of the fig. 6 comparison and an FFT /
//! autocorrelation toolbox used by the data generators and the merge-policy
//! planner.  Implemented from scratch (radix-2 iterative FFT with Bluestein
//! fallback for non-power-of-two lengths).

use std::f64::consts::PI;

/// Complex number (minimal — only what the FFT needs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

impl C64 {
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }
    pub fn mul(self, o: C64) -> C64 {
        C64::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }
    pub fn add(self, o: C64) -> C64 {
        C64::new(self.re + o.re, self.im + o.im)
    }
    pub fn sub(self, o: C64) -> C64 {
        C64::new(self.re - o.re, self.im - o.im)
    }
    pub fn conj(self) -> C64 {
        C64::new(self.re, -self.im)
    }
    pub fn norm_sq(self) -> f64 {
        self.re * self.re + self.im * self.im
    }
    pub fn scale(self, s: f64) -> C64 {
        C64::new(self.re * s, self.im * s)
    }
    fn cis(theta: f64) -> C64 {
        C64::new(theta.cos(), theta.sin())
    }
}

/// In-place radix-2 Cooley–Tukey FFT; `inverse` applies 1/n scaling.
/// Panics if `x.len()` is not a power of two (callers use `fft` below).
fn fft_pow2(x: &mut [C64], inverse: bool) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fft_pow2 needs power-of-two length");
    // bit reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            x.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wl = C64::cis(ang);
        for chunk in x.chunks_mut(len) {
            let mut w = C64::new(1.0, 0.0);
            let half = len / 2;
            for i in 0..half {
                let u = chunk[i];
                let v = chunk[i + half].mul(w);
                chunk[i] = u.add(v);
                chunk[i + half] = u.sub(v);
                w = w.mul(wl);
            }
        }
        len <<= 1;
    }
    if inverse {
        let inv = 1.0 / n as f64;
        for v in x.iter_mut() {
            *v = v.scale(inv);
        }
    }
}

/// FFT of arbitrary length (Bluestein's algorithm for non-power-of-two).
pub fn fft(input: &[C64], inverse: bool) -> Vec<C64> {
    let n = input.len();
    if n == 0 {
        return Vec::new();
    }
    if n.is_power_of_two() {
        let mut x = input.to_vec();
        fft_pow2(&mut x, inverse);
        return x;
    }
    // Bluestein: express DFT as a convolution of length >= 2n-1.
    let sign = if inverse { 1.0 } else { -1.0 };
    let m = (2 * n - 1).next_power_of_two();
    let mut a = vec![C64::default(); m];
    let mut b = vec![C64::default(); m];
    let mut chirp = vec![C64::default(); n];
    for k in 0..n {
        // k^2 mod 2n avoids precision loss for large k
        let e = (k * k) % (2 * n);
        chirp[k] = C64::cis(sign * PI * e as f64 / n as f64);
        a[k] = input[k].mul(chirp[k]);
        b[k] = chirp[k].conj();
        if k > 0 {
            b[m - k] = chirp[k].conj();
        }
    }
    fft_pow2(&mut a, false);
    fft_pow2(&mut b, false);
    for i in 0..m {
        a[i] = a[i].mul(b[i]);
    }
    fft_pow2(&mut a, true);
    let mut out = vec![C64::default(); n];
    for k in 0..n {
        out[k] = a[k].mul(chirp[k]);
        if inverse {
            out[k] = out[k].scale(1.0 / n as f64);
        }
    }
    out
}

/// Real-input FFT magnitude-squared spectrum (one-sided, n/2+1 bins).
pub fn power_spectrum(x: &[f32]) -> Vec<f64> {
    let n = x.len();
    let cx: Vec<C64> = x.iter().map(|&v| C64::new(v as f64, 0.0)).collect();
    let f = fft(&cx, false);
    (0..n / 2 + 1).map(|i| f[i].norm_sq() / n as f64).collect()
}

/// Spectral entropy in bits (paper table 4): Shannon entropy of the
/// normalized one-sided power spectrum, DC excluded.
pub fn spectral_entropy(x: &[f32]) -> f64 {
    let ps = power_spectrum(x);
    let body = &ps[1..]; // exclude DC: the paper's statistic concerns structure
    let total: f64 = body.iter().sum();
    if total <= 0.0 {
        return 0.0;
    }
    -body
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| {
            let q = p / total;
            q * q.log2()
        })
        .sum::<f64>()
}

/// Total harmonic distortion in percent (paper table 4): ratio of the
/// energy in harmonics 2..=n_harmonics of the strongest component to the
/// fundamental's energy.
pub fn thd(x: &[f32], n_harmonics: usize) -> f64 {
    let ps = power_spectrum(x);
    if ps.len() < 3 {
        return 0.0;
    }
    // fundamental = strongest non-DC bin
    let (f0, p0) = ps
        .iter()
        .enumerate()
        .skip(1)
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, &p)| (i, p))
        .unwrap();
    if p0 <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for k in 2..=n_harmonics {
        let bin = f0 * k;
        if bin < ps.len() {
            h += ps[bin];
        }
    }
    100.0 * (h / p0).sqrt()
}

/// Gaussian low-pass filter (fig. 6 baseline), edge-replicated.
pub fn gaussian_filter(x: &[f32], sigma: f64) -> Vec<f32> {
    if sigma <= 0.0 {
        return x.to_vec();
    }
    let radius = (3.0 * sigma).ceil() as isize;
    let mut kernel = Vec::with_capacity((2 * radius + 1) as usize);
    let mut sum = 0.0;
    for i in -radius..=radius {
        let w = (-(i as f64).powi(2) / (2.0 * sigma * sigma)).exp();
        kernel.push(w);
        sum += w;
    }
    for w in kernel.iter_mut() {
        *w /= sum;
    }
    let n = x.len() as isize;
    (0..n)
        .map(|i| {
            let mut acc = 0.0;
            for (j, w) in kernel.iter().enumerate() {
                let idx = (i + j as isize - radius).clamp(0, n - 1);
                acc += w * x[idx as usize] as f64;
            }
            acc as f32
        })
        .collect()
}

/// Biased autocorrelation at lags 0..max_lag (inclusive).
pub fn autocorrelation(x: &[f32], max_lag: usize) -> Vec<f64> {
    let n = x.len();
    let mean = x.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
    let var: f64 = x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>();
    (0..=max_lag.min(n.saturating_sub(1)))
        .map(|lag| {
            if var <= 0.0 {
                return 0.0;
            }
            let mut acc = 0.0;
            for i in 0..n - lag {
                acc += (x[i] as f64 - mean) * (x[i + lag] as f64 - mean);
            }
            acc / var
        })
        .collect()
}

/// Mean pairwise cosine similarity of consecutive rows of a (t, d) matrix —
/// the planner's cheap redundancy statistic (appendix E.6 fig. 19).
pub fn adjacent_cosine_similarity(rows: &[f32], t: usize, d: usize) -> f64 {
    if t < 2 {
        return 1.0;
    }
    let mut acc = 0.0;
    for i in 0..t - 1 {
        let a = &rows[i * d..(i + 1) * d];
        let b = &rows[(i + 1) * d..(i + 2) * d];
        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
        for j in 0..d {
            dot += a[j] as f64 * b[j] as f64;
            na += (a[j] as f64).powi(2);
            nb += (b[j] as f64).powi(2);
        }
        acc += dot / (na.sqrt() * nb.sqrt() + 1e-12);
    }
    acc / (t - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sine(n: usize, cycles: f64, amp: f64) -> Vec<f32> {
        (0..n)
            .map(|i| (amp * (2.0 * PI * cycles * i as f64 / n as f64).sin()) as f32)
            .collect()
    }

    #[test]
    fn fft_roundtrip_pow2() {
        let x: Vec<C64> = (0..64).map(|i| C64::new(i as f64, -(i as f64) / 3.0)).collect();
        let y = fft(&fft(&x, false), true);
        for (a, b) in x.iter().zip(&y) {
            assert!((a.re - b.re).abs() < 1e-9 && (a.im - b.im).abs() < 1e-9);
        }
    }

    #[test]
    fn fft_roundtrip_bluestein() {
        let x: Vec<C64> = (0..100).map(|i| C64::new((i as f64).sin(), 0.0)).collect();
        let y = fft(&fft(&x, false), true);
        for (a, b) in x.iter().zip(&y) {
            assert!((a.re - b.re).abs() < 1e-8, "{} vs {}", a.re, b.re);
        }
    }

    #[test]
    fn fft_matches_naive_dft() {
        let x: Vec<C64> = (0..24).map(|i| C64::new((i as f64 * 0.7).cos(), 0.3 * i as f64)).collect();
        let fast = fft(&x, false);
        for k in 0..24 {
            let mut acc = C64::default();
            for (j, v) in x.iter().enumerate() {
                acc = acc.add(v.mul(C64::cis(-2.0 * PI * (k * j) as f64 / 24.0)));
            }
            assert!((acc.re - fast[k].re).abs() < 1e-8);
            assert!((acc.im - fast[k].im).abs() < 1e-8);
        }
    }

    #[test]
    fn spectrum_peaks_at_sine_frequency() {
        let x = sine(256, 8.0, 1.0);
        let ps = power_spectrum(&x);
        let peak = ps.iter().enumerate().skip(1).max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        assert_eq!(peak, 8);
    }

    #[test]
    fn entropy_orders_noise_above_sine() {
        let clean = sine(512, 4.0, 1.0);
        let mut rng = crate::util::Rng::new(3);
        let noisy: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        assert!(spectral_entropy(&noisy) > spectral_entropy(&clean) + 2.0);
    }

    #[test]
    fn thd_detects_harmonics() {
        let n = 512;
        let clean = sine(n, 4.0, 1.0);
        let distorted: Vec<f32> = (0..n)
            .map(|i| {
                let t = 2.0 * PI * 4.0 * i as f64 / n as f64;
                (t.sin() + 0.4 * (2.0 * t).sin() + 0.3 * (3.0 * t).sin()) as f32
            })
            .collect();
        assert!(thd(&distorted, 8) > thd(&clean, 8) + 20.0);
    }

    #[test]
    fn gaussian_reduces_noise_energy() {
        let mut rng = crate::util::Rng::new(9);
        let x: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let y = gaussian_filter(&x, 2.0);
        let e = |v: &[f32]| v.iter().map(|&a| (a as f64).powi(2)).sum::<f64>();
        assert!(e(&y) < 0.5 * e(&x));
        assert_eq!(gaussian_filter(&x, 0.0), x);
    }

    #[test]
    fn autocorr_periodic_signal() {
        let x = sine(256, 8.0, 1.0); // period 32
        let ac = autocorrelation(&x, 64);
        assert!((ac[0] - 1.0).abs() < 1e-9);
        // biased estimator scales by (n - lag)/n: 224/256 = 0.875
        assert!(ac[32] > 0.85, "ac[32]={}", ac[32]);
        assert!(ac[16] < -0.85, "ac[16]={}", ac[16]);
    }

    #[test]
    fn adjacent_similarity_bounds() {
        let rows = vec![1.0f32, 0.0, 1.0, 0.0, 1.0, 0.0];
        assert!((adjacent_cosine_similarity(&rows, 3, 2) - 1.0).abs() < 1e-9);
        let anti = vec![1.0f32, 0.0, -1.0, 0.0];
        assert!((adjacent_cosine_similarity(&anti, 2, 2) + 1.0).abs() < 1e-9);
    }
}
