//! Bounded log-linear histograms — the fixed-memory replacement for the
//! serving metrics' per-request vectors (DESIGN.md §13).
//!
//! An HDR-style layout extracted straight from the IEEE-754 bit pattern:
//! each power-of-two octave in `[2^min_exp, 2^max_exp)` is split into
//! [`SUB`] = 16 linear sub-buckets (the top [`SUB_BITS`] = 4 mantissa
//! bits), plus an underflow bucket at index 0 (values below `2^min_exp`,
//! including `<= 0` and NaN) and an overflow bucket at the last index.
//! Total size is `(max_exp - min_exp) * 16 + 2` `u64` buckets — a few KB
//! regardless of how many values are recorded, so a serving process can
//! run forever without growing.
//!
//! **Error bound.**  An in-range bucket `[lo, lo + w)` has `w = lo'/16`
//! for `lo' = 2^e <= lo`, so `w/lo <= 1/16`; the midpoint representative
//! is therefore within `w/2 <= lo/32` of any member, a relative error of
//! at most `2^-(SUB_BITS+1)` = **1/32 = 3.125%**.  [`Histogram::percentile`]
//! uses the same nearest-rank rule as [`crate::util::percentile`], so it
//! lands in the bucket holding the exact-rank sample and inherits that
//! bound (edge buckets answer the recorded min/max exactly, and results
//! are clamped to `[min, max]`).
//!
//! **Merge identities.**  [`Histogram::merge`] adds buckets/count/sum
//! elementwise and folds min/max — bucket counts merge exactly
//! (associative + commutative in `u64`), `count` is exact, and `sum`
//! equals the fold of the per-shard sums (f64 addition; exact whenever
//! the values are, e.g. integral batch sizes).  Pinned by the tests here
//! and transliterated in `scripts/crosscheck_obs.py` (golden bucket
//! indices included) so the semantics cannot drift silently.

use anyhow::{ensure, Result};

/// Linear sub-bucket bits per octave (top mantissa bits used).
pub const SUB_BITS: u32 = 4;
/// Linear sub-buckets per power-of-two octave.
pub const SUB: usize = 1 << SUB_BITS;

/// Default latency bounds (seconds): `2^-20` (~0.95us) .. `2^7` (128s),
/// 434 buckets (~3.4 KB).
pub const LATENCY_MIN_EXP: i32 = -20;
/// See [`LATENCY_MIN_EXP`].
pub const LATENCY_MAX_EXP: i32 = 7;

/// A bounded log-linear histogram.  See the module docs for the bucket
/// scheme, error bound and merge identities.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    min_exp: i32,
    max_exp: i32,
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Histogram covering `[2^min_exp, 2^max_exp)` plus the two edge
    /// buckets.  The span is capped so a config typo cannot allocate an
    /// absurd table.
    pub fn new(min_exp: i32, max_exp: i32) -> Result<Histogram> {
        ensure!(min_exp < max_exp, "histogram needs min_exp < max_exp ({min_exp} >= {max_exp})");
        let span = (max_exp - min_exp) as usize;
        ensure!(span <= 64, "histogram span {span} octaves exceeds the 64-octave cap");
        ensure!(
            (-1022..=1023).contains(&min_exp) && (-1022..=1023).contains(&max_exp),
            "histogram exponents must stay in the normal f64 range"
        );
        Ok(Histogram {
            min_exp,
            max_exp,
            buckets: vec![0; span * SUB + 2],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        })
    }

    /// Default latency histogram (seconds): ~1us .. 128s.
    pub fn latency() -> Histogram {
        Histogram::new(LATENCY_MIN_EXP, LATENCY_MAX_EXP).expect("default latency bounds")
    }

    /// Default batch-size histogram: 1 .. 65536 rows.  Small integers are
    /// exactly representable, so `mean()` (= occupancy) stays exact.
    pub fn batch_sizes() -> Histogram {
        Histogram::new(0, 16).expect("default batch bounds")
    }

    /// Bucket index of `v`: 0 underflows (incl. `<= 0` and NaN), the last
    /// bucket overflows, in-range values index by exponent + top mantissa
    /// bits.  Transliterated in `scripts/crosscheck_obs.py::index`.
    fn index(&self, v: f64) -> usize {
        if !(v >= (self.min_exp as f64).exp2()) {
            return 0;
        }
        if v >= (self.max_exp as f64).exp2() {
            return self.buckets.len() - 1;
        }
        let bits = v.to_bits();
        let e = ((bits >> 52) & 0x7ff) as i32 - 1023;
        let sub = ((bits >> (52 - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        1 + ((e - self.min_exp) as usize) * SUB + sub
    }

    /// Midpoint representative of in-range bucket `i` (`1 <= i <= n-2`).
    fn representative(&self, i: usize) -> f64 {
        let k = i - 1;
        let e = self.min_exp + (k / SUB) as i32;
        let octave = (e as f64).exp2();
        let lower = octave * (1.0 + (k % SUB) as f64 / SUB as f64);
        lower + octave / SUB as f64 / 2.0
    }

    pub fn record(&mut self, v: f64) {
        let i = self.index(v);
        self.buckets[i] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Exact mean: `sum / count` uses the true running sum, not bucket
    /// representatives (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Nearest-rank percentile from the buckets — same rank rule as
    /// [`crate::util::percentile`], answering the rank's bucket midpoint
    /// (edge buckets answer the recorded min/max), clamped to
    /// `[min, max]`.  Relative error vs the exact-rank sample is bounded
    /// by 1/32 for in-range values (module docs).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        let last = self.buckets.len() - 1;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum > rank {
                let rep = if i == 0 {
                    self.min
                } else if i == last {
                    self.max
                } else {
                    self.representative(i)
                };
                return rep.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Lossless merge: elementwise bucket add, `count`/`sum` add, min/max
    /// fold.  Errs on mismatched bounds (shards must share one scheme for
    /// the per-shard reports to sum exactly).
    pub fn merge(&mut self, other: &Histogram) -> Result<()> {
        ensure!(
            self.min_exp == other.min_exp && self.max_exp == other.max_exp,
            "histogram bound mismatch: [{}, {}] vs [{}, {}]",
            self.min_exp,
            self.max_exp,
            other.min_exp,
            other.max_exp
        );
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        Ok(())
    }

    /// Heap footprint in bytes — constant in the number of recorded
    /// values (the O(1)-memory pin in `coordinator::metrics`).
    pub fn heap_bytes(&self) -> usize {
        self.buckets.len() * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{percentile, Rng};

    /// The golden table from `scripts/crosscheck_obs.py` — hand-derived
    /// from the IEEE-754 layout at the default latency bounds.
    #[test]
    fn golden_bucket_indices() {
        let h = Histogram::latency();
        assert_eq!(h.buckets.len(), 434);
        for (v, want) in [
            (0.0, 0usize),
            (f64::NAN, 0),
            ((-21f64).exp2(), 0),
            ((-20f64).exp2(), 1),
            (0.001, 161),
            (0.0015, 169),
            (1.0, 321),
            (1.5, 329),
            (64.0, 417),
            (127.9999, 432),
            (128.0, 433),
            (1e9, 433),
        ] {
            assert_eq!(h.index(v), want, "index({v})");
        }
    }

    #[test]
    fn percentile_within_documented_bound_of_sorted_oracle() {
        let mut rng = Rng::new(21);
        let mut h = Histogram::latency();
        let mut vals = Vec::new();
        for _ in 0..5000 {
            // latencies over ~6 decades: ~2us .. ~4s
            let e = -19.0 + (rng.below(21) as f64);
            let v = e.exp2() * (1.0 + rng.uniform());
            h.record(v);
            vals.push(v);
        }
        for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let oracle = percentile(&mut vals, p);
            let got = h.percentile(p);
            let rel = (got - oracle).abs() / oracle;
            assert!(
                rel <= 1.0 / 32.0 + 1e-12,
                "p{p}: hist {got} vs oracle {oracle} (rel {rel})"
            );
        }
    }

    #[test]
    fn merge_is_commutative_associative_with_exact_identities() {
        // dyadic values: f64 sums are exact, so the identities pin
        // bit-for-bit (mirrors crosscheck_obs.py::check_merge_identities)
        let sets: [&[f64]; 3] = [
            &[0.5, 0.25, 1.0, 2.0, 0.125],
            &[4.0, 0.5, 0.5, 8.0],
            &[1.5, 0.75, 0.0078125, 32.0, 2.0, 2.0],
        ];
        let hs: Vec<Histogram> = sets
            .iter()
            .map(|vs| {
                let mut h = Histogram::latency();
                vs.iter().for_each(|&v| h.record(v));
                h
            })
            .collect();
        let mut ab = Histogram::latency();
        ab.merge(&hs[0]).unwrap();
        ab.merge(&hs[1]).unwrap();
        let mut ba = Histogram::latency();
        ba.merge(&hs[1]).unwrap();
        ba.merge(&hs[0]).unwrap();
        assert_eq!(ab, ba, "merge must be commutative");

        let mut left = ab.clone();
        left.merge(&hs[2]).unwrap();
        let mut bc = Histogram::latency();
        bc.merge(&hs[1]).unwrap();
        bc.merge(&hs[2]).unwrap();
        let mut right = Histogram::latency();
        right.merge(&hs[0]).unwrap();
        right.merge(&bc).unwrap();
        assert_eq!(left, right, "merge must be associative on exact values");

        // exact identities vs recording everything directly
        let mut direct = Histogram::latency();
        sets.iter().for_each(|vs| vs.iter().for_each(|&v| direct.record(v)));
        assert_eq!(left, direct);
        assert_eq!(left.count(), 15);
        assert_eq!(left.sum(), direct.sum(), "sum identity must be exact here");
        assert_eq!(left.min(), 0.0078125);
        assert_eq!(left.max(), 32.0);
    }

    #[test]
    fn merged_percentiles_match_pooled_recording() {
        // two "shards" with disjoint latency regimes: the merged
        // histogram answers within the bound of the pooled oracle
        let mut rng = Rng::new(5);
        let (mut a, mut b) = (Histogram::latency(), Histogram::latency());
        let mut all = Vec::new();
        for i in 0..2000 {
            let v = if i % 2 == 0 {
                0.001 * (1.0 + rng.uniform()) // ~1-2ms shard
            } else {
                0.05 * (1.0 + rng.uniform()) // ~50-100ms shard
            };
            if i % 2 == 0 { a.record(v) } else { b.record(v) }
            all.push(v);
        }
        let mut merged = a.clone();
        merged.merge(&b).unwrap();
        assert_eq!(merged.count(), a.count() + b.count());
        assert_eq!(merged.sum(), a.sum() + b.sum());
        for p in [50.0, 99.0] {
            let oracle = percentile(&mut all, p);
            let rel = (merged.percentile(p) - oracle).abs() / oracle;
            assert!(rel <= 1.0 / 32.0 + 1e-12, "merged p{p} off by {rel}");
        }
    }

    #[test]
    fn edge_and_degenerate_behaviour() {
        let mut h = Histogram::latency();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        h.record(0.25);
        for p in [0.0, 50.0, 100.0] {
            assert!((h.percentile(p) - 0.25).abs() <= 0.25 / 32.0);
        }
        // out-of-range values are retained exactly via min/max
        h.record(0.0);
        h.record(1e9);
        assert_eq!(h.count(), 3);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e9);
        assert_eq!(h.percentile(0.0), 0.0, "underflow bucket answers the true min");
        assert_eq!(h.percentile(100.0), 1e9, "overflow bucket answers the true max");
        // mismatched bounds refuse to merge
        let other = Histogram::batch_sizes();
        assert!(h.merge(&other).is_err());
        // degenerate construction
        assert!(Histogram::new(5, 5).is_err());
        assert!(Histogram::new(-10, 60).is_err());
    }

    #[test]
    fn memory_is_constant_in_record_count() {
        let mut h = Histogram::latency();
        let before = h.heap_bytes();
        for i in 0..10_000 {
            h.record(1e-6 * (i + 1) as f64);
        }
        assert_eq!(h.heap_bytes(), before, "recording must never grow the histogram");
        assert_eq!(h.count(), 10_000);
        // integral batch sizes keep the occupancy mean exact
        let mut b = Histogram::batch_sizes();
        for _ in 0..500 {
            b.record(3.0);
            b.record(5.0);
        }
        assert_eq!(b.mean(), 4.0);
    }
}
