//! Per-stage request tracing: a bounded, mutex-guarded ring of span
//! events stamped at each serving lifecycle edge (DESIGN.md §13).
//!
//! One process-global [`TraceRecorder`] (the [`recorder`] singleton, same
//! `OnceLock` idiom as `runtime::pool::WorkerPool::global`) collects
//! [`SpanEvent`]s from every serving thread: intake admission, batcher
//! queue wait, host prep/premerge, the device call (retry attempts in
//! `detail`), response send, and the stream-side prep/exec/deliver
//! edges.  The ring overwrites oldest-first past its capacity (the
//! `dropped` counter says how many), so tracing memory is bounded and
//! the newest spans always survive — a post-incident dump shows the most
//! recent traffic.
//!
//! `sample_every = N` keeps only ids divisible by N (1 = everything), so
//! production rates can trace a deterministic slice instead of paying
//! one ring slot per request.  The enabled flag is a relaxed atomic: the
//! disabled path is one load, no lock — the recorder-off arm of
//! `benches/obs.rs`.
//!
//! [`TraceRecorder::export_chrome`] renders the ring as Chrome
//! `trace_event` JSON (complete "X" events, microsecond timestamps,
//! shard as `tid`) — load it in `chrome://tracing` / Perfetto, or via
//! `tomers trace-dump`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::json::Json;
use crate::util::lock_ignore_poison;

/// A serving lifecycle stage — the label on trace spans and the key of
/// the per-stage duration histograms in `coordinator::metrics`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// net intake: frame arrival -> routing decision + enqueue
    Intake,
    /// batcher queue: request enqueue -> its batch forming
    QueueWait,
    /// host prep: slab pad + premerge on the worker pool
    Prep,
    /// device call, retries/backoff included
    Exec,
    /// terminal response send-out
    Respond,
    /// stream decode-step assembly (session slab fill)
    StreamPrep,
    /// stream device call, retries included
    StreamExec,
    /// stream forecast delivery (outbox offer)
    Deliver,
}

impl Stage {
    /// Every stage, in pipeline order — iteration key for the stage
    /// histogram set.
    pub const ALL: [Stage; 8] = [
        Stage::Intake,
        Stage::QueueWait,
        Stage::Prep,
        Stage::Exec,
        Stage::Respond,
        Stage::StreamPrep,
        Stage::StreamExec,
        Stage::Deliver,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Intake => "intake",
            Stage::QueueWait => "queue_wait",
            Stage::Prep => "prep",
            Stage::Exec => "exec",
            Stage::Respond => "respond",
            Stage::StreamPrep => "stream_prep",
            Stage::StreamExec => "stream_exec",
            Stage::Deliver => "deliver",
        }
    }

    /// Dense index into [`Stage::ALL`]-shaped tables.
    pub fn idx(self) -> usize {
        match self {
            Stage::Intake => 0,
            Stage::QueueWait => 1,
            Stage::Prep => 2,
            Stage::Exec => 3,
            Stage::Respond => 4,
            Stage::StreamPrep => 5,
            Stage::StreamExec => 6,
            Stage::Deliver => 7,
        }
    }
}

/// One completed span: stage + request (or batch-leader / session) id,
/// start relative to the recorder epoch, duration, and a stage-specific
/// detail (batch rows, retry attempts, delivered entries, shard...).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    pub id: u64,
    pub stage: Stage,
    pub shard: usize,
    pub t_start_us: u64,
    pub dur_us: u64,
    pub detail: u32,
}

struct Ring {
    buf: Vec<SpanEvent>,
    cap: usize,
    /// next overwrite slot once `buf.len() == cap`
    next: usize,
    /// spans overwritten (oldest-first) since the last configure
    dropped: u64,
    sample_every: u64,
    epoch: Instant,
}

/// The bounded span recorder.  All methods take `&self`; serving threads
/// share the [`recorder`] singleton.
pub struct TraceRecorder {
    inner: Mutex<Ring>,
    enabled: AtomicBool,
}

impl TraceRecorder {
    pub fn new(capacity: usize, sample_every: u64) -> TraceRecorder {
        TraceRecorder {
            inner: Mutex::new(Ring {
                buf: Vec::new(),
                cap: capacity.max(1),
                next: 0,
                dropped: 0,
                sample_every: sample_every.max(1),
                epoch: Instant::now(),
            }),
            enabled: AtomicBool::new(true),
        }
    }

    /// Reconfigure in place (the `"obs"` config block): clears the ring,
    /// resets the epoch and the dropped counter.
    pub fn configure(&self, capacity: usize, sample_every: u64, enabled: bool) {
        let mut r = lock_ignore_poison(&self.inner);
        r.buf.clear();
        r.buf.shrink_to_fit();
        r.cap = capacity.max(1);
        r.next = 0;
        r.dropped = 0;
        r.sample_every = sample_every.max(1);
        r.epoch = Instant::now();
        drop(r);
        self.enabled.store(enabled, Ordering::Relaxed);
    }

    /// Flip recording without touching the ring — the on/off arms of the
    /// overhead bench.
    pub fn set_enabled(&self, enabled: bool) {
        self.enabled.store(enabled, Ordering::Relaxed);
    }

    /// One relaxed load — the only cost on the disabled path.
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record one completed span.  No-op when disabled or when `id` is
    /// sampled out (`id % sample_every != 0`).  A `start` predating the
    /// epoch clamps to 0 (requests in flight across a `configure`).
    pub fn record(
        &self,
        id: u64,
        stage: Stage,
        shard: usize,
        start: Instant,
        dur: Duration,
        detail: u32,
    ) {
        if !self.is_enabled() {
            return;
        }
        let mut r = lock_ignore_poison(&self.inner);
        if id % r.sample_every != 0 {
            return;
        }
        let t_start_us =
            start.checked_duration_since(r.epoch).unwrap_or(Duration::ZERO).as_micros() as u64;
        let ev = SpanEvent {
            id,
            stage,
            shard,
            t_start_us,
            dur_us: dur.as_micros() as u64,
            detail,
        };
        if r.buf.len() < r.cap {
            r.buf.push(ev);
        } else {
            let slot = r.next;
            r.buf[slot] = ev;
            r.next = (r.next + 1) % r.cap;
            r.dropped += 1;
        }
    }

    /// Copy out the ring oldest-first, plus how many older spans the ring
    /// overwrote to stay bounded.
    pub fn snapshot(&self) -> (Vec<SpanEvent>, u64) {
        let r = lock_ignore_poison(&self.inner);
        let mut out = Vec::with_capacity(r.buf.len());
        if r.buf.len() == r.cap {
            out.extend_from_slice(&r.buf[r.next..]);
            out.extend_from_slice(&r.buf[..r.next]);
        } else {
            out.extend_from_slice(&r.buf);
        }
        (out, r.dropped)
    }

    /// Render the ring as Chrome `trace_event` JSON: complete (`"X"`)
    /// events with microsecond `ts`/`dur`, shard as `tid` — loadable in
    /// `chrome://tracing` / Perfetto.
    pub fn export_chrome(&self) -> Json {
        let (events, dropped) = self.snapshot();
        let evs = events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("name", Json::str(e.stage.name())),
                    ("cat", Json::str("serve")),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(e.t_start_us as f64)),
                    ("dur", Json::num(e.dur_us as f64)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(e.shard as f64)),
                    (
                        "args",
                        Json::obj(vec![
                            ("id", Json::num(e.id as f64)),
                            ("detail", Json::num(e.detail as f64)),
                        ]),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::arr(evs)),
            ("displayTimeUnit", Json::str("ms")),
            ("dropped", Json::num(dropped as f64)),
        ])
    }
}

/// Ids whose spans cover the full batch lifecycle — prep, exec and
/// respond (a batch's leader id carries all three).  The `tomers
/// trace-dump` gate: at least one complete chain proves the stages are
/// actually stitched to the same request.
pub fn complete_chains(events: &[SpanEvent]) -> usize {
    use std::collections::BTreeMap;
    let mut seen: BTreeMap<u64, u8> = BTreeMap::new();
    for e in events {
        let bit = match e.stage {
            Stage::Prep => 1u8,
            Stage::Exec => 2,
            Stage::Respond => 4,
            _ => 0,
        };
        if bit != 0 {
            *seen.entry(e.id).or_insert(0) |= bit;
        }
    }
    seen.values().filter(|&&m| m == 7).count()
}

/// The process-global recorder (defaults: 4096-span ring, no sampling,
/// enabled).  `ObsConfig::apply` / `serve_net` reconfigure it at startup.
pub fn recorder() -> &'static TraceRecorder {
    static RECORDER: OnceLock<TraceRecorder> = OnceLock::new();
    RECORDER.get_or_init(|| TraceRecorder::new(4096, 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(rec: &TraceRecorder, id: u64, stage: Stage) {
        let t0 = Instant::now();
        rec.record(id, stage, 0, t0, Duration::from_micros(5), 1);
    }

    #[test]
    fn ring_eviction_keeps_newest_spans() {
        let rec = TraceRecorder::new(4, 1);
        for id in 0..10u64 {
            span(&rec, id, Stage::Exec);
        }
        let (events, dropped) = rec.snapshot();
        assert_eq!(events.len(), 4, "ring stays at capacity");
        assert_eq!(dropped, 6, "six oldest spans overwritten");
        let ids: Vec<u64> = events.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9], "oldest-first snapshot of the newest spans");
    }

    #[test]
    fn sampling_and_disable_gate_recording() {
        let rec = TraceRecorder::new(16, 2);
        for id in 0..6u64 {
            span(&rec, id, Stage::Prep);
        }
        let (events, _) = rec.snapshot();
        let ids: Vec<u64> = events.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![0, 2, 4], "sample_every=2 keeps even ids only");

        rec.set_enabled(false);
        span(&rec, 8, Stage::Prep);
        assert_eq!(rec.snapshot().0.len(), 3, "disabled recorder drops everything");
        rec.set_enabled(true);
        span(&rec, 10, Stage::Prep);
        assert_eq!(rec.snapshot().0.len(), 4);

        rec.configure(8, 1, true);
        assert_eq!(rec.snapshot().0.len(), 0, "configure clears the ring");
    }

    #[test]
    fn chrome_export_is_valid_and_parses_back() {
        let rec = TraceRecorder::new(16, 1);
        let t0 = Instant::now();
        rec.record(7, Stage::Prep, 1, t0, Duration::from_micros(250), 4);
        rec.record(7, Stage::Exec, 1, t0, Duration::from_micros(900), 2);
        let text = rec.export_chrome().to_string();
        let back = Json::parse(&text).expect("export must be valid JSON");
        let evs = back.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        for ev in evs {
            assert_eq!(ev.req("ph").unwrap().as_str().unwrap(), "X");
            assert_eq!(ev.req("cat").unwrap().as_str().unwrap(), "serve");
            assert_eq!(ev.req("tid").unwrap().as_usize().unwrap(), 1);
            assert!(ev.req("dur").unwrap().as_f64().unwrap() > 0.0);
            assert_eq!(ev.req("args").unwrap().req("id").unwrap().as_usize().unwrap(), 7);
        }
        assert_eq!(back.req("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    }

    #[test]
    fn complete_chains_requires_all_three_stages() {
        let rec = TraceRecorder::new(16, 1);
        for s in [Stage::QueueWait, Stage::Prep, Stage::Exec, Stage::Respond] {
            span(&rec, 1, s);
        }
        span(&rec, 2, Stage::Prep);
        span(&rec, 2, Stage::Exec);
        span(&rec, 3, Stage::Respond);
        let (events, _) = rec.snapshot();
        assert_eq!(complete_chains(&events), 1, "only id 1 carries prep+exec+respond");
    }

    #[test]
    fn stage_table_is_dense_and_named() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.idx(), i, "Stage::ALL order must match idx()");
            assert!(!s.name().is_empty());
        }
    }
}
