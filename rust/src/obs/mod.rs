//! Observability subsystem (DESIGN.md §13): bounded log-linear
//! [`Histogram`]s, the per-stage span [`TraceRecorder`], and the
//! Prometheus text exposition over the structured metrics JSON.
//!
//! The serving stack threads through here at three points:
//!
//! * `coordinator::metrics::Metrics` stores latencies / batch sizes /
//!   per-stage durations as [`Histogram`]s (O(1) memory in request
//!   count, lossless per-shard merging — the identities
//!   `scripts/crosscheck_obs.py` pins);
//! * the pipeline / stream / net layers stamp [`trace::Stage`] spans
//!   into the global [`recorder`] ring (`tomers trace-dump` exports it
//!   as Chrome `trace_event` JSON);
//! * the wire `metrics` request (`net::protocol`) returns
//!   `metrics::merged_json`, which [`prometheus_text`] renders as
//!   Prometheus exposition for `tomers client --metrics`.
//!
//! The `"obs"` config block ([`ObsConfig`]) sizes the ring, the span
//! sampling stride and the latency-histogram bounds.

pub mod hist;
pub mod trace;

use anyhow::Result;

pub use hist::Histogram;
pub use trace::{complete_chains, recorder, SpanEvent, Stage, TraceRecorder};

use crate::json::Json;

/// The `"obs"` config block: trace-ring capacity, span sampling stride,
/// and the latency-histogram bounds (powers of two, seconds).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObsConfig {
    /// bounded span-ring capacity (overwrites oldest past this)
    pub trace_ring: usize,
    /// keep spans for ids divisible by this (1 = trace everything)
    pub sample_every: u64,
    /// latency histograms cover `[2^hist_min_exp, 2^hist_max_exp)` seconds
    pub hist_min_exp: i32,
    /// see `hist_min_exp`
    pub hist_max_exp: i32,
}

impl Default for ObsConfig {
    fn default() -> ObsConfig {
        ObsConfig {
            trace_ring: 4096,
            sample_every: 1,
            hist_min_exp: hist::LATENCY_MIN_EXP,
            hist_max_exp: hist::LATENCY_MAX_EXP,
        }
    }
}

impl ObsConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.trace_ring > 0, "obs.trace_ring must be positive");
        anyhow::ensure!(
            self.trace_ring <= 1 << 22,
            "obs.trace_ring {} exceeds the 4Mi-span cap",
            self.trace_ring
        );
        anyhow::ensure!(self.sample_every > 0, "obs.sample_every must be positive");
        // the histogram constructor owns the bound rules
        Histogram::new(self.hist_min_exp, self.hist_max_exp)?;
        Ok(())
    }

    /// Latency histogram at this config's bounds.
    pub fn latency_histogram(&self) -> Histogram {
        Histogram::new(self.hist_min_exp, self.hist_max_exp)
            .expect("validated obs histogram bounds")
    }

    /// Push the trace settings into the global [`recorder`].
    pub fn apply(&self) {
        recorder().configure(self.trace_ring, self.sample_every, true);
    }
}

fn prom_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "NaN".to_string()
    }
}

fn prom_line(out: &mut String, name: &str, labels: &[(&str, String)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{k}=\"{v}\""));
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(&prom_f64(value));
    out.push('\n');
}

fn num_at(v: &Json, key: &str) -> f64 {
    v.get(key).and_then(|x| x.as_f64().ok()).unwrap_or(0.0)
}

fn prom_summary(
    out: &mut String,
    family: &str,
    labels: &[(&str, String)],
    block: &Json,
) {
    for q in ["p50", "p95", "p99"] {
        if block.get(q).is_some() {
            let mut l = labels.to_vec();
            // "p50" -> the 0.50-style quantile label
            l.push(("quantile", format!("0.{}", q.trim_start_matches('p'))));
            prom_line(out, family, &l, num_at(block, q));
        }
    }
    prom_line(out, &format!("{family}_count"), labels, num_at(block, "count"));
    prom_line(out, &format!("{family}_sum"), labels, num_at(block, "sum"));
}

/// Render the structured metrics JSON (`metrics::merged_json` — the wire
/// `metrics` response) as Prometheus text exposition.  Tolerant of
/// missing sections: absent blocks simply emit nothing.
pub fn prometheus_text(metrics: &Json) -> String {
    let mut out = String::new();
    out.push_str("# TYPE tomers_served counter\n");
    out.push_str("# TYPE tomers_rejected counter\n");
    out.push_str("# TYPE tomers_latency_seconds summary\n");
    let shards: &[Json] = metrics
        .get("shards")
        .and_then(|s| s.as_arr().ok())
        .unwrap_or(&[]);
    for shard in shards {
        let sid = num_at(shard, "shard") as usize;
        let base = vec![("shard", sid.to_string())];
        prom_line(&mut out, "tomers_served", &base, num_at(shard, "served"));
        prom_line(&mut out, "tomers_rejected", &base, num_at(shard, "rejected"));
        if let Some(lat) = shard.get("latency") {
            prom_summary(&mut out, "tomers_latency_seconds", &base, lat);
        }
        if let Some(batch) = shard.get("batch") {
            prom_line(&mut out, "tomers_batch_occupancy", &base, num_at(batch, "mean"));
        }
        if let Some(Ok(stages)) = shard.get("stages").map(|s| s.as_obj()) {
            for (stage, block) in stages {
                let mut l = base.clone();
                l.push(("stage", stage.clone()));
                prom_summary(&mut out, "tomers_stage_seconds", &l, block);
            }
        }
        if let Some(Ok(variants)) = shard.get("variants").map(|v| v.as_obj()) {
            for (name, block) in variants {
                let mut l = base.clone();
                l.push(("variant", name.clone()));
                prom_line(&mut out, "tomers_variant_served", &l, num_at(block, "served"));
                prom_line(
                    &mut out,
                    "tomers_variant_compression_ratio",
                    &l,
                    num_at(block, "compression"),
                );
                prom_line(&mut out, "tomers_variant_tokens_in", &l, num_at(block, "tokens_in"));
                prom_line(&mut out, "tomers_variant_tokens_out", &l, num_at(block, "tokens_out"));
            }
        }
        if let Some(Ok(routes)) = shard.get("routes").map(|v| v.as_obj()) {
            for (name, block) in routes {
                let mut l = base.clone();
                l.push(("variant", name.clone()));
                prom_line(&mut out, "tomers_route_decisions", &l, num_at(block, "decisions"));
                prom_line(
                    &mut out,
                    "tomers_route_entropy_mean",
                    &l,
                    num_at(block, "entropy_mean"),
                );
            }
        }
        if let Some(Ok(faults)) = shard.get("faults").map(|v| v.as_obj()) {
            for (kind, n) in faults {
                let mut l = base.clone();
                l.push(("kind", kind.clone()));
                prom_line(&mut out, "tomers_faults", &l, n.as_f64().unwrap_or(0.0));
            }
        }
        if let Some(Ok(delivery)) = shard.get("delivery").map(|v| v.as_obj()) {
            for (state, n) in delivery {
                let mut l = base.clone();
                l.push(("state", state.clone()));
                prom_line(&mut out, "tomers_delivery", &l, n.as_f64().unwrap_or(0.0));
            }
        }
    }
    if let Some(total) = metrics.get("total") {
        prom_line(&mut out, "tomers_served_total", &[], num_at(total, "served"));
        prom_line(&mut out, "tomers_rejected_total", &[], num_at(total, "rejected"));
        if let Some(lat) = total.get("latency") {
            prom_summary(&mut out, "tomers_latency_seconds_merged", &[], lat);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_config_validates() {
        ObsConfig::default().validate().unwrap();
        assert!(ObsConfig { trace_ring: 0, ..ObsConfig::default() }.validate().is_err());
        assert!(ObsConfig { sample_every: 0, ..ObsConfig::default() }.validate().is_err());
        assert!(
            ObsConfig { hist_min_exp: 3, hist_max_exp: 3, ..ObsConfig::default() }
                .validate()
                .is_err()
        );
        let wide = ObsConfig { hist_min_exp: -40, hist_max_exp: 30, ..ObsConfig::default() };
        assert!(wide.validate().is_err(), "a 70-octave span must be rejected");
    }

    #[test]
    fn prometheus_text_renders_the_metrics_schema() {
        let json = Json::parse(
            r#"{
              "shards": [{
                "shard": 0, "served": 12, "rejected": 1,
                "latency": {"count": 12, "sum": 0.6, "p50": 0.04, "p95": 0.09, "p99": 0.1},
                "batch": {"count": 3, "mean": 4.0},
                "stages": {"exec": {"count": 3, "sum": 0.3, "p50": 0.1}},
                "variants": {"v": {"served": 12, "compression": 2.0,
                                    "tokens_in": 768, "tokens_out": 384}},
                "routes": {"v": {"decisions": 12, "entropy_mean": 4.2}},
                "faults": {"exec_retries": 2},
                "delivery": {"enqueued": 5, "pending": 1}
              }],
              "total": {"served": 12, "rejected": 1,
                        "latency": {"count": 12, "sum": 0.6, "p50": 0.04}}
            }"#,
        )
        .unwrap();
        let text = prometheus_text(&json);
        for needle in [
            "tomers_served{shard=\"0\"} 12",
            "tomers_rejected{shard=\"0\"} 1",
            "tomers_latency_seconds{shard=\"0\",quantile=\"0.50\"} 0.04",
            "tomers_latency_seconds_count{shard=\"0\"} 12",
            "tomers_batch_occupancy{shard=\"0\"} 4",
            "tomers_stage_seconds{shard=\"0\",stage=\"exec\",quantile=\"0.50\"} 0.1",
            "tomers_variant_compression_ratio{shard=\"0\",variant=\"v\"} 2",
            "tomers_route_decisions{shard=\"0\",variant=\"v\"} 12",
            "tomers_faults{shard=\"0\",kind=\"exec_retries\"} 2",
            "tomers_delivery{shard=\"0\",state=\"pending\"} 1",
            "tomers_served_total 12",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}
