//! Ablation benches DESIGN.md calls out beyond the paper's own figures:
//!
//! * `ablation_k` — the locality constraint sweep: accel + MSE vs
//!   k ∈ {1, 4, 16, 64, global} at fixed r (the paper's central design
//!   parameter, eq. 1/2; §C fixes k=t/2 for encoders and k=1 for SSMs —
//!   this sweep shows the whole trade-off curve).
//! * `deconly` — causal merging in a decoder-only forecaster (the
//!   architecture class the §3 causality claim targets).
//! * `ablation_bound` — measured acceleration vs the analytic B.1 bound
//!   across model depths.

use anyhow::Result;

use super::chronos_suite::{eval_chronos, train_mixture};
use super::forecast_suite::dataset;
use super::BenchCtx;
use crate::data::Split;
use crate::json::Json;
use crate::merging::speedup_bound;
use crate::runtime::{Engine, WeightStore};
use crate::train;
use crate::util::Rng;

/// Locality-constraint sweep at fixed r = 64 on chronos-s.
pub fn ablation_k(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let ws = train_mixture(ctx, &engine, "s", ctx.train_steps(400))?;
    let test = dataset("etth1", 6000, 512, 64, Split::Test, ctx.seed);
    let n_eval = ctx.eval_windows(32);
    let mut rows = Vec::new();
    println!("{:>8} {:>8} {:>10} {:>16}", "k", "MSE", "thr/s", "sim-ops (eq.2)");
    let mut cases = vec![
        ("1".to_string(), "chronos_s__r64_k1".to_string(), 1usize),
        ("4".to_string(), "chronos_s__r64_k4".to_string(), 4),
        ("16".to_string(), "chronos_s__r64_k16".to_string(), 16),
        ("64".to_string(), "chronos_s__r64_k64".to_string(), 64),
        ("global".to_string(), "chronos_s__r64".to_string(), 256),
    ];
    cases.insert(0, ("none".to_string(), "chronos_s__r0".to_string(), 0));
    for (label, name, k) in cases {
        let Ok(mut model) = engine.load(&name) else {
            println!("{label:>8} (artifact {name} missing — run aot)");
            continue;
        };
        model.bind_weights(&ws)?;
        let (mse, thr) = eval_chronos(&model, &test, n_eval)?;
        let ops = if k == 0 { 0 } else { crate::merging::similarity_complexity(512, k) };
        println!("{:>8} {:>8.3} {:>10.1} {:>16}", label, mse, thr, ops);
        rows.push(Json::obj(vec![
            ("k", Json::str(label)),
            ("mse", Json::num(mse)),
            ("throughput", Json::num(thr)),
            ("sim_ops", Json::num(ops as f64)),
        ]));
    }
    ctx.save_report("ablation_k", &Json::arr(rows))
}

/// Decoder-only forecaster with causal merging.
pub fn deconly(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let identity = "deconly_L4";
    let cache = ctx.trained_weights_path(identity, "etth1");
    let ws = if cache.exists() {
        WeightStore::load(&cache)?
    } else {
        let mut model = engine.load(&format!("{identity}__train"))?;
        let init =
            WeightStore::load(&ctx.artifact_dir.join(format!("{identity}.weights.bin")))?;
        model.bind_weights(&init)?;
        let batch = model.manifest.batch();
        let ds = dataset("etth1", 6000, 512, 64, Split::Train, ctx.seed);
        let mut rng = Rng::new(ctx.seed ^ 0xDEC);
        let report = train::train_loop(
            &mut model,
            &init,
            ctx.train_steps(300),
            |_| {
                let idx: Vec<usize> = (0..batch).map(|_| rng.below(ds.len())).collect();
                ds.batch_univariate(&idx)
            },
            |step, loss| {
                if step % 50 == 0 {
                    println!("  [deconly/etth1] step {step} mse {loss:.4}");
                }
                true
            },
        )?;
        report.final_weights.save(&cache)?;
        report.final_weights
    };
    let test = dataset("etth1", 6000, 512, 64, Split::Test, ctx.seed);
    let n_eval = ctx.eval_windows(32);
    let mut rows = Vec::new();
    println!("{:>6} {:>8} {:>10}", "r", "MSE", "thr/s");
    let mut base_thr = None;
    for tag in ["r0", "r4", "r8"] {
        let Ok(mut model) = engine.load(&format!("{identity}__{tag}")) else {
            println!("{tag:>6} (artifact missing — run aot)");
            continue;
        };
        model.bind_weights(&ws)?;
        // decoder-only outputs plain values: reuse the forecast evaluator
        // with univariate batches
        let batch = model.manifest.batch();
        let stride = (test.len() / n_eval.max(1)).max(1);
        let (mut mse_sum, mut count, mut secs) = (0.0, 0usize, 0.0);
        let mut idx = 0usize;
        while count < n_eval && (idx + batch) * stride <= test.len() {
            let indices: Vec<usize> =
                (0..batch).map(|b| (idx + b) * stride % test.len()).collect();
            let (x, y) = test.batch_univariate(&indices);
            let t0 = std::time::Instant::now();
            let out = model.execute(&[x])?;
            secs += t0.elapsed().as_secs_f64();
            mse_sum += crate::eval::mse(&out[0], &y)? * batch as f64;
            count += batch;
            idx += batch;
        }
        let (mse, thr) = (mse_sum / count as f64, count as f64 / secs);
        base_thr.get_or_insert(thr);
        println!("{:>6} {:>8.3} {:>10.1} ({:.2}x)", tag, mse, thr,
                 thr / base_thr.unwrap());
        rows.push(Json::obj(vec![
            ("r", Json::str(tag)),
            ("mse", Json::num(mse)),
            ("throughput", Json::num(thr)),
        ]));
    }
    ctx.save_report("deconly", &Json::arr(rows))
}

/// Measured acceleration vs the analytic appendix-B.1 bound per depth.
pub fn ablation_bound(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let mut rows = Vec::new();
    println!("{:>10} {:>8} {:>12} {:>12}", "model", "L", "accel(r128)", "B.1 bound");
    for (size, l) in [("s", 2usize), ("m", 4), ("l", 6)] {
        let identity = format!("chronos_{size}");
        let ws_path = ctx.artifact_dir.join(format!("{identity}.weights.bin"));
        let ws = WeightStore::load(&ws_path)?;
        let mut time_of = |tag: &str| -> Result<f64> {
            let mut model = engine.load(&format!("{identity}__{tag}"))?;
            model.bind_weights(&ws)?;
            let spec = &model.manifest.inputs[0];
            let mut rng = Rng::new(1);
            let x = crate::tensor::Tensor::from_f32(
                &spec.shape,
                (0..spec.elements()).map(|_| rng.normal() as f32).collect(),
            )?;
            let (mean, _) = crate::util::bench(1, 4, || {
                model.execute(&[x.clone()]).unwrap();
            });
            Ok(mean)
        };
        let accel = time_of("r0")? / time_of("r128")?;
        let bound = speedup_bound(l as u32);
        println!("{:>10} {:>8} {:>11.2}x {:>11.2}x", identity, l, accel, bound);
        rows.push(Json::obj(vec![
            ("model", Json::str(identity.clone())),
            ("layers", Json::num(l as f64)),
            ("accel", Json::num(accel)),
            ("bound", Json::num(bound)),
        ]));
    }
    ctx.save_report("ablation_bound", &Json::arr(rows))
}
