//! Benchmark harness: one runner per paper table/figure (DESIGN.md §5).
//!
//! Each runner trains (or loads cached trained weights), evaluates every
//! merge variant on the synthetic counterpart of the paper's dataset, and
//! prints the same rows/series the paper reports.  Absolute numbers differ
//! (CPU PJRT vs A6000 — DESIGN.md §6); the *shape* — who wins, the
//! monotonicities, the crossovers — is the reproduction target.
//!
//! Results are also appended as JSON under `reports/` for EXPERIMENTS.md.

#[cfg(feature = "pjrt")]
pub mod ablations;
#[cfg(feature = "pjrt")]
pub mod chronos_suite;
#[cfg(feature = "pjrt")]
pub mod forecast_suite;
#[cfg(feature = "pjrt")]
pub mod ssm_suite;
#[cfg(feature = "pjrt")]
pub mod studies;

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::json::Json;

/// Shared context for all experiment runners.
pub struct BenchCtx {
    pub artifact_dir: PathBuf,
    pub report_dir: PathBuf,
    /// quick mode: fewer train steps / eval windows (CI-friendly)
    pub quick: bool,
    pub seed: u64,
}

impl BenchCtx {
    pub fn new(artifact_dir: impl Into<PathBuf>, quick: bool) -> Result<BenchCtx> {
        let artifact_dir = artifact_dir.into();
        let report_dir = artifact_dir
            .parent()
            .unwrap_or(Path::new("."))
            .join("reports");
        std::fs::create_dir_all(&report_dir)?;
        Ok(BenchCtx { artifact_dir, report_dir, quick, seed: 2024 })
    }

    pub fn train_steps(&self, full: usize) -> usize {
        if self.quick { (full / 10).max(20) } else { full }
    }

    pub fn eval_windows(&self, full: usize) -> usize {
        if self.quick { (full / 8).max(8) } else { full }
    }

    pub fn save_report(&self, name: &str, value: &Json) -> Result<()> {
        let path = self.report_dir.join(format!("{name}.json"));
        std::fs::write(&path, value.to_string_pretty())?;
        println!("report -> {}", path.display());
        Ok(())
    }

    /// Cached trained weights live next to the artifacts.
    pub fn trained_weights_path(&self, identity: &str, dataset: &str) -> PathBuf {
        self.artifact_dir.join(format!("{identity}.{dataset}.trained.bin"))
    }
}

/// Dispatch an experiment by its paper id.
#[cfg(not(feature = "pjrt"))]
pub fn run(_ctx: &BenchCtx, which: &str) -> Result<()> {
    anyhow::bail!(
        "experiment {which:?} executes compiled artifacts, but this binary was \
         built without the `pjrt` feature; rebuild with `cargo build --features pjrt` \
         (the kernel microbenches still run: `cargo bench --bench merging`)"
    )
}

/// Dispatch an experiment by its paper id.
#[cfg(feature = "pjrt")]
pub fn run(ctx: &BenchCtx, which: &str) -> Result<()> {
    match which {
        "table1" => forecast_suite::table1(ctx),
        "fig2" => forecast_suite::fig2(ctx),
        "table2" | "fig3" => chronos_suite::table2(ctx),
        "fig4" => chronos_suite::fig4_dynamic(ctx),
        "fig5" => forecast_suite::fig5_constant_mse(ctx),
        "fig6" | "fig17" => chronos_suite::fig6_gaussian(ctx),
        "table4" => studies::table4_dataset_properties(ctx),
        "table5" => studies::table5_model_properties(ctx),
        "fig7" | "fig20" => chronos_suite::fig7_input_length(ctx),
        "fig8" => studies::fig8_merge_trace(ctx),
        "fig9" => studies::fig9_subsample(ctx),
        "fig15" => chronos_suite::fig15_metrics(ctx),
        "fig16" => chronos_suite::fig16_pruning(ctx),
        "fig19" => studies::fig19_redundancy(ctx),
        "table3" => ssm_suite::table3(ctx),
        "table8" => forecast_suite::table8_patchtst(ctx),
        "ablation_k" => ablations::ablation_k(ctx),
        "deconly" => ablations::deconly(ctx),
        "ablation_bound" => ablations::ablation_bound(ctx),
        "all" => {
            for exp in [
                "table1", "fig2", "table2", "fig4", "fig5", "fig6", "table4",
                "table5", "fig7", "fig8", "fig9", "fig15", "fig16", "fig19",
                "table3", "table8", "ablation_k", "deconly", "ablation_bound",
            ] {
                println!("\n================ {exp} ================");
                if let Err(e) = run(ctx, exp) {
                    eprintln!("{exp} FAILED: {e:#}");
                }
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment {other:?}; see DESIGN.md §5"),
    }
}
