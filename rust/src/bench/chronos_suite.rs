//! Chronos-family experiments (§5.3, §5.5, §6): the foundation-model suite.
//!
//! The chronos-like models are trained **once** on a mixed corpus of all
//! five synthetic datasets (the foundation-model recipe) and then
//! evaluated zero-shot per dataset — matching the paper's setting where
//! merging is applied to a pretrained Chronos without fine-tuning.

use std::time::Instant;

use anyhow::{Context, Result};

use super::forecast_suite::dataset;
use super::BenchCtx;
use crate::cost;
use crate::data::{self, Split};
use crate::eval::{self, OperatingPoint};
use crate::json::Json;
use crate::runtime::{Engine, Model, WeightStore};
use crate::signal;
use crate::tensor::Tensor;
use crate::train;
use crate::util::Rng;

pub const DATASETS: &[&str] = &["etth1", "ettm1", "weather", "electricity", "traffic"];
pub const SIZES: &[&str] = &["s", "m", "l"];
const M: usize = 512;
const P: usize = 64;

/// Train a chronos size on the mixed corpus (or load the cache).
pub fn train_mixture(ctx: &BenchCtx, engine: &Engine, size: &str, steps: usize) -> Result<WeightStore> {
    let identity = format!("chronos_{size}");
    let cache = ctx.trained_weights_path(&identity, "mixture");
    if cache.exists() {
        return WeightStore::load(&cache);
    }
    let mut model = engine
        .load(&format!("{identity}__train"))
        .with_context(|| format!("train artifact for {identity}"))?;
    let init = WeightStore::load(&ctx.artifact_dir.join(format!("{identity}.weights.bin")))?;
    model.bind_weights(&init)?;
    let batch = model.manifest.batch();
    let sets: Vec<_> = DATASETS
        .iter()
        .map(|n| dataset(n, 6000, M, P, Split::Train, ctx.seed))
        .collect();
    let mut rng = Rng::new(ctx.seed ^ 0xC40);
    let report = train::train_loop(
        &mut model,
        &init,
        steps,
        |_| {
            let ds = &sets[rng.below(sets.len())];
            let idx: Vec<usize> = (0..batch).map(|_| rng.below(ds.len())).collect();
            ds.batch_univariate(&idx)
        },
        |step, loss| {
            if step % 50 == 0 {
                println!("  [chronos_{size}/mixture] step {step} ce {loss:.4}");
            }
            true
        },
    )?;
    println!("  [chronos_{size}] trained {} steps in {:.1}s", report.steps, report.seconds);
    report.final_weights.save(&cache)?;
    Ok(report.final_weights)
}

/// Evaluate a chronos artifact on a dataset: (MSE of dequantized forecast,
/// throughput).  Forecast values are compared in the standardized space.
pub fn eval_chronos(model: &Model, ds: &data::WindowDataset, n_windows: usize) -> Result<(f64, f64)> {
    let batch = model.manifest.batch();
    let vocab = model.manifest.config_usize("vocab").unwrap();
    let clip = model.manifest.config.get("clip").and_then(|c| c.as_f64().ok()).unwrap_or(15.0);
    let m = model.manifest.inputs[0].shape[1];
    anyhow::ensure!(ds.m == m, "dataset m {} != artifact m {}", ds.m, m);
    let stride = (ds.len() / n_windows.max(1)).max(1);
    let (mut mse_sum, mut count, mut elapsed) = (0.0, 0usize, 0.0);
    let mut idx = 0usize;
    while count < n_windows && (idx + batch) * stride <= ds.len() {
        let indices: Vec<usize> = (0..batch).map(|b| (idx + b) * stride % ds.len()).collect();
        let (x, y) = ds.batch_univariate(&indices);
        let t0 = Instant::now();
        let out = model.execute(&[x])?;
        elapsed += t0.elapsed().as_secs_f64();
        let pred = eval::chronos_dequantize(&out[0], &out[1], vocab, clip)?;
        mse_sum += eval::mse(&pred, &y)? * batch as f64;
        count += batch;
        idx += batch;
    }
    anyhow::ensure!(count > 0, "no eval windows");
    Ok((mse_sum / count as f64, count as f64 / elapsed))
}

/// Table 2 (+ figs. 3, 10–14): best-MSE and fastest selections per dataset.
pub fn table2(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let steps = ctx.train_steps(400);
    let n_eval = ctx.eval_windows(64);
    let mut weights = Vec::new();
    for size in SIZES {
        weights.push(train_mixture(ctx, &engine, size, steps)?);
    }
    let mut rows = Vec::new();
    println!("{:<12} {:>8} | {:>8} {:>8} | {:>8} {:>8}", "dataset", "MSE",
             "bestAcc", "bestd%", "fastAcc", "fastd%");
    for ds_name in DATASETS {
        let test = dataset(ds_name, 6000, M, P, Split::Test, ctx.seed);
        let mut points = Vec::new();
        for (size, ws) in SIZES.iter().zip(&weights) {
            let identity = format!("chronos_{size}");
            for spec in [
                crate::merging::MergeSpec::off(),
                crate::merging::MergeSpec::single(32, crate::merging::MergeSpec::DEFAULT_K),
                crate::merging::MergeSpec::single(64, crate::merging::MergeSpec::DEFAULT_K),
                crate::merging::MergeSpec::single(128, crate::merging::MergeSpec::DEFAULT_K),
            ] {
                let name = format!("{identity}__r{}", spec.total_r());
                let mut model = engine.load(&name)?;
                model.bind_weights(ws)?;
                let (mse, thr) = eval_chronos(&model, &test, n_eval)?;
                points.push((
                    size.to_string(),
                    spec.total_r(),
                    OperatingPoint::for_spec(&identity, &spec, mse, thr),
                ));
            }
        }
        // reference: best *unmerged* model (paper: "choose the best model
        // without token merging as reference")
        let reference = points
            .iter()
            .filter(|(_, r, _)| *r == 0)
            .map(|(_, _, p)| p.clone())
            .min_by(|a, b| a.mse.total_cmp(&b.mse))
            .unwrap();
        let merged: Vec<OperatingPoint> =
            points.iter().filter(|(_, r, _)| *r > 0).map(|(_, _, p)| p.clone()).collect();
        let best = eval::select_best_mse(&reference, &merged);
        let fastest = eval::select_fastest_rel(&reference, &merged, 0.03);
        println!(
            "{:<12} {:>8.3} | {:>7.2}x {:>+7.1}% | {:>7.2}x {:>+7.1}%",
            ds_name, reference.mse,
            best.accel(&reference), best.mse_delta_pct(&reference),
            fastest.accel(&reference), fastest.mse_delta_pct(&reference),
        );
        rows.push(Json::obj(vec![
            ("dataset", Json::str(*ds_name)),
            ("mse_ref", Json::num(reference.mse)),
            ("reference", Json::str(reference.name.clone())),
            ("best_accel", Json::num(best.accel(&reference))),
            ("best_mse_delta_pct", Json::num(best.mse_delta_pct(&reference))),
            ("best_name", Json::str(best.name.clone())),
            ("fastest_accel", Json::num(fastest.accel(&reference))),
            ("fastest_mse_delta_pct", Json::num(fastest.mse_delta_pct(&reference))),
            ("fastest_name", Json::str(fastest.name.clone())),
            ("points", Json::arr(points.iter().map(|(s, r, p)| Json::obj(vec![
                ("size", Json::str(s.clone())),
                ("r", Json::num(*r as f64)),
                ("mse", Json::num(p.mse)),
                ("throughput", Json::num(p.throughput)),
            ])).collect())),
        ]));
    }
    ctx.save_report("table2", &Json::arr(rows))
}

/// Fig. 4: dynamic (threshold) merging vs fixed r — FLOPs vs MSE.
pub fn fig4_dynamic(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let ws = train_mixture(ctx, &engine, "s", ctx.train_steps(400))?;
    let test = dataset("etth1", 6000, M, P, Split::Test, ctx.seed);
    let n_eval = ctx.eval_windows(32);
    let mut rows = Vec::new();

    // manifest config for the FLOPs model
    let probe = engine.load("chronos_s__r0")?;
    let d = probe.manifest.config_usize("d").unwrap();
    let hidden = probe.manifest.config_usize("mlp_hidden").unwrap();
    let layers = probe.manifest.config_usize("enc_layers").unwrap();

    println!("{:<12} {:>10} {:>12} {:>8}", "mode", "param", "GFLOPs/req", "MSE");
    // dynamic: one artifact, threshold swept at runtime (batch sizes 1, 10)
    for b in [1usize, 10] {
        let name = format!("chronos_s__dyn_b{b}");
        let mut model = engine.load(&name)?;
        model.bind_weights(&ws)?;
        let vocab = model.manifest.config_usize("vocab").unwrap();
        for th in [0.5, 0.7, 0.8, 0.9, 0.95, 0.99] {
            let (mut mse_sum, mut count) = (0.0, 0usize);
            let mut eff_sum = 0.0f64;
            let stride = (test.len() / n_eval.max(1)).max(1);
            let mut idx = 0;
            while count < n_eval && (idx + b) * stride <= test.len() {
                let indices: Vec<usize> = (0..b).map(|i| (idx + i) * stride).collect();
                let (x, y) = test.batch_univariate(&indices);
                let out = model.execute(&[x, Tensor::scalar_f32(th as f32)])?;
                let pred = eval::chronos_dequantize(&out[0], &out[1], vocab, 15.0)?;
                mse_sum += eval::mse(&pred, &y)? * b as f64;
                // out[2]: per-element effective token count summed over layers
                let eff = out[2].i32s()?;
                eff_sum += eff.iter().map(|&e| e as f64).sum::<f64>() / eff.len() as f64;
                count += b;
                idx += b;
            }
            let mean_eff = eff_sum / (count as f64 / b as f64);
            // translate the summed effective counts into a per-layer schedule
            let per_layer = mean_eff / layers as f64;
            let tokens: Vec<usize> = std::iter::once(M)
                .chain((0..layers).map(|_| per_layer as usize))
                .collect();
            let flops = cost::encoder_flops(cost::Arch::Vanilla, &tokens, d, hidden, false);
            let mse = mse_sum / count as f64;
            println!("{:<12} {:>10.2} {:>12.3} {:>8.3}", format!("dyn(b={b})"), th,
                     flops as f64 / 1e9, mse);
            rows.push(Json::obj(vec![
                ("mode", Json::str(format!("dynamic_b{b}"))),
                ("threshold", Json::num(th)),
                ("gflops", Json::num(flops as f64 / 1e9)),
                ("mse", Json::num(mse)),
            ]));
        }
    }
    // fixed r for comparison
    for r in [0usize, 32, 64, 128] {
        let name = format!("chronos_s__r{r}");
        let mut model = engine.load(&name)?;
        model.bind_weights(&ws)?;
        let (mse, _) = eval_chronos(&model, &test, n_eval)?;
        let tokens = model.manifest.enc_tokens().unwrap();
        let flops = cost::encoder_flops(cost::Arch::Vanilla, &tokens, d, hidden, true);
        println!("{:<12} {:>10} {:>12.3} {:>8.3}", "fixed", r, flops as f64 / 1e9, mse);
        rows.push(Json::obj(vec![
            ("mode", Json::str("fixed")),
            ("r", Json::num(r as f64)),
            ("gflops", Json::num(flops as f64 / 1e9)),
            ("mse", Json::num(mse)),
        ]));
    }
    ctx.save_report("fig4", &Json::arr(rows))
}

/// Fig. 6 / 17: Gaussian low-pass filtering vs token merging.
pub fn fig6_gaussian(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let ws = train_mixture(ctx, &engine, "s", ctx.train_steps(400))?;
    let n_eval = ctx.eval_windows(32);
    let mut rows = Vec::new();
    println!("{:<12} {:<16} {:>8}", "dataset", "setting", "MSE");
    let sets = if ctx.quick { vec!["etth1"] } else { vec!["etth1", "electricity"] };
    for ds_name in sets {
        let test = dataset(ds_name, 6000, M, P, Split::Test, ctx.seed);
        // (a) Gaussian-filtered input, no merging
        let mut model0 = engine.load("chronos_s__r0")?;
        model0.bind_weights(&ws)?;
        for sigma in [0.0, 1.0, 2.0, 4.0] {
            let (mse, _) = eval_chronos_filtered(&model0, &test, n_eval, sigma)?;
            println!("{:<12} {:<16} {:>8.3}", ds_name, format!("gauss s={sigma}"), mse);
            rows.push(Json::obj(vec![
                ("dataset", Json::str(ds_name)),
                ("setting", Json::str(format!("gauss_{sigma}"))),
                ("mse", Json::num(mse)),
            ]));
        }
        // (b) token merging
        for r in [32usize, 64, 128] {
            let mut model = engine.load(&format!("chronos_s__r{r}"))?;
            model.bind_weights(&ws)?;
            let (mse, _) = eval_chronos(&model, &test, n_eval)?;
            println!("{:<12} {:<16} {:>8.3}", ds_name, format!("merge r={r}"), mse);
            rows.push(Json::obj(vec![
                ("dataset", Json::str(ds_name)),
                ("setting", Json::str(format!("merge_{r}"))),
                ("mse", Json::num(mse)),
            ]));
        }
        // (c) both combined (paper: "together leads to the best results")
        let mut model = engine.load("chronos_s__r64")?;
        model.bind_weights(&ws)?;
        let (mse, _) = eval_chronos_filtered(&model, &test, n_eval, 2.0)?;
        println!("{:<12} {:<16} {:>8.3}", ds_name, "gauss2+merge64", mse);
        rows.push(Json::obj(vec![
            ("dataset", Json::str(ds_name)),
            ("setting", Json::str("gauss2_merge64")),
            ("mse", Json::num(mse)),
        ]));
    }
    ctx.save_report("fig6", &Json::arr(rows))
}

fn eval_chronos_filtered(
    model: &Model,
    ds: &data::WindowDataset,
    n_windows: usize,
    sigma: f64,
) -> Result<(f64, f64)> {
    let batch = model.manifest.batch();
    let vocab = model.manifest.config_usize("vocab").unwrap();
    let m = model.manifest.inputs[0].shape[1];
    let stride = (ds.len() / n_windows.max(1)).max(1);
    let (mut mse_sum, mut count, mut elapsed) = (0.0, 0usize, 0.0);
    let mut idx = 0usize;
    while count < n_windows && (idx + batch) * stride <= ds.len() {
        let indices: Vec<usize> = (0..batch).map(|b| (idx + b) * stride % ds.len()).collect();
        let (x, y) = ds.batch_univariate(&indices);
        // low-pass filter each context row
        let mut data = x.f32s()?.to_vec();
        for b in 0..batch {
            let row = signal::gaussian_filter(&data[b * m..(b + 1) * m], sigma);
            data[b * m..(b + 1) * m].copy_from_slice(&row);
        }
        let xf = Tensor::from_f32(&[batch, m], data)?;
        let t0 = Instant::now();
        let out = model.execute(&[xf])?;
        elapsed += t0.elapsed().as_secs_f64();
        let pred = eval::chronos_dequantize(&out[0], &out[1], vocab, 15.0)?;
        mse_sum += eval::mse(&pred, &y)? * batch as f64;
        count += batch;
        idx += batch;
    }
    Ok((mse_sum / count as f64, count as f64 / elapsed))
}

/// Fig. 7 / 20: input-length dependence.
pub fn fig7_input_length(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let ws = train_mixture(ctx, &engine, "s", ctx.train_steps(400))?;
    let n_eval = ctx.eval_windows(32);
    let mut rows = Vec::new();
    println!("{:>6} {:>6} {:>8} {:>10}", "m", "r", "MSE", "thr/s");
    for (m, rs) in [(128usize, [0usize, 16]), (256, [0, 32]), (512, [0, 64]), (1024, [0, 128])] {
        for r in rs {
            let name = if m == 512 {
                format!("chronos_s__r{r}")
            } else {
                format!("chronos_s__m{m}_r{r}")
            };
            let Ok(mut model) = engine.load(&name) else {
                println!("{:>6} {:>6}   (artifact {name} missing — run aot --full)", m, r);
                continue;
            };
            model.bind_weights(&ws)?;
            let test = dataset("etth1", 8000, m, P, Split::Test, ctx.seed);
            let (mse, thr) = eval_chronos(&model, &test, n_eval)?;
            println!("{:>6} {:>6} {:>8.3} {:>10.1}", m, r, mse, thr);
            rows.push(Json::obj(vec![
                ("m", Json::num(m as f64)),
                ("r", Json::num(r as f64)),
                ("mse", Json::num(mse)),
                ("throughput", Json::num(thr)),
            ]));
        }
    }
    ctx.save_report("fig7", &Json::arr(rows))
}

/// Fig. 15: similarity-metric ablation (cosine vs L1 vs L2).
pub fn fig15_metrics(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let ws = train_mixture(ctx, &engine, "s", ctx.train_steps(400))?;
    let test = dataset("etth1", 6000, M, P, Split::Test, ctx.seed);
    let n_eval = ctx.eval_windows(32);
    let mut rows = Vec::new();
    println!("{:<8} {:>8} {:>10}", "metric", "MSE", "thr/s");
    for (label, name) in [
        ("cos", "chronos_s__r64".to_string()),
        ("l1", "chronos_s__r64_l1".to_string()),
        ("l2", "chronos_s__r64_l2".to_string()),
    ] {
        let Ok(mut model) = engine.load(&name) else {
            println!("{label:<8} (artifact missing — run aot --full)");
            continue;
        };
        model.bind_weights(&ws)?;
        let (mse, thr) = eval_chronos(&model, &test, n_eval)?;
        println!("{:<8} {:>8.3} {:>10.1}", label, mse, thr);
        rows.push(Json::obj(vec![
            ("metric", Json::str(label)),
            ("mse", Json::num(mse)),
            ("throughput", Json::num(thr)),
        ]));
    }
    ctx.save_report("fig15", &Json::arr(rows))
}

/// Fig. 16: merging vs pruning.
pub fn fig16_pruning(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let ws = train_mixture(ctx, &engine, "s", ctx.train_steps(400))?;
    let test = dataset("etth1", 6000, M, P, Split::Test, ctx.seed);
    let n_eval = ctx.eval_windows(32);
    let mut rows = Vec::new();
    println!("{:<8} {:>8}", "mode", "MSE");
    for (label, name) in [
        ("none", "chronos_s__r0"),
        ("merge", "chronos_s__r64"),
        ("prune", "chronos_s__r64_prune"),
    ] {
        let mut model = engine.load(name)?;
        model.bind_weights(&ws)?;
        let (mse, _) = eval_chronos(&model, &test, n_eval)?;
        println!("{:<8} {:>8.3}", label, mse);
        rows.push(Json::obj(vec![("mode", Json::str(label)), ("mse", Json::num(mse))]));
    }
    ctx.save_report("fig16", &Json::arr(rows))
}
