//! Table-1 family experiments: the five time series transformers.
//!
//! * `table1` — local merging on pretrained models (paper table 1): per
//!   (arch, depth, dataset), train the r0 model, then evaluate every merge
//!   variant and apply the paper's §5.1 selection rule (fastest within
//!   +0.01 val MSE; fall back to no merging).
//! * `fig2` — training *with* merging.
//! * `fig5_constant_mse` — the constant-MSE outcome on the vanilla
//!   transformer.
//! * `table8_patchtst` — merging over patch tokens.

use std::time::Instant;

use anyhow::{Context, Result};

use super::BenchCtx;
use crate::data::{self, Split, WindowDataset};
use crate::eval::{self, OperatingPoint};
use crate::json::Json;
use crate::runtime::{Engine, Model, WeightStore};
use crate::tensor::Tensor;
use crate::train;
use crate::util::Rng;

pub const ARCHS: &[&str] = &["transformer", "informer", "autoformer", "fedformer", "nonstationary"];

/// Build the standardized window dataset for a named synthetic profile.
pub fn dataset(name: &str, len: usize, m: usize, p: usize, split: Split, seed: u64) -> WindowDataset {
    let prof = data::profile(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
    // the model suite is compiled for 7 variates; wider datasets expose a
    // 7-variate view (see Series::take_vars)
    let series = data::generate(prof, len, seed).take_vars(7);
    let scaler = data::Scaler::fit(&series, Split::Train);
    WindowDataset::new(scaler.transform(&series), m, p, split)
}

/// Train via the `__train` artifact or load the cached trained weights.
pub fn train_or_load(
    ctx: &BenchCtx,
    engine: &Engine,
    identity: &str,
    train_artifact: &str,
    ds_name: &str,
    steps: usize,
    univariate: bool,
) -> Result<WeightStore> {
    let cache = ctx.trained_weights_path(identity, ds_name);
    if cache.exists() {
        return WeightStore::load(&cache);
    }
    let mut model = engine
        .load(train_artifact)
        .with_context(|| format!("loading train artifact {train_artifact}"))?;
    let init = WeightStore::load(&ctx.artifact_dir.join(format!("{identity}.weights.bin")))?;
    model.bind_weights(&init)?;
    let batch = model.manifest.batch();
    let cfg_m = model.manifest.config_usize("m").unwrap();
    let cfg_p = model.manifest.config_usize("p").unwrap();
    let ds = dataset(ds_name, 6000, cfg_m, cfg_p, Split::Train, ctx.seed);
    let mut rng = Rng::new(ctx.seed ^ 0xBA7C);
    let mut es = train::EarlyStop::new(steps / 4);
    let report = train::train_loop(
        &mut model,
        &init,
        steps,
        |_| {
            let idx: Vec<usize> = (0..batch).map(|_| rng.below(ds.len())).collect();
            if univariate {
                ds.batch_univariate(&idx)
            } else {
                ds.batch(&idx)
            }
        },
        |step, loss| {
            if step % 50 == 0 {
                println!("  [{identity}/{ds_name}] step {step} loss {loss:.4}");
            }
            es.keep_going(loss)
        },
    )?;
    println!(
        "  [{identity}/{ds_name}] trained {} steps in {:.1}s (final loss {:.4})",
        report.steps,
        report.seconds,
        report.losses.last().copied().unwrap_or(f64::NAN)
    );
    report.final_weights.save(&cache)?;
    Ok(report.final_weights)
}

/// Evaluate a forecast artifact over `n_windows` eval windows: (MSE,
/// throughput samples/s).
pub fn eval_forecast(
    model: &Model,
    ds: &WindowDataset,
    n_windows: usize,
) -> Result<(f64, f64)> {
    let batch = model.manifest.batch();
    let stride = (ds.len() / n_windows.max(1)).max(1);
    let mut mse_sum = 0.0;
    let mut count = 0usize;
    let mut elapsed = 0.0;
    let mut idx = 0usize;
    while idx + batch <= ds.len() / stride && count < n_windows {
        let indices: Vec<usize> = (0..batch).map(|b| (idx + b) * stride % ds.len()).collect();
        let (x, y) = ds.batch(&indices);
        let t0 = Instant::now();
        let out = model.execute(&[x])?;
        elapsed += t0.elapsed().as_secs_f64();
        mse_sum += eval::mse(&out[0], &y)? * batch as f64;
        count += batch;
        idx += batch;
    }
    anyhow::ensure!(count > 0, "no eval windows");
    Ok((mse_sum / count as f64, count as f64 / elapsed))
}

fn datasets_for(ctx: &BenchCtx) -> Vec<&'static str> {
    if ctx.quick {
        vec!["etth1", "electricity"]
    } else {
        vec!["etth1", "ettm1", "weather", "electricity", "traffic"]
    }
}

fn depths_for(ctx: &BenchCtx) -> Vec<usize> {
    if ctx.quick { vec![2] } else { vec![2, 4] }
}

/// Paper table 1.
pub fn table1(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let steps = ctx.train_steps(300);
    let n_eval = ctx.eval_windows(64);
    let mut rows = Vec::new();
    println!("{:<12} {:>2} {:<14} {:>8} {:>8} {:>8}  selected", "dataset", "L", "arch", "MSE", "Accel", "MSEd%");
    for ds_name in datasets_for(ctx) {
        for &l in &depths_for(ctx) {
            for &arch in ARCHS {
                let identity = format!("fc_{arch}_L{l}");
                let ws = train_or_load(
                    ctx, &engine, &identity, &format!("{identity}__train"),
                    ds_name, steps, false,
                )?;
                let val = dataset(ds_name, 6000, 192, 96, Split::Val, ctx.seed);
                let test = dataset(ds_name, 6000, 192, 96, Split::Test, ctx.seed);
                let mut val_pts = Vec::new();
                let mut test_pts = Vec::new();
                for tag in ["r0", "r16", "r32"] {
                    let name = format!("{identity}__{tag}");
                    let mut model = engine.load(&name)?;
                    model.bind_weights(&ws)?;
                    let (vm, vt) = eval_forecast(&model, &val, n_eval)?;
                    let (tm, tt) = eval_forecast(&model, &test, n_eval)?;
                    val_pts.push(OperatingPoint { name: tag.into(), mse: vm, throughput: vt });
                    test_pts.push(OperatingPoint { name: tag.into(), mse: tm, throughput: tt });
                }
                // §5.1 rule on the validation set, report on test
                let chosen = eval::select_fastest_within(&val_pts[0], &val_pts[1..], 0.01);
                let test_ref = &test_pts[0];
                let test_sel = test_pts.iter().find(|p| p.name == chosen.name).unwrap();
                println!(
                    "{:<12} {:>2} {:<14} {:>8.3} {:>7.2}x {:>+7.1}%  {}",
                    ds_name, l, arch, test_ref.mse,
                    test_sel.accel(test_ref),
                    test_sel.mse_delta_pct(test_ref),
                    chosen.name,
                );
                rows.push(Json::obj(vec![
                    ("dataset", Json::str(ds_name)),
                    ("layers", Json::num(l as f64)),
                    ("arch", Json::str(arch)),
                    ("mse_ref", Json::num(test_ref.mse)),
                    ("accel", Json::num(test_sel.accel(test_ref))),
                    ("mse_delta_pct", Json::num(test_sel.mse_delta_pct(test_ref))),
                    ("selected", Json::str(chosen.name.clone())),
                ]));
            }
        }
    }
    ctx.save_report("table1", &Json::arr(rows))
}

/// Fig. 2: training with token merging vs merging only at inference.
pub fn fig2(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let steps = ctx.train_steps(300);
    let n_eval = ctx.eval_windows(48);
    let ds_name = "traffic";
    let mut rows = Vec::new();
    println!("{:<14} {:<12} {:>8} {:>8}", "arch", "trained", "MSE", "Accel");
    for arch in ["autoformer", "nonstationary"] {
        let identity = format!("fc_{arch}_L2");
        let test = dataset(ds_name, 6000, 192, 96, Split::Test, ctx.seed);
        // (a) plain training, merging at inference
        let ws_plain = train_or_load(ctx, &engine, &identity, &format!("{identity}__train"),
                                     ds_name, steps, false)?;
        // (b) training WITH merging (the __trainmerge artifact has r_train>0)
        let cache = ctx.trained_weights_path(&identity, &format!("{ds_name}-merge"));
        let ws_merge = if cache.exists() {
            WeightStore::load(&cache)?
        } else {
            let ws = train_with_artifact(ctx, &engine, &identity,
                                         &format!("{identity}__trainmerge"), ds_name, steps)?;
            ws.save(&cache)?;
            ws
        };
        let mut report = |label: &str, ws: &WeightStore| -> Result<()> {
            let mut points = Vec::new();
            for tag in ["r0", "r16", "r32"] {
                let mut model = engine.load(&format!("{identity}__{tag}"))?;
                model.bind_weights(ws)?;
                let (mse, thr) = eval_forecast(&model, &test, n_eval)?;
                points.push(OperatingPoint { name: tag.into(), mse, throughput: thr });
            }
            for p in &points {
                println!("{:<14} {:<12} {:>8.3} {:>7.2}x ({})", arch, label, p.mse,
                         p.accel(&points[0]), p.name);
                rows.push(Json::obj(vec![
                    ("arch", Json::str(arch)),
                    ("trained", Json::str(label)),
                    ("variant", Json::str(p.name.clone())),
                    ("mse", Json::num(p.mse)),
                    ("accel", Json::num(p.accel(&points[0]))),
                ]));
            }
            Ok(())
        };
        report("plain", &ws_plain)?;
        report("with-merge", &ws_merge)?;
    }
    ctx.save_report("fig2", &Json::arr(rows))
}

fn train_with_artifact(
    ctx: &BenchCtx,
    engine: &Engine,
    identity: &str,
    artifact: &str,
    ds_name: &str,
    steps: usize,
) -> Result<WeightStore> {
    let mut model = engine.load(artifact)?;
    let init = WeightStore::load(&ctx.artifact_dir.join(format!("{identity}.weights.bin")))?;
    model.bind_weights(&init)?;
    let batch = model.manifest.batch();
    let ds = dataset(ds_name, 6000, 192, 96, Split::Train, ctx.seed);
    let mut rng = Rng::new(ctx.seed ^ 0x71A1);
    let report = train::train_loop(
        &mut model, &init, steps,
        |_| {
            let idx: Vec<usize> = (0..batch).map(|_| rng.below(ds.len())).collect();
            ds.batch(&idx)
        },
        |step, loss| {
            if step % 50 == 0 {
                println!("  [{artifact}/{ds_name}] step {step} loss {loss:.4}");
            }
            true
        },
    )?;
    Ok(report.final_weights)
}

/// Fig. 5: merge-rate sweep on the vanilla transformer — the constant-MSE
/// outcome.
pub fn fig5_constant_mse(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let steps = ctx.train_steps(300);
    let n_eval = ctx.eval_windows(48);
    let identity = "fc_transformer_L2";
    let ws = train_or_load(ctx, &engine, identity, "fc_transformer_L2__train",
                           "etth1", steps, false)?;
    let test = dataset("etth1", 6000, 192, 96, Split::Test, ctx.seed);
    let mut rows = Vec::new();
    println!("{:>6} {:>8} {:>10}", "r", "MSE", "thr/s");
    for tag in ["r0", "r16", "r32"] {
        let mut model = engine.load(&format!("{identity}__{tag}"))?;
        model.bind_weights(&ws)?;
        let (mse, thr) = eval_forecast(&model, &test, n_eval)?;
        println!("{:>6} {:>8.3} {:>10.1}", tag, mse, thr);
        rows.push(Json::obj(vec![
            ("r", Json::str(tag)),
            ("mse", Json::num(mse)),
            ("throughput", Json::num(thr)),
        ]));
    }
    ctx.save_report("fig5", &Json::arr(rows))
}

/// Table 8: PatchTST with merging over patch tokens.
pub fn table8_patchtst(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let steps = ctx.train_steps(300);
    let n_eval = ctx.eval_windows(48);
    let identity = "patchtst_L2";
    let mut rows = Vec::new();
    println!("{:<12} {:>8} {:>8} {:>8}", "dataset", "MSE", "Accel", "MSEd%");
    for ds_name in datasets_for(ctx).into_iter().take(3) {
        let ws = train_or_load(ctx, &engine, identity, "patchtst_L2__train",
                               ds_name, steps, false)?;
        let test = dataset(ds_name, 6000, 192, 96, Split::Test, ctx.seed);
        let mut points = Vec::new();
        for tag in ["r0", "r4", "r8"] {
            let mut model = engine.load(&format!("{identity}__{tag}"))?;
            model.bind_weights(&ws)?;
            let (mse, thr) = eval_forecast(&model, &test, n_eval)?;
            points.push(OperatingPoint { name: tag.into(), mse, throughput: thr });
        }
        let sel = eval::select_fastest_within(&points[0], &points[1..], 0.01);
        println!("{:<12} {:>8.3} {:>7.2}x {:>+7.1}%", ds_name, points[0].mse,
                 sel.accel(&points[0]), sel.mse_delta_pct(&points[0]));
        rows.push(Json::obj(vec![
            ("dataset", Json::str(ds_name)),
            ("mse_ref", Json::num(points[0].mse)),
            ("accel", Json::num(sel.accel(&points[0]))),
            ("mse_delta_pct", Json::num(sel.mse_delta_pct(&points[0]))),
        ]));
    }
    ctx.save_report("table8", &Json::arr(rows))
}

/// Tensor helper shared by the chronos suite.
pub fn slice_batch(x: &Tensor, rows: usize) -> Result<Tensor> {
    let shape = x.shape();
    let inner: usize = shape[1..].iter().product();
    let mut s = vec![rows];
    s.extend_from_slice(&shape[1..]);
    Tensor::from_f32(&s, x.f32s()?[..rows * inner].to_vec())
}
