//! State-space experiments (§5.4, table 3): Hyena and Mamba on genomic
//! classification, local (k=1) vs global (k=t/2) merging.

use std::time::Instant;

use anyhow::Result;

use super::BenchCtx;
use crate::data::genomic;
use crate::eval;
use crate::json::Json;
use crate::runtime::{Engine, Model, WeightStore};
use crate::tensor::Tensor;
use crate::train;
use crate::util::Rng;

fn train_classifier(ctx: &BenchCtx, engine: &Engine, identity: &str, steps: usize) -> Result<WeightStore> {
    let cache = ctx.trained_weights_path(identity, "genomic");
    if cache.exists() {
        return WeightStore::load(&cache);
    }
    let mut model = engine.load(&format!("{identity}__train"))?;
    let init = WeightStore::load(&ctx.artifact_dir.join(format!("{identity}.weights.bin")))?;
    model.bind_weights(&init)?;
    let batch = model.manifest.batch();
    let m = model.manifest.config_usize("m").unwrap();
    let mut rng = Rng::new(ctx.seed ^ 0x6E0);
    let report = train::train_loop(
        &mut model,
        &init,
        steps,
        |_| {
            let (ids, labels) = genomic::batch(batch, m, &mut rng);
            (
                Tensor::from_i32(&[batch, m], ids).unwrap(),
                Tensor::from_i32(&[batch], labels).unwrap(),
            )
        },
        |step, loss| {
            if step % 50 == 0 {
                println!("  [{identity}/genomic] step {step} ce {loss:.4}");
            }
            true
        },
    )?;
    println!("  [{identity}] trained {} steps in {:.1}s", report.steps, report.seconds);
    report.final_weights.save(&cache)?;
    Ok(report.final_weights)
}

fn eval_classifier(model: &Model, n_batches: usize, seed: u64) -> Result<(f64, f64)> {
    let batch = model.manifest.batch();
    let m = model.manifest.config_usize("m").unwrap();
    let mut rng = Rng::new(seed ^ 0xE7A1); // held-out stream
    let (mut correct, mut total, mut elapsed) = (0.0, 0usize, 0.0);
    for _ in 0..n_batches {
        let (ids, labels) = genomic::batch(batch, m, &mut rng);
        let x = Tensor::from_i32(&[batch, m], ids)?;
        let t0 = Instant::now();
        let out = model.execute(&[x])?;
        elapsed += t0.elapsed().as_secs_f64();
        correct += eval::accuracy(&out[0], &labels)? * batch as f64;
        total += batch;
    }
    Ok((correct / total as f64, total as f64 / elapsed))
}

/// Table 3: local vs global merging on Hyena and Mamba.
pub fn table3(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let steps = ctx.train_steps(300);
    let n_batches = ctx.eval_windows(16);
    let mut rows = Vec::new();
    println!("{:<8} {:<22} {:>8} {:>10}", "model", "merging", "Accel", "Accuracy");
    for identity in ["hyena_L4", "mamba_L4"] {
        let ws = train_classifier(ctx, &engine, identity, steps)?;
        let mut results = Vec::new();
        for tag in ["r0", "r64_k1", "r128_k1", "r64_kglobal", "r128_kglobal"] {
            let name = format!("{identity}__{tag}");
            let mut model = engine.load(&name)?;
            model.bind_weights(&ws)?;
            let (acc, thr) = eval_classifier(&model, n_batches, ctx.seed)?;
            results.push((tag.to_string(), acc, thr));
        }
        let base_thr = results[0].2;
        // paper rows: no merging / local fastest / local best / global
        // fastest / global best
        let pick = |filter: &str, best_quality: bool| -> &(String, f64, f64) {
            results
                .iter()
                .skip(1)
                .filter(|(t, _, _)| t.contains(filter))
                .max_by(|a, b| {
                    if best_quality {
                        a.1.total_cmp(&b.1)
                    } else {
                        a.2.total_cmp(&b.2)
                    }
                })
                .unwrap()
        };
        let mut emit = |label: &str, row: &(String, f64, f64)| {
            println!("{:<8} {:<22} {:>7.2}x {:>9.1}%", identity, label,
                     row.2 / base_thr, row.1 * 100.0);
            rows.push(Json::obj(vec![
                ("model", Json::str(identity)),
                ("merging", Json::str(label)),
                ("variant", Json::str(row.0.clone())),
                ("accel", Json::num(row.2 / base_thr)),
                ("accuracy", Json::num(row.1)),
            ]));
        };
        emit("no merging", &results[0]);
        emit("local fastest", pick("k1", false));
        emit("local best", pick("k1", true));
        emit("global fastest", pick("kglobal", false));
        emit("global best", pick("kglobal", true));
    }
    ctx.save_report("table3", &Json::arr(rows))
}
