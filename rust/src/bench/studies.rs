//! Analysis experiments of §6 and the appendix: dataset/model predictors,
//! merge traces, subsample validation, redundancy.

use anyhow::Result;

use super::chronos_suite::{eval_chronos, train_mixture};
use super::forecast_suite::{dataset, train_or_load, ARCHS};
use super::BenchCtx;
use crate::data::{self, Split};
use crate::json::Json;
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Table 4: quality improvement vs spectral entropy / THD per dataset.
pub fn table4_dataset_properties(ctx: &BenchCtx) -> Result<()> {
    // dataset statistics from the signal substrate
    let mut rows = Vec::new();
    println!("{:<12} {:>10} {:>8} {:>10}", "dataset", "MSEd%", "entropy", "THD");
    // MSE deltas come from the table2 report when available
    let t2 = std::fs::read_to_string(ctx.report_dir.join("table2.json"))
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    for prof in data::PROFILES {
        let series = data::generate(prof, 4096, ctx.seed);
        let (entropy, thd) = data::dataset_stats(&series, 1024);
        let msed = t2
            .as_ref()
            .and_then(|v| {
                v.as_arr().ok()?.iter().find(|row| {
                    row.get("dataset").and_then(|d| d.as_str().ok()) == Some(prof.name)
                })
            })
            .and_then(|row| row.get("best_mse_delta_pct").and_then(|x| x.as_f64().ok()));
        match msed {
            Some(d) => println!("{:<12} {:>+9.1}% {:>8.2} {:>10.2}", prof.name, d, entropy, thd),
            None => println!("{:<12} {:>10} {:>8.2} {:>10.2}", prof.name, "(run table2)", entropy, thd),
        }
        rows.push(Json::obj(vec![
            ("dataset", Json::str(prof.name)),
            ("mse_delta_pct", msed.map(Json::num).unwrap_or(Json::Null)),
            ("spectral_entropy", Json::num(entropy)),
            ("thd", Json::num(thd)),
        ]));
    }
    ctx.save_report("table4", &Json::arr(rows))
}

/// Mean pairwise cosine similarity over the token axis of a (b, t, d)
/// probe tensor (paper table 5's statistic).
pub fn mean_token_similarity(tokens: &Tensor) -> Result<f64> {
    let shape = tokens.shape();
    anyhow::ensure!(shape.len() == 3, "probe shape {:?}", shape);
    let (b, t, d) = (shape[0], shape[1], shape[2]);
    let data = tokens.f32s()?;
    let mut acc = 0.0;
    let mut n = 0usize;
    let stride = (t / 32).max(1); // sample pairs for O(t) cost
    for bi in 0..b {
        for i in (0..t).step_by(stride) {
            for j in ((i + stride)..t).step_by(stride) {
                let a = &data[(bi * t + i) * d..(bi * t + i + 1) * d];
                let c = &data[(bi * t + j) * d..(bi * t + j + 1) * d];
                let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
                for k in 0..d {
                    dot += a[k] as f64 * c[k] as f64;
                    na += (a[k] as f64).powi(2);
                    nb += (c[k] as f64).powi(2);
                }
                acc += dot / (na.sqrt() * nb.sqrt() + 1e-12);
                n += 1;
            }
        }
    }
    Ok(acc / n as f64)
}

/// Table 5: MSE degradation vs post-layer-1 token similarity per model.
pub fn table5_model_properties(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let steps = ctx.train_steps(300);
    let ds_name = "etth1";
    let t1 = std::fs::read_to_string(ctx.report_dir.join("table1.json"))
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let mut rows = Vec::new();
    println!("{:<16} {:>10} {:>12}", "model", "MSEd%", "token-sim");
    for &arch in ARCHS {
        let identity = format!("fc_{arch}_L2");
        let name = format!("{identity}__r0_probe");
        let Ok(mut model) = engine.load(&name) else {
            println!("{arch:<16} (probe artifact missing — run aot --full)");
            continue;
        };
        let ws = train_or_load(ctx, &engine, &identity, &format!("{identity}__train"),
                               ds_name, steps, false)?;
        model.bind_weights(&ws)?;
        let test = dataset(ds_name, 6000, 192, 96, Split::Test, ctx.seed);
        let idx: Vec<usize> = (0..model.manifest.batch()).collect();
        let (x, _) = test.batch(&idx);
        let out = model.execute(&[x])?;
        // probe output: out0 = forecast, out1 = layer-1 tokens
        let sim = mean_token_similarity(&out[1])?;
        let msed = t1
            .as_ref()
            .and_then(|v| {
                v.as_arr().ok()?.iter().find(|row| {
                    row.get("arch").and_then(|a| a.as_str().ok()) == Some(arch)
                        && row.get("dataset").and_then(|d| d.as_str().ok()) == Some(ds_name)
                        && row.get("layers").and_then(|l| l.as_usize().ok()) == Some(2)
                })
            })
            .and_then(|row| row.get("mse_delta_pct").and_then(|x| x.as_f64().ok()));
        match msed {
            Some(d) => println!("{:<16} {:>+9.1}% {:>12.3}", arch, d, sim),
            None => println!("{:<16} {:>10} {:>12.3}", arch, "(run table1)", sim),
        }
        rows.push(Json::obj(vec![
            ("model", Json::str(arch)),
            ("mse_delta_pct", msed.map(Json::num).unwrap_or(Json::Null)),
            ("token_similarity", Json::num(sim)),
        ]));
    }
    ctx.save_report("table5", &Json::arr(rows))
}

/// Fig. 8: trace which source positions merge together.
pub fn fig8_merge_trace(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let ws = train_mixture(ctx, &engine, "s", ctx.train_steps(400))?;
    let Ok(mut model) = engine.load("chronos_s__r64_trace") else {
        println!("(trace artifact missing — run aot --full)");
        return Ok(());
    };
    model.bind_weights(&ws)?;
    let test = dataset("etth1", 6000, 512, 64, Split::Test, ctx.seed);
    let idx: Vec<usize> = (0..model.manifest.batch()).collect();
    let (x, _) = test.batch_univariate(&idx);
    let out = model.execute(&[x])?;
    // out2: composed slot map (b, m) — original position -> final slot
    let slot_map = out[2].i32s()?;
    let m = model.manifest.config_usize("m").unwrap();
    // report the 3 largest merge groups of sample 0 (paper shows top 3)
    let sm = &slot_map[..m];
    let mut counts = std::collections::BTreeMap::new();
    for &s in sm {
        *counts.entry(s).or_insert(0usize) += 1;
    }
    let mut groups: Vec<(i32, usize)> = counts.into_iter().collect();
    groups.sort_by_key(|&(_, c)| std::cmp::Reverse(c));
    let mut rows = Vec::new();
    println!("top merge groups (slot: #sources, span):");
    for &(slot, count) in groups.iter().take(3) {
        let members: Vec<usize> = (0..m).filter(|&p| sm[p] == slot).collect();
        let span = members.last().unwrap() - members.first().unwrap();
        println!("  slot {slot}: {count} tokens, positions {}..{} (span {span})",
                 members.first().unwrap(), members.last().unwrap());
        rows.push(Json::obj(vec![
            ("slot", Json::num(slot as f64)),
            ("count", Json::num(count as f64)),
            ("span", Json::num(span as f64)),
        ]));
    }
    ctx.save_report("fig8", &Json::arr(rows))
}

/// Fig. 9: subsampled vs full test set.
pub fn fig9_subsample(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let ws = train_mixture(ctx, &engine, "s", ctx.train_steps(400))?;
    let test = dataset("etth1", 8000, 512, 64, Split::Test, ctx.seed);
    let mut rows = Vec::new();
    println!("{:<8} {:>12} {:>12}", "r", "MSE(sub)", "MSE(full)");
    for r in [0usize, 64] {
        let mut model = engine.load(&format!("chronos_s__r{r}"))?;
        model.bind_weights(&ws)?;
        let (sub, _) = eval_chronos(&model, &test, ctx.eval_windows(16))?;
        let (full, _) = eval_chronos(&model, &test, ctx.eval_windows(128))?;
        println!("{:<8} {:>12.3} {:>12.3}", r, sub, full);
        rows.push(Json::obj(vec![
            ("r", Json::num(r as f64)),
            ("mse_subsampled", Json::num(sub)),
            ("mse_full", Json::num(full)),
        ]));
    }
    ctx.save_report("fig9", &Json::arr(rows))
}

/// Fig. 19: redundant-token fraction vs similarity threshold, with and
/// without positional embedding, from layer-1 probe tokens.
pub fn fig19_redundancy(ctx: &BenchCtx) -> Result<()> {
    let engine = Engine::new(&ctx.artifact_dir)?;
    let ws = train_mixture(ctx, &engine, "s", ctx.train_steps(400))?;
    let test = dataset("etth1", 6000, 512, 64, Split::Test, ctx.seed);
    let mut rows = Vec::new();
    println!("{:<10} {:>6} {:>10}", "pos-embed", "thresh", "mergeable");
    for (label, name) in [("with", "chronos_s__r0_probe"), ("without", "chronos_s__r0_probe_nope")] {
        let Ok(mut model) = engine.load(name) else {
            println!("{label:<10} (artifact missing — run aot --full)");
            continue;
        };
        model.bind_weights(&ws)?;
        let idx: Vec<usize> = (0..model.manifest.batch()).collect();
        let (x, _) = test.batch_univariate(&idx);
        let out = model.execute(&[x])?;
        let tokens = &out[2]; // (b, m, d) layer-1 reps
        let shape = tokens.shape().to_vec();
        let (b, t, d) = (shape[0], shape[1], shape[2]);
        let data = tokens.f32s()?;
        // one scratch-backed match per sequence, counted against every
        // threshold (the match is threshold-independent)
        let thresholds = [0.5, 0.7, 0.8, 0.9, 0.95, 0.99];
        let mut mergeable = [0usize; 6];
        let mut total = 0usize;
        let mut scratch = crate::merging::MergeScratch::new();
        for bi in 0..b {
            let rows_slice = &data[bi * t * d..(bi + 1) * t * d];
            crate::merging::match_tokens_scratch(rows_slice, t, d, 1, &mut scratch);
            total += scratch.scores().len();
            for (ti, &th) in thresholds.iter().enumerate() {
                mergeable[ti] += scratch.scores().iter().filter(|&&s| s > th).count();
            }
        }
        for (ti, &th) in thresholds.iter().enumerate() {
            let frac = mergeable[ti] as f64 / total as f64;
            println!("{:<10} {:>6.2} {:>9.1}%", label, th, frac * 100.0);
            rows.push(Json::obj(vec![
                ("pos_embed", Json::str(label)),
                ("threshold", Json::num(th)),
                ("mergeable_frac", Json::num(frac)),
            ]));
        }
    }
    ctx.save_report("fig19", &Json::arr(rows))
}
