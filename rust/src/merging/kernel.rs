//! The optimized single-sequence merge kernel.
//!
//! Semantics are identical to [`super::reference`] (the legacy scalar
//! implementation); the differential test suite
//! (`tests/merging_differential.rs`) proves tokens/sizes/slot_map
//! equivalence over randomized cases.  What changed:
//!
//! * **Precomputed norms** — the reference recomputes `|a|` and `|b|`
//!   inside every banded pair, i.e. O(k) times per token.  Here every
//!   token's L2 norm is computed once, so each pair costs a single dot.
//! * **Explicit SIMD with runtime dispatch** — the banded dot and the
//!   norms are [`super::simd`] primitives: hand-written AVX2 (x86_64) /
//!   NEON (aarch64) vector loops selected once per process
//!   ([`super::simd::active_isa`]), with a 4-lane chunked scalar fallback
//!   that is always available and forceable via `TOMERS_FORCE_SCALAR=1`.
//!   The `Accum::F64` vector paths are **bit-for-bit identical** to the
//!   scalar path (mul+add only, never FMA — see `simd.rs` for why), so
//!   dispatch never changes results in the default precision.
//! * **Cache-blocked matching** — [`match_tokens_scratch_tiled`] walks
//!   the A-token axis in tiles sized from `d` ([`matching_tile`]), fusing
//!   the norm pass into the score pass so a tile's token rows are still
//!   L1/L2-resident when its banded scores read them, instead of
//!   streaming the whole `t·d` slab once for norms and again for scores.
//!   Per-token norms are order-independent, so tiling is bitwise-neutral.
//! * **O(t) top-r selection** — `select_nth_unstable_by` with a total
//!   order (score desc, index asc) replaces the full O(t log t) sort.
//!   The total order is NaN-safe by construction (the legacy
//!   `partial_cmp().unwrap()` was a latent, never-reachable panic — see
//!   `reference.rs`) and makes the selected *set* identical to the
//!   reference's stable descending sort, tie-for-tie.
//! * **Zero allocations** — every intermediate lives in a caller-provided
//!   [`MergeScratch`]; outputs land in a reusable [`MergeResult`].
//!
//! The select and scatter stages deliberately remain single streaming
//! passes: each already reads its inputs exactly once, and the scatter's
//! f64 accumulation order (original position order, divide-not-reciprocal)
//! is part of the bitwise contract with [`super::reference`] and
//! [`super::incremental`], so there is no locality to recover there
//! without reordering float ops.
//!
//! **Norm-accumulation order (PR 7 reorder):** the sum-of-squares norm
//! historically accumulated serially in index order — an order the
//! reference's cosine shared, and one a 4-wide vector unit cannot
//! reproduce.  It now uses the same 4-lane chunked order as the dot
//! (`simd::sumsq_f64`), and `reference.rs::sumsq` mirrors that exact
//! order so the norm computation stays bitwise-shared between kernel and
//! oracle at every `d` (and the full scores stay bitwise-shared at
//! `d < 4`, where the chunked dot and the oracle's serial dot coincide —
//! the `d == 1` reference pins in `tests/streaming_differential.rs`
//! depend on this).  Any future change to the accumulation order MUST be
//! made in `simd.rs` (scalar + both vector paths) and `reference.rs`
//! together.
//!
//! The public [`token_norm`] / [`pair_score`] entry points resolve the
//! dispatch per call, so the streaming incremental path stays bit-for-bit
//! equal to the batch kernel under every ISA.

use super::scratch::MergeScratch;
use super::simd::{self, Isa};
use super::MergeResult;

/// Accumulation precision of the banded dot (and the matching norms).
///
/// * [`Accum::F64`] — the default: f64 accumulators, bitwise identical to
///   the reference path **under every dispatched ISA** (scalar, AVX2,
///   NEON — see `simd.rs`).  Every pre-existing entry point uses this.
/// * [`Accum::F32`] — f32 accumulators throughout the similarity
///   computation (ROADMAP "f32 accumulation variants"): half the
///   accumulator register width, so the dot runs twice as many lanes per
///   SIMD op — for throughput-bound callers that tolerate a tiny score
///   perturbation.  The merge itself (size-weighted scatter-average)
///   stays f64 in both modes; only *which* pairs merge can differ, and
///   only on near-ties.
///
/// Accuracy contract (checked by `tests/merging_differential.rs` and
/// `tests/merging_dispatch.rs`): for standardized inputs (|x| = O(1)) and
/// d <= 64 the f32 cosine scores stay within **1e-5** of the f64 scores
/// (measured worst case ~2e-7 over 20k random pairs; the 50x margin
/// covers lane-count reassociation, including the AVX2 8-lane FMA path).
/// Error grows ~sqrt(d)·eps_f32, so expect ~1e-4 by d ~ 4096.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Accum {
    #[default]
    F64,
    F32,
}

/// L2 norm of one token row under the given accumulation precision —
/// exactly the per-token norm [`match_tokens_scratch_accum`] precomputes,
/// down to the rounding of every intermediate.  Exposed so the streaming
/// incremental path (`merging::incremental`) stays bit-for-bit equal to
/// the batch kernel.  Resolves the SIMD dispatch per call; the batch
/// matching loop hoists it instead ([`super::simd::active_isa`] is
/// process-global, so both see the same ISA).
#[inline]
pub fn token_norm(row: &[f32], accum: Accum) -> f64 {
    token_norm_isa(row, accum, simd::active_isa())
}

#[inline]
fn token_norm_isa(row: &[f32], accum: Accum, isa: Isa) -> f64 {
    match accum {
        Accum::F64 => simd::sumsq_f64(isa, row).sqrt(),
        Accum::F32 => simd::sumsq_f32(isa, row).sqrt(),
    }
}

/// Banded cosine score of one (A, B) pair given the tokens' precomputed
/// [`token_norm`]s — exactly the score the matching stage computes
/// (including the `1e-8` denominator guard).  See [`token_norm`] for why
/// this is public and how dispatch stays consistent with it.
#[inline]
pub fn pair_score(a: &[f32], b: &[f32], na: f64, nb: f64, accum: Accum) -> f64 {
    pair_score_isa(a, b, na, nb, accum, simd::active_isa())
}

#[inline]
fn pair_score_isa(a: &[f32], b: &[f32], na: f64, nb: f64, accum: Accum, isa: Isa) -> f64 {
    let dot = match accum {
        Accum::F64 => simd::dot_f64(isa, a, b),
        Accum::F32 => simd::dot_f32(isa, a, b),
    };
    dot / (na * nb + 1e-8)
}

/// Default t-axis tile (in A-tokens) for the cache-blocked matching walk,
/// derived from the token dimension `d`.
///
/// Rationale: a tile of `T` A-tokens touches its `T` A-rows plus the `T`
/// B-rows of the band core (the `2(k-1)` band-overhang rows are shared
/// with neighbouring tiles), i.e. about `2·T·4d` bytes of token data.
/// `T = 32 KiB / 8d` keeps that working set within half a typical
/// 48–64 KiB L1d, leaving room for the norms/scores being written.  The
/// clamp floor of 64 keeps tiles from degenerating at large `d` (the set
/// then spills to L2, still far better than streaming the whole slab),
/// and the 4096 cap bounds the norm-watermark lead at small `d`.
pub fn matching_tile(d: usize) -> usize {
    const TILE_TARGET_BYTES: usize = 32 * 1024;
    (TILE_TARGET_BYTES / (8 * d.max(1))).clamp(64, 4096)
}

/// Bipartite soft matching under locality constraint `k` (paper eq. 1)
/// into `scratch.scores` / `scratch.best` — zero allocations when warm.
///
/// Identical contract to [`super::match_tokens`]: tokens at even positions
/// form subset A, odd positions subset B; for each A-token the best
/// B-match within the band `|i - j| < k` is found.
pub fn match_tokens_scratch(tokens: &[f32], t: usize, d: usize, k: usize, scratch: &mut MergeScratch) {
    match_tokens_scratch_accum(tokens, t, d, k, scratch, Accum::F64);
}

/// [`match_tokens_scratch`] with an explicit accumulation precision for
/// the banded dot and the norms (see [`Accum`]).  Uses the
/// [`matching_tile`] default for the cache-blocked walk.
pub fn match_tokens_scratch_accum(
    tokens: &[f32],
    t: usize,
    d: usize,
    k: usize,
    scratch: &mut MergeScratch,
    accum: Accum,
) {
    match_tokens_scratch_tiled(tokens, t, d, k, scratch, accum, matching_tile(d));
}

/// The cache-blocked matching walk with an explicit t-axis tile (in
/// A-tokens).  `tile >= t/2` degenerates to the pre-blocking two-pass
/// walk (all norms, then all scores) — the `blocked_vs_streaming` row in
/// `benches/merging.rs` measures exactly that contrast, and
/// `tests/merging_dispatch.rs` pins that every tile size is bitwise
/// equivalent (per-token norms and per-pair scores are order-independent
/// computations; tiling only changes traversal order).
///
/// Within a tile `[i0, i1)` the walk first extends the norm watermark to
/// cover every token the tile's band can read — A-rows `2i` for `i < i1`
/// and B-rows `2j+1` for `j <= min(i1-1 + k-1, t2-1)`, both monotone in
/// `i1` — then scores the tile's A-tokens while those rows are hot.
pub fn match_tokens_scratch_tiled(
    tokens: &[f32],
    t: usize,
    d: usize,
    k: usize,
    scratch: &mut MergeScratch,
    accum: Accum,
    tile: usize,
) {
    assert!(tokens.len() >= t * d, "tokens slab too short: {} < {}", tokens.len(), t * d);
    let te = t - (t % 2);
    let t2 = te / 2;
    let k = k.clamp(1, t2.max(1));
    let isa = simd::active_isa();

    scratch.norms.clear();
    scratch.norms.resize(te, 0.0);
    scratch.scores.clear();
    scratch.scores.resize(t2, f64::NEG_INFINITY);
    scratch.best.clear();
    scratch.best.resize(t2, 0);
    if t2 == 0 {
        return;
    }

    let tile = tile.max(1);
    // Norm watermark: token positions < filled have norms computed.
    let mut filled = 0usize;
    let mut i0 = 0usize;
    while i0 < t2 {
        let i1 = (i0 + tile).min(t2);
        // Highest token position the tile reads is the B-row of the band
        // end: 2·min(i1-1 + k-1, t2-1) + 1.  need is the exclusive bound.
        let need = 2 * (i1 - 1 + (k - 1)).min(t2 - 1) + 2;
        while filled < need {
            scratch.norms[filled] = token_norm_isa(&tokens[filled * d..(filled + 1) * d], accum, isa);
            filled += 1;
        }
        for i in i0..i1 {
            let a = &tokens[(2 * i) * d..(2 * i + 1) * d];
            let na = scratch.norms[2 * i];
            let lo = i.saturating_sub(k - 1);
            let hi = (i + k - 1).min(t2 - 1);
            let mut best_score = f64::NEG_INFINITY;
            let mut best_j = 0usize;
            for j in lo..=hi {
                let b = &tokens[(2 * j + 1) * d..(2 * j + 2) * d];
                // predictable per-case branch inside the score; the dot dominates
                let s = pair_score_isa(a, b, na, scratch.norms[2 * j + 1], accum, isa);
                if s > best_score {
                    best_score = s;
                    best_j = j;
                }
            }
            scratch.scores[i] = best_score;
            scratch.best[i] = best_j;
        }
        i0 = i1;
    }
}

/// Merge the `r` most similar A-tokens into their matched B-tokens, using
/// the match already present in `scratch` (from [`match_tokens_scratch`]).
/// Requires `1 <= r <= t2`.
fn merge_given_match(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    r: usize,
    scratch: &mut MergeScratch,
    out: &mut MergeResult,
) {
    let te = t - (t % 2);
    let t2 = te / 2;
    debug_assert!(r >= 1 && r <= t2);

    // Split-borrow the scratch fields so `order` can be selected against
    // `scores` without aliasing.
    let MergeScratch { scores, best, order, merged, kept_slot, num, den, .. } = scratch;

    // Top-r A-tokens under the total order (score desc, index asc): the
    // same set a stable descending sort by score selects, found in O(t2).
    order.clear();
    order.extend(0..t2);
    if r < t2 {
        order.select_nth_unstable_by(r - 1, |&x, &y| {
            scores[y].total_cmp(&scores[x]).then_with(|| x.cmp(&y))
        });
    }
    merged.clear();
    merged.resize(t2, false);
    for &i in order[..r].iter() {
        merged[i] = true;
    }

    // Output slots for kept tokens, in temporal order.
    out.slot_map.clear();
    out.slot_map.resize(t, 0);
    kept_slot.clear();
    kept_slot.resize(t, usize::MAX);
    let mut slot = 0usize;
    for p in 0..t {
        let is_merged_a = p % 2 == 0 && p < te && merged[p / 2];
        if !is_merged_a {
            kept_slot[p] = slot;
            out.slot_map[p] = slot;
            slot += 1;
        }
    }
    debug_assert_eq!(slot, t - r);
    for i in 0..t2 {
        if merged[i] {
            let partner = 2 * best[i] + 1;
            out.slot_map[2 * i] = kept_slot[partner];
        }
    }

    // Size-weighted scatter-average, accumulated in f64 in original
    // position order (bitwise identical to the reference).  One streaming
    // pass by construction — see the module docs for why this stage is
    // not tiled.
    let out_t = t - r;
    num.clear();
    num.resize(out_t * d, 0.0);
    den.clear();
    den.resize(out_t, 0.0);
    for p in 0..t {
        let s = out.slot_map[p];
        let w = sizes[p] as f64;
        den[s] += w;
        let row = &tokens[p * d..(p + 1) * d];
        let acc = &mut num[s * d..(s + 1) * d];
        for j in 0..d {
            acc[j] += row[j] as f64 * w;
        }
    }
    out.tokens.clear();
    out.tokens.resize(out_t * d, 0.0);
    for s in 0..out_t {
        // (num / den) exactly as the reference computes it — divide, don't
        // multiply by a reciprocal, to stay bitwise identical.
        let row = &mut out.tokens[s * d..(s + 1) * d];
        let nrow = &num[s * d..(s + 1) * d];
        for j in 0..d {
            row[j] = (nrow[j] / den[s]) as f32;
        }
    }
    out.sizes.clear();
    out.sizes.extend(den.iter().map(|&x| x as f32));
}

/// Copy-through "merge" for `r == 0`: output mirrors the input.
fn passthrough(tokens: &[f32], sizes: &[f32], t: usize, out: &mut MergeResult) {
    out.tokens.clear();
    out.tokens.extend_from_slice(tokens);
    out.sizes.clear();
    out.sizes.extend_from_slice(sizes);
    out.slot_map.clear();
    out.slot_map.extend(0..t);
}

/// Zero-allocation twin of [`super::merge_fixed_r`]: match + top-r merge
/// into `out`, with every intermediate in `scratch`.
// too_many_arguments: the kernel layer is the one deliberate exception to
// the MergeSpec/MergePlan API — it takes the paper's full positional
// tuple so the innermost loop stays free of struct indirection; every
// non-kernel caller goes through a compiled plan instead.
#[allow(clippy::too_many_arguments)]
pub fn merge_fixed_r_scratch(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    r: usize,
    k: usize,
    scratch: &mut MergeScratch,
    out: &mut MergeResult,
) {
    merge_fixed_r_scratch_accum(tokens, sizes, t, d, r, k, scratch, out, Accum::F64);
}

/// [`merge_fixed_r_scratch`] with an explicit accumulation precision for
/// the matching stage (the scatter-average stays f64 — see [`Accum`]).
// too_many_arguments: kernel-layer exception, see merge_fixed_r_scratch.
#[allow(clippy::too_many_arguments)]
pub fn merge_fixed_r_scratch_accum(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    r: usize,
    k: usize,
    scratch: &mut MergeScratch,
    out: &mut MergeResult,
    accum: Accum,
) {
    assert_eq!(tokens.len(), t * d);
    assert_eq!(sizes.len(), t);
    let te = t - (t % 2);
    let t2 = te / 2;
    let r = r.min(t2);
    if r == 0 {
        passthrough(tokens, sizes, t, out);
        return;
    }
    match_tokens_scratch_accum(tokens, t, d, k, scratch, accum);
    merge_given_match(tokens, sizes, t, d, r, scratch, out);
}

/// Zero-allocation twin of [`super::merge_dynamic`] (§5.5): merge every
/// pair whose similarity exceeds `threshold`; returns the effective token
/// count `t - r`.  Unlike the layered wrapper, the match is computed once
/// and shared between the threshold count and the merge itself.
// too_many_arguments: kernel-layer exception, see merge_fixed_r_scratch.
#[allow(clippy::too_many_arguments)]
pub fn merge_dynamic_scratch(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    k: usize,
    threshold: f64,
    scratch: &mut MergeScratch,
    out: &mut MergeResult,
) -> usize {
    merge_dynamic_scratch_accum(tokens, sizes, t, d, k, threshold, scratch, out, Accum::F64)
}

/// [`merge_dynamic_scratch`] with an explicit accumulation precision for
/// the matching stage (see [`Accum`]) — completing the mode × precision
/// matrix the plan layer dispatches over.
// too_many_arguments: kernel-layer exception, see merge_fixed_r_scratch.
#[allow(clippy::too_many_arguments)]
pub fn merge_dynamic_scratch_accum(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    k: usize,
    threshold: f64,
    scratch: &mut MergeScratch,
    out: &mut MergeResult,
    accum: Accum,
) -> usize {
    assert_eq!(tokens.len(), t * d);
    assert_eq!(sizes.len(), t);
    let te = t - (t % 2);
    let t2 = te / 2;
    match_tokens_scratch_accum(tokens, t, d, k, scratch, accum);
    let r = scratch.scores.iter().filter(|&&s| s > threshold).count().min(t2);
    if r == 0 {
        passthrough(tokens, sizes, t, out);
        return t;
    }
    merge_given_match(tokens, sizes, t, d, r, scratch, out);
    t - r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::reference;
    use crate::util::Rng;

    #[test]
    fn dot_matches_serial() {
        let mut rng = Rng::new(11);
        let isa = simd::active_isa();
        for n in [0usize, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let serial: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let scale: f64 =
                a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum::<f64>().max(1.0);
            assert!((simd::dot_f64(isa, &a, &b) - serial).abs() < 1e-9, "n={n}");
            // the f32 lane accumulation stays within its (magnitude-scaled
            // raw-reduction) contract too
            assert!((simd::dot_f32(isa, &a, &b) - serial).abs() < 1e-4 * scale, "n={n}");
        }
    }

    #[test]
    fn f32_accum_scores_track_f64() {
        let mut rng = Rng::new(14);
        let mut s64 = MergeScratch::new();
        let mut s32 = MergeScratch::new();
        for &(t, d, k) in &[(32usize, 8usize, 4usize), (41, 16, 8), (64, 64, 32)] {
            let tokens: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
            match_tokens_scratch_accum(&tokens, t, d, k, &mut s64, Accum::F64);
            match_tokens_scratch_accum(&tokens, t, d, k, &mut s32, Accum::F32);
            for (i, (a, b)) in s64.scores().iter().zip(s32.scores()).enumerate() {
                assert!((a - b).abs() <= 1e-5, "score[{i}] t={t} d={d} k={k}: {a} vs {b}");
            }
        }
    }

    /// Tiling only reorders the walk: every tile size must give bitwise
    /// identical norms, scores and matches (per-token norms and per-pair
    /// scores are order-independent computations).
    #[test]
    fn tile_size_is_bitwise_neutral() {
        let mut rng = Rng::new(15);
        let mut blocked = MergeScratch::new();
        let mut streaming = MergeScratch::new();
        for &(t, d, k) in &[(64usize, 8usize, 4usize), (97, 3, 16), (33, 1, 33), (128, 64, 1)] {
            let tokens: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
            // streaming baseline: one tile covering everything
            match_tokens_scratch_tiled(&tokens, t, d, k, &mut streaming, Accum::F64, usize::MAX);
            for tile in [1usize, 2, 3, 7, 64] {
                match_tokens_scratch_tiled(&tokens, t, d, k, &mut blocked, Accum::F64, tile);
                assert_eq!(blocked.scores(), streaming.scores(), "t={t} d={d} k={k} tile={tile}");
                assert_eq!(blocked.best(), streaming.best(), "t={t} d={d} k={k} tile={tile}");
            }
            // and the default-tile entry point agrees too
            match_tokens_scratch_accum(&tokens, t, d, k, &mut blocked, Accum::F64);
            assert_eq!(blocked.scores(), streaming.scores(), "default tile t={t} d={d} k={k}");
        }
    }

    #[test]
    fn matching_tile_is_d_derived_and_clamped() {
        assert_eq!(matching_tile(1), 4096);
        assert_eq!(matching_tile(8), 512);
        assert_eq!(matching_tile(64), 64);
        assert_eq!(matching_tile(4096), 64);
        assert_eq!(matching_tile(0), 4096); // degenerate d guarded
    }

    #[test]
    fn matches_reference_on_smoke_cases() {
        let mut rng = Rng::new(12);
        let mut scratch = MergeScratch::new();
        let mut out = crate::merging::MergeResult::default();
        for &(t, d, r, k) in &[
            (16usize, 4usize, 4usize, 2usize),
            (17, 3, 5, 8),
            (6, 1, 3, 3),
            (32, 8, 16, 16),
            (9, 2, 0, 1),
        ] {
            let tokens: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
            let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(4) as f32).collect();
            merge_fixed_r_scratch(&tokens, &sizes, t, d, r, k, &mut scratch, &mut out);
            let refr = reference::merge_fixed_r_reference(&tokens, &sizes, t, d, r, k);
            assert_eq!(out.slot_map, refr.slot_map, "t={t} d={d} r={r} k={k}");
            for (a, b) in out.tokens.iter().zip(&refr.tokens) {
                assert!((a - b).abs() <= 1e-5, "t={t} d={d} r={r} k={k}");
            }
            for (a, b) in out.sizes.iter().zip(&refr.sizes) {
                assert!((a - b).abs() <= 1e-5);
            }
        }
    }

    #[test]
    fn dynamic_shares_match_with_layered_path() {
        let mut rng = Rng::new(13);
        let (t, d, k) = (40usize, 6usize, 4usize);
        let tokens: Vec<f32> = (0..t * d).map(|_| rng.normal() as f32).collect();
        let sizes = vec![1.0f32; t];
        let mut scratch = MergeScratch::new();
        let mut out = crate::merging::MergeResult::default();
        for th in [-1.1, 0.0, 0.5, 1.1] {
            let eff = merge_dynamic_scratch(&tokens, &sizes, t, d, k, th, &mut scratch, &mut out);
            let (refr, ref_eff) = reference::merge_dynamic_reference(&tokens, &sizes, t, d, k, th);
            assert_eq!(eff, ref_eff, "threshold {th}");
            assert_eq!(out.slot_map, refr.slot_map);
        }
    }
}
