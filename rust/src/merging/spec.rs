//! [`MergeSpec`]: the one typed description of a merge configuration.
//!
//! The paper defines a single family of algorithms — local bipartite
//! merging with a neighborhood `k`, a per-layer `r` schedule, a
//! dynamic-threshold variant (§5.5) and a causal restriction — and this
//! type is its value-object form.  A spec is **validated once**
//! ([`MergeSpec::validate`]) and **compiled** against a concrete shape
//! ([`MergeSpec::compile`]) into a reusable [`MergePlan`], which owns the
//! precomputed per-layer token counts and the scratch state and is the
//! only execution entry point (`MergePlan::run*` in
//! [`super::pipeline`]).
//!
//! Lifecycle (DESIGN.md §2):
//!
//! ```text
//! MergeSpec { mode, k, accum, causal }      declarative, serializable
//!     │  validate()                          k >= 1, causal => k == 1,
//!     │                                      schedule entries >= 1,
//!     │                                      threshold finite and >= 0
//!     ▼  compile(t, d)                       schedule feasible at every
//! MergePlan { counts, slots[scratch] }       layer, final count >= 1
//!     │  run / run_into / run_batch_into     zero allocations when warm
//!     ▼
//! PipelineResult { tokens, sizes, slot_map, token_counts }
//! ```
//!
//! Errors that previously surfaced as kernel asserts (or silent nonsense:
//! an infeasible `r` silently clamped, `k = 0` silently bumped to 1, a
//! NaN threshold merging nothing) are rejected here with messages naming
//! the offending field.

use anyhow::{bail, ensure, Result};

use super::analytic::{merge_schedule, similarity_complexity};
use super::kernel::Accum;
use super::pipeline::MergePlan;

/// What to merge: nothing, a fixed per-layer schedule, or every pair over
/// a similarity threshold (paper §5.5).
#[derive(Clone, Debug, PartialEq)]
pub enum MergeMode {
    /// No merging.  Compiled plans are exact passthroughs; the serving
    /// layer reads `Off` as "host premerge disabled".
    Off,
    /// Merge exactly `schedule[l]` token pairs at layer `l` (paper §3).
    /// An empty schedule is a valid identity — the serving config uses it
    /// as the "enabled, derive the depth per shape" template (see
    /// [`MergeSpec::premerge_to`]).
    FixedR { schedule: Vec<usize> },
    /// One layer of dynamic merging: merge every banded pair whose cosine
    /// similarity exceeds `threshold` (paper §5.5).  The output length is
    /// data-dependent; [`super::PipelineResult::token_counts`] reports it.
    Dynamic { threshold: f64 },
}

/// A validated-once, run-many description of a merge configuration.
///
/// Construct with [`MergeSpec::off`] / [`MergeSpec::single`] /
/// [`MergeSpec::fixed_r`] / [`MergeSpec::layered_for`] /
/// [`MergeSpec::dynamic`], refine with the `with_*` builders, then
/// [`MergeSpec::compile`] against a `(t, d)` shape.
#[derive(Clone, Debug, PartialEq)]
pub struct MergeSpec {
    /// merge mode (fixed schedule / dynamic threshold / off)
    pub mode: MergeMode,
    /// locality constraint of the bipartite matching (paper eq. 1):
    /// candidates within `|i - j| < k`; must be >= 1
    pub k: usize,
    /// accumulation precision of the banded dot (see [`Accum`])
    pub accum: Accum,
    /// causal restriction: only adjacent-pair merges are allowed, so
    /// information never moves backward in time — requires `k == 1`
    pub causal: bool,
}

impl MergeSpec {
    /// Default locality constraint used by the serving layer when a config
    /// names only `r` (matches the paper's serving experiments).
    pub const DEFAULT_K: usize = 8;

    /// No merging (`k` is irrelevant but kept valid).
    pub fn off() -> MergeSpec {
        MergeSpec { mode: MergeMode::Off, k: 1, accum: Accum::F64, causal: false }
    }

    /// One merge step of `r` pairs with locality `k`.
    pub fn single(r: usize, k: usize) -> MergeSpec {
        MergeSpec::fixed_r(vec![r], k)
    }

    /// A fixed per-layer schedule with locality `k`.
    pub fn fixed_r(schedule: Vec<usize>, k: usize) -> MergeSpec {
        MergeSpec { mode: MergeMode::FixedR { schedule }, k, accum: Accum::F64, causal: false }
    }

    /// The paper's static rule (`merge_schedule`): up to `r` pairs per
    /// layer for `layers` layers, never dropping below `floor` tokens —
    /// resolved against the input length `t` it will run at.
    pub fn layered_for(t: usize, r: usize, layers: usize, floor: usize, k: usize) -> MergeSpec {
        let counts = merge_schedule(t, r, layers, floor);
        let schedule = counts.windows(2).map(|w| w[0] - w[1]).filter(|&r_l| r_l > 0).collect();
        MergeSpec::fixed_r(schedule, k)
    }

    /// One layer of dynamic-threshold merging (§5.5).
    pub fn dynamic(threshold: f64, k: usize) -> MergeSpec {
        MergeSpec { mode: MergeMode::Dynamic { threshold }, k, accum: Accum::F64, causal: false }
    }

    /// Select the accumulation precision of the banded dot.
    pub fn with_accum(mut self, accum: Accum) -> MergeSpec {
        self.accum = accum;
        self
    }

    /// Mark the spec causal (validation then requires `k == 1`).
    pub fn with_causal(mut self) -> MergeSpec {
        self.causal = true;
        self
    }

    /// True when the spec performs no merging at all.
    pub fn is_off(&self) -> bool {
        matches!(self.mode, MergeMode::Off)
    }

    /// Total merged pairs over all layers (0 for `Off`; the *maximum*
    /// for `Dynamic`, which is data-dependent, is unknown — returns 0).
    pub fn total_r(&self) -> usize {
        match &self.mode {
            MergeMode::FixedR { schedule } => schedule.iter().sum(),
            _ => 0,
        }
    }

    /// Number of merge layers this spec executes.
    pub fn layers(&self) -> usize {
        match &self.mode {
            MergeMode::Off => 0,
            MergeMode::FixedR { schedule } => schedule.len(),
            MergeMode::Dynamic { .. } => 1,
        }
    }

    /// Eq. 2 similarity-computation cost of one merge step at length `t`
    /// under this spec's locality constraint.
    pub fn similarity_cost(&self, t: usize) -> usize {
        similarity_complexity(t, self.k)
    }

    /// Shape-independent validation; [`MergeSpec::compile`] calls this
    /// and additionally checks the schedule against the concrete shape.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.k >= 1, "merge spec: locality k must be >= 1, got 0");
        if self.causal {
            ensure!(
                self.k == 1,
                "merge spec: causal merging requires k == 1 (adjacent pairs only), got k = {}",
                self.k
            );
        }
        match &self.mode {
            MergeMode::Off => {}
            MergeMode::FixedR { schedule } => {
                for (l, &r_l) in schedule.iter().enumerate() {
                    ensure!(
                        r_l >= 1,
                        "merge spec: schedule[{l}] is 0 — drop the layer (or use mode Off)"
                    );
                }
            }
            MergeMode::Dynamic { threshold } => {
                ensure!(
                    !threshold.is_nan(),
                    "merge spec: dynamic threshold is NaN"
                );
                ensure!(
                    *threshold >= 0.0,
                    "merge spec: dynamic threshold must be >= 0 (cosine similarity), got {threshold}"
                );
            }
        }
        Ok(())
    }

    /// Derive the concrete premerge spec that takes a `len`-token context
    /// down to exactly `target` tokens, keeping this spec's `k`, `accum`
    /// and `causal` and **replacing the schedule** (each layer can merge
    /// at most half of the even prefix, so deep compression takes several
    /// layers) — `self` is the fixed-mode template, usually with an empty
    /// schedule.  A dynamic spec is rejected rather than silently
    /// converted: its data-dependent output cannot land on an exact
    /// target.  Replaces the old free-standing `premerge_schedule` +
    /// loose-tuple plumbing.
    pub fn premerge_to(&self, len: usize, target: usize) -> Result<MergeSpec> {
        ensure!(!self.is_off(), "premerge requested but the merge spec is Off");
        ensure!(
            !matches!(self.mode, MergeMode::Dynamic { .. }),
            "premerge must land on an exact token target, which a dynamic-threshold \
             spec cannot guarantee — use a fixed-mode template"
        );
        ensure!(target >= 1, "premerge target must be >= 1");
        ensure!(
            len >= target,
            "context length {len} is shorter than the premerge target {target}"
        );
        let mut schedule = Vec::new();
        let mut cur = len;
        while cur > target {
            let feasible = (cur - cur % 2) / 2;
            let r = feasible.min(cur - target);
            if r == 0 {
                bail!("cannot premerge {len} -> {target}: stalled at {cur} tokens");
            }
            schedule.push(r);
            cur -= r;
        }
        Ok(MergeSpec {
            mode: MergeMode::FixedR { schedule },
            k: self.k,
            accum: self.accum,
            causal: self.causal,
        })
    }

    /// Compile against a concrete `(t, d)` shape: validates the spec,
    /// checks every schedule layer is feasible (`r_l` no larger than half
    /// the even prefix at that layer — this is where `r >= t` and
    /// schedule/shape mismatches are rejected instead of silently
    /// clamped), precomputes the per-layer token counts and allocates one
    /// scratch slot.  Add slots for batched execution with
    /// [`MergePlan::with_slots`].
    pub fn compile(&self, t: usize, d: usize) -> Result<MergePlan> {
        self.validate()?;
        ensure!(t >= 1, "merge plan: t must be >= 1");
        ensure!(d >= 1, "merge plan: d must be >= 1");
        let counts = match &self.mode {
            MergeMode::Off | MergeMode::Dynamic { .. } => vec![t],
            MergeMode::FixedR { schedule } => {
                let mut counts = Vec::with_capacity(schedule.len() + 1);
                let mut cur = t;
                counts.push(cur);
                for (l, &r_l) in schedule.iter().enumerate() {
                    let feasible = (cur - cur % 2) / 2;
                    ensure!(
                        r_l <= feasible,
                        "merge plan: schedule[{l}] = {r_l} infeasible at {cur} tokens \
                         (at most {feasible} pairs can merge; input t = {t})"
                    );
                    cur -= r_l;
                    counts.push(cur);
                }
                counts
            }
        };
        Ok(MergePlan::new(self.clone(), t, d, counts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_accepts_the_paper_family() {
        assert!(MergeSpec::off().validate().is_ok());
        assert!(MergeSpec::single(16, 8).validate().is_ok());
        assert!(MergeSpec::fixed_r(vec![8, 4, 2], 1).with_causal().validate().is_ok());
        assert!(MergeSpec::dynamic(0.85, 16).validate().is_ok());
        // threshold above 1 = "never merge": legal, useful for sweeps
        assert!(MergeSpec::dynamic(1.1, 2).validate().is_ok());
        // empty schedule is the serving template (identity until derived)
        assert!(MergeSpec::fixed_r(Vec::new(), 8).validate().is_ok());
    }

    #[test]
    fn layered_for_matches_static_rule() {
        let spec = MergeSpec::layered_for(96, 16, 4, 4, 8);
        match &spec.mode {
            MergeMode::FixedR { schedule } => assert_eq!(schedule, &vec![16, 16, 16, 16]),
            m => panic!("unexpected mode {m:?}"),
        }
        assert_eq!(spec.total_r(), 64);
        // floor-limited tail layers drop out instead of appearing as 0
        let spec = MergeSpec::layered_for(10, 100, 4, 4, 8);
        assert_eq!(spec.total_r(), 6);
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn premerge_to_reaches_target() {
        let tmpl = MergeSpec::fixed_r(Vec::new(), 8);
        let get = |len: usize, target: usize| -> Vec<usize> {
            match tmpl.premerge_to(len, target).unwrap().mode {
                MergeMode::FixedR { schedule } => schedule,
                m => panic!("unexpected mode {m:?}"),
            }
        };
        assert_eq!(get(768, 512), vec![256]);
        assert_eq!(get(2048, 512), vec![1024, 512]);
        assert_eq!(get(512, 512), Vec::<usize>::new());
        // odd lengths: feasible merges bounded by the even prefix
        let rs = get(1001, 100);
        let mut cur = 1001usize;
        for &r in &rs {
            assert!(r <= (cur - cur % 2) / 2);
            cur -= r;
        }
        assert_eq!(cur, 100);
        // derived specs keep k/accum/causal and always compile
        let causal = MergeSpec::fixed_r(Vec::new(), 1).with_causal();
        let derived = causal.premerge_to(96, 24).unwrap();
        assert!(derived.causal && derived.k == 1);
        assert!(derived.compile(96, 1).is_ok());
    }

    #[test]
    fn premerge_to_rejects_bad_requests() {
        let tmpl = MergeSpec::fixed_r(Vec::new(), 8);
        assert!(MergeSpec::off().premerge_to(100, 10).is_err());
        assert!(tmpl.premerge_to(100, 0).is_err());
        assert!(tmpl.premerge_to(10, 100).is_err());
        // a dynamic spec cannot promise an exact target — rejected, never
        // silently converted to fixed
        assert!(MergeSpec::dynamic(0.9, 8).premerge_to(100, 10).is_err());
    }

    #[test]
    fn compile_precomputes_layer_counts() {
        let plan = MergeSpec::fixed_r(vec![16, 16, 8], 4).compile(96, 8).unwrap();
        assert_eq!(plan.layer_counts(), &[96, 80, 64, 56]);
        assert_eq!(plan.out_tokens(), 56);
        let plan = MergeSpec::off().compile(40, 2).unwrap();
        assert_eq!(plan.layer_counts(), &[40]);
        let plan = MergeSpec::dynamic(0.9, 2).compile(40, 2).unwrap();
        assert_eq!(plan.layer_counts(), &[40]);
    }

    #[test]
    fn compile_rejects_infeasible_schedules() {
        // r >= t (one layer cannot merge more than half the even prefix)
        assert!(MergeSpec::single(32, 4).compile(32, 4).is_err());
        assert!(MergeSpec::single(17, 4).compile(32, 4).is_err());
        assert!(MergeSpec::single(16, 4).compile(32, 4).is_ok());
        // feasible per layer but the tail layer overruns
        assert!(MergeSpec::fixed_r(vec![16, 8, 8], 4).compile(32, 4).is_err());
        // zero-size shapes
        assert!(MergeSpec::off().compile(0, 4).is_err());
        assert!(MergeSpec::off().compile(4, 0).is_err());
    }
}
