//! Explicit-SIMD similarity primitives behind runtime ISA dispatch.
//!
//! This module owns the four scalar reduction primitives the merge kernel
//! is built from — [`dot_f64`] / [`dot_f32`] (banded cosine dot) and
//! [`sumsq_f64`] / [`sumsq_f32`] (token norms) — together with
//! hand-written AVX2 (x86_64) and NEON (aarch64) implementations of each,
//! selected once per process and dispatched per call through [`Isa`].
//!
//! # The bitwise-F64 contract
//!
//! The scalar `Accum::F64` dot accumulates over **four independent f64
//! lanes in strided order** (`s_l += a[4c+l]·b[4c+l]`), reduced as
//! `(s0 + s1) + (s2 + s3) + tail`.  A 4-wide f64 vector accumulator
//! performs *the same* IEEE-754 operation sequence per lane —
//! f32→f64 convert (exact), multiply (rounded once), add (rounded once) —
//! so the AVX2 and NEON F64 paths are **bit-for-bit identical** to the
//! scalar path, not merely close.  Two consequences:
//!
//! * **No FMA on any F64 path.**  A fused multiply-add rounds once where
//!   mul+add rounds twice, which breaks bitwise identity with the scalar
//!   kernel, the incremental streaming path, and the differential oracle.
//!   FMA is used only on the x86 `Accum::F32` path, whose contract is
//!   tolerance-based (scores within 1e-5 of f64 — see
//!   [`Accum`](super::kernel::Accum)).
//! * The norms use the same 4-lane chunked order (`sumsq`), which is
//!   mirrored verbatim by `merging/reference.rs` so the oracle stays
//!   bitwise comparable (see the note in `kernel.rs`).
//!
//! The NEON F64 path models the 4-lane accumulator as two `float64x2_t`
//! registers holding lanes (0,1) and (2,3); the reduction
//! `(s0 + s1) + (s2 + s3) + tail` is unchanged.  The NEON F32 path is a
//! 4-lane mul+add and therefore *also* bitwise identical to the scalar
//! `Accum::F32` twin; only x86 F32 (8-lane FMA) trades bitwise identity
//! for throughput, inside the documented 1e-5 contract.
//!
//! # Selection
//!
//! [`active_isa`] resolves, in order:
//!
//! 1. the process-local [`force_scalar`] override (bench/test hook, an
//!    atomic — lets one process time SIMD vs scalar back to back);
//! 2. `TOMERS_FORCE_SCALAR=1` in the environment, read **once** at first
//!    use (cached alongside the CPU feature probe);
//! 3. CPU feature detection: `avx2 && fma` on x86_64
//!    (`is_x86_feature_detected!`), NEON unconditionally on aarch64
//!    (baseline for every aarch64 Rust target), scalar everywhere else.
//!
//! The selected ISA is observable — never infer it from timing — via
//! [`dispatch_report`] (the string `Metrics::report()` and the merging
//! bench JSON embed) and [`Isa::name`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Instruction set the similarity primitives dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable 4-lane chunked scalar loops (always available; the
    /// bitwise ground truth the vector paths must reproduce for F64).
    Scalar,
    /// x86_64 AVX2: 4×f64 vector accumulator for F64 (mul+add, no FMA —
    /// bitwise), 8×f32 FMA accumulator for F32 (within the 1e-5 contract).
    Avx2,
    /// aarch64 NEON: 2×2 f64 accumulators for F64 and a 4×f32 mul+add for
    /// F32 — both bitwise identical to the scalar paths.
    Neon,
}

impl Isa {
    /// Stable lower-case name (`"scalar"` / `"avx2"` / `"neon"`), used in
    /// `Metrics::report()` and the `BENCH_merging.json` `isa` field.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }
}

/// Bench/test override: route every primitive through the scalar path
/// while `true`, regardless of what the host supports.  Process-local and
/// reversible, unlike the `TOMERS_FORCE_SCALAR` environment variable
/// (which is latched at first use); this is what lets the merging bench
/// time `simd_vs_scalar` inside one process.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);
static DETECTED: OnceLock<Isa> = OnceLock::new();

/// Environment + CPU probe, evaluated once per process.
fn detect() -> Isa {
    if std::env::var_os("TOMERS_FORCE_SCALAR").is_some_and(|v| v == "1") {
        return Isa::Scalar;
    }
    detect_cpu()
}

#[cfg(target_arch = "x86_64")]
fn detect_cpu() -> Isa {
    // FMA is required alongside AVX2: the f32 path uses fused ops.
    // (Every AVX2 CPU to date also has FMA, but probe both anyway.)
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        Isa::Avx2
    } else {
        Isa::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_cpu() -> Isa {
    // NEON (ASIMD) is baseline on every aarch64 Rust target.
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_cpu() -> Isa {
    Isa::Scalar
}

/// The ISA every kernel primitive dispatches to right now.  Callers on a
/// hot path should fetch this once per kernel invocation and pass it down
/// rather than re-resolving per element pair.
#[inline]
pub fn active_isa() -> Isa {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        return Isa::Scalar;
    }
    *DETECTED.get_or_init(detect)
}

/// Detected CPU SIMD features as a comma-joined string (independent of
/// what [`active_isa`] selected — a forced-scalar run still reports the
/// hardware), for the bench JSON `cpu_features` field.
pub fn cpu_features() -> String {
    let mut feats: Vec<&str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        for (name, have) in [
            ("sse2", std::arch::is_x86_feature_detected!("sse2")),
            ("avx", std::arch::is_x86_feature_detected!("avx")),
            ("avx2", std::arch::is_x86_feature_detected!("avx2")),
            ("fma", std::arch::is_x86_feature_detected!("fma")),
            ("avx512f", std::arch::is_x86_feature_detected!("avx512f")),
        ] {
            if have {
                feats.push(name);
            }
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        feats.push("neon");
    }
    if feats.is_empty() {
        feats.push("none");
    }
    feats.join(",")
}

/// One-line dispatch summary, e.g.
/// `isa=avx2 features=sse2,avx,avx2,fma f64=4-lane f32=8-lane+fma`.
/// This string — not wall-clock timing — is the contract for asserting
/// where dispatch routed (see `tests/dispatch_env.rs`).
pub fn dispatch_report() -> String {
    let isa = active_isa();
    let lanes = match isa {
        Isa::Scalar => "f64=4-lane f32=4-lane",
        Isa::Avx2 => "f64=4-lane f32=8-lane+fma",
        Isa::Neon => "f64=2x2-lane f32=4-lane",
    };
    format!("isa={} features={} {lanes}", isa.name(), cpu_features())
}

// ---------------------------------------------------------------------------
// Scalar paths: the bitwise ground truth.

/// Scalar F64 dot: four independent f64 accumulators over strided indices
/// `4c + l`, serial tail, reduced `(s0 + s1) + (s2 + s3) + tail`.  The
/// vector paths must reproduce this op-for-op.
pub fn dot_f64_scalar(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] as f64 * b[i] as f64;
        s1 += a[i + 1] as f64 * b[i + 1] as f64;
        s2 += a[i + 2] as f64 * b[i + 2] as f64;
        s3 += a[i + 3] as f64 * b[i + 3] as f64;
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..n {
        tail += a[i] as f64 * b[i] as f64;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Scalar F64 sum of squares, in the same 4-lane chunked order as
/// [`dot_f64_scalar`] (historically this was a serial index-order loop;
/// the reorder is mirrored by `reference.rs` — see `kernel.rs` docs).
pub fn sumsq_f64_scalar(a: &[f32]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        let (x0, x1) = (a[i] as f64, a[i + 1] as f64);
        let (x2, x3) = (a[i + 2] as f64, a[i + 3] as f64);
        s0 += x0 * x0;
        s1 += x1 * x1;
        s2 += x2 * x2;
        s3 += x3 * x3;
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..n {
        let x = a[i] as f64;
        tail += x * x;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Scalar F32 dot twin: four independent f32 lanes, widened to f64 only
/// at the very end.
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0f32;
    for i in chunks * 4..n {
        tail += a[i] * b[i];
    }
    ((s0 + s1) + (s2 + s3) + tail) as f64
}

/// Scalar F32 sum-of-squares twin, 4-lane chunked like
/// [`sumsq_f64_scalar`].
pub fn sumsq_f32_scalar(a: &[f32]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * a[i];
        s1 += a[i + 1] * a[i + 1];
        s2 += a[i + 2] * a[i + 2];
        s3 += a[i + 3] * a[i + 3];
    }
    let mut tail = 0.0f32;
    for i in chunks * 4..n {
        tail += a[i] * a[i];
    }
    ((s0 + s1) + (s2 + s3) + tail) as f64
}

// ---------------------------------------------------------------------------
// AVX2 (x86_64)

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// 4×f64 vector accumulator; lane `l` holds exactly the scalar `s_l`.
    /// mul+add, **not** FMA, so every intermediate rounds exactly like
    /// the scalar path — bitwise identical (module docs).
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and `a.len() == b.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            let i = c * 4;
            // SAFETY: i + 4 <= chunks * 4 <= n, so both 4-lane reads are
            // in bounds of `a` (and of `b` by the a.len() == b.len()
            // precondition); loadu has no alignment requirement.
            let va = _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(i)));
            let vb = _mm256_cvtps_pd(_mm_loadu_ps(b.as_ptr().add(i)));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut tail = 0.0f64;
        for i in chunks * 4..n {
            tail += a[i] as f64 * b[i] as f64;
        }
        (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]) + tail
    }

    /// Vector sum of squares; same lane layout and reduction as
    /// [`dot_f64`], so bitwise identical to `sumsq_f64_scalar`.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sumsq_f64(a: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            // SAFETY: c * 4 + 4 <= n, so the 4-lane unaligned read stays
            // inside `a`.
            let v = _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(c * 4)));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(v, v));
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut tail = 0.0f64;
        for i in chunks * 4..n {
            let x = a[i] as f64;
            tail += x * x;
        }
        (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]) + tail
    }

    /// 8×f32 FMA accumulator.  Twice the lanes of the scalar F32 twin and
    /// fused rounding — NOT bitwise equal to it, but well inside the
    /// `Accum::F32` 1e-5 score contract (reassociation error here is the
    /// same order as the scalar twin's own deviation from f64).
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and FMA, and
    /// `a.len() == b.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let i = c * 8;
            // SAFETY: i + 8 <= chunks * 8 <= n keeps both 8-lane
            // unaligned reads in bounds (b by the equal-length
            // precondition); fmadd requires the fma feature enabled on
            // this fn.
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_fmadd_ps(va, vb, acc);
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut tail = 0.0f32;
        for i in chunks * 8..n {
            tail += a[i] * b[i];
        }
        let body = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        (body + tail) as f64
    }

    /// 8×f32 FMA sum of squares; same contract as [`dot_f32`].
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sumsq_f32(a: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            // SAFETY: c * 8 + 8 <= n, so the 8-lane unaligned read stays
            // inside `a`.
            let v = _mm256_loadu_ps(a.as_ptr().add(c * 8));
            acc = _mm256_fmadd_ps(v, v, acc);
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut tail = 0.0f32;
        for i in chunks * 8..n {
            tail += a[i] * a[i];
        }
        let body = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        (body + tail) as f64
    }
}

// ---------------------------------------------------------------------------
// NEON (aarch64)

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// The scalar 4-lane f64 accumulator as two `float64x2_t` registers:
    /// `acc01` holds lanes (s0, s1), `acc23` holds (s2, s3).  mul+add
    /// only (no `vfmaq_f64`) — bitwise identical to the scalar path.
    ///
    /// # Safety
    /// Caller must ensure NEON is available (baseline on aarch64) and
    /// `a.len() == b.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        for c in 0..chunks {
            let i = c * 4;
            // SAFETY: i + 4 <= chunks * 4 <= n keeps both 4-lane loads
            // in bounds (`b` by the equal-length precondition); vld1q
            // tolerates unaligned addresses on aarch64.
            let va = vld1q_f32(a.as_ptr().add(i));
            let vb = vld1q_f32(b.as_ptr().add(i));
            let lo = vmulq_f64(vcvt_f64_f32(vget_low_f32(va)), vcvt_f64_f32(vget_low_f32(vb)));
            let hi = vmulq_f64(vcvt_high_f64_f32(va), vcvt_high_f64_f32(vb));
            acc01 = vaddq_f64(acc01, lo);
            acc23 = vaddq_f64(acc23, hi);
        }
        let (s0, s1) = (vgetq_lane_f64::<0>(acc01), vgetq_lane_f64::<1>(acc01));
        let (s2, s3) = (vgetq_lane_f64::<0>(acc23), vgetq_lane_f64::<1>(acc23));
        let mut tail = 0.0f64;
        for i in chunks * 4..n {
            tail += a[i] as f64 * b[i] as f64;
        }
        (s0 + s1) + (s2 + s3) + tail
    }

    /// NEON sum of squares; same lane layout as [`dot_f64`] — bitwise
    /// identical to `sumsq_f64_scalar`.
    ///
    /// # Safety
    /// Caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    pub unsafe fn sumsq_f64(a: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        for c in 0..chunks {
            // SAFETY: c * 4 + 4 <= n keeps the 4-lane load inside `a`;
            // unaligned loads are architecturally supported.
            let v = vld1q_f32(a.as_ptr().add(c * 4));
            let lo = vcvt_f64_f32(vget_low_f32(v));
            let hi = vcvt_high_f64_f32(v);
            acc01 = vaddq_f64(acc01, vmulq_f64(lo, lo));
            acc23 = vaddq_f64(acc23, vmulq_f64(hi, hi));
        }
        let (s0, s1) = (vgetq_lane_f64::<0>(acc01), vgetq_lane_f64::<1>(acc01));
        let (s2, s3) = (vgetq_lane_f64::<0>(acc23), vgetq_lane_f64::<1>(acc23));
        let mut tail = 0.0f64;
        for i in chunks * 4..n {
            let x = a[i] as f64;
            tail += x * x;
        }
        (s0 + s1) + (s2 + s3) + tail
    }

    /// 4×f32 mul+add — the same lane count, op order and reduction as the
    /// scalar F32 twin, so bitwise identical to it (unlike x86's 8-lane
    /// FMA variant).
    ///
    /// # Safety
    /// Caller must ensure NEON is available and `a.len() == b.len()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let i = c * 4;
            // SAFETY: i + 4 <= chunks * 4 <= n bounds both loads (`b`
            // via the equal-length precondition); no alignment needed.
            let va = vld1q_f32(a.as_ptr().add(i));
            let vb = vld1q_f32(b.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(va, vb));
        }
        let (s0, s1) = (vgetq_lane_f32::<0>(acc), vgetq_lane_f32::<1>(acc));
        let (s2, s3) = (vgetq_lane_f32::<2>(acc), vgetq_lane_f32::<3>(acc));
        let mut tail = 0.0f32;
        for i in chunks * 4..n {
            tail += a[i] * b[i];
        }
        (((s0 + s1) + (s2 + s3)) + tail) as f64
    }

    /// NEON F32 sum of squares; bitwise identical to `sumsq_f32_scalar`.
    ///
    /// # Safety
    /// Caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    pub unsafe fn sumsq_f32(a: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = vdupq_n_f32(0.0);
        for c in 0..chunks {
            // SAFETY: c * 4 + 4 <= n keeps the 4-lane load inside `a`.
            let v = vld1q_f32(a.as_ptr().add(c * 4));
            acc = vaddq_f32(acc, vmulq_f32(v, v));
        }
        let (s0, s1) = (vgetq_lane_f32::<0>(acc), vgetq_lane_f32::<1>(acc));
        let (s2, s3) = (vgetq_lane_f32::<2>(acc), vgetq_lane_f32::<3>(acc));
        let mut tail = 0.0f32;
        for i in chunks * 4..n {
            tail += a[i] * a[i];
        }
        (((s0 + s1) + (s2 + s3)) + tail) as f64
    }
}

// ---------------------------------------------------------------------------
// Dispatch

/// F64 banded dot under `isa`.  Bit-for-bit identical across every ISA
/// (module docs); `isa` is a parameter — not re-resolved here — so hot
/// loops resolve dispatch once per kernel call.
#[inline]
pub fn dot_f64(isa: Isa, a: &[f32], b: &[f32]) -> f64 {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_isa only yields Avx2 after is_x86_feature_detected
        // confirmed avx2+fma on this CPU.
        Isa::Avx2 => unsafe { avx2::dot_f64(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on every aarch64 Rust target.
        Isa::Neon => unsafe { neon::dot_f64(a, b) },
        _ => dot_f64_scalar(a, b),
    }
}

/// F64 sum of squares under `isa` (bitwise identical across ISAs).
#[inline]
pub fn sumsq_f64(isa: Isa, a: &[f32]) -> f64 {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 implies a successful avx2+fma feature probe.
        Isa::Avx2 => unsafe { avx2::sumsq_f64(a) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Isa::Neon => unsafe { neon::sumsq_f64(a) },
        _ => sumsq_f64_scalar(a),
    }
}

/// F32 banded dot under `isa`.  Scalar and NEON agree bitwise; AVX2 is
/// within the `Accum::F32` 1e-5 score contract.
#[inline]
pub fn dot_f32(isa: Isa, a: &[f32], b: &[f32]) -> f64 {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 implies a successful avx2+fma feature probe.
        Isa::Avx2 => unsafe { avx2::dot_f32(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Isa::Neon => unsafe { neon::dot_f32(a, b) },
        _ => dot_f32_scalar(a, b),
    }
}

/// F32 sum of squares under `isa`; same contract split as [`dot_f32`].
#[inline]
pub fn sumsq_f32(isa: Isa, a: &[f32]) -> f64 {
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx2 implies a successful avx2+fma feature probe.
        Isa::Avx2 => unsafe { avx2::sumsq_f32(a) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        Isa::Neon => unsafe { neon::sumsq_f32(a) },
        _ => sumsq_f32_scalar(a),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Length sweep covering the remainder/alignment edges of both lane
    /// widths (4 for f64/scalar-f32/neon, 8 for the avx2 f32 path).
    const LENS: [usize; 12] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 63, 64, 257];

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Tolerance for raw f32 reductions vs an f64 witness: f32 rounding
    /// error scales with the sum of |terms|, so the bound must too (the
    /// kernel's flat 1e-5 contract is on *normalized* cosine scores).
    fn f32_tol(scale: f64) -> f64 {
        1e-4 * scale.max(1.0)
    }

    #[test]
    fn scalar_f64_matches_serial_reference() {
        let mut rng = Rng::new(21);
        for n in LENS {
            let (a, b) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n));
            let dot: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let dot_scale: f64 =
                a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            let ss: f64 = a.iter().map(|&x| (x as f64) * (x as f64)).sum();
            assert!((dot_f64_scalar(&a, &b) - dot).abs() < 1e-9, "n={n}");
            assert!((sumsq_f64_scalar(&a) - ss).abs() < 1e-9, "n={n}");
            assert!((dot_f32_scalar(&a, &b) - dot).abs() < f32_tol(dot_scale), "n={n}");
            assert!((sumsq_f32_scalar(&a) - ss).abs() < f32_tol(ss), "n={n}");
        }
    }

    #[test]
    fn vector_f64_is_bitwise_equal_to_scalar() {
        let isa = *DETECTED.get_or_init(detect);
        if isa == Isa::Scalar {
            eprintln!("WARN: no SIMD path on this host — vector bitwise test is vacuous");
        }
        let mut rng = Rng::new(22);
        for n in LENS {
            for _ in 0..8 {
                let (a, b) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n));
                // f64: exact bit equality, the core dispatch contract
                assert_eq!(
                    dot_f64(isa, &a, &b).to_bits(),
                    dot_f64_scalar(&a, &b).to_bits(),
                    "dot_f64 n={n} isa={}",
                    isa.name()
                );
                assert_eq!(
                    sumsq_f64(isa, &a).to_bits(),
                    sumsq_f64_scalar(&a).to_bits(),
                    "sumsq_f64 n={n} isa={}",
                    isa.name()
                );
                // f32: lane-reassociation error bounded relative to the
                // term-magnitude sum (bitwise on NEON, 8-lane FMA on AVX2)
                let dot_scale: f64 =
                    a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
                assert!(
                    (dot_f32(isa, &a, &b) - dot_f32_scalar(&a, &b)).abs() <= f32_tol(dot_scale)
                );
                assert!(
                    (sumsq_f32(isa, &a) - sumsq_f32_scalar(&a)).abs()
                        <= f32_tol(sumsq_f64_scalar(&a))
                );
            }
        }
    }

    #[test]
    fn force_scalar_overrides_dispatch() {
        force_scalar(true);
        assert_eq!(active_isa(), Isa::Scalar);
        assert!(dispatch_report().starts_with("isa=scalar "));
        force_scalar(false);
        assert_eq!(active_isa(), *DETECTED.get_or_init(detect));
    }

    #[test]
    fn report_names_are_stable() {
        assert_eq!(Isa::Scalar.name(), "scalar");
        assert_eq!(Isa::Avx2.name(), "avx2");
        assert_eq!(Isa::Neon.name(), "neon");
        let report = dispatch_report();
        assert!(report.contains("features="), "{report}");
        assert!(!cpu_features().is_empty());
    }
}
