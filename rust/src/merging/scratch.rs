//! [`MergeScratch`]: the reusable arena behind the optimized merge kernel.
//!
//! All intermediate buffers the kernel needs — per-token norms, per-pair
//! best scores/indices, the top-r selection workspace, slot bookkeeping and
//! the f64 scatter accumulators — live here.  Buffers are grow-only:
//! `clear()` + `resize()` keeps capacity, so after the first call at a
//! given `(t, d)` the kernel performs **zero heap allocations per call**.

/// Reusable workspace for [`crate::merging::kernel`].  Construct once per
/// worker/thread and pass to every kernel call.
#[derive(Clone, Debug, Default)]
pub struct MergeScratch {
    /// per-token L2 norm, length `te` (even prefix of t)
    pub(crate) norms: Vec<f64>,
    /// per-A-token best similarity, length `t2`
    pub(crate) scores: Vec<f64>,
    /// per-A-token best B index, length `t2`
    pub(crate) best: Vec<usize>,
    /// top-r selection workspace, length `t2`
    pub(crate) order: Vec<usize>,
    /// per-A-token merged flag, length `t2`
    pub(crate) merged: Vec<bool>,
    /// original position -> kept slot (usize::MAX for merged), length `t`
    pub(crate) kept_slot: Vec<usize>,
    /// f64 scatter numerator, length `out_t * d`
    pub(crate) num: Vec<f64>,
    /// f64 scatter denominator (summed sizes), length `out_t`
    pub(crate) den: Vec<f64>,
}

impl MergeScratch {
    pub fn new() -> MergeScratch {
        MergeScratch::default()
    }

    /// Pre-size every buffer for a `(t, d)` problem so even the first call
    /// is allocation-free.
    pub fn with_capacity(t: usize, d: usize) -> MergeScratch {
        let t2 = t / 2;
        MergeScratch {
            norms: Vec::with_capacity(t),
            scores: Vec::with_capacity(t2),
            best: Vec::with_capacity(t2),
            order: Vec::with_capacity(t2),
            merged: Vec::with_capacity(t2),
            kept_slot: Vec::with_capacity(t),
            num: Vec::with_capacity(t * d),
            den: Vec::with_capacity(t),
        }
    }

    /// Best-match scores of the last [`crate::merging::kernel::match_tokens_scratch`]
    /// call (one entry per A-token).
    pub fn scores(&self) -> &[f64] {
        &self.scores
    }

    /// Best-match B indices of the last matching call.
    pub fn best(&self) -> &[usize] {
        &self.best
    }

    /// Consume the scratch, returning the (scores, best) match buffers —
    /// the allocating wrapper API uses this to avoid a copy.
    pub fn into_match(self) -> (Vec<f64>, Vec<usize>) {
        (self.scores, self.best)
    }
}
