//! Analytic models: eq. 2 similarity complexity, the appendix B.1 speed-up
//! bound, and the static per-layer merge schedule shared with the Python
//! side.

/// Similarity-computation complexity of local merging (paper eq. 2):
/// `t/2 + (k-1)(t-k)` pairwise scores; global merging (`k = t/2`) costs
/// `t^2/4`.
pub fn similarity_complexity(t: usize, k: usize) -> usize {
    let t2 = t / 2;
    let k = k.clamp(1, t2.max(1));
    if k >= t2 {
        t2 * t2
    } else {
        t2 + (k - 1) * (t - k)
    }
}

/// Upper bound on transformer speed-up from merging half the tokens per
/// layer (appendix B.1): `3 L 4^{L-1} / (4^L - 1)`.
pub fn speedup_bound(layers: u32) -> f64 {
    let l = layers as f64;
    3.0 * l * 4f64.powi(layers as i32 - 1) / (4f64.powi(layers as i32) - 1.0)
}

/// Static merge schedule (same rule as the Python side): token counts per
/// layer for fixed `r`, floor `q`.
pub fn merge_schedule(t: usize, r: usize, num_layers: usize, q: usize) -> Vec<usize> {
    let mut counts = vec![t];
    let mut cur = t;
    for _ in 0..num_layers {
        let even = cur - (cur % 2);
        let step = r.min(even / 2).min(cur.saturating_sub(q));
        cur -= step;
        counts.push(cur);
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complexity_matches_eq2() {
        // k = 1 -> t/2 (linear); k = t/2 -> t^2/4 (quadratic)
        assert_eq!(similarity_complexity(192, 1), 96);
        assert_eq!(similarity_complexity(192, 96), 96 * 96);
        // eq. 2 formula spot check: t=100, k=5 -> 50 + 4*95 = 430
        assert_eq!(similarity_complexity(100, 5), 430);
        // monotone in k
        let mut prev = 0;
        for k in 1..=96 {
            let c = similarity_complexity(192, k);
            assert!(c >= prev);
            prev = c;
        }
    }

    #[test]
    fn speedup_bound_values() {
        // B.1: L=1 -> 1.0; grows with L; asymptote 3L/4 slope
        assert!((speedup_bound(1) - 1.0).abs() < 1e-9);
        assert!(speedup_bound(2) > 1.5 && speedup_bound(2) < 2.0);
        assert!(speedup_bound(10) > 7.0);
        for l in 1..12 {
            assert!(speedup_bound(l + 1) > speedup_bound(l));
        }
    }

    #[test]
    fn schedule_respects_floor() {
        let s = merge_schedule(96, 16, 4, 4);
        assert_eq!(s, vec![96, 80, 64, 48, 32]);
        let s = merge_schedule(10, 100, 4, 4);
        assert_eq!(*s.last().unwrap(), 4);
    }
}
