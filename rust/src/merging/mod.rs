//! Token merging (paper §3): one typed API over batched, zero-allocation
//! Rust kernels.
//!
//! Mirrors the Layer-2 JAX semantics exactly (same A/B split, banded
//! matching, top-r selection, size-weighted averaging, order preservation,
//! slot maps) so that the coordinator's merge-policy planner, the property
//! tests and the artifact cross-validation probes all agree on one
//! definition of "merge".
//!
//! # The API (DESIGN.md §2)
//!
//! All merging is described by a [`MergeSpec`] — mode
//! ([`MergeMode::FixedR`] schedule / [`MergeMode::Dynamic`] threshold /
//! [`MergeMode::Off`]), locality `k`, accumulation precision
//! ([`Accum`]), causal flag — validated in one place and compiled
//! against a `(t, d)` shape into a [`MergePlan`], the only execution
//! entry point:
//!
//! ```no_run
//! use tomers::merging::MergeSpec;
//! # fn main() -> anyhow::Result<()> {
//! # let (tokens, sizes) = (vec![0.0f32; 192 * 64], vec![1.0f32; 192]);
//! let mut plan = MergeSpec::single(48, 16).compile(192, 64)?;
//! let merged = plan.run(&tokens, &sizes);
//! assert_eq!(merged.sizes.len(), 192 - 48);
//! # Ok(())
//! # }
//! ```
//!
//! Batched slabs go through [`MergePlan::run_batch_into`] on the shared
//! [`crate::runtime::pool::WorkerPool`].  The pre-PR 3 positional-tuple
//! entry points (`merge_fixed_r(tokens, sizes, t, d, r, k)`-style)
//! survive below as deprecated wrappers for exactly one purpose: the
//! differential suite pins the plan path bit-for-bit against them and
//! against [`reference`].
//!
//! # Module layout
//!
//! * [`spec`]      — [`MergeSpec`] / [`MergeMode`]: validation,
//!   [`MergeSpec::premerge_to`] derivation, compilation.
//! * [`pipeline`]  — [`MergePlan`]: plan-driven dispatch over the kernel
//!   (single-sequence, pool-batched, and the `thread::scope` bench
//!   baseline), slot-map composition, [`PipelineResult`].
//! * [`kernel`]    — the optimized single-sequence kernel.  Per-token norms
//!   are precomputed once (one dot per banded pair instead of recomputing
//!   `|a|` O(k) times), the matching walk is cache-blocked over the
//!   t-axis ([`kernel::matching_tile`]), and top-r selection uses
//!   `select_nth_unstable` (O(t)) instead of a full sort (O(t log t)).
//!   All entry points take a [`MergeScratch`] and an out-param, so steady
//!   state does **zero heap allocations per call**.  This is the one
//!   layer that keeps the paper's full positional tuple (scoped
//!   `too_many_arguments` allows; the crate-wide allow is gone).
//! * [`simd`]      — the dot/sum-of-squares reduction primitives the
//!   kernel is built from: explicit AVX2 (x86_64) / NEON (aarch64) vector
//!   loops behind one-time runtime dispatch ([`simd::active_isa`],
//!   overridable via `TOMERS_FORCE_SCALAR=1`), with a 4-lane chunked
//!   scalar fallback that is the bitwise ground truth for `Accum::F64`
//!   (DESIGN.md §11).
//! * [`scratch`]   — [`MergeScratch`], the reusable arena backing the
//!   kernel (norms, scores, match indices, slot workspace, f64 scatter
//!   accumulators).  Grow-only: buffers are `clear()`+`resize()`d, never
//!   reallocated once warm.
//! * `batch`       — the crate-internal chunked fan-out shared by the
//!   plan's pool and scope paths (one scratch slot per chunk, no spawns).
//! * [`incremental`] — [`IncrementalMerge`], the O(n·d) append-path twin
//!   of a causal plan for streaming decode (bit-for-bit equal to a full
//!   recompute; entry point [`MergePlan::incremental`]).
//! * [`reference`] — the legacy scalar implementation, kept verbatim as
//!   the differential-test oracle and the bench baseline.
//! * [`analytic`]  — eq. 2 complexity model, the B.1 speed-up bound and
//!   the static merge schedule (`MergeSpec::layered_for` is its typed
//!   front).
//!
//! # `BENCH_merging.json` schema
//!
//! `cargo bench --bench merging` writes a machine-readable perf record so
//! the kernel's trajectory accumulates across PRs (see `scripts/verify.sh`
//! for the regression gate).  Schema (`schema_version` 4 — v4 added the
//! `isa`/`cpu_features` dispatch record and the per-case
//! `simd_vs_scalar` / `blocked_vs_streaming` p50 ratios; v3 switched the
//! batched rows to the `MergePlan` entry points; v2 added the
//! pool-vs-scope comparison and the pool spawn/steal counters):
//!
//! ```json
//! {
//!   "schema_version": 4,
//!   "bench": "merging",
//!   "quick": false,
//!   "threads": 8,
//!   "pool_workers": 8,
//!   "isa": "avx2",             // simd::active_isa().name()
//!   "cpu_features": "sse2,avx,avx2,fma",  // simd::cpu_features()
//!   "post_warmup_spawns": 0,   // thread spawns during the timed runs (must be 0)
//!   "pool_steals": 0,          // lifetime steal count after the run
//!   "cases": [
//!     {
//!       "t": 8192, "d": 64, "k": 16, "r": 2048, "batch": 8,
//!       "legacy_ms": 0.0,          // reference scalar path, per batch
//!       "optimized_ms": 0.0,       // warm-scratch kernel, single thread
//!       "batched_ms": 0.0,         // MergePlan::run_batch_into on the pool (mean)
//!       "batched_p50_ms": 0.0,     //   .. median
//!       "batched_scope_ms": 0.0,   // MergePlan::run_batch_into_scoped baseline (mean)
//!       "batched_scope_p50_ms": 0.0, //   .. median
//!       "speedup_optimized": 0.0,  // legacy_ms / optimized_ms
//!       "speedup_batched": 0.0,    // legacy_ms / batched_ms (pool path)
//!       "simd_p50_ms": 0.0,        // single-thread kernel p50, dispatched ISA
//!       "scalar_p50_ms": 0.0,      //   .. same work forced through the scalar path
//!       "simd_vs_scalar": 0.0,     // scalar_p50_ms / simd_p50_ms (1.0 on scalar hosts)
//!       "blocked_p50_ms": 0.0,     // matching p50, default matching_tile(d)
//!       "streaming_p50_ms": 0.0,   //   .. tile = MAX (pre-blocking two-pass walk)
//!       "blocked_vs_streaming": 0.0 // streaming_p50_ms / blocked_p50_ms
//!     }
//!   ]
//! }
//! ```

pub mod analytic;
pub(crate) mod batch;
pub mod incremental;
pub mod kernel;
pub mod pipeline;
pub mod reference;
pub mod scratch;
pub mod simd;
pub mod spec;

pub use analytic::{merge_schedule, similarity_complexity, speedup_bound};
pub use incremental::IncrementalMerge;
pub use kernel::{
    match_tokens_scratch, merge_dynamic_scratch, merge_fixed_r_scratch, Accum,
};
pub use pipeline::{MergePlan, PipelineResult};
pub use scratch::MergeScratch;
pub use spec::{MergeMode, MergeSpec};

/// Result of one merge step over `t` tokens of dim `d`.
///
/// Also usable as a reusable out-param for the zero-allocation kernel
/// entry points: the buffers are `clear()`+`resize()`d in place.
#[derive(Clone, Debug, Default)]
pub struct MergeResult {
    /// (t - r) * d merged tokens, temporal order preserved.
    pub tokens: Vec<f32>,
    /// token sizes (number of originals each token represents)
    pub sizes: Vec<f32>,
    /// original position -> output slot (length t)
    pub slot_map: Vec<usize>,
}

/// Bipartite soft matching under locality constraint `k` (paper eq. 1).
///
/// Tokens at even positions form subset A, odd positions subset B; for each
/// A-token the best B-match within the band `|i - j| < k` is found.
/// Returns (best_score, best_j) per A-token.
#[deprecated(
    since = "0.3.0",
    note = "hold a MergeScratch and call kernel::match_tokens_scratch (zero-allocation)"
)]
pub fn match_tokens(tokens: &[f32], t: usize, d: usize, k: usize) -> (Vec<f64>, Vec<usize>) {
    let mut scratch = MergeScratch::new();
    kernel::match_tokens_scratch(tokens, t, d, k, &mut scratch);
    scratch.into_match()
}

/// Merge the `r` most similar A-tokens into their matched B-tokens
/// (size-weighted average, order-preserving) — the Rust twin of
/// `python/compile/merging.py::merge_fixed_r`.
///
/// One-shot wrapper over a single-layer [`MergePlan`]; keeps the legacy
/// lenient contract (`r` clamped to the feasible maximum, `k` clamped to
/// at least 1) that [`MergeSpec`] validation deliberately rejects.
#[deprecated(since = "0.3.0", note = "build a MergeSpec and compile a MergePlan")]
pub fn merge_fixed_r(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    r: usize,
    k: usize,
) -> MergeResult {
    let t2 = (t - t % 2) / 2;
    let r = r.min(t2);
    if t == 0 || d == 0 {
        return MergeResult::default();
    }
    let spec = if r == 0 { MergeSpec::off() } else { MergeSpec::single(r, k.max(1)) };
    let mut plan = spec.compile(t, d).expect("clamped legacy parameters always compile");
    let res = plan.run(tokens, sizes);
    MergeResult { tokens: res.tokens, sizes: res.sizes, slot_map: res.slot_map }
}

/// Clone-to-neighbours unmerge: gather rows through the slot map.
pub fn unmerge(tokens: &[f32], d: usize, slot_map: &[usize]) -> Vec<f32> {
    let mut out = vec![0.0f32; slot_map.len() * d];
    unmerge_into(tokens, d, slot_map, &mut out);
    out
}

/// Zero-allocation unmerge into a caller-provided buffer
/// (`out.len() == slot_map.len() * d`).
pub fn unmerge_into(tokens: &[f32], d: usize, slot_map: &[usize], out: &mut [f32]) {
    assert_eq!(out.len(), slot_map.len() * d);
    for (p, &s) in slot_map.iter().enumerate() {
        out[p * d..(p + 1) * d].copy_from_slice(&tokens[s * d..(s + 1) * d]);
    }
}

/// Dynamic merging (§5.5): merge pairs whose similarity exceeds the
/// threshold; returns (tokens', sizes', effective_token_count).
///
/// Calls the kernel directly rather than a plan because the legacy
/// contract accepts *any* threshold (a negative one means "merge every
/// feasible pair"), which [`MergeSpec::validate`] deliberately rejects;
/// the differential suite pins the plan path against this wrapper on the
/// valid range.
#[deprecated(since = "0.3.0", note = "build a MergeSpec::dynamic and compile a MergePlan")]
pub fn merge_dynamic(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    k: usize,
    threshold: f64,
) -> (MergeResult, usize) {
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    let eff =
        kernel::merge_dynamic_scratch(tokens, sizes, t, d, k, threshold, &mut scratch, &mut out);
    (out, eff)
}

/// One-shot batched merge on the process-wide pool: a machine-sized
/// single-layer [`MergePlan`] per call.
#[deprecated(
    since = "0.3.0",
    note = "compile a MergePlan once and call run_batch_into per slab"
)]
pub fn merge_batch(
    tokens: &[f32],
    sizes: &[f32],
    b: usize,
    t: usize,
    d: usize,
    r: usize,
    k: usize,
) -> Vec<MergeResult> {
    let t2 = (t - t % 2) / 2;
    let r = r.min(t2);
    if t == 0 || d == 0 {
        return vec![MergeResult::default(); b];
    }
    let spec = if r == 0 { MergeSpec::off() } else { MergeSpec::single(r, k.max(1)) };
    let mut plan = spec
        .compile(t, d)
        .expect("clamped legacy parameters always compile")
        .with_default_parallelism();
    let mut outs = Vec::new();
    plan.run_batch_into(crate::runtime::pool::WorkerPool::global(), tokens, sizes, b, &mut outs);
    outs
        .into_iter()
        .map(|res| MergeResult { tokens: res.tokens, sizes: res.sizes, slot_map: res.slot_map })
        .collect()
}

// The tests below intentionally exercise the deprecated one-shot wrappers:
// they are the legacy-semantics pins the differential suite builds on.
#[allow(deprecated)]
#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_tokens(rng: &mut Rng, t: usize, d: usize) -> Vec<f32> {
        (0..t * d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn merge_shapes_and_mass() {
        let mut rng = Rng::new(1);
        for &(t, d, r, k) in &[(24usize, 8usize, 4usize, 1usize), (24, 8, 8, 3), (25, 4, 6, 12)] {
            let tokens = rand_tokens(&mut rng, t, d);
            let sizes = vec![1.0f32; t];
            let res = merge_fixed_r(&tokens, &sizes, t, d, r, k);
            assert_eq!(res.tokens.len(), (t - r) * d);
            assert_eq!(res.sizes.len(), t - r);
            let total: f32 = res.sizes.iter().sum();
            assert!((total - t as f32).abs() < 1e-3);
            // weighted token sum preserved
            for j in 0..d {
                let before: f64 = (0..t).map(|p| tokens[p * d + j] as f64).sum();
                let after: f64 = (0..t - r)
                    .map(|s| res.tokens[s * d + j] as f64 * res.sizes[s] as f64)
                    .sum();
                assert!((before - after).abs() < 1e-3, "axis {j}: {before} vs {after}");
            }
        }
    }

    #[test]
    fn causal_k1_merges_adjacent_only() {
        let mut rng = Rng::new(2);
        let (t, d) = (32, 4);
        let tokens = rand_tokens(&mut rng, t, d);
        // the causal spec compiles (k == 1) and behaves like the k=1 wrapper
        let mut plan = MergeSpec::single(8, 1).with_causal().compile(t, d).unwrap();
        let res = plan.run(&tokens, &vec![1.0; t]);
        assert_eq!(res.slot_map, merge_fixed_r(&tokens, &vec![1.0; t], t, d, 8, 1).slot_map);
        for s in 0..t - 8 {
            let sources: Vec<usize> =
                (0..t).filter(|&p| res.slot_map[p] == s).collect();
            let span = sources.iter().max().unwrap() - sources.iter().min().unwrap();
            assert!(span <= 1, "slot {s} merged non-adjacent positions {sources:?}");
        }
    }

    #[test]
    fn identical_tokens_merge_losslessly() {
        let (t, d) = (16, 4);
        let tokens: Vec<f32> = (0..t * d).map(|i| ((i % d) + 1) as f32).collect();
        let res = merge_fixed_r(&tokens, &vec![1.0; t], t, d, 8, 8);
        for s in 0..t - 8 {
            for j in 0..d {
                assert!((res.tokens[s * d + j] - (j + 1) as f32).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn unmerge_restores_length() {
        let mut rng = Rng::new(3);
        let (t, d) = (20, 6);
        let tokens = rand_tokens(&mut rng, t, d);
        let res = merge_fixed_r(&tokens, &vec![1.0; t], t, d, 5, 2);
        let um = unmerge(&res.tokens, d, &res.slot_map);
        assert_eq!(um.len(), t * d);
        // kept tokens whose slot holds only them are bit-identical
        for p in 0..t {
            let s = res.slot_map[p];
            if res.sizes[s] == 1.0 {
                assert_eq!(&um[p * d..(p + 1) * d], &tokens[p * d..(p + 1) * d]);
            }
        }
    }

    #[test]
    fn dynamic_threshold_extremes() {
        let mut rng = Rng::new(4);
        let (t, d) = (16, 4);
        let tokens = rand_tokens(&mut rng, t, d);
        let (res, eff) = merge_dynamic(&tokens, &vec![1.0; t], t, d, 1, 1.1);
        assert_eq!(eff, t);
        assert_eq!(res.tokens, tokens);
        // the legacy wrapper still accepts the out-of-spec negative
        // threshold ("merge everything") the typed API rejects
        let (_, eff) = merge_dynamic(&tokens, &vec![1.0; t], t, d, 1, -1.1);
        assert_eq!(eff, t - t / 2);
    }

    #[test]
    fn matching_respects_band() {
        let mut rng = Rng::new(5);
        let (t, d, k) = (40, 4, 3);
        let tokens = rand_tokens(&mut rng, t, d);
        let (_, best) = match_tokens(&tokens, t, d, k);
        for (i, &j) in best.iter().enumerate() {
            assert!((i as isize - j as isize).unsigned_abs() < k);
        }
    }

    /// Regression (NaN hardening): top-r selection used
    /// `partial_cmp().unwrap()`, a latent panic that NaN scores would
    /// trigger — though NaN could never actually reach `scores`, since
    /// `if s > scores[i]` rejects NaN (see `reference.rs` header).  Both
    /// paths now use a total order; this pins down that NaN-containing
    /// tokens merge without panicking and shape invariants hold, so a
    /// future matching-loop refactor can't re-arm the hazard unnoticed.
    #[test]
    fn nan_tokens_do_not_panic() {
        let mut rng = Rng::new(6);
        let (t, d, r, k) = (24usize, 4usize, 6usize, 3usize);
        let mut tokens = rand_tokens(&mut rng, t, d);
        tokens[5] = f32::NAN;
        tokens[40] = f32::NAN;
        tokens[41] = f32::NAN;
        let sizes = vec![1.0f32; t];
        let res = merge_fixed_r(&tokens, &sizes, t, d, r, k);
        assert_eq!(res.tokens.len(), (t - r) * d);
        assert_eq!(res.sizes.len(), t - r);
        assert_eq!(res.slot_map.len(), t);
        assert!(res.slot_map.iter().all(|&s| s < t - r));
        // the legacy reference path must tolerate NaN too
        let refr = reference::merge_fixed_r_reference(&tokens, &sizes, t, d, r, k);
        assert_eq!(refr.tokens.len(), (t - r) * d);
        let (_, eff) = merge_dynamic(&tokens, &sizes, t, d, k, 0.5);
        assert!(eff <= t);
    }

    /// Scratch reuse across heterogeneous shapes must not leak state.
    #[test]
    fn scratch_reuse_is_stateless() {
        let mut rng = Rng::new(7);
        let mut scratch = MergeScratch::new();
        let mut out = MergeResult::default();
        for &(t, d, r, k) in &[(40usize, 8usize, 10usize, 4usize), (9, 3, 2, 1), (64, 16, 30, 32), (11, 5, 0, 2)] {
            let tokens = rand_tokens(&mut rng, t, d);
            let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(3) as f32).collect();
            kernel::merge_fixed_r_scratch(&tokens, &sizes, t, d, r, k, &mut scratch, &mut out);
            let fresh = merge_fixed_r(&tokens, &sizes, t, d, r, k);
            assert_eq!(out.tokens, fresh.tokens, "t={t} d={d} r={r} k={k}");
            assert_eq!(out.sizes, fresh.sizes);
            assert_eq!(out.slot_map, fresh.slot_map);
        }
    }
}
