//! Token merging (paper §3): batched, zero-allocation Rust kernels.
//!
//! Mirrors the Layer-2 JAX semantics exactly (same A/B split, banded
//! matching, top-r selection, size-weighted averaging, order preservation,
//! slot maps) so that the coordinator's merge-policy planner, the property
//! tests and the artifact cross-validation probes all agree on one
//! definition of "merge".
//!
//! # Module layout
//!
//! * [`kernel`]    — the optimized single-sequence kernel.  Per-token norms
//!   are precomputed once (one dot per banded pair instead of recomputing
//!   `|a|` O(k) times), the cosine dot runs as a 4-lane chunked f64
//!   accumulation the compiler can autovectorize, and top-r selection uses
//!   `select_nth_unstable` (O(t)) instead of a full sort (O(t log t)).
//!   All entry points take a [`MergeScratch`] and an out-param, so steady
//!   state does **zero heap allocations per call**.
//! * [`scratch`]   — [`MergeScratch`], the reusable arena backing the
//!   kernel (norms, scores, match indices, slot workspace, f64 scatter
//!   accumulators).  Grow-only: buffers are `clear()`+`resize()`d, never
//!   reallocated once warm.
//! * [`batch`]     — [`BatchMerger`] / [`merge_batch`]: one merge over a
//!   `(b, t, d)` slab, parallelized across the batch on the shared
//!   persistent [`crate::runtime::pool::WorkerPool`] (no per-call thread
//!   spawns), one scratch per slot; an [`Accum::F32`] banded-dot variant
//!   for throughput-bound callers.
//! * [`pipeline`]  — [`MergePipeline`]: runs a whole per-layer schedule
//!   (`merge_schedule`) in one call, reusing scratch across layers and
//!   composing per-layer slot maps so a single gather unmerges the final
//!   tokens back to input positions.  [`BatchPipeline`] is its batched,
//!   pool-backed form (the serving prep stage's premerge engine).
//! * [`reference`] — the legacy scalar implementation, kept verbatim as
//!   the differential-test oracle and the bench baseline.
//! * [`analytic`]  — eq. 2 complexity model, the B.1 speed-up bound and
//!   the static merge schedule.
//!
//! The original single-shot API (`match_tokens`, `merge_fixed_r`,
//! `unmerge`, `merge_dynamic`) survives below as thin wrappers over the
//! optimized kernel, so Layer-2 JAX parity semantics and all existing
//! callers/tests are untouched.
//!
//! # `BENCH_merging.json` schema
//!
//! `cargo bench --bench merging` writes a machine-readable perf record so
//! the kernel's trajectory accumulates across PRs (see `scripts/verify.sh`
//! for the regression gate).  Schema (`schema_version` 2 — v2 added the
//! pool-vs-scope comparison and the pool spawn/steal counters):
//!
//! ```json
//! {
//!   "schema_version": 2,
//!   "bench": "merging",
//!   "quick": false,
//!   "threads": 8,
//!   "pool_workers": 8,
//!   "post_warmup_spawns": 0,   // thread spawns during the timed runs (must be 0)
//!   "pool_steals": 0,          // lifetime steal count after the run
//!   "cases": [
//!     {
//!       "t": 8192, "d": 64, "k": 16, "r": 2048, "batch": 8,
//!       "legacy_ms": 0.0,          // reference scalar path, per batch
//!       "optimized_ms": 0.0,       // warm-scratch kernel, single thread
//!       "batched_ms": 0.0,         // BatchMerger on the WorkerPool (mean)
//!       "batched_p50_ms": 0.0,     //   .. median
//!       "batched_scope_ms": 0.0,   // PR 1 thread::scope baseline (mean)
//!       "batched_scope_p50_ms": 0.0, //   .. median
//!       "speedup_optimized": 0.0,  // legacy_ms / optimized_ms
//!       "speedup_batched": 0.0     // legacy_ms / batched_ms (pool path)
//!     }
//!   ]
//! }
//! ```

pub mod analytic;
pub mod batch;
pub mod kernel;
pub mod pipeline;
pub mod reference;
pub mod scratch;

pub use analytic::{merge_schedule, similarity_complexity, speedup_bound};
pub use batch::{merge_batch, BatchMerger};
pub use kernel::{match_tokens_scratch, merge_dynamic_scratch, merge_fixed_r_scratch, Accum};
pub use pipeline::{BatchPipeline, MergePipeline, PipelineResult};
pub use scratch::MergeScratch;

/// Result of one merge step over `t` tokens of dim `d`.
///
/// Also usable as a reusable out-param for the zero-allocation kernel
/// entry points: the buffers are `clear()`+`resize()`d in place.
#[derive(Clone, Debug, Default)]
pub struct MergeResult {
    /// (t - r) * d merged tokens, temporal order preserved.
    pub tokens: Vec<f32>,
    /// token sizes (number of originals each token represents)
    pub sizes: Vec<f32>,
    /// original position -> output slot (length t)
    pub slot_map: Vec<usize>,
}

/// Bipartite soft matching under locality constraint `k` (paper eq. 1).
///
/// Tokens at even positions form subset A, odd positions subset B; for each
/// A-token the best B-match within the band `|i - j| < k` is found.
/// Returns (best_score, best_j) per A-token.
///
/// Thin wrapper over [`kernel::match_tokens_scratch`]; allocates a fresh
/// scratch per call.  Hot paths should hold a [`MergeScratch`] instead.
pub fn match_tokens(tokens: &[f32], t: usize, d: usize, k: usize) -> (Vec<f64>, Vec<usize>) {
    let mut scratch = MergeScratch::new();
    kernel::match_tokens_scratch(tokens, t, d, k, &mut scratch);
    scratch.into_match()
}

/// Merge the `r` most similar A-tokens into their matched B-tokens
/// (size-weighted average, order-preserving) — the Rust twin of
/// `python/compile/merging.py::merge_fixed_r`.
///
/// Thin wrapper over [`kernel::merge_fixed_r_scratch`]; allocates a fresh
/// scratch per call.  Hot paths should hold a [`MergeScratch`] instead.
pub fn merge_fixed_r(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    r: usize,
    k: usize,
) -> MergeResult {
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    kernel::merge_fixed_r_scratch(tokens, sizes, t, d, r, k, &mut scratch, &mut out);
    out
}

/// Clone-to-neighbours unmerge: gather rows through the slot map.
pub fn unmerge(tokens: &[f32], d: usize, slot_map: &[usize]) -> Vec<f32> {
    let mut out = vec![0.0f32; slot_map.len() * d];
    unmerge_into(tokens, d, slot_map, &mut out);
    out
}

/// Zero-allocation unmerge into a caller-provided buffer
/// (`out.len() == slot_map.len() * d`).
pub fn unmerge_into(tokens: &[f32], d: usize, slot_map: &[usize], out: &mut [f32]) {
    assert_eq!(out.len(), slot_map.len() * d);
    for (p, &s) in slot_map.iter().enumerate() {
        out[p * d..(p + 1) * d].copy_from_slice(&tokens[s * d..(s + 1) * d]);
    }
}

/// Dynamic merging (§5.5): merge pairs whose similarity exceeds the
/// threshold; returns (tokens', sizes', effective_token_count).
///
/// Thin wrapper over [`kernel::merge_dynamic_scratch`].
pub fn merge_dynamic(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    k: usize,
    threshold: f64,
) -> (MergeResult, usize) {
    let mut scratch = MergeScratch::new();
    let mut out = MergeResult::default();
    let eff = kernel::merge_dynamic_scratch(tokens, sizes, t, d, k, threshold, &mut scratch, &mut out);
    (out, eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_tokens(rng: &mut Rng, t: usize, d: usize) -> Vec<f32> {
        (0..t * d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn merge_shapes_and_mass() {
        let mut rng = Rng::new(1);
        for &(t, d, r, k) in &[(24usize, 8usize, 4usize, 1usize), (24, 8, 8, 3), (25, 4, 6, 12)] {
            let tokens = rand_tokens(&mut rng, t, d);
            let sizes = vec![1.0f32; t];
            let res = merge_fixed_r(&tokens, &sizes, t, d, r, k);
            assert_eq!(res.tokens.len(), (t - r) * d);
            assert_eq!(res.sizes.len(), t - r);
            let total: f32 = res.sizes.iter().sum();
            assert!((total - t as f32).abs() < 1e-3);
            // weighted token sum preserved
            for j in 0..d {
                let before: f64 = (0..t).map(|p| tokens[p * d + j] as f64).sum();
                let after: f64 = (0..t - r)
                    .map(|s| res.tokens[s * d + j] as f64 * res.sizes[s] as f64)
                    .sum();
                assert!((before - after).abs() < 1e-3, "axis {j}: {before} vs {after}");
            }
        }
    }

    #[test]
    fn causal_k1_merges_adjacent_only() {
        let mut rng = Rng::new(2);
        let (t, d) = (32, 4);
        let tokens = rand_tokens(&mut rng, t, d);
        let res = merge_fixed_r(&tokens, &vec![1.0; t], t, d, 8, 1);
        for s in 0..t - 8 {
            let sources: Vec<usize> =
                (0..t).filter(|&p| res.slot_map[p] == s).collect();
            let span = sources.iter().max().unwrap() - sources.iter().min().unwrap();
            assert!(span <= 1, "slot {s} merged non-adjacent positions {sources:?}");
        }
    }

    #[test]
    fn identical_tokens_merge_losslessly() {
        let (t, d) = (16, 4);
        let tokens: Vec<f32> = (0..t * d).map(|i| ((i % d) + 1) as f32).collect();
        let res = merge_fixed_r(&tokens, &vec![1.0; t], t, d, 8, 8);
        for s in 0..t - 8 {
            for j in 0..d {
                assert!((res.tokens[s * d + j] - (j + 1) as f32).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn unmerge_restores_length() {
        let mut rng = Rng::new(3);
        let (t, d) = (20, 6);
        let tokens = rand_tokens(&mut rng, t, d);
        let res = merge_fixed_r(&tokens, &vec![1.0; t], t, d, 5, 2);
        let um = unmerge(&res.tokens, d, &res.slot_map);
        assert_eq!(um.len(), t * d);
        // kept tokens whose slot holds only them are bit-identical
        for p in 0..t {
            let s = res.slot_map[p];
            if res.sizes[s] == 1.0 {
                assert_eq!(&um[p * d..(p + 1) * d], &tokens[p * d..(p + 1) * d]);
            }
        }
    }

    #[test]
    fn dynamic_threshold_extremes() {
        let mut rng = Rng::new(4);
        let (t, d) = (16, 4);
        let tokens = rand_tokens(&mut rng, t, d);
        let (res, eff) = merge_dynamic(&tokens, &vec![1.0; t], t, d, 1, 1.1);
        assert_eq!(eff, t);
        assert_eq!(res.tokens, tokens);
        let (_, eff) = merge_dynamic(&tokens, &vec![1.0; t], t, d, 1, -1.1);
        assert_eq!(eff, t - t / 2);
    }

    #[test]
    fn matching_respects_band() {
        let mut rng = Rng::new(5);
        let (t, d, k) = (40, 4, 3);
        let tokens = rand_tokens(&mut rng, t, d);
        let (_, best) = match_tokens(&tokens, t, d, k);
        for (i, &j) in best.iter().enumerate() {
            assert!((i as isize - j as isize).unsigned_abs() < k);
        }
    }

    /// Regression (NaN hardening): top-r selection used
    /// `partial_cmp().unwrap()`, a latent panic that NaN scores would
    /// trigger — though NaN could never actually reach `scores`, since
    /// `if s > scores[i]` rejects NaN (see `reference.rs` header).  Both
    /// paths now use a total order; this pins down that NaN-containing
    /// tokens merge without panicking and shape invariants hold, so a
    /// future matching-loop refactor can't re-arm the hazard unnoticed.
    #[test]
    fn nan_tokens_do_not_panic() {
        let mut rng = Rng::new(6);
        let (t, d, r, k) = (24usize, 4usize, 6usize, 3usize);
        let mut tokens = rand_tokens(&mut rng, t, d);
        tokens[5] = f32::NAN;
        tokens[40] = f32::NAN;
        tokens[41] = f32::NAN;
        let sizes = vec![1.0f32; t];
        let res = merge_fixed_r(&tokens, &sizes, t, d, r, k);
        assert_eq!(res.tokens.len(), (t - r) * d);
        assert_eq!(res.sizes.len(), t - r);
        assert_eq!(res.slot_map.len(), t);
        assert!(res.slot_map.iter().all(|&s| s < t - r));
        // the legacy reference path must tolerate NaN too
        let refr = reference::merge_fixed_r_reference(&tokens, &sizes, t, d, r, k);
        assert_eq!(refr.tokens.len(), (t - r) * d);
        let (_, eff) = merge_dynamic(&tokens, &sizes, t, d, k, 0.5);
        assert!(eff <= t);
    }

    /// Scratch reuse across heterogeneous shapes must not leak state.
    #[test]
    fn scratch_reuse_is_stateless() {
        let mut rng = Rng::new(7);
        let mut scratch = MergeScratch::new();
        let mut out = MergeResult::default();
        for &(t, d, r, k) in &[(40usize, 8usize, 10usize, 4usize), (9, 3, 2, 1), (64, 16, 30, 32), (11, 5, 0, 2)] {
            let tokens = rand_tokens(&mut rng, t, d);
            let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(3) as f32).collect();
            kernel::merge_fixed_r_scratch(&tokens, &sizes, t, d, r, k, &mut scratch, &mut out);
            let fresh = merge_fixed_r(&tokens, &sizes, t, d, r, k);
            assert_eq!(out.tokens, fresh.tokens, "t={t} d={d} r={r} k={k}");
            assert_eq!(out.sizes, fresh.sizes);
            assert_eq!(out.slot_map, fresh.slot_map);
        }
    }
}
