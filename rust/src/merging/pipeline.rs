//! [`MergePlan`]: plan-driven merge execution.
//!
//! A plan is a [`MergeSpec`](super::MergeSpec) compiled against a concrete
//! `(t, d)` shape: per-layer token counts are precomputed and validated,
//! and every intermediate (kernel scratch, ping-pong layer buffers) lives
//! in plan-owned slots, so steady-state execution performs **zero heap
//! allocations and zero thread spawns** — the same guarantees PR 1–2
//! established for the raw kernel, now behind one typed entry point.
//!
//! * [`MergePlan::run`] / [`MergePlan::run_into`] — one sequence.  Multi-
//!   layer schedules reuse one scratch and two ping-pong buffers across
//!   layers and compose the per-layer slot maps into a single
//!   `original position -> final slot` gather (unmerge is **one** gather
//!   instead of one per layer).
//! * [`MergePlan::run_batch_into`] — a `(b, t, d)` slab on the shared
//!   [`WorkerPool`]: one slot per contiguous sequence chunk (see
//!   [`MergePlan::with_slots`]), chunks run as pool tasks.  This replaces
//!   the PR 1–2 `BatchMerger::merge_batch_into` /
//!   `BatchPipeline::run_schedule_into` function matrix.
//! * [`MergePlan::run_batch_into_scoped`] — the PR 1 `std::thread::scope`
//!   fan-out, kept **only** as the bench baseline (`benches/merging.rs`
//!   gates pool <= scope); it spawns threads per call.
//!
//! Dynamic mode (§5.5) runs as a single data-dependent layer; the
//! realized output length lands in [`PipelineResult::token_counts`].
//!
//! SIMD dispatch and cache blocking (PR 7) ride through every plan path
//! automatically: all three entry points bottom out in the
//! [`kernel`] scratch functions, which resolve
//! [`super::simd::active_isa`] per call (one process-global probe) and
//! tile the matching walk via [`kernel::matching_tile`].  There is no
//! per-plan ISA state to configure — a plan compiled before the first
//! kernel call behaves identically to one compiled after, and the
//! coordinator's `HostPrep` premerge (which executes compiled plans)
//! inherits both for free.  `Accum::F64` plans are bitwise-invariant to
//! the dispatched ISA (see `simd.rs`).

use super::kernel;
use super::scratch::MergeScratch;
use super::spec::{MergeMode, MergeSpec};
use super::{unmerge, MergeResult};
use crate::runtime::pool::WorkerPool;

/// Output of one plan (or legacy pipeline) run.
#[derive(Clone, Debug, Default)]
pub struct PipelineResult {
    /// final merged tokens, `token_counts.last() * d`
    pub tokens: Vec<f32>,
    /// final token sizes
    pub sizes: Vec<f32>,
    /// composed map: original position (length t) -> final output slot
    pub slot_map: Vec<usize>,
    /// token count before layer 0 and after each layer; for dynamic mode
    /// the realized (data-dependent) count is the last entry
    pub token_counts: Vec<usize>,
}

impl PipelineResult {
    /// One-shot unmerge through the composed slot map: returns `(t, d)`
    /// rows, each original position receiving its merged representative.
    pub fn unmerge(&self, d: usize) -> Vec<f32> {
        unmerge(&self.tokens, d, &self.slot_map)
    }

    /// Tokens entering layer 0 (0 before any run).
    pub fn tokens_in(&self) -> usize {
        self.token_counts.first().copied().unwrap_or(0)
    }

    /// Tokens surviving the last layer (0 before any run).
    pub fn tokens_out(&self) -> usize {
        self.token_counts.last().copied().unwrap_or(0)
    }

    /// Merge layers this run executed (`token_counts` holds the count
    /// before layer 0 plus one entry per layer).
    pub fn layers(&self) -> usize {
        self.token_counts.len().saturating_sub(1)
    }

    /// Realized compression `tokens_in / tokens_out` of this run (1.0
    /// when nothing merged) — the per-call merge-efficiency sample the
    /// serving metrics aggregate (`Metrics::record_compression`).
    pub fn compression_ratio(&self) -> f64 {
        if self.tokens_out() == 0 {
            1.0
        } else {
            self.tokens_in() as f64 / self.tokens_out() as f64
        }
    }
}

/// Per-chunk execution state: kernel scratch plus two ping-pong layer
/// buffers.  Grow-only, like everything the kernel touches.
#[derive(Default)]
struct PlanSlot {
    scratch: MergeScratch,
    cur: MergeResult,
    next: MergeResult,
}

/// The shape-and-schedule view shared by every slot of one plan run
/// (split off `MergePlan` so slots can borrow it while being iterated
/// mutably).
struct PlanView<'a> {
    spec: &'a MergeSpec,
    rs: &'a [usize],
    counts: &'a [usize],
    t: usize,
    d: usize,
}

impl PlanSlot {
    /// Run the plan over one `(t, d)` sequence into `out` (buffers are
    /// cleared and refilled in place — no allocations when warm).
    fn run_into(
        &mut self,
        view: &PlanView,
        tokens: &[f32],
        sizes: &[f32],
        out: &mut PipelineResult,
    ) {
        let (t, d) = (view.t, view.d);
        debug_assert_eq!(tokens.len(), t * d);
        debug_assert_eq!(sizes.len(), t);

        out.slot_map.clear();
        out.slot_map.extend(0..t);
        out.token_counts.clear();

        match &view.spec.mode {
            MergeMode::Off => {
                out.tokens.clear();
                out.tokens.extend_from_slice(tokens);
                out.sizes.clear();
                out.sizes.extend_from_slice(sizes);
                out.token_counts.push(t);
            }
            MergeMode::Dynamic { threshold } => {
                let eff = kernel::merge_dynamic_scratch_accum(
                    tokens,
                    sizes,
                    t,
                    d,
                    view.spec.k,
                    *threshold,
                    &mut self.scratch,
                    &mut self.next,
                    view.spec.accum,
                );
                for slot in out.slot_map.iter_mut() {
                    *slot = self.next.slot_map[*slot];
                }
                out.tokens.clear();
                out.tokens.extend_from_slice(&self.next.tokens);
                out.sizes.clear();
                out.sizes.extend_from_slice(&self.next.sizes);
                out.token_counts.push(t);
                out.token_counts.push(eff);
            }
            MergeMode::FixedR { .. } => {
                out.token_counts.extend_from_slice(view.counts);
                if view.rs.is_empty() {
                    out.tokens.clear();
                    out.tokens.extend_from_slice(tokens);
                    out.sizes.clear();
                    out.sizes.extend_from_slice(sizes);
                    return;
                }
                let PlanSlot { scratch, cur, next } = self;
                cur.tokens.clear();
                cur.tokens.extend_from_slice(tokens);
                cur.sizes.clear();
                cur.sizes.extend_from_slice(sizes);
                let mut cur_t = t;
                for &r_l in view.rs {
                    kernel::merge_fixed_r_scratch_accum(
                        &cur.tokens,
                        &cur.sizes,
                        cur_t,
                        d,
                        r_l,
                        view.spec.k,
                        scratch,
                        next,
                        view.spec.accum,
                    );
                    // Compose: original -> (slot in cur) -> (slot in next).
                    for slot in out.slot_map.iter_mut() {
                        *slot = next.slot_map[*slot];
                    }
                    cur_t = next.sizes.len();
                    std::mem::swap(cur, next);
                }
                debug_assert_eq!(cur_t, *view.counts.last().unwrap());
                out.tokens.clear();
                out.tokens.extend_from_slice(&cur.tokens);
                out.sizes.clear();
                out.sizes.extend_from_slice(&cur.sizes);
            }
        }
    }
}

/// A compiled, reusable merge executor — see [`MergeSpec::compile`] and
/// the module docs for the lifecycle.
pub struct MergePlan {
    spec: MergeSpec,
    t: usize,
    d: usize,
    /// token counts before layer 0 and after each fixed layer
    counts: Vec<usize>,
    /// per-layer r derived from `counts` (empty for Off/Dynamic)
    rs: Vec<usize>,
    slots: Vec<PlanSlot>,
}

impl MergePlan {
    /// Called by [`MergeSpec::compile`] with an already-validated spec and
    /// feasibility-checked counts.
    pub(crate) fn new(spec: MergeSpec, t: usize, d: usize, counts: Vec<usize>) -> MergePlan {
        let rs = match spec.mode {
            MergeMode::FixedR { .. } => counts.windows(2).map(|w| w[0] - w[1]).collect(),
            _ => Vec::new(),
        };
        MergePlan { spec, t, d, counts, rs, slots: vec![PlanSlot::default()] }
    }

    /// The spec this plan was compiled from.
    pub fn spec(&self) -> &MergeSpec {
        &self.spec
    }

    /// Sequence length the plan is compiled for.
    pub fn t(&self) -> usize {
        self.t
    }

    /// Token dimensionality the plan is compiled for.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Token counts before layer 0 and after each fixed layer (length
    /// `layers + 1`; just `[t]` for Off/Dynamic, whose realized count is
    /// only known per run).
    pub fn layer_counts(&self) -> &[usize] {
        &self.counts
    }

    /// Final token count for Off/FixedR plans; for Dynamic plans this is
    /// the upper bound `t` (the realized count is data-dependent).
    pub fn out_tokens(&self) -> usize {
        *self.counts.last().unwrap()
    }

    /// Number of scratch slots (the maximum batch-chunk parallelism).
    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Resize to `n` scratch slots (clamped to at least 1) for batched
    /// execution; one chunk of the batch runs per slot.
    pub fn with_slots(mut self, n: usize) -> MergePlan {
        self.slots.resize_with(n.max(1), PlanSlot::default);
        self
    }

    /// A plan sized to the machine (`available_parallelism` slots).
    pub fn with_default_parallelism(self) -> MergePlan {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        self.with_slots(n)
    }

    /// An incremental append-path twin of this plan for streaming decode:
    /// a fresh [`IncrementalMerge`](super::IncrementalMerge) over the same
    /// spec and `d`, whose state after appending a history equals running
    /// this spec's full-sequence plan over it bit-for-bit.  Errs unless
    /// the spec is `Off` or causal `Dynamic` (see
    /// `merging::incremental` for why fixed-`r` cannot be incremental).
    pub fn incremental(&self) -> anyhow::Result<super::IncrementalMerge> {
        super::IncrementalMerge::new(self.spec.clone(), self.d)
    }

    /// Run over one `(t, d)` sequence, allocating the result.  Hot paths
    /// should reuse a buffer via [`MergePlan::run_into`].
    pub fn run(&mut self, tokens: &[f32], sizes: &[f32]) -> PipelineResult {
        let mut out = PipelineResult::default();
        self.run_into(tokens, sizes, &mut out);
        out
    }

    /// Zero-allocation single-sequence run into a reusable `out`.
    pub fn run_into(&mut self, tokens: &[f32], sizes: &[f32], out: &mut PipelineResult) {
        assert_eq!(tokens.len(), self.t * self.d, "token slab shape mismatch");
        assert_eq!(sizes.len(), self.t, "sizes shape mismatch");
        let view = PlanView {
            spec: &self.spec,
            rs: self.rs.as_slice(),
            counts: self.counts.as_slice(),
            t: self.t,
            d: self.d,
        };
        self.slots[0].run_into(&view, tokens, sizes, out);
    }

    /// Run over every sequence of a `(b, t, d)` slab (row-major,
    /// sequence-contiguous; per-sequence sizes `(b, t)`), writing one
    /// [`PipelineResult`] per sequence into `outs` (resized to `b`).
    /// Contiguous chunks run as tasks on `pool`, one per slot; a
    /// single-slot plan (or a single-sequence batch) runs inline on the
    /// caller.
    pub fn run_batch_into(
        &mut self,
        pool: &WorkerPool,
        tokens: &[f32],
        sizes: &[f32],
        b: usize,
        outs: &mut Vec<PipelineResult>,
    ) {
        assert_eq!(tokens.len(), b * self.t * self.d, "token slab shape mismatch");
        assert_eq!(sizes.len(), b * self.t, "sizes slab shape mismatch");
        outs.resize_with(b, PipelineResult::default);
        if b == 0 {
            return;
        }
        let view = PlanView {
            spec: &self.spec,
            rs: self.rs.as_slice(),
            counts: self.counts.as_slice(),
            t: self.t,
            d: self.d,
        };
        super::batch::run_chunked(
            pool,
            &mut self.slots,
            tokens,
            sizes,
            b,
            view.t,
            view.d,
            outs,
            |slot, tok, sz, out| slot.run_into(&view, tok, sz, out),
        );
    }

    /// The PR 1 `std::thread::scope` fan-out, kept verbatim as the bench
    /// baseline (`benches/merging.rs` gates the pool path against it).
    /// Spawns `slots()` fresh threads **per call** — do not use outside
    /// benches.
    pub fn run_batch_into_scoped(
        &mut self,
        tokens: &[f32],
        sizes: &[f32],
        b: usize,
        outs: &mut Vec<PipelineResult>,
    ) {
        assert_eq!(tokens.len(), b * self.t * self.d, "token slab shape mismatch");
        assert_eq!(sizes.len(), b * self.t, "sizes slab shape mismatch");
        outs.resize_with(b, PipelineResult::default);
        if b == 0 {
            return;
        }
        let view = PlanView {
            spec: &self.spec,
            rs: self.rs.as_slice(),
            counts: self.counts.as_slice(),
            t: self.t,
            d: self.d,
        };
        let (t, d) = (view.t, view.d);
        let slots = &mut self.slots;
        let n_slots = slots.len();
        let chunk = (b + n_slots - 1) / n_slots;
        if n_slots == 1 || b == 1 {
            let slot = &mut slots[0];
            for (i, out) in outs.iter_mut().enumerate() {
                let tok = &tokens[i * t * d..(i + 1) * t * d];
                slot.run_into(&view, tok, &sizes[i * t..(i + 1) * t], out);
            }
            return;
        }
        let view = &view;
        std::thread::scope(|scope| {
            let mut slot_iter = slots.iter_mut();
            for (out_chunk, (tok_chunk, size_chunk)) in outs
                .chunks_mut(chunk)
                .zip(tokens.chunks(chunk * t * d).zip(sizes.chunks(chunk * t)))
            {
                let slot = slot_iter.next().expect("one slot per chunk");
                scope.spawn(move || {
                    for (i, out) in out_chunk.iter_mut().enumerate() {
                        slot.run_into(
                            view,
                            &tok_chunk[i * t * d..(i + 1) * t * d],
                            &size_chunk[i * t..(i + 1) * t],
                            out,
                        );
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::reference::merge_fixed_r_reference;
    use crate::merging::{merge_schedule, MergeSpec};
    use crate::util::Rng;

    fn rand_tokens(rng: &mut Rng, t: usize, d: usize) -> Vec<f32> {
        (0..t * d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn plan_matches_sequential_single_shots() {
        let mut rng = Rng::new(31);
        let (t, d, k, r, layers, q) = (48usize, 6usize, 3usize, 8usize, 4usize, 4usize);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(2) as f32).collect();

        let mut plan = MergeSpec::layered_for(t, r, layers, q, k).compile(t, d).unwrap();
        let res = plan.run(&tokens, &sizes);

        // sequential reference composition
        let counts = merge_schedule(t, r, layers, q);
        let mut cur_tokens = tokens.clone();
        let mut cur_sizes = sizes.clone();
        let mut cur_t = t;
        let mut composed: Vec<usize> = (0..t).collect();
        for w in counts.windows(2) {
            let step = w[0] - w[1];
            if step == 0 {
                continue;
            }
            let m = merge_fixed_r_reference(&cur_tokens, &cur_sizes, cur_t, d, step, k);
            for slot in composed.iter_mut() {
                *slot = m.slot_map[*slot];
            }
            cur_tokens = m.tokens;
            cur_sizes = m.sizes;
            cur_t = w[1];
        }
        assert_eq!(*res.token_counts.last().unwrap(), *counts.last().unwrap());
        assert_eq!(res.slot_map, composed);
        for (a, b) in res.tokens.iter().zip(&cur_tokens) {
            assert!((a - b).abs() <= 1e-5);
        }
        assert_eq!(res.sizes.len(), cur_sizes.len());
    }

    #[test]
    fn composed_unmerge_equals_layerwise_unmerge() {
        let mut rng = Rng::new(32);
        let (t, d, k) = (40usize, 4usize, 2usize);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0f32; t];
        let rs = [6usize, 6, 4];

        // layerwise: keep each layer's slot_map, then gather back up
        let mut cur_tokens = tokens.clone();
        let mut cur_sizes = sizes.clone();
        let mut cur_t = t;
        let mut maps = Vec::new();
        for &r_l in &rs {
            let m = merge_fixed_r_reference(&cur_tokens, &cur_sizes, cur_t, d, r_l, k);
            cur_t -= r_l;
            maps.push(m.slot_map.clone());
            cur_tokens = m.tokens;
            cur_sizes = m.sizes;
        }
        let mut up = cur_tokens.clone();
        for map in maps.iter().rev() {
            up = unmerge(&up, d, map);
        }

        let mut plan = MergeSpec::fixed_r(rs.to_vec(), k).compile(t, d).unwrap();
        let res = plan.run(&tokens, &sizes);
        assert_eq!(res.unmerge(d), up);
    }

    #[test]
    fn plan_reuse_across_inputs_is_stateless() {
        let mut rng = Rng::new(33);
        let (t, d) = (30usize, 4usize);
        let spec = MergeSpec::fixed_r(vec![5, 5, 4], 2);
        let mut plan = spec.compile(t, d).unwrap();
        let mut out = PipelineResult::default();
        for _ in 0..3 {
            let tokens = rand_tokens(&mut rng, t, d);
            let sizes = vec![1.0f32; t];
            plan.run_into(&tokens, &sizes, &mut out);
            let fresh = spec.compile(t, d).unwrap().run(&tokens, &sizes);
            assert_eq!(out.tokens, fresh.tokens);
            assert_eq!(out.slot_map, fresh.slot_map);
            assert_eq!(out.token_counts, fresh.token_counts);
        }
    }

    #[test]
    fn batch_plan_matches_per_sequence_runs() {
        let mut rng = Rng::new(35);
        let pool = WorkerPool::new(3);
        let (b, t, d, k) = (6usize, 36usize, 4usize, 3usize);
        let spec = MergeSpec::fixed_r(vec![8, 6, 4], k);
        let tokens = rand_tokens(&mut rng, b * t, d);
        let sizes: Vec<f32> = (0..b * t).map(|_| 1.0 + rng.below(2) as f32).collect();
        for slots in [1usize, 2, 5] {
            let mut plan = spec.compile(t, d).unwrap().with_slots(slots);
            assert_eq!(plan.slots(), slots);
            let mut outs = Vec::new();
            plan.run_batch_into(&pool, &tokens, &sizes, b, &mut outs);
            assert_eq!(outs.len(), b);
            let mut single = spec.compile(t, d).unwrap();
            for i in 0..b {
                let want = single.run(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                );
                assert_eq!(outs[i].tokens, want.tokens, "slots={slots} seq={i}");
                assert_eq!(outs[i].slot_map, want.slot_map);
                assert_eq!(outs[i].token_counts, want.token_counts);
            }
        }
    }

    #[test]
    fn pool_path_equals_scoped_baseline() {
        let mut rng = Rng::new(36);
        let pool = WorkerPool::new(4);
        let (b, t, d) = (9usize, 26usize, 4usize);
        let tokens = rand_tokens(&mut rng, b * t, d);
        let sizes = vec![1.0f32; b * t];
        let mut plan = MergeSpec::single(6, 5).compile(t, d).unwrap().with_slots(4);
        let (mut on_pool, mut scoped) = (Vec::new(), Vec::new());
        plan.run_batch_into(&pool, &tokens, &sizes, b, &mut on_pool);
        plan.run_batch_into_scoped(&tokens, &sizes, b, &mut scoped);
        for i in 0..b {
            assert_eq!(on_pool[i].slot_map, scoped[i].slot_map, "seq {i}");
            assert_eq!(on_pool[i].tokens, scoped[i].tokens);
            assert_eq!(on_pool[i].sizes, scoped[i].sizes);
        }
    }

    #[test]
    fn off_and_identity_plans_pass_through() {
        let mut rng = Rng::new(37);
        let (t, d) = (17usize, 3usize);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(2) as f32).collect();
        for spec in [MergeSpec::off(), MergeSpec::fixed_r(Vec::new(), 4)] {
            let mut plan = spec.compile(t, d).unwrap();
            let res = plan.run(&tokens, &sizes);
            assert_eq!(res.tokens, tokens);
            assert_eq!(res.sizes, sizes);
            assert_eq!(res.slot_map, (0..t).collect::<Vec<_>>());
            assert_eq!(*res.token_counts.last().unwrap(), t);
        }
    }

    #[test]
    fn dynamic_plan_reports_realized_count() {
        let mut rng = Rng::new(38);
        let (t, d) = (16usize, 4usize);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0f32; t];
        // threshold above any cosine: nothing merges
        let mut plan = MergeSpec::dynamic(1.1, 1).compile(t, d).unwrap();
        let res = plan.run(&tokens, &sizes);
        assert_eq!(res.token_counts, vec![t, t]);
        assert_eq!(res.tokens, tokens);
        assert_eq!((res.tokens_in(), res.tokens_out(), res.layers()), (t, t, 1));
        assert_eq!(res.compression_ratio(), 1.0);
        // threshold 0 on identical tokens: every pair merges
        let constant: Vec<f32> = (0..t * d).map(|i| ((i % d) + 1) as f32).collect();
        let mut plan = MergeSpec::dynamic(0.0, 1).compile(t, d).unwrap();
        let res = plan.run(&constant, &sizes);
        assert_eq!(*res.token_counts.last().unwrap(), t - t / 2);
        assert_eq!(res.sizes.len(), t - t / 2);
        assert_eq!(res.tokens_out(), t - t / 2);
        assert!((res.compression_ratio() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_batch_is_fine() {
        let pool = WorkerPool::new(2);
        let mut plan = MergeSpec::single(2, 1).compile(8, 4).unwrap().with_slots(4);
        let mut outs = vec![PipelineResult::default(); 3];
        plan.run_batch_into(&pool, &[], &[], 0, &mut outs);
        assert!(outs.is_empty());
    }

    #[test]
    fn schedule_floor_limits_depth() {
        let mut rng = Rng::new(34);
        let (t, d) = (20usize, 3usize);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0f32; t];
        let mut plan = MergeSpec::layered_for(t, 100, 6, 4, 1).compile(t, d).unwrap();
        let res = plan.run(&tokens, &sizes);
        assert_eq!(*res.token_counts.last().unwrap(), 4);
        assert_eq!(res.sizes.len(), 4);
        assert_eq!(res.tokens.len(), 4 * d);
        // every original position maps to a live final slot
        assert!(res.slot_map.iter().all(|&s| s < 4));
        let total: f64 = res.sizes.iter().map(|&s| s as f64).sum();
        assert!((total - t as f64).abs() < 1e-3);
    }
}
