//! [`MergePipeline`]: run a whole per-layer merge schedule in one call.
//!
//! The coordinator's planner and the bench suites reason about *schedules*
//! — "merge r tokens per layer for L layers, floor q" — not single merge
//! steps.  Running a schedule through the single-shot API allocates fresh
//! intermediates per layer and leaves the caller to compose slot maps by
//! hand.  The pipeline instead:
//!
//! * reuses one [`MergeScratch`] and two ping-pong [`MergeResult`] buffers
//!   across all layers (zero steady-state allocations until the final
//!   result copy-out), and
//! * composes the per-layer slot maps into a single
//!   `original position -> final slot` gather, so unmerging the final
//!   tokens back to input positions is **one** gather instead of L.
//!
//! [`BatchPipeline`] lifts this to a `(b, t, d)` slab on the shared
//! [`WorkerPool`]: one persistent [`MergePipeline`] per slot, contiguous
//! sequence chunks as pool tasks — the serving prep stage uses it to
//! premerge over-length contexts while the previous batch executes on the
//! device.

use super::analytic::merge_schedule;
use super::kernel;
use super::scratch::MergeScratch;
use super::{unmerge, MergeResult};
use crate::runtime::pool::WorkerPool;

/// Output of a pipeline run.
#[derive(Clone, Debug, Default)]
pub struct PipelineResult {
    /// final merged tokens, `token_counts.last() * d`
    pub tokens: Vec<f32>,
    /// final token sizes
    pub sizes: Vec<f32>,
    /// composed map: original position (length t) -> final output slot
    pub slot_map: Vec<usize>,
    /// token count before layer 0 and after each layer (length layers + 1)
    pub token_counts: Vec<usize>,
}

impl PipelineResult {
    /// One-shot unmerge through the composed slot map: returns `(t, d)`
    /// rows, each original position receiving its merged representative.
    pub fn unmerge(&self, d: usize) -> Vec<f32> {
        unmerge(&self.tokens, d, &self.slot_map)
    }
}

/// Reusable multi-layer merge executor.  Construct once per worker, call
/// [`MergePipeline::run`] (fixed r + floor, the `merge_schedule` rule) or
/// [`MergePipeline::run_schedule`] (explicit per-layer r) per sequence.
#[derive(Default)]
pub struct MergePipeline {
    scratch: MergeScratch,
    cur: MergeResult,
    next: MergeResult,
    composed: Vec<usize>,
}

impl MergePipeline {
    pub fn new() -> MergePipeline {
        MergePipeline::default()
    }

    /// Run the static schedule `merge_schedule(t, r, num_layers, q)` —
    /// merge up to `r` tokens per layer, never dropping below `q` tokens.
    pub fn run(
        &mut self,
        tokens: &[f32],
        sizes: &[f32],
        t: usize,
        d: usize,
        k: usize,
        r: usize,
        num_layers: usize,
        q: usize,
    ) -> PipelineResult {
        let counts = merge_schedule(t, r, num_layers, q);
        let rs: Vec<usize> = counts.windows(2).map(|w| w[0] - w[1]).collect();
        self.run_schedule(tokens, sizes, t, d, k, &rs)
    }

    /// Run an explicit per-layer schedule: `rs[l]` tokens are merged at
    /// layer `l` (clamped per layer to the feasible maximum, like the
    /// single-shot API).
    pub fn run_schedule(
        &mut self,
        tokens: &[f32],
        sizes: &[f32],
        t: usize,
        d: usize,
        k: usize,
        rs: &[usize],
    ) -> PipelineResult {
        assert_eq!(tokens.len(), t * d);
        assert_eq!(sizes.len(), t);
        let MergePipeline { scratch, cur, next, composed } = self;

        cur.tokens.clear();
        cur.tokens.extend_from_slice(tokens);
        cur.sizes.clear();
        cur.sizes.extend_from_slice(sizes);

        composed.clear();
        composed.extend(0..t);
        let mut token_counts = Vec::with_capacity(rs.len() + 1);
        let mut cur_t = t;
        token_counts.push(cur_t);

        for &r_l in rs {
            kernel::merge_fixed_r_scratch(
                &cur.tokens,
                &cur.sizes,
                cur_t,
                d,
                r_l,
                k,
                scratch,
                next,
            );
            // Compose: original -> (slot in cur) -> (slot in next).
            for slot in composed.iter_mut() {
                *slot = next.slot_map[*slot];
            }
            cur_t = next.sizes.len();
            token_counts.push(cur_t);
            std::mem::swap(cur, next);
        }

        PipelineResult {
            tokens: cur.tokens.clone(),
            sizes: cur.sizes.clone(),
            slot_map: composed.clone(),
            token_counts,
        }
    }
}

/// Batched multi-layer merge executor on the shared [`WorkerPool`]: one
/// [`MergePipeline`] per slot, so scratch stays warm across calls and the
/// chunks parallelize without allocation or thread spawns.
pub struct BatchPipeline {
    slots: Vec<MergePipeline>,
}

impl BatchPipeline {
    /// A batch pipeline with `slots` concurrent chunk slots (clamped to at
    /// least 1).
    pub fn new(slots: usize) -> BatchPipeline {
        BatchPipeline { slots: (0..slots.max(1)).map(|_| MergePipeline::new()).collect() }
    }

    /// Sized to the machine (`available_parallelism`).
    pub fn with_default_parallelism() -> BatchPipeline {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        BatchPipeline::new(n)
    }

    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Run the explicit per-layer schedule `rs` over every sequence of a
    /// `(b, t, d)` slab (row-major, sequence-contiguous; per-sequence
    /// sizes `(b, t)`), writing one [`PipelineResult`] per sequence into
    /// `outs` (resized to `b`).  Single-slot (or single-sequence) runs
    /// stay inline on the caller.
    #[allow(clippy::too_many_arguments)]
    pub fn run_schedule_into(
        &mut self,
        pool: &WorkerPool,
        tokens: &[f32],
        sizes: &[f32],
        b: usize,
        t: usize,
        d: usize,
        k: usize,
        rs: &[usize],
        outs: &mut Vec<PipelineResult>,
    ) {
        assert_eq!(tokens.len(), b * t * d, "token slab shape mismatch");
        assert_eq!(sizes.len(), b * t, "sizes slab shape mismatch");
        outs.resize_with(b, PipelineResult::default);
        if b == 0 {
            return;
        }
        super::batch::run_chunked(pool, &mut self.slots, tokens, sizes, b, t, d, outs, |pipe, tok, sz, out| {
            *out = pipe.run_schedule(tok, sz, t, d, k, rs);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::{merge_fixed_r, merge_schedule, unmerge};
    use crate::util::Rng;

    fn rand_tokens(rng: &mut Rng, t: usize, d: usize) -> Vec<f32> {
        (0..t * d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn pipeline_matches_sequential_single_shots() {
        let mut rng = Rng::new(31);
        let (t, d, k, r, layers, q) = (48usize, 6usize, 3usize, 8usize, 4usize, 4usize);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes: Vec<f32> = (0..t).map(|_| 1.0 + rng.below(2) as f32).collect();

        let mut pipe = MergePipeline::new();
        let res = pipe.run(&tokens, &sizes, t, d, k, r, layers, q);

        // sequential reference composition
        let counts = merge_schedule(t, r, layers, q);
        let mut cur_tokens = tokens.clone();
        let mut cur_sizes = sizes.clone();
        let mut cur_t = t;
        let mut composed: Vec<usize> = (0..t).collect();
        for w in counts.windows(2) {
            let step = w[0] - w[1];
            let m = merge_fixed_r(&cur_tokens, &cur_sizes, cur_t, d, step, k);
            for slot in composed.iter_mut() {
                *slot = m.slot_map[*slot];
            }
            cur_tokens = m.tokens;
            cur_sizes = m.sizes;
            cur_t = w[1];
        }
        assert_eq!(res.token_counts, counts);
        assert_eq!(res.slot_map, composed);
        assert_eq!(res.tokens, cur_tokens);
        assert_eq!(res.sizes, cur_sizes);
    }

    #[test]
    fn composed_unmerge_equals_layerwise_unmerge() {
        let mut rng = Rng::new(32);
        let (t, d, k) = (40usize, 4usize, 2usize);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0f32; t];
        let rs = [6usize, 6, 4];

        // layerwise: keep each layer's slot_map, then gather back up
        let mut cur_tokens = tokens.clone();
        let mut cur_sizes = sizes.clone();
        let mut cur_t = t;
        let mut maps = Vec::new();
        for &r_l in &rs {
            let m = merge_fixed_r(&cur_tokens, &cur_sizes, cur_t, d, r_l, k);
            cur_t -= r_l;
            maps.push(m.slot_map.clone());
            cur_tokens = m.tokens;
            cur_sizes = m.sizes;
        }
        let mut up = cur_tokens.clone();
        for map in maps.iter().rev() {
            up = unmerge(&up, d, map);
        }

        let mut pipe = MergePipeline::new();
        let res = pipe.run_schedule(&tokens, &sizes, t, d, k, &rs);
        assert_eq!(res.unmerge(d), up);
    }

    #[test]
    fn pipeline_reuse_across_inputs() {
        let mut rng = Rng::new(33);
        let mut pipe = MergePipeline::new();
        for &(t, d) in &[(30usize, 4usize), (17, 3), (64, 8)] {
            let tokens = rand_tokens(&mut rng, t, d);
            let sizes = vec![1.0f32; t];
            let res = pipe.run(&tokens, &sizes, t, d, 2, 5, 3, 4);
            let mut fresh = MergePipeline::new();
            let res2 = fresh.run(&tokens, &sizes, t, d, 2, 5, 3, 4);
            assert_eq!(res.tokens, res2.tokens, "t={t} d={d}");
            assert_eq!(res.slot_map, res2.slot_map);
            assert_eq!(res.token_counts, res2.token_counts);
        }
    }

    #[test]
    fn batch_pipeline_matches_per_sequence_runs() {
        let mut rng = Rng::new(35);
        let pool = WorkerPool::new(3);
        let (b, t, d, k) = (6usize, 36usize, 4usize, 3usize);
        let rs = [8usize, 6, 4];
        let tokens = rand_tokens(&mut rng, b * t, d);
        let sizes: Vec<f32> = (0..b * t).map(|_| 1.0 + rng.below(2) as f32).collect();
        for slots in [1usize, 2, 5] {
            let mut bp = BatchPipeline::new(slots);
            let mut outs = Vec::new();
            bp.run_schedule_into(&pool, &tokens, &sizes, b, t, d, k, &rs, &mut outs);
            assert_eq!(outs.len(), b);
            let mut single = MergePipeline::new();
            for i in 0..b {
                let want = single.run_schedule(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    k,
                    &rs,
                );
                assert_eq!(outs[i].tokens, want.tokens, "slots={slots} seq={i}");
                assert_eq!(outs[i].slot_map, want.slot_map);
                assert_eq!(outs[i].token_counts, want.token_counts);
            }
        }
    }

    #[test]
    fn schedule_floor_limits_depth() {
        let mut rng = Rng::new(34);
        let (t, d) = (20usize, 3usize);
        let tokens = rand_tokens(&mut rng, t, d);
        let sizes = vec![1.0f32; t];
        let mut pipe = MergePipeline::new();
        let res = pipe.run(&tokens, &sizes, t, d, 1, 100, 6, 4);
        assert_eq!(*res.token_counts.last().unwrap(), 4);
        assert_eq!(res.sizes.len(), 4);
        assert_eq!(res.tokens.len(), 4 * d);
        // every original position maps to a live final slot
        assert!(res.slot_map.iter().all(|&s| s < 4));
        let total: f64 = res.sizes.iter().map(|&s| s as f64).sum();
        assert!((total - t as f64).abs() < 1e-3);
    }
}
