//! The legacy scalar merge implementation, kept verbatim as the
//! differential-test oracle and the bench baseline.
//!
//! This is the allocation-heavy single-sequence code the optimized
//! [`super::kernel`] replaced on the hot path: cosine recomputes both norms
//! per banded pair, top-r selection is a full stable sort, and every call
//! allocates its intermediates.  Do not "optimize" this module — its value
//! is being the simplest possible statement of the paper's §3 semantics.
//!
//! One hardening change relative to the original: top-r selection orders
//! by `f64::total_cmp` instead of `partial_cmp().unwrap()`.  The unwrap
//! was a latent hazard, not a live bug — NaN can never actually enter
//! `scores`, because the matching update `if s > scores[i]` is false for
//! NaN, so every score stays `-inf` or finite.  `total_cmp` removes the
//! panic path outright so no future refactor of the matching loop can
//! re-arm it (see `nan_tokens_do_not_panic` in `mod.rs`).
//!
//! One accumulation-order change (PR 7): the norm sum-of-squares in
//! [`cosine`] accumulates in the same 4-lane chunked order as the kernel's
//! `simd::sumsq_f64` instead of serially, mirroring the kernel's reorder
//! so the shared-norm bitwise relationship between oracle and kernel is
//! preserved (the dot stays serial — the kernel's 4-lane dot was never
//! bitwise-shared with the oracle except at d < 4, where chunked and
//! serial coincide).  See the norm-accumulation note in `kernel.rs`.

use super::MergeResult;

/// Sum of squares in the kernel's 4-lane chunked accumulation order —
/// a verbatim mirror of `simd::sumsq_f64_scalar`; change both together
/// or the d < 4 bitwise pins and the shared-norm contract break.
fn sumsq(a: &[f32]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for c in 0..chunks {
        let i = c * 4;
        let (x0, x1) = (a[i] as f64, a[i + 1] as f64);
        let (x2, x3) = (a[i + 2] as f64, a[i + 3] as f64);
        s0 += x0 * x0;
        s1 += x1 * x1;
        s2 += x2 * x2;
        s3 += x3 * x3;
    }
    let mut tail = 0.0f64;
    for i in chunks * 4..n {
        let x = a[i] as f64;
        tail += x * x;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Cosine similarity between two d-vectors.
fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let mut dot = 0.0f64;
    for i in 0..a.len() {
        dot += a[i] as f64 * b[i] as f64;
    }
    dot / (sumsq(a).sqrt() * sumsq(b).sqrt() + 1e-8)
}

/// Reference bipartite soft matching (paper eq. 1): per A-token, the best
/// B-match within the band `|i - j| < k`.
pub fn match_tokens_reference(
    tokens: &[f32],
    t: usize,
    d: usize,
    k: usize,
) -> (Vec<f64>, Vec<usize>) {
    let te = t - (t % 2);
    let t2 = te / 2;
    let k = k.clamp(1, t2.max(1));
    let mut scores = vec![f64::NEG_INFINITY; t2];
    let mut best = vec![0usize; t2];
    for i in 0..t2 {
        let a = &tokens[(2 * i) * d..(2 * i + 1) * d];
        let lo = i.saturating_sub(k - 1);
        let hi = (i + k - 1).min(t2 - 1);
        for j in lo..=hi {
            let b = &tokens[(2 * j + 1) * d..(2 * j + 2) * d];
            let s = cosine(a, b);
            if s > scores[i] {
                scores[i] = s;
                best[i] = j;
            }
        }
    }
    (scores, best)
}

/// Reference fixed-r merge: stable descending sort for top-r, fresh
/// allocations throughout.
pub fn merge_fixed_r_reference(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    r: usize,
    k: usize,
) -> MergeResult {
    assert_eq!(tokens.len(), t * d);
    assert_eq!(sizes.len(), t);
    let te = t - (t % 2);
    let t2 = te / 2;
    let r = r.min(t2);
    if r == 0 {
        return MergeResult {
            tokens: tokens.to_vec(),
            sizes: sizes.to_vec(),
            slot_map: (0..t).collect(),
        };
    }
    let (scores, best) = match_tokens_reference(tokens, t, d, k);
    // top-r A tokens by score (total order: NaN-safe, unlike the original
    // partial_cmp().unwrap())
    let mut order: Vec<usize> = (0..t2).collect();
    order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
    let mut merged = vec![false; t2];
    for &i in order.iter().take(r) {
        merged[i] = true;
    }
    // output slots for kept tokens, in temporal order
    let mut slot_map = vec![0usize; t];
    let mut slot = 0usize;
    let mut kept_slot = vec![usize::MAX; t];
    for p in 0..t {
        let is_merged_a = p % 2 == 0 && p < te && merged[p / 2];
        if !is_merged_a {
            kept_slot[p] = slot;
            slot_map[p] = slot;
            slot += 1;
        }
    }
    debug_assert_eq!(slot, t - r);
    for i in 0..t2 {
        if merged[i] {
            let partner = 2 * best[i] + 1;
            slot_map[2 * i] = kept_slot[partner];
        }
    }
    // size-weighted scatter-average
    let out_t = t - r;
    let mut num = vec![0.0f64; out_t * d];
    let mut den = vec![0.0f64; out_t];
    for p in 0..t {
        let s = slot_map[p];
        let w = sizes[p] as f64;
        den[s] += w;
        for j in 0..d {
            num[s * d + j] += tokens[p * d + j] as f64 * w;
        }
    }
    let mut out = vec![0.0f32; out_t * d];
    for s in 0..out_t {
        for j in 0..d {
            out[s * d + j] = (num[s * d + j] / den[s]) as f32;
        }
    }
    MergeResult {
        tokens: out,
        sizes: den.iter().map(|&x| x as f32).collect(),
        slot_map,
    }
}

/// Reference dynamic merging (§5.5).
pub fn merge_dynamic_reference(
    tokens: &[f32],
    sizes: &[f32],
    t: usize,
    d: usize,
    k: usize,
    threshold: f64,
) -> (MergeResult, usize) {
    let te = t - (t % 2);
    let t2 = te / 2;
    let (scores, _) = match_tokens_reference(tokens, t, d, k);
    let r = scores.iter().filter(|&&s| s > threshold).count().min(t2);
    let res = merge_fixed_r_reference(tokens, sizes, t, d, r, k);
    let eff = t - r;
    (res, eff)
}
