//! Batched merging over a `(b, t, d)` slab, on the shared [`WorkerPool`].
//!
//! [`BatchMerger`] owns one [`MergeScratch`] per worker *slot* and splits
//! the batch into contiguous chunks, one pool task per slot.  The pool's
//! persistent threads execute (and steal) the chunks; because every chunk
//! carries its own scratch, it does not matter which thread runs which
//! chunk.  Warm, a merge of the whole slab performs **no heap allocations
//! and no thread spawns**: the allocation-free property comes from the
//! scratches, the spawn-free property from the pool (its
//! `spawned_threads` counter pins this down in `tests/runtime_pool.rs`).
//!
//! PR 1's implementation fanned out a fresh `std::thread::scope` per call;
//! that path survives verbatim as [`BatchMerger::merge_batch_into_scoped`]
//! so `benches/merging.rs` can keep printing the pool-vs-scope comparison
//! (the pool must never lose to it), but no production caller uses it.
//!
//! Accumulation precision: [`BatchMerger::with_accum`] selects the
//! [`Accum::F32`] banded-dot variant for throughput-bound callers; the
//! default ([`BatchMerger::new`]) stays bitwise identical to the
//! reference.  See [`Accum`] for the accuracy contract.

use super::kernel::{self, Accum};
use super::scratch::MergeScratch;
use super::MergeResult;
use crate::runtime::pool::WorkerPool;

/// Shared chunked fan-out for batched-by-sequence merge work: splits a
/// `(b, t, d)` slab into one contiguous chunk per slot and runs
/// `f(slot_state, seq_tokens, seq_sizes, out)` per sequence — inline when
/// there is a single slot (or sequence), as pool tasks otherwise.  Both
/// [`BatchMerger::merge_batch_into`] and
/// [`crate::merging::BatchPipeline::run_schedule_into`] are this helper
/// plus a per-sequence kernel call.
pub(crate) fn run_chunked<S: Send, T: Send, F>(
    pool: &WorkerPool,
    slots: &mut [S],
    tokens: &[f32],
    sizes: &[f32],
    b: usize,
    t: usize,
    d: usize,
    outs: &mut [T],
    f: F,
) where
    F: Fn(&mut S, &[f32], &[f32], &mut T) + Send + Sync,
{
    debug_assert_eq!(outs.len(), b);
    let n_slots = slots.len();
    if n_slots == 1 || b == 1 {
        let slot = &mut slots[0];
        for (i, out) in outs.iter_mut().enumerate() {
            f(slot, &tokens[i * t * d..(i + 1) * t * d], &sizes[i * t..(i + 1) * t], out);
        }
        return;
    }
    // Contiguous chunk per slot; the last chunk may be short.
    let chunk = (b + n_slots - 1) / n_slots;
    let f = &f;
    let tasks: Vec<_> = outs
        .chunks_mut(chunk)
        .zip(tokens.chunks(chunk * t * d).zip(sizes.chunks(chunk * t)))
        .zip(slots.iter_mut())
        .map(|((out_chunk, (tok_chunk, size_chunk)), slot)| {
            move || {
                for (i, out) in out_chunk.iter_mut().enumerate() {
                    f(slot, &tok_chunk[i * t * d..(i + 1) * t * d], &size_chunk[i * t..(i + 1) * t], out);
                }
            }
        })
        .collect();
    pool.run(tasks);
}

/// Reusable batched merge executor: `slots` scratch arenas, one per
/// concurrent chunk.  Construct once, call
/// [`BatchMerger::merge_batch_into`] per slab.
pub struct BatchMerger {
    scratches: Vec<MergeScratch>,
    accum: Accum,
}

impl BatchMerger {
    /// A merger with a fixed slot count (clamped to at least 1), f64
    /// accumulation.
    pub fn new(slots: usize) -> BatchMerger {
        BatchMerger::with_accum(slots, Accum::F64)
    }

    /// A merger with an explicit accumulation precision for the banded dot.
    pub fn with_accum(slots: usize, accum: Accum) -> BatchMerger {
        let slots = slots.max(1);
        BatchMerger {
            scratches: (0..slots).map(|_| MergeScratch::new()).collect(),
            accum,
        }
    }

    /// A merger sized to the machine (`available_parallelism`).
    pub fn with_default_parallelism() -> BatchMerger {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        BatchMerger::new(n)
    }

    /// Number of scratch slots (the maximum chunk parallelism).
    pub fn workers(&self) -> usize {
        self.scratches.len()
    }

    pub fn accum(&self) -> Accum {
        self.accum
    }

    /// Merge a `(b, t, d)` slab of tokens (row-major, sequence-contiguous)
    /// with per-sequence sizes `(b, t)`, writing one [`MergeResult`] per
    /// sequence into `outs` (resized to `b`).  Chunks run as tasks on
    /// `pool`; a single-slot merger (or a single-sequence batch) runs
    /// inline on the caller.
    #[allow(clippy::too_many_arguments)]
    pub fn merge_batch_into(
        &mut self,
        pool: &WorkerPool,
        tokens: &[f32],
        sizes: &[f32],
        b: usize,
        t: usize,
        d: usize,
        r: usize,
        k: usize,
        outs: &mut Vec<MergeResult>,
    ) {
        assert_eq!(tokens.len(), b * t * d, "token slab shape mismatch");
        assert_eq!(sizes.len(), b * t, "sizes slab shape mismatch");
        outs.resize_with(b, MergeResult::default);
        if b == 0 {
            return;
        }
        let accum = self.accum;
        run_chunked(
            pool,
            &mut self.scratches,
            tokens,
            sizes,
            b,
            t,
            d,
            outs,
            |scratch, tok, sz, out| {
                kernel::merge_fixed_r_scratch_accum(tok, sz, t, d, r, k, scratch, out, accum);
            },
        );
    }

    /// The PR 1 `std::thread::scope` fan-out, kept verbatim as the bench
    /// baseline (`benches/merging.rs` compares it against the pool path).
    /// Spawns `workers()` fresh threads **per call** — do not use on hot
    /// paths.
    #[allow(clippy::too_many_arguments)]
    pub fn merge_batch_into_scoped(
        &mut self,
        tokens: &[f32],
        sizes: &[f32],
        b: usize,
        t: usize,
        d: usize,
        r: usize,
        k: usize,
        outs: &mut Vec<MergeResult>,
    ) {
        assert_eq!(tokens.len(), b * t * d, "token slab shape mismatch");
        assert_eq!(sizes.len(), b * t, "sizes slab shape mismatch");
        outs.resize_with(b, MergeResult::default);
        if b == 0 {
            return;
        }
        let slots = self.scratches.len();
        let accum = self.accum;
        let chunk = (b + slots - 1) / slots;
        if slots == 1 || b == 1 {
            let scratch = &mut self.scratches[0];
            for (i, out) in outs.iter_mut().enumerate() {
                kernel::merge_fixed_r_scratch_accum(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                    scratch,
                    out,
                    accum,
                );
            }
            return;
        }
        std::thread::scope(|scope| {
            let mut scratch_iter = self.scratches.iter_mut();
            for (out_chunk, (tok_chunk, size_chunk)) in outs
                .chunks_mut(chunk)
                .zip(tokens.chunks(chunk * t * d).zip(sizes.chunks(chunk * t)))
            {
                let scratch = scratch_iter.next().expect("one scratch per chunk");
                scope.spawn(move || {
                    for (i, out) in out_chunk.iter_mut().enumerate() {
                        kernel::merge_fixed_r_scratch_accum(
                            &tok_chunk[i * t * d..(i + 1) * t * d],
                            &size_chunk[i * t..(i + 1) * t],
                            t,
                            d,
                            r,
                            k,
                            scratch,
                            out,
                            accum,
                        );
                    }
                });
            }
        });
    }
}

/// One-shot batched merge on the process-wide pool: allocates a
/// [`BatchMerger`] sized to the machine and returns per-sequence results.
/// Hot paths should hold a `BatchMerger` and call
/// [`BatchMerger::merge_batch_into`] instead.
pub fn merge_batch(
    tokens: &[f32],
    sizes: &[f32],
    b: usize,
    t: usize,
    d: usize,
    r: usize,
    k: usize,
) -> Vec<MergeResult> {
    let mut merger = BatchMerger::with_default_parallelism();
    let mut outs = Vec::new();
    merger.merge_batch_into(WorkerPool::global(), tokens, sizes, b, t, d, r, k, &mut outs);
    outs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::merge_fixed_r;
    use crate::util::Rng;

    #[test]
    fn batch_matches_single_sequence_path() {
        let mut rng = Rng::new(21);
        let pool = WorkerPool::new(3);
        let (b, t, d, r, k) = (7usize, 30usize, 5usize, 8usize, 3usize);
        let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
        let sizes: Vec<f32> = (0..b * t).map(|_| 1.0 + rng.below(3) as f32).collect();
        for slots in [1usize, 2, 4, 16] {
            let mut merger = BatchMerger::new(slots);
            let mut outs = Vec::new();
            merger.merge_batch_into(&pool, &tokens, &sizes, b, t, d, r, k, &mut outs);
            assert_eq!(outs.len(), b);
            for i in 0..b {
                let single = merge_fixed_r(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                );
                assert_eq!(outs[i].slot_map, single.slot_map, "slots={slots} seq={i}");
                assert_eq!(outs[i].tokens, single.tokens);
                assert_eq!(outs[i].sizes, single.sizes);
            }
        }
    }

    #[test]
    fn pool_path_equals_scoped_baseline() {
        let mut rng = Rng::new(23);
        let pool = WorkerPool::new(4);
        let (b, t, d, r, k) = (9usize, 26usize, 4usize, 6usize, 5usize);
        let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
        let sizes = vec![1.0f32; b * t];
        let mut merger = BatchMerger::new(4);
        let (mut on_pool, mut scoped) = (Vec::new(), Vec::new());
        merger.merge_batch_into(&pool, &tokens, &sizes, b, t, d, r, k, &mut on_pool);
        merger.merge_batch_into_scoped(&tokens, &sizes, b, t, d, r, k, &mut scoped);
        for i in 0..b {
            assert_eq!(on_pool[i].slot_map, scoped[i].slot_map, "seq {i}");
            assert_eq!(on_pool[i].tokens, scoped[i].tokens);
            assert_eq!(on_pool[i].sizes, scoped[i].sizes);
        }
    }

    #[test]
    fn f32_accum_batch_holds_invariants() {
        let mut rng = Rng::new(24);
        let pool = WorkerPool::new(2);
        let (b, t, d, r, k) = (5usize, 24usize, 8usize, 6usize, 4usize);
        let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
        let sizes = vec![1.0f32; b * t];
        let mut merger = BatchMerger::with_accum(3, Accum::F32);
        assert_eq!(merger.accum(), Accum::F32);
        let mut outs = Vec::new();
        merger.merge_batch_into(&pool, &tokens, &sizes, b, t, d, r, k, &mut outs);
        for out in &outs {
            assert_eq!(out.tokens.len(), (t - r) * d);
            let total: f32 = out.sizes.iter().sum();
            assert!((total - t as f32).abs() < 1e-3);
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let pool = WorkerPool::new(2);
        let mut merger = BatchMerger::new(4);
        let mut outs = vec![MergeResult::default(); 3];
        merger.merge_batch_into(&pool, &[], &[], 0, 8, 4, 2, 1, &mut outs);
        assert!(outs.is_empty());
    }

    #[test]
    fn convenience_entry_point() {
        let mut rng = Rng::new(22);
        let (b, t, d) = (3usize, 12usize, 4usize);
        let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
        let sizes = vec![1.0f32; b * t];
        let outs = merge_batch(&tokens, &sizes, b, t, d, 3, 2);
        assert_eq!(outs.len(), b);
        for out in &outs {
            assert_eq!(out.tokens.len(), (t - 3) * d);
        }
    }
}
