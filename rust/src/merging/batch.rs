//! Batched merging over a `(b, t, d)` slab.
//!
//! [`BatchMerger`] owns one [`MergeScratch`] per worker and fans the batch
//! out across `std::thread::scope` threads; each worker runs the
//! zero-allocation kernel over a contiguous chunk of sequences.  Warm, a
//! merge of the whole slab performs no heap allocations beyond what the
//! caller-provided `MergeResult` out-slots already hold.

use super::kernel;
use super::scratch::MergeScratch;
use super::MergeResult;

/// Reusable batched merge executor: `workers` scratch arenas, one per
/// thread.  Construct once, call [`BatchMerger::merge_batch_into`] per
/// slab.
pub struct BatchMerger {
    workers: usize,
    scratches: Vec<MergeScratch>,
}

impl BatchMerger {
    /// A merger with a fixed worker count (clamped to at least 1).
    pub fn new(workers: usize) -> BatchMerger {
        let workers = workers.max(1);
        BatchMerger { workers, scratches: (0..workers).map(|_| MergeScratch::new()).collect() }
    }

    /// A merger sized to the machine (`available_parallelism`).
    pub fn with_default_parallelism() -> BatchMerger {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        BatchMerger::new(n)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Merge a `(b, t, d)` slab of tokens (row-major, sequence-contiguous)
    /// with per-sequence sizes `(b, t)`, writing one [`MergeResult`] per
    /// sequence into `outs` (resized to `b`).
    pub fn merge_batch_into(
        &mut self,
        tokens: &[f32],
        sizes: &[f32],
        b: usize,
        t: usize,
        d: usize,
        r: usize,
        k: usize,
        outs: &mut Vec<MergeResult>,
    ) {
        assert_eq!(tokens.len(), b * t * d, "token slab shape mismatch");
        assert_eq!(sizes.len(), b * t, "sizes slab shape mismatch");
        outs.resize_with(b, MergeResult::default);
        if b == 0 {
            return;
        }
        // Contiguous chunk per worker; the last chunk may be short.
        let chunk = (b + self.workers - 1) / self.workers;
        if self.workers == 1 || b == 1 {
            let scratch = &mut self.scratches[0];
            for (i, out) in outs.iter_mut().enumerate() {
                kernel::merge_fixed_r_scratch(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                    scratch,
                    out,
                );
            }
            return;
        }
        std::thread::scope(|scope| {
            let mut scratch_iter = self.scratches.iter_mut();
            for (out_chunk, (tok_chunk, size_chunk)) in outs
                .chunks_mut(chunk)
                .zip(tokens.chunks(chunk * t * d).zip(sizes.chunks(chunk * t)))
            {
                let scratch = scratch_iter.next().expect("one scratch per chunk");
                scope.spawn(move || {
                    for (i, out) in out_chunk.iter_mut().enumerate() {
                        kernel::merge_fixed_r_scratch(
                            &tok_chunk[i * t * d..(i + 1) * t * d],
                            &size_chunk[i * t..(i + 1) * t],
                            t,
                            d,
                            r,
                            k,
                            scratch,
                            out,
                        );
                    }
                });
            }
        });
    }
}

/// One-shot batched merge: allocates a [`BatchMerger`] sized to the
/// machine and returns per-sequence results.  Hot paths should hold a
/// `BatchMerger` and call [`BatchMerger::merge_batch_into`] instead.
pub fn merge_batch(
    tokens: &[f32],
    sizes: &[f32],
    b: usize,
    t: usize,
    d: usize,
    r: usize,
    k: usize,
) -> Vec<MergeResult> {
    let mut merger = BatchMerger::with_default_parallelism();
    let mut outs = Vec::new();
    merger.merge_batch_into(tokens, sizes, b, t, d, r, k, &mut outs);
    outs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::merge_fixed_r;
    use crate::util::Rng;

    #[test]
    fn batch_matches_single_sequence_path() {
        let mut rng = Rng::new(21);
        let (b, t, d, r, k) = (7usize, 30usize, 5usize, 8usize, 3usize);
        let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
        let sizes: Vec<f32> = (0..b * t).map(|_| 1.0 + rng.below(3) as f32).collect();
        for workers in [1usize, 2, 4, 16] {
            let mut merger = BatchMerger::new(workers);
            let mut outs = Vec::new();
            merger.merge_batch_into(&tokens, &sizes, b, t, d, r, k, &mut outs);
            assert_eq!(outs.len(), b);
            for i in 0..b {
                let single = merge_fixed_r(
                    &tokens[i * t * d..(i + 1) * t * d],
                    &sizes[i * t..(i + 1) * t],
                    t,
                    d,
                    r,
                    k,
                );
                assert_eq!(outs[i].slot_map, single.slot_map, "workers={workers} seq={i}");
                assert_eq!(outs[i].tokens, single.tokens);
                assert_eq!(outs[i].sizes, single.sizes);
            }
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let mut merger = BatchMerger::new(4);
        let mut outs = vec![MergeResult::default(); 3];
        merger.merge_batch_into(&[], &[], 0, 8, 4, 2, 1, &mut outs);
        assert!(outs.is_empty());
    }

    #[test]
    fn convenience_entry_point() {
        let mut rng = Rng::new(22);
        let (b, t, d) = (3usize, 12usize, 4usize);
        let tokens: Vec<f32> = (0..b * t * d).map(|_| rng.normal() as f32).collect();
        let sizes = vec![1.0f32; b * t];
        let outs = merge_batch(&tokens, &sizes, b, t, d, 3, 2);
        assert_eq!(outs.len(), b);
        for out in &outs {
            assert_eq!(out.tokens.len(), (t - 3) * d);
        }
    }
}
