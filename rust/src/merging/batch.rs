//! Shared chunked fan-out for batched merge work on the [`WorkerPool`].
//!
//! PR 1–2 exposed batching through `BatchMerger` / `BatchPipeline`, each
//! with its own positional-tuple entry point; both are gone — batched
//! execution is [`crate::merging::MergePlan::run_batch_into`], and this
//! module keeps only the underlying splitter it shares with the
//! `thread::scope` bench baseline.  The guarantees are unchanged: one
//! slot (scratch arena) per contiguous chunk, so it does not matter which
//! pool thread runs which chunk, and a warm batch performs **no heap
//! allocations and no thread spawns** (the pool's `spawned_threads`
//! counter pins this down in `tests/runtime_pool.rs`).

use crate::runtime::pool::WorkerPool;

/// Split a `(b, t, d)` slab into one contiguous chunk per slot and run
/// `f(slot_state, seq_tokens, seq_sizes, out)` per sequence — inline when
/// there is a single slot (or sequence), as pool tasks otherwise.
// too_many_arguments: crate-internal splitter under the kernel-layer
// exception — it threads the raw slab shape between MergePlan and the
// pool, and bundling (b, t, d) into a struct here would just be a second
// MergePlan.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_chunked<S: Send, T: Send, F>(
    pool: &WorkerPool,
    slots: &mut [S],
    tokens: &[f32],
    sizes: &[f32],
    b: usize,
    t: usize,
    d: usize,
    outs: &mut [T],
    f: F,
) where
    F: Fn(&mut S, &[f32], &[f32], &mut T) + Send + Sync,
{
    debug_assert_eq!(outs.len(), b);
    let n_slots = slots.len();
    if n_slots == 1 || b == 1 {
        let slot = &mut slots[0];
        for (i, out) in outs.iter_mut().enumerate() {
            f(slot, &tokens[i * t * d..(i + 1) * t * d], &sizes[i * t..(i + 1) * t], out);
        }
        return;
    }
    // Contiguous chunk per slot; the last chunk may be short.
    let chunk = (b + n_slots - 1) / n_slots;
    let f = &f;
    let tasks: Vec<_> = outs
        .chunks_mut(chunk)
        .zip(tokens.chunks(chunk * t * d).zip(sizes.chunks(chunk * t)))
        .zip(slots.iter_mut())
        .map(|((out_chunk, (tok_chunk, size_chunk)), slot)| {
            move || {
                for (i, out) in out_chunk.iter_mut().enumerate() {
                    let tok = &tok_chunk[i * t * d..(i + 1) * t * d];
                    f(slot, tok, &size_chunk[i * t..(i + 1) * t], out);
                }
            }
        })
        .collect();
    pool.run(tasks);
}
