//! Shared chunked fan-out for batched merge work on the [`WorkerPool`].
//!
//! PR 1–2 exposed batching through `BatchMerger` / `BatchPipeline`, each
//! with its own positional-tuple entry point; both are gone — batched
//! execution is [`crate::merging::MergePlan::run_batch_into`], and this
//! module keeps only the underlying splitter it shares with the
//! `thread::scope` bench baseline.  The guarantees are unchanged: one
//! slot (scratch arena) per contiguous chunk, so it does not matter which
//! pool thread runs which chunk, and a warm batch performs **no heap
//! allocations and no thread spawns** (the pool's `spawned_threads`
//! counter pins this down in `tests/runtime_pool.rs`).
//!
//! The splitter is **balanced**: `min(n_slots, b)` chunks whose sizes
//! differ by at most one row, never an empty chunk.  The previous
//! ceil-div split (`chunk = ⌈b / n_slots⌉` rows per chunk) wasted
//! parallelism on small batches — e.g. b=9 over 8 slots produced five
//! chunks of two sequences each (three slots idle, critical path 2)
//! where the balanced split runs 8 chunks (seven slots busy, critical
//! path 2 only on one) — and for b slightly above a multiple of the
//! slot count left whole slots without work.

use crate::runtime::pool::WorkerPool;

/// Balanced contiguous partition of `b` rows into at most `n_slots`
/// chunks: `min(n_slots, b)` chunk lengths, each `>= 1`, differing by at
/// most one, summing to `b`, larger chunks first.
pub(crate) fn chunk_lens(b: usize, n_slots: usize) -> impl Iterator<Item = usize> {
    let n_chunks = n_slots.min(b);
    let base = if n_chunks == 0 { 0 } else { b / n_chunks };
    let extra = if n_chunks == 0 { 0 } else { b % n_chunks };
    (0..n_chunks).map(move |c| if c < extra { base + 1 } else { base })
}

/// Split a `(b, t, d)` slab into one contiguous chunk per slot and run
/// `f(slot_state, seq_tokens, seq_sizes, out)` per sequence — inline when
/// there is a single slot (or sequence), as pool tasks otherwise.
///
/// SIMD dispatch note: `f` runs on pool threads, but
/// [`crate::merging::simd::active_isa`] is process-global (one cached
/// probe), so every chunk computes under the same ISA as the caller.
// too_many_arguments: crate-internal splitter under the kernel-layer
// exception — it threads the raw slab shape between MergePlan and the
// pool, and bundling (b, t, d) into a struct here would just be a second
// MergePlan.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_chunked<S: Send, T: Send, F>(
    pool: &WorkerPool,
    slots: &mut [S],
    tokens: &[f32],
    sizes: &[f32],
    b: usize,
    t: usize,
    d: usize,
    outs: &mut [T],
    f: F,
) where
    F: Fn(&mut S, &[f32], &[f32], &mut T) + Send + Sync,
{
    debug_assert_eq!(outs.len(), b);
    let n_slots = slots.len();
    if n_slots == 1 || b == 1 {
        let slot = &mut slots[0];
        for (i, out) in outs.iter_mut().enumerate() {
            f(slot, &tokens[i * t * d..(i + 1) * t * d], &sizes[i * t..(i + 1) * t], out);
        }
        return;
    }
    // Balanced contiguous chunks — every chunk non-empty by construction,
    // so no slot is handed zero rows and no pool task is a no-op.
    let f = &f;
    let mut outs_rest = outs;
    let mut tok_rest = tokens;
    let mut size_rest = sizes;
    let mut slots_rest = slots;
    let mut tasks = Vec::with_capacity(n_slots.min(b));
    for rows in chunk_lens(b, n_slots) {
        let (out_chunk, outs_tail) = std::mem::take(&mut outs_rest).split_at_mut(rows);
        outs_rest = outs_tail;
        let (tok_chunk, tok_tail) = tok_rest.split_at(rows * t * d);
        tok_rest = tok_tail;
        let (size_chunk, size_tail) = size_rest.split_at(rows * t);
        size_rest = size_tail;
        let (slot_chunk, slots_tail) = std::mem::take(&mut slots_rest).split_at_mut(1);
        slots_rest = slots_tail;
        let slot = &mut slot_chunk[0];
        tasks.push(move || {
            for (i, out) in out_chunk.iter_mut().enumerate() {
                let tok = &tok_chunk[i * t * d..(i + 1) * t * d];
                f(slot, tok, &size_chunk[i * t..(i + 1) * t], out);
            }
        });
    }
    pool.run(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The splitter invariants behind the "no slot receives zero rows"
    /// guarantee: partition sums to b, no empty chunks, sizes differ by
    /// at most one.
    #[test]
    fn chunk_lens_is_balanced_and_never_empty() {
        for n_slots in 1..=12usize {
            for b in 0..=40usize {
                let lens: Vec<usize> = chunk_lens(b, n_slots).collect();
                assert_eq!(lens.iter().sum::<usize>(), b, "b={b} slots={n_slots}");
                assert_eq!(lens.len(), n_slots.min(b), "b={b} slots={n_slots}");
                assert!(lens.iter().all(|&l| l >= 1) || b == 0, "empty chunk: b={b} slots={n_slots}");
                if let (Some(max), Some(min)) = (lens.iter().max(), lens.iter().min()) {
                    assert!(max - min <= 1, "imbalance: b={b} slots={n_slots} {lens:?}");
                }
            }
        }
    }

    /// End-to-end over the pool: every sequence is processed exactly once,
    /// chunks stay contiguous, and — the PR 7 small-fix pin — no slot that
    /// receives work receives zero rows (observed via per-slot counters).
    #[test]
    fn run_chunked_processes_every_row_once_with_no_empty_slots() {
        let pool = WorkerPool::new(4);
        let (t, d) = (6usize, 3usize);
        for n_slots in [1usize, 2, 3, 4, 8] {
            for b in [1usize, 2, 3, 5, 8, 9, 16, 17] {
                // slot state = rows seen by this slot
                let mut slots: Vec<usize> = vec![0; n_slots];
                let tokens: Vec<f32> = (0..b * t * d).map(|i| i as f32).collect();
                let sizes: Vec<f32> = vec![1.0; b * t];
                let mut outs: Vec<f32> = vec![-1.0; b];
                run_chunked(
                    &pool,
                    &mut slots,
                    &tokens,
                    &sizes,
                    b,
                    t,
                    d,
                    &mut outs,
                    |seen, tok, sz, out| {
                        *seen += 1;
                        assert_eq!(tok.len(), t * d);
                        assert_eq!(sz.len(), t);
                        // first element identifies the sequence index
                        *out = tok[0] / (t * d) as f32;
                    },
                );
                // every sequence processed exactly once, in order
                for (i, &o) in outs.iter().enumerate() {
                    assert_eq!(o as usize, i, "b={b} slots={n_slots}");
                }
                let used: Vec<usize> = slots.iter().copied().filter(|&c| c > 0).collect();
                assert_eq!(used.iter().sum::<usize>(), b, "b={b} slots={n_slots}");
                if n_slots > 1 && b > 1 {
                    // balanced fan-out: min(slots, b) slots busy, each with
                    // at least one row — the old ceil-div split failed this
                    // at e.g. b=9, slots=8 (five chunks of two).
                    assert_eq!(used.len(), n_slots.min(b), "b={b} slots={n_slots}");
                    let (mx, mn) = (used.iter().max().unwrap(), used.iter().min().unwrap());
                    assert!(mx - mn <= 1, "b={b} slots={n_slots} {slots:?}");
                }
            }
        }
    }
}
