//! [`IncrementalMerge`]: O(n·d) incremental *causal* merging for
//! streaming decode (the serving-side realisation of the paper's claim
//! that local merging, being causal, is usable in decoders).
//!
//! # Why causal merging is incrementally computable
//!
//! Under the causal restriction (`k == 1`, adjacent pairs only) every
//! A-token at even position `2i` has exactly one match candidate: its
//! right neighbour at `2i + 1`.  The pair's cosine score therefore
//! depends on those two tokens **only**, and a dynamic-threshold
//! decision (`score > threshold`, paper §5.5) is pair-local: appending
//! observations can never change a decision already made.  This is what
//! makes the merged representation maintainable as a running state —
//! append `n` new points, pay O(n·d), and the state equals a full
//! recompute over the entire history.
//!
//! The fixed-`r` mode is deliberately **rejected** here: its top-`r`
//! selection is global (a newly appended, highly similar pair can push a
//! previously merged pair out of the budget), so a fixed-`r` causal plan
//! cannot be updated incrementally — it must be recomputed.  The
//! constructor enforces `Off | Dynamic`-with-`causal`.
//!
//! # Exactness contract
//!
//! The state is **bit-for-bit identical** to running the full-sequence
//! causal [`MergePlan`](super::MergePlan) (same spec, compiled at the
//! current raw length) over the whole history, for either
//! [`Accum`](super::kernel::Accum) variant, because every float op is
//! shared with the batch kernel:
//!
//! * scores come from [`kernel::token_norm`] + [`kernel::pair_score`] —
//!   the very functions the matching stage calls.  Both resolve the
//!   process-global SIMD dispatch ([`super::simd::active_isa`]) on entry,
//!   so the streaming path always computes under the same ISA as the
//!   batch kernel — and the F64 primitives are bitwise identical across
//!   ISAs anyway (see `simd.rs`), so dispatch cannot split the contract;
//! * a merged pair is accumulated exactly like the kernel's
//!   size-weighted scatter: `num[j] = a[j]·wa + b[j]·wb` in f64 in
//!   position order, `den = wa + wb`, output `(num / den) as f32` —
//!   the IEEE-754 op sequence is identical, so so are the bits;
//! * a kept token passes through verbatim, which equals the kernel's
//!   scatter `(x·w / w) as f32` exactly: `x·w` is exact in f64 (24-bit
//!   by 24-bit significands) and correctly-rounded division by `w`
//!   returns the representable true quotient `x`.
//!
//! `tests/streaming_differential.rs` pins incremental ≡ plan (bitwise)
//! ≡ `merging::reference` oracle (bitwise at `d == 1`, where the
//! kernel's chunked dot degenerates to the reference's serial loop)
//! across randomized append schedules.
//!
//! One documented divergence: NaN tokens.  The kernel's dynamic path
//! counts finite above-threshold scores but *selects* under
//! `f64::total_cmp`, where positive NaN sorts above `+inf`; the
//! incremental path keeps a NaN-scored pair unmerged.  Finite inputs —
//! the only inputs with defined merge semantics — agree everywhere.
//!
//! # Front trimming (bounded sessions)
//!
//! [`IncrementalMerge::trim_front`] drops the oldest merged tokens to
//! bound memory for long-lived sessions.  Because pair decisions are
//! local, trimming whole output tokens off the front leaves the retained
//! state equal to the corresponding *suffix* of the full recompute; the
//! exactness contract then applies to that suffix.

use anyhow::{ensure, Result};

use super::kernel;
use super::spec::{MergeMode, MergeSpec};

/// Running causal-merge state over an append-only token stream.
/// Construct via [`IncrementalMerge::new`] or
/// [`MergePlan::incremental`](super::MergePlan::incremental).
#[derive(Clone, Debug)]
pub struct IncrementalMerge {
    /// `Dynamic { threshold }` with `causal` (or `Off`): validated at
    /// construction, never changed.
    spec: MergeSpec,
    d: usize,
    /// decided output tokens (merged pairs and kept singles), row-major
    tokens: Vec<f32>,
    /// one size per decided output token
    sizes: Vec<f32>,
    /// pending A-token (`d` values) awaiting its right neighbour; empty
    /// when the raw length is even
    tail: Vec<f32>,
    tail_size: f32,
    /// precomputed [`kernel::token_norm`] of the tail (undefined when no
    /// tail is pending)
    tail_norm: f64,
    /// total raw tokens appended (the `t` a full recompute would see)
    raw_len: usize,
    /// pairs merged so far (`r` of the equivalent full-sequence run)
    merged_pairs: usize,
    /// decided output tokens dropped off the front by [`Self::trim_front`]
    trimmed: usize,
}

impl IncrementalMerge {
    /// A fresh state for `spec` over `d`-dimensional tokens.  `spec` must
    /// be `Off` or causal `Dynamic` (see the module docs for why fixed-`r`
    /// is rejected).
    pub fn new(spec: MergeSpec, d: usize) -> Result<IncrementalMerge> {
        spec.validate()?;
        ensure!(d >= 1, "incremental merge: d must be >= 1");
        match &spec.mode {
            MergeMode::Off => {}
            MergeMode::Dynamic { .. } => ensure!(
                spec.causal,
                "incremental merge requires a causal spec (k == 1, adjacent pairs \
                 only) — non-causal matching lets information flow backward, which \
                 an append-only state cannot represent"
            ),
            MergeMode::FixedR { .. } => anyhow::bail!(
                "incremental merge supports Off or causal Dynamic specs only: a \
                 fixed-r schedule selects its pairs globally (top-r), so appends \
                 can reassign the budget and the state cannot be maintained in \
                 O(n·d) — recompute a MergePlan instead"
            ),
        }
        Ok(IncrementalMerge {
            spec,
            d,
            tokens: Vec::new(),
            sizes: Vec::new(),
            tail: Vec::new(),
            tail_size: 1.0,
            tail_norm: 0.0,
            raw_len: 0,
            merged_pairs: 0,
            trimmed: 0,
        })
    }

    /// The spec this state was built from.
    pub fn spec(&self) -> &MergeSpec {
        &self.spec
    }

    /// Token dimensionality.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Total raw tokens appended so far.
    pub fn raw_len(&self) -> usize {
        self.raw_len
    }

    /// Pairs merged so far — the `r` of the equivalent full-sequence
    /// causal run.
    pub fn merged_pairs(&self) -> usize {
        self.merged_pairs
    }

    /// Output tokens currently held (decided prefix + pending tail),
    /// after any front trimming.
    pub fn len(&self) -> usize {
        self.tokens.len() / self.d + usize::from(!self.tail.is_empty())
    }

    /// True when nothing has been appended (or everything was trimmed).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Output tokens dropped off the front by [`Self::trim_front`].
    pub fn trimmed(&self) -> usize {
        self.trimmed
    }

    /// Output tokens produced over the whole history: currently held plus
    /// any trimmed off the front.
    pub fn output_len(&self) -> usize {
        self.len() + self.trimmed
    }

    /// Realized stream compression `raw_len / output_len` (1.0 before any
    /// append) — the merge-efficiency sample the serving metrics
    /// aggregate per session.
    pub fn compression_ratio(&self) -> f64 {
        let out = self.output_len();
        if out == 0 {
            1.0
        } else {
            self.raw_len as f64 / out as f64
        }
    }

    /// Append `n` unit-size observations (`points.len() == n * d`).
    pub fn append(&mut self, points: &[f32]) {
        assert_eq!(points.len() % self.d, 0, "points not a whole number of tokens");
        for row in points.chunks_exact(self.d) {
            self.push_token(row, 1.0);
        }
    }

    /// Append one token row with an explicit size (`size > 0`; raw
    /// observations are size 1).
    pub fn push_token(&mut self, row: &[f32], size: f32) {
        assert_eq!(row.len(), self.d, "token row length != d");
        debug_assert!(size > 0.0, "token sizes must be positive");
        let merging = match &self.spec.mode {
            MergeMode::Dynamic { threshold } => Some(*threshold),
            _ => None,
        };
        let Some(threshold) = merging else {
            // Off: verbatim passthrough, exactly like the plan's Off arm.
            self.tokens.extend_from_slice(row);
            self.sizes.push(size);
            self.raw_len += 1;
            return;
        };
        if self.raw_len % 2 == 0 {
            // A-token: hold as the pending tail, norm precomputed once.
            self.tail.clear();
            self.tail.extend_from_slice(row);
            self.tail_size = size;
            self.tail_norm = kernel::token_norm(row, self.spec.accum);
        } else {
            // B-token: the pair (tail, row) is complete — decide it with
            // the batch kernel's own score function.
            let nb = kernel::token_norm(row, self.spec.accum);
            let s = kernel::pair_score(&self.tail, row, self.tail_norm, nb, self.spec.accum);
            if s > threshold {
                // Size-weighted merge, op-for-op the kernel's scatter:
                // f64 accumulation in position order, divide (never a
                // reciprocal), narrow once.
                let (wa, wb) = (self.tail_size as f64, size as f64);
                let den = wa + wb;
                for j in 0..self.d {
                    let num = self.tail[j] as f64 * wa + row[j] as f64 * wb;
                    self.tokens.push((num / den) as f32);
                }
                self.sizes.push(den as f32);
                self.merged_pairs += 1;
            } else {
                // Both kept: verbatim, bit-equal to the kernel's
                // (x·w / w) scatter (see the module docs).
                self.tokens.extend_from_slice(&self.tail);
                self.sizes.push(self.tail_size);
                self.tokens.extend_from_slice(row);
                self.sizes.push(size);
            }
            self.tail.clear();
        }
        self.raw_len += 1;
    }

    /// Materialize the current merged representation (decided prefix plus
    /// the pending tail) into reusable buffers — what a full-sequence
    /// causal [`MergePlan`](super::MergePlan) run over the whole history
    /// would output (minus any trimmed front).
    pub fn snapshot_into(&self, tokens: &mut Vec<f32>, sizes: &mut Vec<f32>) {
        tokens.clear();
        tokens.extend_from_slice(&self.tokens);
        sizes.clear();
        sizes.extend_from_slice(&self.sizes);
        if !self.tail.is_empty() {
            tokens.extend_from_slice(&self.tail);
            sizes.push(self.tail_size);
        }
    }

    /// Copy the **last** `m = size_row.len()` output tokens right-aligned
    /// into `row`/`size_row` (`row` holds `m * d` interleaved values, one
    /// size per token, so a batch slab's disjoint chunks can be filled in
    /// parallel).  When fewer than `m` tokens exist, the front is padded
    /// by repeating the oldest available token — the slab-padding
    /// convention of `coordinator::pipeline::HostPrep` — with padding
    /// sizes set to 0 so a size-aware consumer can mask them out.
    /// Returns the number of real (unpadded) tokens.
    pub fn context_tail_into(&self, row: &mut [f32], size_row: &mut [f32]) -> usize {
        let d = self.d;
        let m = size_row.len();
        assert_eq!(row.len(), m * d, "row must hold m * d values");
        row.fill(0.0);
        size_row.fill(0.0);
        let have = self.len();
        let take = have.min(m);
        if take == 0 {
            return 0;
        }
        // gather the last `take` (token, size) pairs, tail included
        let decided = self.sizes.len();
        let from_tail = usize::from(!self.tail.is_empty()).min(take);
        let from_decided = take - from_tail;
        let start = decided - from_decided;
        for (i, p) in (start..decided).enumerate() {
            let dst = (m - take + i) * d;
            row[dst..dst + d].copy_from_slice(&self.tokens[p * d..(p + 1) * d]);
            size_row[m - take + i] = self.sizes[p];
        }
        if from_tail == 1 {
            row[(m - 1) * d..m * d].copy_from_slice(&self.tail);
            size_row[m - 1] = self.tail_size;
        }
        // edge-replicate the oldest real token across the front padding
        let edge = (m - take) * d;
        for f in 0..m - take {
            row.copy_within(edge..edge + d, f * d);
        }
        take
    }

    /// Drop decided output tokens off the front until at most
    /// `max_tokens` remain (the pending tail counts; it is never
    /// dropped).  Returns how many were dropped.  See the module docs for
    /// the suffix-equivalence this preserves.
    pub fn trim_front(&mut self, max_tokens: usize) -> usize {
        let max_tokens = max_tokens.max(1);
        let have = self.len();
        if have <= max_tokens {
            return 0;
        }
        let drop = (have - max_tokens).min(self.sizes.len());
        self.tokens.drain(..drop * self.d);
        self.sizes.drain(..drop);
        self.trimmed += drop;
        drop
    }

    /// Reset to an empty state (same spec/d), keeping buffer capacity.
    pub fn clear(&mut self) {
        self.tokens.clear();
        self.sizes.clear();
        self.tail.clear();
        self.raw_len = 0;
        self.merged_pairs = 0;
        self.trimmed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::MergeSpec;
    use crate::util::Rng;

    fn causal_dynamic(th: f64) -> MergeSpec {
        MergeSpec::dynamic(th, 1).with_causal()
    }

    #[test]
    fn rejects_non_incremental_specs() {
        assert!(IncrementalMerge::new(MergeSpec::off(), 4).is_ok());
        assert!(IncrementalMerge::new(causal_dynamic(0.9), 1).is_ok());
        // non-causal dynamic, fixed-r, and d = 0 are all rejected
        assert!(IncrementalMerge::new(MergeSpec::dynamic(0.9, 1), 1).is_err());
        assert!(IncrementalMerge::new(MergeSpec::single(4, 1).with_causal(), 1).is_err());
        assert!(IncrementalMerge::new(causal_dynamic(0.9), 0).is_err());
        // invalid specs fail validation before the mode check
        assert!(IncrementalMerge::new(MergeSpec::dynamic(f64::NAN, 1).with_causal(), 1).is_err());
    }

    #[test]
    fn matches_full_plan_bitwise() {
        let mut rng = Rng::new(41);
        let d = 3;
        let spec = causal_dynamic(0.2);
        let mut inc = IncrementalMerge::new(spec.clone(), d).unwrap();
        let mut history: Vec<f32> = Vec::new();
        let (mut snap_t, mut snap_s) = (Vec::new(), Vec::new());
        for step in 0..40 {
            let n = 1 + rng.below(5);
            let points: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
            history.extend_from_slice(&points);
            inc.append(&points);
            let t = history.len() / d;
            let full = spec.compile(t, d).unwrap().run(&history, &vec![1.0; t]);
            inc.snapshot_into(&mut snap_t, &mut snap_s);
            assert_eq!(snap_t, full.tokens, "step {step} t={t}");
            assert_eq!(snap_s, full.sizes, "step {step}");
            assert_eq!(inc.raw_len(), t);
            assert_eq!(t - inc.merged_pairs(), *full.token_counts.last().unwrap());
        }
    }

    #[test]
    fn off_spec_is_identity() {
        let mut inc = IncrementalMerge::new(MergeSpec::off(), 2).unwrap();
        let pts = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        inc.append(&pts);
        let (mut t, mut s) = (Vec::new(), Vec::new());
        inc.snapshot_into(&mut t, &mut s);
        assert_eq!(t, pts.to_vec());
        assert_eq!(s, vec![1.0; 3]);
        assert_eq!(inc.merged_pairs(), 0);
        assert_eq!(inc.output_len(), 3);
        assert_eq!(inc.compression_ratio(), 1.0);
    }

    #[test]
    fn context_tail_pads_and_right_aligns() {
        let mut inc = IncrementalMerge::new(causal_dynamic(1.5), 1).unwrap();
        inc.append(&[10.0, 20.0, 30.0]);
        let (mut row, mut sz) = (vec![0.0f32; 5], vec![0.0f32; 5]);
        // fewer tokens than m: edge-replicated front, sizes 0 on padding
        let fill = inc.context_tail_into(&mut row, &mut sz);
        assert_eq!(fill, 3);
        assert_eq!(row, vec![10.0, 10.0, 10.0, 20.0, 30.0]);
        assert_eq!(sz, vec![0.0, 0.0, 1.0, 1.0, 1.0]);
        // more tokens than m: the most recent m, tail included
        let (mut row, mut sz) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        let fill = inc.context_tail_into(&mut row, &mut sz);
        assert_eq!(fill, 2);
        assert_eq!(row, vec![20.0, 30.0]);
        // empty state: zeros, fill 0
        let empty = IncrementalMerge::new(MergeSpec::off(), 1).unwrap();
        let (mut row, mut sz) = (vec![9.0f32; 3], vec![9.0f32; 3]);
        assert_eq!(empty.context_tail_into(&mut row, &mut sz), 0);
        assert_eq!(row, vec![0.0; 3]);
    }

    #[test]
    fn context_tail_handles_multivariate_rows() {
        // d = 2, threshold above the cosine ceiling: nothing merges
        let mut inc = IncrementalMerge::new(causal_dynamic(1.5), 2).unwrap();
        inc.append(&[1.0, 10.0, 2.0, 20.0, 3.0, 30.0]); // 3 frames
        let (mut row, mut sz) = (vec![0.0f32; 2 * 5], vec![0.0f32; 5]);
        let fill = inc.context_tail_into(&mut row, &mut sz);
        assert_eq!(fill, 3);
        // front padding edge-replicates the oldest whole frame
        assert_eq!(row, vec![1.0, 10.0, 1.0, 10.0, 1.0, 10.0, 2.0, 20.0, 3.0, 30.0]);
        assert_eq!(sz, vec![0.0, 0.0, 1.0, 1.0, 1.0]);
        // m smaller than held tokens: the most recent frames, tail included
        let (mut row, mut sz) = (vec![0.0f32; 2 * 2], vec![0.0f32; 2]);
        assert_eq!(inc.context_tail_into(&mut row, &mut sz), 2);
        assert_eq!(row, vec![2.0, 20.0, 3.0, 30.0]);
        assert_eq!(sz, vec![1.0, 1.0]);
    }

    #[test]
    fn trim_front_keeps_suffix_equal() {
        let mut rng = Rng::new(43);
        let spec = causal_dynamic(0.0);
        let mut inc = IncrementalMerge::new(spec.clone(), 1).unwrap();
        let mut history = Vec::new();
        for _ in 0..30 {
            let pts: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            history.extend_from_slice(&pts);
            inc.append(&pts);
            inc.trim_front(8);
            assert!(inc.len() <= 8);
        }
        let t = history.len();
        let full = spec.compile(t, 1).unwrap().run(&history, &vec![1.0; t]);
        let (mut snap_t, mut snap_s) = (Vec::new(), Vec::new());
        inc.snapshot_into(&mut snap_t, &mut snap_s);
        let total = inc.trimmed() + snap_s.len();
        assert_eq!(total, full.sizes.len(), "trim must only drop, not distort");
        assert_eq!(snap_t.as_slice(), &full.tokens[inc.trimmed()..]);
        assert_eq!(snap_s.as_slice(), &full.sizes[inc.trimmed()..]);
    }
}
