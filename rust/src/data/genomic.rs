//! Genomic sequence substrate for the state-space experiments (§5.4).
//!
//! Substitution (DESIGN.md §7): the paper classifies the *Dummy Mouse
//! Enhancers Ensembl* dataset (long nucleotide sequences, binary label).
//! We generate the same task shape: class 1 sequences contain planted
//! enhancer-like motifs (with point mutations) at random positions in a
//! GC-biased background; class 0 is background only.  The signal is sparse
//! and positional — exactly the regime where token merging must preserve
//! local information to keep accuracy.

use crate::util::Rng;

/// Nucleotide vocabulary: A=0 C=1 G=2 T=3 N=4 (matches the Python side).
pub const VOCAB: usize = 5;

/// Enhancer-like core motifs (real TF binding cores: TATA, CAAT, GC-box,
/// E-box, AP-1).
const MOTIFS: &[&str] = &["TATAAA", "CCAAT", "GGGCGG", "CACGTG", "TGACTCA"];

fn base_id(c: u8) -> i32 {
    match c {
        b'A' => 0,
        b'C' => 1,
        b'G' => 2,
        b'T' => 3,
        _ => 4,
    }
}

/// One labelled example: `ids` of length `len`, label in {0, 1}.
pub struct Example {
    pub ids: Vec<i32>,
    pub label: i32,
}

/// Generate a single example.  Positive examples carry 3–6 motif instances
/// with a 10% per-base mutation rate.
pub fn example(len: usize, label: i32, rng: &mut Rng) -> Example {
    // GC-biased background (~42% GC like mouse genome)
    let mut ids: Vec<i32> = (0..len)
        .map(|_| {
            let u = rng.uniform();
            if u < 0.29 {
                0 // A
            } else if u < 0.50 {
                1 // C
            } else if u < 0.71 {
                2 // G
            } else {
                3 // T
            }
        })
        .collect();
    if label == 1 {
        let n_motifs = 3 + rng.below(4);
        for _ in 0..n_motifs {
            let motif = MOTIFS[rng.below(MOTIFS.len())].as_bytes();
            if len <= motif.len() {
                continue;
            }
            let pos = rng.below(len - motif.len());
            for (i, &c) in motif.iter().enumerate() {
                if rng.uniform() < 0.10 {
                    continue; // point mutation: keep background base
                }
                ids[pos + i] = base_id(c);
            }
        }
    }
    Example { ids, label }
}

/// A balanced batch: (ids (b, len) flattened, labels (b,)).
pub fn batch(b: usize, len: usize, rng: &mut Rng) -> (Vec<i32>, Vec<i32>) {
    let mut ids = Vec::with_capacity(b * len);
    let mut labels = Vec::with_capacity(b);
    for i in 0..b {
        let label = (i % 2) as i32;
        let ex = example(len, label, rng);
        ids.extend_from_slice(&ex.ids);
        labels.push(ex.label);
    }
    (ids, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_in_vocab() {
        let mut rng = Rng::new(1);
        let ex = example(512, 1, &mut rng);
        assert_eq!(ex.ids.len(), 512);
        assert!(ex.ids.iter().all(|&i| (0..VOCAB as i32).contains(&i)));
    }

    #[test]
    fn positive_class_contains_motifs() {
        // Count exact motif hits: positives should have far more than
        // background chance across many examples.
        let hits = |ids: &[i32], motif: &str| -> usize {
            let m: Vec<i32> = motif.bytes().map(base_id).collect();
            ids.windows(m.len()).filter(|w| *w == m.as_slice()).count()
        };
        let mut rng = Rng::new(2);
        let (mut pos, mut neg) = (0usize, 0usize);
        for _ in 0..40 {
            let ep = example(1024, 1, &mut rng);
            let en = example(1024, 0, &mut rng);
            for m in MOTIFS {
                pos += hits(&ep.ids, m);
                neg += hits(&en.ids, m);
            }
        }
        assert!(pos > neg + 40, "pos={pos} neg={neg}");
    }

    #[test]
    fn batches_are_balanced() {
        let mut rng = Rng::new(3);
        let (ids, labels) = batch(8, 128, &mut rng);
        assert_eq!(ids.len(), 8 * 128);
        assert_eq!(labels.iter().filter(|&&l| l == 1).count(), 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = batch(4, 64, &mut Rng::new(9));
        let b = batch(4, 64, &mut Rng::new(9));
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }
}
