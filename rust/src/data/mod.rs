//! Dataset substrate: seeded synthetic generators matched to the paper's
//! evaluation datasets on the §6.2 predictors (spectral entropy, THD),
//! plus windowing/splits/normalization and a CSV loader for real data.
//!
//! Substitution record (DESIGN.md §7): the paper uses ETTh1/ETTm1/Weather/
//! Electricity/Traffic.  The paper's own analysis says what matters for
//! token merging is the *spectral structure* of the series — high spectral
//! entropy and THD (noisy, harmonically distorted) predict quality gains,
//! low entropy predicts neutral outcomes.  Each generator below reproduces
//! its dataset's qualitative profile (table 4 ordering), verified by unit
//! tests against the Rust `signal` module.

pub mod genomic;

use crate::signal;
use crate::tensor::Tensor;
use crate::util::Rng;

/// A generated multivariate series: row-major (len, n_vars).
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub n_vars: usize,
    pub len: usize,
    pub values: Vec<f32>,
}

impl Series {
    pub fn column(&self, v: usize) -> Vec<f32> {
        (0..self.len).map(|i| self.values[i * self.n_vars + v]).collect()
    }

    /// Restrict to the first `n` variates (the table-1 model suite is
    /// compiled for 7 variates; datasets with more expose a 7-var view —
    /// merging operates on the time axis, so this preserves the studied
    /// behaviour).
    pub fn take_vars(&self, n: usize) -> Series {
        let n = n.min(self.n_vars);
        let mut values = Vec::with_capacity(self.len * n);
        for i in 0..self.len {
            values.extend_from_slice(&self.values[i * self.n_vars..i * self.n_vars + n]);
        }
        Series { name: self.name.clone(), n_vars: n, len: self.len, values }
    }
}

/// Spectral profile of one synthetic dataset.
#[derive(Clone, Debug)]
pub struct Profile {
    pub name: &'static str,
    pub n_vars: usize,
    /// (period in samples, amplitude) of seasonal components
    pub seasonal: &'static [(f64, f64)],
    /// amplitudes of harmonics 2..=H of the fundamental (drives THD)
    pub harmonics: &'static [f64],
    /// white-noise std (drives spectral entropy)
    pub noise: f64,
    /// random-walk (integrated noise) std — low-frequency wander
    pub walk: f64,
    /// linear trend per 1000 samples
    pub trend: f64,
}

/// Table-4 ordering: ETTm1/ETTh1/Traffic = high entropy & THD;
/// Electricity/Weather = low.  Periods follow the real datasets'
/// granularities (daily cycle = 24 samples hourly / 96 quarter-hourly).
pub const PROFILES: &[Profile] = &[
    Profile { name: "ettm1", n_vars: 7, seasonal: &[(96.0, 1.0), (672.0, 0.4)],
              harmonics: &[0.55, 0.4, 0.3, 0.22], noise: 0.9, walk: 0.03, trend: 0.05 },
    Profile { name: "etth1", n_vars: 7, seasonal: &[(24.0, 1.0), (168.0, 0.4)],
              harmonics: &[0.5, 0.35, 0.25, 0.18], noise: 0.75, walk: 0.03, trend: 0.05 },
    Profile { name: "traffic", n_vars: 16, seasonal: &[(24.0, 1.0), (168.0, 0.7)],
              harmonics: &[0.3, 0.2, 0.12], noise: 0.45, walk: 0.01, trend: 0.0 },
    Profile { name: "electricity", n_vars: 16, seasonal: &[(24.0, 1.0), (168.0, 0.5)],
              harmonics: &[0.22, 0.12], noise: 0.18, walk: 0.005, trend: 0.02 },
    Profile { name: "weather", n_vars: 12, seasonal: &[(144.0, 1.0)],
              harmonics: &[0.15], noise: 0.12, walk: 0.02, trend: 0.01 },
];

pub fn profile(name: &str) -> Option<&'static Profile> {
    PROFILES.iter().find(|p| p.name == name)
}

/// Generate `len` samples of the profile's multivariate series.
pub fn generate(p: &Profile, len: usize, seed: u64) -> Series {
    let mut values = vec![0.0f32; len * p.n_vars];
    for v in 0..p.n_vars {
        let mut rng = Rng::new(seed ^ 0x5EED).fork(v as u64 + 1);
        let phase = rng.uniform() * 2.0 * std::f64::consts::PI;
        let amp_jitter = 0.7 + 0.6 * rng.uniform();
        let mut walk = 0.0f64;
        for i in 0..len {
            let t = i as f64;
            let mut x = 0.0f64;
            for &(period, amp) in p.seasonal {
                let w = 2.0 * std::f64::consts::PI * t / period + phase;
                x += amp * amp_jitter * w.sin();
                // harmonic distortion of the fundamental only
                if period == p.seasonal[0].0 {
                    for (h, &ha) in p.harmonics.iter().enumerate() {
                        x += amp * ha * ((h as f64 + 2.0) * w).sin();
                    }
                }
            }
            walk += rng.normal() * p.walk;
            x += walk + p.trend * t / 1000.0 + rng.normal() * p.noise;
            values[i * p.n_vars + v] = x as f32;
        }
    }
    Series { name: p.name.to_string(), n_vars: p.n_vars, len, values }
}

/// Load a multivariate series from CSV (header row, optional first date
/// column skipped when non-numeric) — for users with the real datasets.
pub fn load_csv(path: &std::path::Path) -> anyhow::Result<Series> {
    let text = std::fs::read_to_string(path)?;
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if i == 0 || line.trim().is_empty() {
            continue; // header
        }
        let fields: Vec<&str> = line.split(',').collect();
        let start = usize::from(fields[0].parse::<f32>().is_err());
        let row: Result<Vec<f32>, _> = fields[start..].iter().map(|f| f.trim().parse::<f32>()).collect();
        rows.push(row?);
    }
    anyhow::ensure!(!rows.is_empty(), "empty csv");
    let n_vars = rows[0].len();
    anyhow::ensure!(rows.iter().all(|r| r.len() == n_vars), "ragged csv");
    Ok(Series {
        name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned(),
        n_vars,
        len: rows.len(),
        values: rows.into_iter().flatten().collect(),
    })
}

/// Chronological train/val/test split (70/10/20, the Autoformer convention).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

pub fn split_range(len: usize, split: Split) -> (usize, usize) {
    let train_end = len * 7 / 10;
    let val_end = len * 8 / 10;
    match split {
        Split::Train => (0, train_end),
        Split::Val => (train_end, val_end),
        Split::Test => (val_end, len),
    }
}

/// Per-variate standardisation statistics fit on the train split.
#[derive(Clone, Debug)]
pub struct Scaler {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl Scaler {
    pub fn fit(series: &Series, split: Split) -> Scaler {
        let (lo, hi) = split_range(series.len, split);
        let n = (hi - lo).max(1) as f64;
        let mut mean = vec![0.0; series.n_vars];
        let mut std = vec![0.0; series.n_vars];
        for i in lo..hi {
            for v in 0..series.n_vars {
                mean[v] += series.values[i * series.n_vars + v] as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= n;
        }
        for i in lo..hi {
            for v in 0..series.n_vars {
                let d = series.values[i * series.n_vars + v] as f64 - mean[v];
                std[v] += d * d;
            }
        }
        for s in std.iter_mut() {
            *s = (*s / n).sqrt().max(1e-6);
        }
        Scaler { mean, std }
    }

    pub fn transform(&self, series: &Series) -> Series {
        let mut out = series.clone();
        for i in 0..series.len {
            for v in 0..series.n_vars {
                let idx = i * series.n_vars + v;
                out.values[idx] =
                    ((series.values[idx] as f64 - self.mean[v]) / self.std[v]) as f32;
            }
        }
        out
    }
}

/// Sliding-window forecasting dataset over a (standardized) series.
pub struct WindowDataset {
    pub series: Series,
    pub m: usize,
    pub p: usize,
    pub lo: usize,
    pub hi: usize,
}

impl WindowDataset {
    pub fn new(series: Series, m: usize, p: usize, split: Split) -> WindowDataset {
        let (lo, hi) = split_range(series.len, split);
        WindowDataset { series, m, p, lo, hi }
    }

    /// Number of (x, y) windows available.
    pub fn len(&self) -> usize {
        (self.hi - self.lo).saturating_sub(self.m + self.p - 1)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Window `i`: x (m, n_vars), y (p, n_vars).
    pub fn window(&self, i: usize) -> (Tensor, Tensor) {
        let n = self.series.n_vars;
        let start = self.lo + i;
        let x = self.series.values[start * n..(start + self.m) * n].to_vec();
        let y = self.series.values
            [(start + self.m) * n..(start + self.m + self.p) * n]
            .to_vec();
        (
            Tensor::from_f32(&[self.m, n], x).unwrap(),
            Tensor::from_f32(&[self.p, n], y).unwrap(),
        )
    }

    /// Batch of windows at the given indices: x (b, m, n), y (b, p, n).
    pub fn batch(&self, indices: &[usize]) -> (Tensor, Tensor) {
        let pairs: Vec<(Tensor, Tensor)> = indices.iter().map(|&i| self.window(i)).collect();
        let xs: Vec<Tensor> = pairs.iter().map(|(x, _)| x.clone()).collect();
        let ys: Vec<Tensor> = pairs.iter().map(|(_, y)| y.clone()).collect();
        (Tensor::stack(&xs).unwrap(), Tensor::stack(&ys).unwrap())
    }

    /// Univariate batch for the Chronos family: x (b, m), y (b, p), cycling
    /// through variates.
    pub fn batch_univariate(&self, indices: &[usize]) -> (Tensor, Tensor) {
        let n = self.series.n_vars;
        let mut xs = Vec::with_capacity(indices.len() * self.m);
        let mut ys = Vec::with_capacity(indices.len() * self.p);
        for (j, &i) in indices.iter().enumerate() {
            let v = j % n;
            let start = self.lo + i;
            for s in 0..self.m {
                xs.push(self.series.values[(start + s) * n + v]);
            }
            for s in 0..self.p {
                ys.push(self.series.values[(start + self.m + s) * n + v]);
            }
        }
        (
            Tensor::from_f32(&[indices.len(), self.m], xs).unwrap(),
            Tensor::from_f32(&[indices.len(), self.p], ys).unwrap(),
        )
    }
}

/// Dataset-level spectral statistics (paper table 4), averaged over variates.
pub fn dataset_stats(series: &Series, window: usize) -> (f64, f64) {
    let mut ent = 0.0;
    let mut th = 0.0;
    for v in 0..series.n_vars {
        let col = series.column(v);
        let w = &col[..window.min(col.len())];
        ent += signal::spectral_entropy(w);
        th += signal::thd(w, 8);
    }
    (ent / series.n_vars as f64, th / series.n_vars as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_reproduce_table4_ordering() {
        // High-entropy group (ettm1, etth1, traffic) must rank above the
        // low-entropy group (electricity, weather) on spectral entropy.
        let mut ents = std::collections::HashMap::new();
        for p in PROFILES {
            let s = generate(p, 2048, 7);
            let (e, _) = dataset_stats(&s, 1024);
            ents.insert(p.name, e);
        }
        for hi in ["ettm1", "etth1"] {
            for lo in ["electricity", "weather"] {
                assert!(
                    ents[hi] > ents[lo],
                    "{hi}={:.2} should exceed {lo}={:.2}", ents[hi], ents[lo]
                );
            }
        }
        assert!(ents["traffic"] > ents["weather"]);
    }

    #[test]
    fn thd_ordering_matches_table4() {
        let get = |name: &str| {
            let p = profile(name).unwrap();
            let s = generate(p, 2048, 7);
            dataset_stats(&s, 1024).1
        };
        assert!(get("ettm1") > get("weather"));
        assert!(get("etth1") > get("electricity"));
    }

    #[test]
    fn generation_is_deterministic() {
        let p = profile("etth1").unwrap();
        let a = generate(p, 256, 42);
        let b = generate(p, 256, 42);
        assert_eq!(a.values, b.values);
        let c = generate(p, 256, 43);
        assert_ne!(a.values, c.values);
    }

    #[test]
    fn splits_are_chronological_and_disjoint() {
        let (a, b) = split_range(1000, Split::Train);
        let (c, d) = split_range(1000, Split::Val);
        let (e, f) = split_range(1000, Split::Test);
        assert!(a < b && b == c && c < d && d == e && e < f && f == 1000);
    }

    #[test]
    fn scaler_standardizes_train_split() {
        let p = profile("electricity").unwrap();
        let s = generate(p, 4000, 1);
        let sc = Scaler::fit(&s, Split::Train);
        let z = sc.transform(&s);
        let (lo, hi) = split_range(z.len, Split::Train);
        for v in 0..z.n_vars.min(3) {
            let col: Vec<f32> = (lo..hi).map(|i| z.values[i * z.n_vars + v]).collect();
            let mean: f64 = col.iter().map(|&x| x as f64).sum::<f64>() / col.len() as f64;
            let var: f64 =
                col.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / col.len() as f64;
            assert!(mean.abs() < 1e-3, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
    }

    #[test]
    fn windows_align_x_and_y() {
        let p = profile("etth1").unwrap();
        let s = generate(p, 3000, 5);
        let ds = WindowDataset::new(s.clone(), 192, 96, Split::Test);
        assert!(ds.len() > 100);
        let (x, y) = ds.window(10);
        assert_eq!(x.shape(), &[192, 7]);
        assert_eq!(y.shape(), &[96, 7]);
        // y starts exactly where x ends
        let (lo, _) = split_range(3000, Split::Test);
        let start = lo + 10;
        assert_eq!(x.f32s().unwrap()[0], s.values[start * 7]);
        assert_eq!(y.f32s().unwrap()[0], s.values[(start + 192) * 7]);
    }

    #[test]
    fn batching_shapes() {
        let p = profile("weather").unwrap();
        let s = generate(p, 3000, 5);
        let ds = WindowDataset::new(s, 192, 96, Split::Val);
        let (x, y) = ds.batch(&[0, 1, 2, 3]);
        assert_eq!(x.shape(), &[4, 192, 12]);
        assert_eq!(y.shape(), &[4, 96, 12]);
        let (xu, yu) = ds.batch_univariate(&[0, 1, 2, 3]);
        assert_eq!(xu.shape(), &[4, 192]);
        assert_eq!(yu.shape(), &[4, 96]);
    }

    #[test]
    fn csv_loader_roundtrip() {
        let dir = std::env::temp_dir().join("tomers_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mini.csv");
        std::fs::write(&path, "date,a,b\n2020-01-01,1.0,2.0\n2020-01-02,3.0,4.0\n").unwrap();
        let s = load_csv(&path).unwrap();
        assert_eq!((s.len, s.n_vars), (2, 2));
        assert_eq!(s.values, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
