//! JSON config system for the serving launcher and experiment runner.
//!
//! A deployment is described by one JSON file (variants, policy
//! thresholds, batching, workload) so the serving system is launchable
//! without recompiling — the "real config system + launcher" shape of a
//! deployable framework.
//!
//! ```json
//! {
//!   "artifact_dir": "artifacts",
//!   "policy": {
//!     "variants": [{"name": "chronos_s__r0", "r": 0},
//!                   {"name": "chronos_s__r128", "r": 128}],
//!     "entropy_lo": 3.0,
//!     "entropy_hi": 7.5
//!   },
//!   "batching": {"max_wait_ms": 20, "max_queue": 4096},
//!   "merge_workers": 0,
//!   "host_merge": {"enabled": true, "k": 8}
//! }
//! ```

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::coordinator::policy::{MergePolicy, Variant};
use crate::coordinator::{HostMergeConfig, ServerConfig};
use crate::json::Json;

#[derive(Clone, Debug)]
pub struct ServeFileConfig {
    pub artifact_dir: PathBuf,
    pub policy: MergePolicy,
    pub max_wait: Duration,
    pub max_queue: usize,
    /// worker count for the process-wide host-merge pool (0 = machine default)
    pub merge_workers: usize,
    pub host_merge: HostMergeConfig,
}

impl ServeFileConfig {
    pub fn load(path: &Path) -> Result<ServeFileConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing config {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<ServeFileConfig> {
        let v = Json::parse(text)?;
        let artifact_dir = PathBuf::from(
            v.get("artifact_dir").and_then(|d| d.as_str().ok()).unwrap_or("artifacts"),
        );

        let pol = v.req("policy")?;
        let mut variants = Vec::new();
        for item in pol.req("variants")?.as_arr()? {
            variants.push(Variant {
                name: item.req("name")?.as_str()?.to_string(),
                r: item.req("r")?.as_usize()?,
            });
        }
        ensure!(!variants.is_empty(), "policy.variants must not be empty");
        ensure!(
            variants.windows(2).all(|w| w[0].r <= w[1].r),
            "policy.variants must be ordered by increasing r"
        );
        let lo = pol.get("entropy_lo").and_then(|x| x.as_f64().ok()).unwrap_or(3.0);
        let hi = pol.get("entropy_hi").and_then(|x| x.as_f64().ok()).unwrap_or(7.5);
        ensure!(lo < hi, "entropy_lo must be < entropy_hi");
        let policy = MergePolicy::uniform(variants, lo, hi);

        let batching = v.get("batching");
        let max_wait_ms = batching
            .and_then(|b| b.get("max_wait_ms"))
            .and_then(|x| x.as_f64().ok())
            .unwrap_or(20.0);
        let max_queue = batching
            .and_then(|b| b.get("max_queue"))
            .and_then(|x| x.as_usize().ok())
            .unwrap_or(4096);
        ensure!(max_wait_ms >= 0.0 && max_queue > 0, "invalid batching config");

        let merge_workers = v
            .get("merge_workers")
            .and_then(|x| x.as_usize().ok())
            .unwrap_or(0);
        let hm = v.get("host_merge");
        let host_merge = HostMergeConfig {
            enabled: hm
                .and_then(|h| h.get("enabled"))
                .and_then(|x| x.as_bool().ok())
                .unwrap_or(HostMergeConfig::default().enabled),
            k: hm
                .and_then(|h| h.get("k"))
                .and_then(|x| x.as_usize().ok())
                .unwrap_or(HostMergeConfig::default().k),
        };
        ensure!(host_merge.k >= 1, "host_merge.k must be >= 1");

        Ok(ServeFileConfig {
            artifact_dir,
            policy,
            max_wait: Duration::from_micros((max_wait_ms * 1000.0) as u64),
            max_queue,
            merge_workers,
            host_merge,
        })
    }

    pub fn into_server_config(self) -> ServerConfig {
        ServerConfig {
            artifact_dir: self.artifact_dir,
            policy: self.policy,
            max_wait: self.max_wait,
            max_queue: self.max_queue,
            merge_workers: self.merge_workers,
            host_merge: self.host_merge,
        }
    }

    /// The default config written by `tomers serve --write-config`.
    pub fn example() -> &'static str {
        r#"{
 "artifact_dir": "artifacts",
 "policy": {
  "variants": [
   {"name": "chronos_s__r0", "r": 0},
   {"name": "chronos_s__r32", "r": 32},
   {"name": "chronos_s__r128", "r": 128}
  ],
  "entropy_lo": 3.0,
  "entropy_hi": 7.5
 },
 "batching": {"max_wait_ms": 20, "max_queue": 4096},
 "merge_workers": 0,
 "host_merge": {"enabled": true, "k": 8}
}
"#
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_example() {
        let cfg = ServeFileConfig::parse(ServeFileConfig::example()).unwrap();
        assert_eq!(cfg.policy.variants.len(), 3);
        assert_eq!(cfg.policy.variants[2].r, 128);
        assert_eq!(cfg.max_wait, Duration::from_millis(20));
        assert_eq!(cfg.max_queue, 4096);
        assert_eq!(cfg.artifact_dir, PathBuf::from("artifacts"));
        assert_eq!(cfg.merge_workers, 0);
        assert!(cfg.host_merge.enabled);
        assert_eq!(cfg.host_merge.k, 8);
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "x__r0", "r": 0}]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.max_queue, 4096);
        assert_eq!(cfg.policy.variants.len(), 1);
        assert_eq!(cfg.merge_workers, 0);
        assert!(cfg.host_merge.enabled, "host premerge defaults on");
    }

    #[test]
    fn parses_serving_overrides() {
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "x__r0", "r": 0}]},
                "merge_workers": 6,
                "host_merge": {"enabled": false, "k": 3}}"#,
        )
        .unwrap();
        assert_eq!(cfg.merge_workers, 6);
        assert!(!cfg.host_merge.enabled);
        assert_eq!(cfg.host_merge.k, 3);
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "x__r0", "r": 0}]},
                "host_merge": {"k": 0}}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ServeFileConfig::parse(r#"{"policy": {"variants": []}}"#).is_err());
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 9}, {"name": "b", "r": 1}]}}"#
        )
        .is_err());
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}],
                "entropy_lo": 9.0, "entropy_hi": 1.0}}"#
        )
        .is_err());
        assert!(ServeFileConfig::parse("not json").is_err());
    }

    #[test]
    fn roundtrips_into_server_config() {
        let cfg = ServeFileConfig::parse(ServeFileConfig::example()).unwrap();
        let sc = cfg.into_server_config();
        assert_eq!(sc.max_queue, 4096);
    }
}
