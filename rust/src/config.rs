//! JSON config system for the serving launcher and experiment runner.
//!
//! A deployment is described by one JSON file (variants, policy
//! thresholds, batching, workload) so the serving system is launchable
//! without recompiling — the "real config system + launcher" shape of a
//! deployable framework.
//!
//! ```json
//! {
//!   "artifact_dir": "artifacts",
//!   "policy": {
//!     "variants": [{"name": "chronos_s__r0", "r": 0},
//!                   {"name": "chronos_s__r128", "r": 128}],
//!     "entropy_lo": 3.0,
//!     "entropy_hi": 7.5
//!   },
//!   "batching": {"max_wait_ms": 20, "max_queue": 4096}
//! }
//! ```

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::coordinator::policy::{MergePolicy, Variant};
use crate::coordinator::ServerConfig;
use crate::json::Json;

#[derive(Clone, Debug)]
pub struct ServeFileConfig {
    pub artifact_dir: PathBuf,
    pub policy: MergePolicy,
    pub max_wait: Duration,
    pub max_queue: usize,
}

impl ServeFileConfig {
    pub fn load(path: &Path) -> Result<ServeFileConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing config {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<ServeFileConfig> {
        let v = Json::parse(text)?;
        let artifact_dir = PathBuf::from(
            v.get("artifact_dir").and_then(|d| d.as_str().ok()).unwrap_or("artifacts"),
        );

        let pol = v.req("policy")?;
        let mut variants = Vec::new();
        for item in pol.req("variants")?.as_arr()? {
            variants.push(Variant {
                name: item.req("name")?.as_str()?.to_string(),
                r: item.req("r")?.as_usize()?,
            });
        }
        ensure!(!variants.is_empty(), "policy.variants must not be empty");
        ensure!(
            variants.windows(2).all(|w| w[0].r <= w[1].r),
            "policy.variants must be ordered by increasing r"
        );
        let lo = pol.get("entropy_lo").and_then(|x| x.as_f64().ok()).unwrap_or(3.0);
        let hi = pol.get("entropy_hi").and_then(|x| x.as_f64().ok()).unwrap_or(7.5);
        ensure!(lo < hi, "entropy_lo must be < entropy_hi");
        let policy = MergePolicy::uniform(variants, lo, hi);

        let batching = v.get("batching");
        let max_wait_ms = batching
            .and_then(|b| b.get("max_wait_ms"))
            .and_then(|x| x.as_f64().ok())
            .unwrap_or(20.0);
        let max_queue = batching
            .and_then(|b| b.get("max_queue"))
            .and_then(|x| x.as_usize().ok())
            .unwrap_or(4096);
        ensure!(max_wait_ms >= 0.0 && max_queue > 0, "invalid batching config");

        Ok(ServeFileConfig {
            artifact_dir,
            policy,
            max_wait: Duration::from_micros((max_wait_ms * 1000.0) as u64),
            max_queue,
        })
    }

    pub fn into_server_config(self) -> ServerConfig {
        ServerConfig {
            artifact_dir: self.artifact_dir,
            policy: self.policy,
            max_wait: self.max_wait,
            max_queue: self.max_queue,
        }
    }

    /// The default config written by `tomers serve --write-config`.
    pub fn example() -> &'static str {
        r#"{
 "artifact_dir": "artifacts",
 "policy": {
  "variants": [
   {"name": "chronos_s__r0", "r": 0},
   {"name": "chronos_s__r32", "r": 32},
   {"name": "chronos_s__r128", "r": 128}
  ],
  "entropy_lo": 3.0,
  "entropy_hi": 7.5
 },
 "batching": {"max_wait_ms": 20, "max_queue": 4096}
}
"#
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_example() {
        let cfg = ServeFileConfig::parse(ServeFileConfig::example()).unwrap();
        assert_eq!(cfg.policy.variants.len(), 3);
        assert_eq!(cfg.policy.variants[2].r, 128);
        assert_eq!(cfg.max_wait, Duration::from_millis(20));
        assert_eq!(cfg.max_queue, 4096);
        assert_eq!(cfg.artifact_dir, PathBuf::from("artifacts"));
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "x__r0", "r": 0}]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.max_queue, 4096);
        assert_eq!(cfg.policy.variants.len(), 1);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ServeFileConfig::parse(r#"{"policy": {"variants": []}}"#).is_err());
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 9}, {"name": "b", "r": 1}]}}"#
        )
        .is_err());
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}],
                "entropy_lo": 9.0, "entropy_hi": 1.0}}"#
        )
        .is_err());
        assert!(ServeFileConfig::parse("not json").is_err());
    }

    #[test]
    fn roundtrips_into_server_config() {
        let cfg = ServeFileConfig::parse(ServeFileConfig::example()).unwrap();
        let sc = cfg.into_server_config();
        assert_eq!(sc.max_queue, 4096);
    }
}
