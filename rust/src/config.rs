//! JSON config system for the serving launcher and experiment runner.
//!
//! A deployment is described by one JSON file (variants, policy
//! thresholds, batching, the serving [`MergeSpec`]) so the serving system
//! is launchable without recompiling — the "real config system +
//! launcher" shape of a deployable framework.
//!
//! ```json
//! {
//!   "artifact_dir": "artifacts",
//!   "policy": {
//!     "variants": [{"name": "chronos_s__r0", "r": 0},
//!                   {"name": "chronos_s__r128", "r": 128}],
//!     "entropy_lo": 3.0,
//!     "entropy_hi": 7.5
//!   },
//!   "batching": {"max_wait_ms": 20, "max_queue": 4096},
//!   "merge_workers": 0,
//!   "merge": {"mode": "fixed", "k": 8}
//! }
//! ```
//!
//! The top-level `merge` block is the host-premerge [`MergeSpec`]
//! (`{"mode": "off"}` disables premerging; the schedule is derived per
//! request shape, so it takes only `mode`/`k`/`accum`/`causal`).  Each
//! variant entry takes either the shorthand `"r"` (a single fixed-`r`
//! step at the default locality) or a full `"merge"` block, so variants
//! can differ in mode and `k`, not just `r`.  `merge` keys per mode:
//! `"off"` takes only `mode`; `"fixed"` adds `k`, `r` or `schedule`
//! (per-layer `r` array), `accum` (`"f64" | "f32"`), `causal`;
//! `"dynamic"` adds `k`, `threshold`, `accum`, `causal`.
//!
//! The optional `"streaming"` block configures the streaming decode
//! subsystem (DESIGN.md §9): session-table capacity and TTL, the raw
//! ring / merged-retention bounds, the decode-readiness threshold, the
//! per-frame channel count `"d"` (homogeneous across the process), the
//! decode `"variant"` (which loaded artifact executes stream steps) and
//! the entropy → causal-merge-threshold ladder
//! (`streaming::StreamPolicy`).  Under `tomers serve` the block wires
//! the dual serving loop; omit it for batch-only serving.  The root
//! `"spec_source"` key (`"manifest"` default | `"config"`) picks which
//! side wins when a loaded artifact's manifest carries a `merge_spec`.
//!
//! The optional `"faults"` block configures fault tolerance (DESIGN.md
//! §10): device-call retry/backoff, request and decode-step deadlines,
//! the session/variant quarantine budgets, and the stream-forecast
//! delivery bounds (outbox capacity, TTL).  Omit it for the defaults
//! (bounded retry, no deadlines).
//!
//! **Unknown keys are rejected at every level** with an error naming the
//! key and the accepted set — a typo like `"entropy_low"` fails loudly
//! instead of silently falling back to the default, and a key another
//! mode would read (a `threshold` under `"fixed"`) is an error, not a
//! no-op.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::policy::{MergePolicy, Variant};
use crate::coordinator::{FaultPolicy, ServerConfig};
use crate::json::Json;
use crate::merging::{Accum, MergeMode, MergeSpec};
use crate::net::NetConfig;
use crate::obs::ObsConfig;
use crate::streaming::{StreamPolicy, StreamingConfig};

#[derive(Clone, Debug)]
pub struct ServeFileConfig {
    pub artifact_dir: PathBuf,
    pub policy: MergePolicy,
    pub max_wait: Duration,
    pub max_queue: usize,
    /// worker count for the process-wide host-merge pool (0 = machine default)
    pub merge_workers: usize,
    /// host-premerge spec for over-length contexts
    pub merge: MergeSpec,
    /// streaming decode subsystem (`None` = batch-only serving)
    pub streaming: Option<StreamingConfig>,
    /// `"spec_source"`: prefer each artifact's `Manifest.merge_spec` over
    /// the variant declaration (`"manifest"`, the default) or force the
    /// declaration (`"config"`)
    pub prefer_manifest_spec: bool,
    /// fault tolerance: retry/backoff, deadlines, quarantine budgets and
    /// delivery bounds (the `"faults"` block; defaults when omitted)
    pub faults: FaultPolicy,
    /// sharded network serving front (the `"net"` block, DESIGN.md §12);
    /// `None` = in-process serving only.  Consumed by `tomers serve-net`.
    pub net: Option<NetConfig>,
    /// observability: trace-ring capacity/sampling and latency-histogram
    /// bounds (the `"obs"` block, DESIGN.md §13; defaults when omitted)
    pub obs: ObsConfig,
}

/// Error unless `v` is a JSON object whose every key is in `allowed`
/// (a non-object here would otherwise make every lookup silently fall
/// back to its default).  `path` names the enclosing block in the error.
/// `pub(crate)` so the wire protocol (`net::protocol`) applies the same
/// strictness discipline to every frame it parses.
pub(crate) fn reject_unknown_keys(v: &Json, path: &str, allowed: &[&str]) -> Result<()> {
    let Json::Obj(map) = v else {
        bail!("{path} must be a JSON object — accepted keys: {allowed:?}");
    };
    for key in map.keys() {
        ensure!(
            allowed.contains(&key.as_str()),
            "unknown key {key:?} in {path} — accepted keys: {allowed:?}"
        );
    }
    Ok(())
}

/// Parse a `merge` JSON block into a validated [`MergeSpec`].
///
/// The accepted key set depends on `mode`, so a key another mode would
/// read is rejected instead of silently ignored (e.g. a `threshold`
/// under `"mode": "fixed"` is an error, not a no-op).
pub fn merge_spec_from_json(v: &Json, path: &str) -> Result<MergeSpec> {
    let mode = v.get("mode").map(|m| m.as_str()).transpose()?.unwrap_or("fixed");
    let allowed: &[&str] = match mode {
        "off" => &["mode"],
        "fixed" => &["mode", "k", "r", "schedule", "accum", "causal"],
        "dynamic" => &["mode", "k", "threshold", "accum", "causal"],
        other => bail!("{path}: unknown merge mode {other:?} (off | fixed | dynamic)"),
    };
    reject_unknown_keys(v, path, allowed)?;
    let k = match v.get("k") {
        Some(x) => x.as_usize()?,
        None => MergeSpec::DEFAULT_K,
    };
    let mut spec = match mode {
        "off" => MergeSpec::off(),
        "fixed" => {
            let schedule = match (v.get("schedule"), v.get("r")) {
                (Some(_), Some(_)) => {
                    bail!("{path}: give either \"r\" or \"schedule\", not both")
                }
                (Some(s), None) => s.usize_list()?,
                (None, Some(r)) => vec![r.as_usize()?],
                // no r/schedule: the serving template (depth derived per shape)
                (None, None) => Vec::new(),
            };
            MergeSpec::fixed_r(schedule, k)
        }
        "dynamic" => {
            let threshold = v
                .get("threshold")
                .context("merge mode \"dynamic\" requires \"threshold\"")?
                .as_f64()?;
            MergeSpec::dynamic(threshold, k)
        }
        _ => unreachable!("mode validated by the allowed-key match above"),
    };
    if let Some(a) = v.get("accum") {
        spec.accum = match a.as_str()? {
            "f64" => Accum::F64,
            "f32" => Accum::F32,
            other => bail!("{path}: unknown accum {other:?} (f64 | f32)"),
        };
    }
    if let Some(c) = v.get("causal") {
        if c.as_bool()? {
            spec = spec.with_causal();
        }
    }
    spec.validate().with_context(|| format!("invalid {path}"))?;
    Ok(spec)
}

/// Serialize a [`MergeSpec`] to the same JSON dialect
/// [`merge_spec_from_json`] parses — the canonical artifact-manifest form
/// (`runtime::Manifest::merge_spec`).  Only keys the spec's mode accepts
/// are emitted, so the round trip survives the parser's mode-dependent
/// unknown-key rejection.
pub fn merge_spec_to_json(spec: &MergeSpec) -> Json {
    match &spec.mode {
        MergeMode::Off => Json::obj(vec![("mode", Json::str("off"))]),
        MergeMode::FixedR { schedule } => {
            let mut pairs = vec![
                ("mode", Json::str("fixed")),
                ("k", Json::num(spec.k as f64)),
                (
                    "schedule",
                    Json::arr(schedule.iter().map(|&r| Json::num(r as f64)).collect()),
                ),
            ];
            if spec.accum == Accum::F32 {
                pairs.push(("accum", Json::str("f32")));
            }
            if spec.causal {
                pairs.push(("causal", Json::Bool(true)));
            }
            Json::obj(pairs)
        }
        MergeMode::Dynamic { threshold } => {
            let mut pairs = vec![
                ("mode", Json::str("dynamic")),
                ("k", Json::num(spec.k as f64)),
                ("threshold", Json::num(*threshold)),
            ];
            if spec.accum == Accum::F32 {
                pairs.push(("accum", Json::str("f32")));
            }
            if spec.causal {
                pairs.push(("causal", Json::Bool(true)));
            }
            Json::obj(pairs)
        }
    }
}

/// Parse a `"streaming"` JSON block into a validated [`StreamingConfig`]
/// — same unknown-key-rejection discipline as the `"merge"` block.
pub fn streaming_from_json(v: &Json, path: &str) -> Result<StreamingConfig> {
    reject_unknown_keys(
        v,
        path,
        &[
            "max_sessions",
            "session_ttl_ms",
            "reprobe_every",
            "raw_window",
            "max_merged",
            "min_new",
            "d",
            "variant",
            "policy",
        ],
    )?;
    let defaults = StreamingConfig::default();
    let get_usize = |key: &str, dflt: usize| -> Result<usize> {
        Ok(v.get(key).map(|x| x.as_usize()).transpose()?.unwrap_or(dflt))
    };
    let ttl_ms = v
        .get("session_ttl_ms")
        .map(|x| x.as_f64())
        .transpose()?
        .unwrap_or(defaults.session_ttl.as_secs_f64() * 1e3);
    ensure!(
        ttl_ms.is_finite() && ttl_ms > 0.0,
        "{path}: session_ttl_ms must be a positive number"
    );
    let policy = match v.get("policy") {
        Some(p) => {
            reject_unknown_keys(
                p,
                &format!("{path}.policy"),
                &["entropy_lo", "entropy_hi", "thresholds"],
            )?;
            let d = StreamPolicy::default();
            StreamPolicy {
                entropy_lo: p
                    .get("entropy_lo")
                    .map(|x| x.as_f64())
                    .transpose()?
                    .unwrap_or(d.entropy_lo),
                entropy_hi: p
                    .get("entropy_hi")
                    .map(|x| x.as_f64())
                    .transpose()?
                    .unwrap_or(d.entropy_hi),
                thresholds: match p.get("thresholds") {
                    Some(t) => t.as_arr()?.iter().map(|x| x.as_f64()).collect::<Result<_>>()?,
                    None => d.thresholds,
                },
            }
        }
        None => defaults.policy.clone(),
    };
    let cfg = StreamingConfig {
        max_sessions: get_usize("max_sessions", defaults.max_sessions)?,
        session_ttl: Duration::from_micros((ttl_ms * 1000.0) as u64),
        reprobe_every: get_usize("reprobe_every", defaults.reprobe_every)?,
        raw_window: get_usize("raw_window", defaults.raw_window)?,
        max_merged: get_usize("max_merged", defaults.max_merged)?,
        min_new: get_usize("min_new", defaults.min_new)?,
        d: get_usize("d", defaults.d)?,
        policy,
        variant: match v.get("variant") {
            Some(x) => Some(x.as_str()?.to_string()),
            None => None,
        },
    };
    cfg.validate().with_context(|| format!("invalid {path}"))?;
    Ok(cfg)
}

/// Parse a `"faults"` JSON block into a validated
/// [`FaultPolicy`] — same unknown-key-rejection discipline as the
/// `"merge"` and `"streaming"` blocks.  Durations are milliseconds;
/// `request_deadline_ms` / `step_deadline_ms` default to absent (no
/// deadline), everything else to [`FaultPolicy::default`].
pub fn faults_from_json(v: &Json, path: &str) -> Result<FaultPolicy> {
    reject_unknown_keys(
        v,
        path,
        &[
            "max_retries",
            "backoff_base_ms",
            "backoff_max_ms",
            "request_deadline_ms",
            "step_deadline_ms",
            "session_fault_budget",
            "variant_fault_budget",
            "outbox_cap",
            "forecast_ttl_ms",
        ],
    )?;
    let defaults = FaultPolicy::default();
    let get_ms = |key: &str, dflt: Duration| -> Result<Duration> {
        match v.get(key) {
            Some(x) => {
                let ms = x.as_f64()?;
                ensure!(
                    ms.is_finite() && ms >= 0.0,
                    "{path}: {key} must be a non-negative number of milliseconds"
                );
                Ok(Duration::from_micros((ms * 1000.0) as u64))
            }
            None => Ok(dflt),
        }
    };
    let get_opt_ms = |key: &str| -> Result<Option<Duration>> {
        match v.get(key) {
            Some(x) => {
                let ms = x.as_f64()?;
                ensure!(
                    ms.is_finite() && ms > 0.0,
                    "{path}: {key} must be a positive number of milliseconds"
                );
                Ok(Some(Duration::from_micros((ms * 1000.0) as u64)))
            }
            None => Ok(None),
        }
    };
    let get_u32 = |key: &str, dflt: u32| -> Result<u32> {
        match v.get(key) {
            Some(x) => Ok(u32::try_from(x.as_usize()?)
                .map_err(|_| anyhow::anyhow!("{path}: {key} out of range"))?),
            None => Ok(dflt),
        }
    };
    let policy = FaultPolicy {
        max_retries: match v.get("max_retries") {
            Some(x) => x.as_usize()?,
            None => defaults.max_retries,
        },
        backoff_base: get_ms("backoff_base_ms", defaults.backoff_base)?,
        backoff_max: get_ms("backoff_max_ms", defaults.backoff_max)?,
        request_deadline: get_opt_ms("request_deadline_ms")?,
        step_deadline: get_opt_ms("step_deadline_ms")?,
        session_fault_budget: get_u32("session_fault_budget", defaults.session_fault_budget)?,
        variant_fault_budget: get_u32("variant_fault_budget", defaults.variant_fault_budget)?,
        outbox_cap: match v.get("outbox_cap") {
            Some(x) => x.as_usize()?,
            None => defaults.outbox_cap,
        },
        forecast_ttl: get_ms("forecast_ttl_ms", defaults.forecast_ttl)?,
    };
    policy.validate().with_context(|| format!("invalid {path}"))?;
    Ok(policy)
}

/// Parse a `"net"` JSON block into a validated [`NetConfig`] — the
/// sharded network front (DESIGN.md §12).  Same strictness as the other
/// blocks; every field defaults from [`NetConfig::default`].
pub fn net_from_json(v: &Json, path: &str) -> Result<NetConfig> {
    reject_unknown_keys(v, path, &["shards", "addr", "max_conns", "max_frame_bytes"])?;
    let defaults = NetConfig::default();
    let get_usize = |key: &str, dflt: usize| -> Result<usize> {
        match v.get(key) {
            Some(x) => x.as_usize().with_context(|| format!("{path}: bad {key}")),
            None => Ok(dflt),
        }
    };
    let cfg = NetConfig {
        shards: get_usize("shards", defaults.shards)?,
        addr: match v.get("addr") {
            Some(a) => a.as_str()?.to_string(),
            None => defaults.addr,
        },
        max_conns: get_usize("max_conns", defaults.max_conns)?,
        max_frame_bytes: get_usize("max_frame_bytes", defaults.max_frame_bytes)?,
    };
    cfg.validate().with_context(|| format!("invalid {path}"))?;
    Ok(cfg)
}

/// Parse an `"obs"` JSON block into a validated [`ObsConfig`] — the
/// observability settings (DESIGN.md §13).  Same strictness as the other
/// blocks; every field defaults from [`ObsConfig::default`].  The
/// histogram exponents are powers of two: the latency histogram covers
/// `[2^hist_min_exp, 2^hist_max_exp)` seconds.
pub fn obs_from_json(v: &Json, path: &str) -> Result<ObsConfig> {
    reject_unknown_keys(
        v,
        path,
        &["trace_ring", "sample_every", "hist_min_exp", "hist_max_exp"],
    )?;
    let defaults = ObsConfig::default();
    let get_i32 = |key: &str, dflt: i32| -> Result<i32> {
        match v.get(key) {
            Some(x) => {
                let n = x.as_f64()?;
                ensure!(
                    n.fract() == 0.0 && (-1022.0..=1023.0).contains(&n),
                    "{path}: {key} must be an integer binary exponent in [-1022, 1023]"
                );
                Ok(n as i32)
            }
            None => Ok(dflt),
        }
    };
    let cfg = ObsConfig {
        trace_ring: match v.get("trace_ring") {
            Some(x) => x.as_usize().with_context(|| format!("{path}: bad trace_ring"))?,
            None => defaults.trace_ring,
        },
        sample_every: match v.get("sample_every") {
            Some(x) => {
                x.as_usize().with_context(|| format!("{path}: bad sample_every"))? as u64
            }
            None => defaults.sample_every,
        },
        hist_min_exp: get_i32("hist_min_exp", defaults.hist_min_exp)?,
        hist_max_exp: get_i32("hist_max_exp", defaults.hist_max_exp)?,
    };
    cfg.validate().with_context(|| format!("invalid {path}"))?;
    Ok(cfg)
}

impl ServeFileConfig {
    pub fn load(path: &Path) -> Result<ServeFileConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing config {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<ServeFileConfig> {
        let v = Json::parse(text)?;
        reject_unknown_keys(
            &v,
            "the config root",
            &[
                "artifact_dir",
                "policy",
                "batching",
                "merge_workers",
                "merge",
                "streaming",
                "spec_source",
                "faults",
                "net",
                "obs",
            ],
        )?;
        let artifact_dir = PathBuf::from(
            v.get("artifact_dir").and_then(|d| d.as_str().ok()).unwrap_or("artifacts"),
        );

        let pol = v.req("policy")?;
        reject_unknown_keys(pol, "\"policy\"", &["variants", "entropy_lo", "entropy_hi"])?;
        let mut variants = Vec::new();
        for (i, item) in pol.req("variants")?.as_arr()?.iter().enumerate() {
            let path = format!("\"policy.variants[{i}]\"");
            reject_unknown_keys(item, &path, &["name", "r", "merge"])?;
            let name = item.req("name")?.as_str()?.to_string();
            let variant = match (item.get("merge"), item.get("r")) {
                (Some(m), None) => {
                    let spec = merge_spec_from_json(m, &format!("{path}.merge"))?;
                    // the schedule-free fixed template is a serving-level
                    // concept; a variant describes a concrete artifact, so
                    // a fixed block here must say how much it merges
                    if let MergeMode::FixedR { schedule } = &spec.mode {
                        ensure!(
                            !schedule.is_empty(),
                            "{path}.merge: mode \"fixed\" needs \"r\" or \"schedule\" \
                             (the schedule-free template is only valid in the \
                             top-level serving \"merge\" block)"
                        );
                    }
                    Variant::new(name, spec)
                }
                (None, Some(r)) => Variant::fixed(name, r.as_usize()?),
                (Some(_), Some(_)) => {
                    bail!("{path}: give either \"r\" or \"merge\", not both")
                }
                (None, None) => bail!("{path}: needs \"r\" or a \"merge\" block"),
            };
            variants.push(variant);
        }
        ensure!(!variants.is_empty(), "policy.variants must not be empty");
        // The entropy thresholds map list position to aggressiveness, so
        // fixed-r variants must come in increasing r; dynamic variants are
        // exempt (their effective r is data-dependent) and ordered by hand.
        let fixed_rs: Vec<usize> = variants
            .iter()
            .filter(|v| !matches!(v.spec.mode, MergeMode::Dynamic { .. }))
            .map(|v| v.r())
            .collect();
        ensure!(
            fixed_rs.windows(2).all(|w| w[0] <= w[1]),
            "policy.variants must be ordered by increasing merge rate r"
        );
        let lo = pol.get("entropy_lo").map(|x| x.as_f64()).transpose()?.unwrap_or(3.0);
        let hi = pol.get("entropy_hi").map(|x| x.as_f64()).transpose()?.unwrap_or(7.5);
        ensure!(lo < hi, "entropy_lo must be < entropy_hi");
        let policy = MergePolicy::uniform(variants, lo, hi);

        let batching = v.get("batching");
        if let Some(b) = batching {
            reject_unknown_keys(b, "\"batching\"", &["max_wait_ms", "max_queue"])?;
        }
        let max_wait_ms = batching
            .and_then(|b| b.get("max_wait_ms"))
            .map(|x| x.as_f64())
            .transpose()?
            .unwrap_or(20.0);
        let max_queue = batching
            .and_then(|b| b.get("max_queue"))
            .map(|x| x.as_usize())
            .transpose()?
            .unwrap_or(4096);
        ensure!(max_wait_ms >= 0.0 && max_queue > 0, "invalid batching config");

        let merge_workers = v
            .get("merge_workers")
            .map(|x| x.as_usize())
            .transpose()?
            .unwrap_or(0);
        let merge = match v.get("merge") {
            Some(m) => merge_spec_from_json(m, "\"merge\"")?,
            None => crate::coordinator::default_host_merge(),
        };
        // The host premerge derives its schedule per (context length,
        // artifact m) at serve time; an explicit r/schedule or a dynamic
        // threshold here would be silently discarded, so reject it.
        match &merge.mode {
            MergeMode::Off => {}
            MergeMode::FixedR { schedule } => ensure!(
                schedule.is_empty(),
                "\"merge\": the host premerge schedule is derived per request shape — \
                 drop \"r\"/\"schedule\" (give only mode/k/accum/causal)"
            ),
            MergeMode::Dynamic { .. } => bail!(
                "\"merge\": host premerge must hit the artifact's exact context length, \
                 so mode \"dynamic\" is not supported here — use \"off\" or \"fixed\""
            ),
        }

        let streaming = v
            .get("streaming")
            .map(|s| streaming_from_json(s, "\"streaming\""))
            .transpose()?;

        let faults = v
            .get("faults")
            .map(|f| faults_from_json(f, "\"faults\""))
            .transpose()?
            .unwrap_or_default();

        let net = v.get("net").map(|n| net_from_json(n, "\"net\"")).transpose()?;

        let obs = v
            .get("obs")
            .map(|o| obs_from_json(o, "\"obs\""))
            .transpose()?
            .unwrap_or_default();

        // Which source wins when a loaded artifact's manifest carries a
        // merge_spec: the manifest (default — the artifact is the ground
        // truth for what was compiled into it) or the config declaration.
        let prefer_manifest_spec = match v.get("spec_source") {
            None => true,
            Some(s) => match s.as_str()? {
                "manifest" => true,
                "config" => false,
                other => bail!(
                    "\"spec_source\": unknown value {other:?} (manifest | config)"
                ),
            },
        };

        Ok(ServeFileConfig {
            artifact_dir,
            policy,
            max_wait: Duration::from_micros((max_wait_ms * 1000.0) as u64),
            max_queue,
            merge_workers,
            merge,
            streaming,
            prefer_manifest_spec,
            faults,
            net,
            obs,
        })
    }

    pub fn into_server_config(self) -> ServerConfig {
        ServerConfig {
            artifact_dir: self.artifact_dir,
            policy: self.policy,
            max_wait: self.max_wait,
            max_queue: self.max_queue,
            merge_workers: self.merge_workers,
            merge: self.merge,
            streaming: self.streaming,
            prefer_manifest_spec: self.prefer_manifest_spec,
            faults: self.faults,
        }
    }

    /// The default config written by `tomers serve --write-config`.  The
    /// `"streaming"` block is live under `tomers serve`: it wires stream
    /// sessions through the dual serving loop, decoding on `"variant"`
    /// (here the unmerged artifact; `"d"` is its channel count) — drop
    /// the block for batch-only serving.  `"spec_source"` picks which
    /// merge-spec source wins when a loaded manifest carries one.  The
    /// `"faults"` block configures fault tolerance (DESIGN.md §10) —
    /// shown here with its defaults plus an explicit request deadline.
    /// The `"net"` block configures the sharded network front
    /// (`tomers serve-net`, DESIGN.md §12); in-process serving ignores it.
    pub fn example() -> &'static str {
        r#"{
 "artifact_dir": "artifacts",
 "policy": {
  "variants": [
   {"name": "chronos_s__r0", "r": 0},
   {"name": "chronos_s__r32", "r": 32},
   {"name": "chronos_s__r128", "merge": {"mode": "fixed", "r": 128, "k": 16}}
  ],
  "entropy_lo": 3.0,
  "entropy_hi": 7.5
 },
 "batching": {"max_wait_ms": 20, "max_queue": 4096},
 "merge_workers": 0,
 "merge": {"mode": "fixed", "k": 8},
 "spec_source": "manifest",
 "streaming": {
  "max_sessions": 1024,
  "session_ttl_ms": 60000,
  "reprobe_every": 256,
  "raw_window": 1024,
  "max_merged": 4096,
  "min_new": 16,
  "d": 1,
  "variant": "chronos_s__r0",
  "policy": {"entropy_lo": 3.0, "entropy_hi": 7.5, "thresholds": [1.1, 0.95, 0.8]}
 },
 "faults": {
  "max_retries": 2,
  "backoff_base_ms": 2,
  "backoff_max_ms": 250,
  "request_deadline_ms": 5000,
  "session_fault_budget": 3,
  "variant_fault_budget": 5,
  "outbox_cap": 16,
  "forecast_ttl_ms": 60000
 },
 "net": {
  "shards": 2,
  "addr": "127.0.0.1:7070",
  "max_conns": 64,
  "max_frame_bytes": 1048576
 },
 "obs": {
  "trace_ring": 4096,
  "sample_every": 1,
  "hist_min_exp": -20,
  "hist_max_exp": 7
 }
}
"#
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_example() {
        let cfg = ServeFileConfig::parse(ServeFileConfig::example()).unwrap();
        assert_eq!(cfg.policy.variants.len(), 3);
        assert_eq!(cfg.policy.variants[2].r(), 128);
        assert_eq!(cfg.policy.variants[2].spec.k, 16);
        assert!(cfg.policy.variants[0].spec.is_off());
        assert_eq!(cfg.max_wait, Duration::from_millis(20));
        assert_eq!(cfg.max_queue, 4096);
        assert_eq!(cfg.artifact_dir, PathBuf::from("artifacts"));
        assert_eq!(cfg.merge_workers, 0);
        assert!(!cfg.merge.is_off());
        assert_eq!(cfg.merge.k, 8);
        let streaming = cfg.streaming.expect("example carries a streaming block");
        assert_eq!(streaming.max_sessions, 1024);
        assert_eq!(streaming.min_new, 16);
        assert_eq!(streaming.d, 1);
        assert_eq!(streaming.variant.as_deref(), Some("chronos_s__r0"));
        assert_eq!(streaming.policy.thresholds, vec![1.1, 0.95, 0.8]);
        assert!(cfg.prefer_manifest_spec, "the example names the default spec source");
        assert_eq!(cfg.faults.max_retries, 2);
        assert_eq!(cfg.faults.request_deadline, Some(Duration::from_secs(5)));
        assert_eq!(cfg.faults.step_deadline, None, "no step deadline in the example");
        assert_eq!(cfg.faults.outbox_cap, 16);
        assert_eq!(cfg.faults.forecast_ttl, Duration::from_secs(60));
        let net = cfg.net.expect("example carries a net block");
        assert_eq!(net.shards, 2);
        assert_eq!(net.addr, "127.0.0.1:7070");
        assert_eq!(net.max_conns, 64);
        assert_eq!(net.max_frame_bytes, 1 << 20);
        assert_eq!(cfg.obs, ObsConfig::default(), "the example shows the obs defaults");
    }

    #[test]
    fn parses_obs_block() {
        let base = r#"{"policy": {"variants": [{"name": "a", "r": 0}]}"#;
        // omitted block = defaults
        let cfg = ServeFileConfig::parse(&format!("{base}}}")).unwrap();
        assert_eq!(cfg.obs, ObsConfig::default());
        // partial block: named keys override, the rest default
        let cfg = ServeFileConfig::parse(&format!(
            r#"{base}, "obs": {{"trace_ring": 128, "hist_min_exp": -10}}}}"#
        ))
        .unwrap();
        assert_eq!(cfg.obs.trace_ring, 128);
        assert_eq!(cfg.obs.hist_min_exp, -10);
        assert_eq!(cfg.obs.sample_every, ObsConfig::default().sample_every);
        assert_eq!(cfg.obs.hist_max_exp, ObsConfig::default().hist_max_exp);
        // unknown key rejected with the accepted set named
        let err = ServeFileConfig::parse(&format!(
            r#"{base}, "obs": {{"trace_rings": 128}}}}"#
        ))
        .unwrap_err();
        assert!(err.to_string().contains("trace_rings"), "{err}");
        assert!(err.to_string().contains("trace_ring"), "{err}");
        // degenerate values rejected at parse time
        for bad in [
            r#"{"trace_ring": 0}"#,
            r#"{"sample_every": 0}"#,
            r#"{"hist_min_exp": 8, "hist_max_exp": 7}"#,
            r#"{"hist_min_exp": 2.5}"#,
            r#"{"hist_max_exp": 99999}"#,
        ] {
            let err = ServeFileConfig::parse(&format!(r#"{base}, "obs": {bad}}}"#))
                .unwrap_err();
            assert!(err.to_string().contains("obs"), "{bad}: {err}");
        }
        // non-object block
        assert!(ServeFileConfig::parse(&format!(r#"{base}, "obs": "on"}}"#)).is_err());
    }

    #[test]
    fn parses_net_block() {
        let base = r#"{"policy": {"variants": [{"name": "a", "r": 0}]}"#;
        // omitted block: no network front
        let cfg = ServeFileConfig::parse(&format!("{base}}}")).unwrap();
        assert!(cfg.net.is_none());
        // partial block: named keys override, the rest default
        let cfg =
            ServeFileConfig::parse(&format!(r#"{base}, "net": {{"shards": 4}}}}"#)).unwrap();
        let net = cfg.net.unwrap();
        assert_eq!(net.shards, 4);
        assert_eq!(net.addr, NetConfig::default().addr);
        assert_eq!(net.max_frame_bytes, NetConfig::default().max_frame_bytes);
        // unknown key rejected, degenerate values rejected
        let err = ServeFileConfig::parse(&format!(r#"{base}, "net": {{"shard": 4}}}}"#))
            .unwrap_err();
        assert!(err.to_string().contains("shard"), "{err}");
        for bad in [
            r#"{"shards": 0}"#,
            r#"{"max_conns": 0}"#,
            r#"{"max_frame_bytes": 0}"#,
            r#"{"addr": ""}"#,
        ] {
            let err = ServeFileConfig::parse(&format!(r#"{base}, "net": {bad}}}"#))
                .unwrap_err();
            assert!(err.to_string().contains("net"), "{bad}: {err}");
        }
    }

    #[test]
    fn parses_faults_block() {
        let base = |block: &str| {
            format!(
                r#"{{"policy": {{"variants": [{{"name": "a", "r": 0}}]}}, "faults": {}}}"#,
                block
            )
        };
        // partial block: named keys override, the rest default
        let cfg = ServeFileConfig::parse(&base(
            r#"{"max_retries": 5, "step_deadline_ms": 40, "outbox_cap": 4}"#,
        ))
        .unwrap();
        assert_eq!(cfg.faults.max_retries, 5);
        assert_eq!(cfg.faults.step_deadline, Some(Duration::from_millis(40)));
        assert_eq!(cfg.faults.outbox_cap, 4);
        assert_eq!(cfg.faults.backoff_base, FaultPolicy::default().backoff_base);
        assert_eq!(cfg.faults.request_deadline, None, "deadlines default off");
        // omitted block = all defaults
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.faults, FaultPolicy::default());
        // the block survives into the server config
        let sc = ServeFileConfig::parse(&base(r#"{"max_retries": 0}"#))
            .unwrap()
            .into_server_config();
        assert_eq!(sc.faults.max_retries, 0);
    }

    #[test]
    fn rejects_bad_faults_blocks() {
        let base = |block: &str| {
            format!(
                r#"{{"policy": {{"variants": [{{"name": "a", "r": 0}}]}}, "faults": {}}}"#,
                block
            )
        };
        // unknown key, with the accepted set named
        let err = ServeFileConfig::parse(&base(r#"{"retries": 3}"#)).unwrap_err();
        assert!(err.to_string().contains("retries"), "{err}");
        assert!(err.to_string().contains("max_retries"), "{err}");
        // non-object block
        assert!(ServeFileConfig::parse(&base(r#""on""#)).is_err());
        // validation failures surface at parse time, naming the field
        let err = ServeFileConfig::parse(&base(r#"{"outbox_cap": 0}"#)).unwrap_err();
        assert!(format!("{err:#}").contains("outbox_cap"), "{err:#}");
        assert!(ServeFileConfig::parse(&base(r#"{"backoff_base_ms": 0}"#)).is_err());
        assert!(ServeFileConfig::parse(
            &base(r#"{"backoff_base_ms": 10, "backoff_max_ms": 1}"#)
        )
        .is_err());
        assert!(ServeFileConfig::parse(&base(r#"{"request_deadline_ms": 0}"#)).is_err());
        assert!(ServeFileConfig::parse(&base(r#"{"session_fault_budget": 0}"#)).is_err());
        // wrong-typed values error instead of defaulting
        assert!(ServeFileConfig::parse(&base(r#"{"max_retries": "lots"}"#)).is_err());
    }

    #[test]
    fn spec_source_escape_hatch_parses() {
        let base = |root_extra: &str| {
            format!(r#"{{"policy": {{"variants": [{{"name": "a", "r": 0}}]}}{root_extra}}}"#)
        };
        // default: the manifest wins
        let cfg = ServeFileConfig::parse(&base("")).unwrap();
        assert!(cfg.prefer_manifest_spec);
        // explicit default
        let cfg = ServeFileConfig::parse(&base(r#", "spec_source": "manifest""#)).unwrap();
        assert!(cfg.prefer_manifest_spec);
        // the escape hatch forces the config declaration
        let cfg = ServeFileConfig::parse(&base(r#", "spec_source": "config""#)).unwrap();
        assert!(!cfg.prefer_manifest_spec);
        // unknown values are rejected with the accepted set named
        let err = ServeFileConfig::parse(&base(r#", "spec_source": "artifact""#)).unwrap_err();
        assert!(err.to_string().contains("manifest | config"), "{err}");
        // wrong-typed values error instead of defaulting
        assert!(ServeFileConfig::parse(&base(r#", "spec_source": 1"#)).is_err());
        // the flag survives into the server config
        let sc = ServeFileConfig::parse(&base(r#", "spec_source": "config""#))
            .unwrap()
            .into_server_config();
        assert!(!sc.prefer_manifest_spec);
    }

    #[test]
    fn streaming_d_and_variant_parse_and_validate() {
        let base = |block: &str| {
            format!(
                r#"{{"policy": {{"variants": [{{"name": "a", "r": 0}}]}}, "streaming": {}}}"#,
                block
            )
        };
        let cfg = ServeFileConfig::parse(&base(r#"{"d": 7, "variant": "a"}"#)).unwrap();
        let s = cfg.streaming.unwrap();
        assert_eq!(s.d, 7);
        assert_eq!(s.variant.as_deref(), Some("a"));
        // defaults: univariate, variant unset (the policy's first)
        let cfg = ServeFileConfig::parse(&base("{}")).unwrap();
        let s = cfg.streaming.unwrap();
        assert_eq!(s.d, 1);
        assert!(s.variant.is_none());
        // d = 0 and wrong types fail at parse time
        assert!(ServeFileConfig::parse(&base(r#"{"d": 0}"#)).is_err());
        assert!(ServeFileConfig::parse(&base(r#"{"variant": 3}"#)).is_err());
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "x__r0", "r": 0}]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.max_queue, 4096);
        assert_eq!(cfg.policy.variants.len(), 1);
        assert_eq!(cfg.merge_workers, 0);
        assert!(!cfg.merge.is_off(), "host premerge defaults on");
        assert_eq!(cfg.merge.k, MergeSpec::DEFAULT_K);
        assert!(cfg.streaming.is_none(), "streaming is opt-in");
    }

    #[test]
    fn parses_streaming_block() {
        // partial block: named keys override, the rest default
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]},
                "streaming": {"max_sessions": 32, "min_new": 8,
                              "policy": {"thresholds": [1.2, 0.7]}}}"#,
        )
        .unwrap();
        let s = cfg.streaming.unwrap();
        assert_eq!(s.max_sessions, 32);
        assert_eq!(s.min_new, 8);
        assert_eq!(s.raw_window, StreamingConfig::default().raw_window);
        assert_eq!(s.policy.thresholds, vec![1.2, 0.7]);
        assert_eq!(s.policy.entropy_lo, 3.0);
        s.validate().unwrap();
        // empty block = all defaults
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]}, "streaming": {}}"#,
        )
        .unwrap();
        assert_eq!(cfg.streaming.unwrap(), StreamingConfig::default());
    }

    #[test]
    fn rejects_bad_streaming_blocks() {
        let base = |block: &str| {
            format!(
                r#"{{"policy": {{"variants": [{{"name": "a", "r": 0}}]}}, "streaming": {}}}"#,
                block
            )
        };
        // unknown key, with the accepted set named
        let err = ServeFileConfig::parse(&base(r#"{"max_session": 8}"#)).unwrap_err();
        assert!(err.to_string().contains("max_session"), "{err}");
        assert!(err.to_string().contains("max_sessions"), "{err}");
        // unknown policy key
        assert!(ServeFileConfig::parse(&base(r#"{"policy": {"threshold": [0.9]}}"#)).is_err());
        // non-object block
        assert!(ServeFileConfig::parse(&base(r#""on""#)).is_err());
        // validation failures surface at parse time, naming the field
        assert!(ServeFileConfig::parse(&base(r#"{"max_sessions": 0}"#)).is_err());
        assert!(ServeFileConfig::parse(&base(r#"{"session_ttl_ms": 0}"#)).is_err());
        assert!(ServeFileConfig::parse(&base(r#"{"raw_window": 1}"#)).is_err());
        // an increasing threshold ladder merges less at higher entropy
        let err =
            ServeFileConfig::parse(&base(r#"{"policy": {"thresholds": [0.7, 0.9]}}"#)).unwrap_err();
        assert!(err.to_string().contains("non-increasing"), "{err}");
        // wrong-typed values error instead of defaulting
        assert!(ServeFileConfig::parse(&base(r#"{"max_sessions": "many"}"#)).is_err());
    }

    #[test]
    fn merge_spec_json_round_trips() {
        let specs = vec![
            MergeSpec::off(),
            MergeSpec::single(128, 16),
            MergeSpec::fixed_r(vec![16, 8, 4], 2).with_accum(Accum::F32),
            MergeSpec::fixed_r(vec![8], 1).with_causal(),
            MergeSpec::fixed_r(Vec::new(), 8),
            MergeSpec::dynamic(0.85, 4),
            MergeSpec::dynamic(0.0, 1).with_causal().with_accum(Accum::F32),
        ];
        for spec in specs {
            let json = merge_spec_to_json(&spec);
            // the emitted form survives the strict parser (unknown-key
            // rejection included) and round-trips exactly
            let text = json.to_string();
            let back =
                merge_spec_from_json(&Json::parse(&text).unwrap(), "\"round-trip\"").unwrap();
            assert_eq!(back, spec, "{text}");
        }
    }

    #[test]
    fn parses_serving_overrides() {
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "x__r0", "r": 0}]},
                "merge_workers": 6,
                "merge": {"mode": "off"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.merge_workers, 6);
        assert!(cfg.merge.is_off());
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "x__r0", "r": 0}]},
                "merge": {"mode": "fixed", "k": 3, "accum": "f32"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.merge.k, 3);
        assert_eq!(cfg.merge.accum, Accum::F32);
        // spec validation runs at parse time: k = 0 is rejected here, not
        // by a kernel assert at serve time
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "x__r0", "r": 0}]},
                "merge": {"k": 0}}"#
        )
        .is_err());
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "x__r0", "r": 0}]},
                "merge": {"mode": "dynamic", "threshold": -0.5}}"#
        )
        .is_err());
    }

    #[test]
    fn parses_per_variant_specs() {
        let cfg = ServeFileConfig::parse(
            r#"{"policy": {"variants": [
                  {"name": "a", "r": 0},
                  {"name": "b", "merge": {"mode": "fixed", "schedule": [16, 8], "k": 2, "causal": false}},
                  {"name": "c", "merge": {"mode": "dynamic", "threshold": 0.9, "k": 4}}
               ]}}"#,
        )
        .unwrap();
        let b = &cfg.policy.variants[1];
        assert_eq!(b.r(), 24);
        assert_eq!(b.spec.k, 2);
        assert!(matches!(&b.spec.mode, MergeMode::FixedR { schedule } if schedule == &vec![16, 8]));
        assert!(matches!(cfg.policy.variants[2].spec.mode, MergeMode::Dynamic { .. }));
        // "r" and "merge" together are ambiguous
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 8, "merge": {"mode": "off"}}]}}"#
        )
        .is_err());
        // fixed-r ordering is still enforced among the non-dynamic variants
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [
                  {"name": "a", "r": 32},
                  {"name": "c", "merge": {"mode": "dynamic", "threshold": 0.9}},
                  {"name": "b", "r": 8}
               ]}}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_mode_inapplicable_keys_and_serving_schedules() {
        // a threshold under mode "fixed" would be silently dead — reject it
        let err = ServeFileConfig::parse(
            r#"{"policy": {"variants": [
                  {"name": "a", "merge": {"mode": "fixed", "threshold": 0.9}}]}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("threshold"), "{err}");
        // r/schedule under "dynamic", and k under "off", likewise
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [
                  {"name": "a", "merge": {"mode": "dynamic", "threshold": 0.9, "r": 8}}]}}"#,
        )
        .is_err());
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "merge": {"mode": "off", "k": 4}}]}}"#,
        )
        .is_err());
        // the serving-level merge block derives its schedule per shape:
        // an explicit r/schedule or a dynamic mode is rejected, not ignored
        let err = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]},
                "merge": {"mode": "fixed", "r": 128}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("derived per request shape"), "{err}");
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]},
                "merge": {"mode": "dynamic", "threshold": 0.9}}"#,
        )
        .is_err());
    }

    #[test]
    fn rejects_non_object_blocks_and_schedule_free_variants() {
        // "merge": "off" (string, not an object) must not silently parse
        // as the enabled default template
        let err = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]}, "merge": "off"}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("must be a JSON object"), "{err}");
        // non-object batching likewise
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]}, "batching": 5}"#
        )
        .is_err());
        // a variant-level fixed block must say how much it merges — the
        // schedule-free template would silently read as r = 0
        let err = ServeFileConfig::parse(
            r#"{"policy": {"variants": [
                  {"name": "x__r64", "merge": {"mode": "fixed", "k": 8}}]}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("needs \"r\" or \"schedule\""), "{err}");
    }

    #[test]
    fn rejects_unknown_keys_at_every_level() {
        // root-level typo (the old name of the merge block)
        let err = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]}, "host_merge": {"k": 8}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("host_merge"), "{err}");
        assert!(err.to_string().contains("merge"), "{err}");
        // policy-level typo: entropy_low would silently default before
        let err = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}], "entropy_low": 1.0}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("entropy_low"), "{err}");
        assert!(err.to_string().contains("entropy_lo"), "{err}");
        // variant-level typo
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0, "rate": 3}]}}"#
        )
        .is_err());
        // batching-level typo
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]},
                "batching": {"max_wait": 20}}"#
        )
        .is_err());
        // merge-block typo
        let err = ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}]},
                "merge": {"mode": "fixed", "locality": 8}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("locality"), "{err}");
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(ServeFileConfig::parse(r#"{"policy": {"variants": []}}"#).is_err());
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 9}, {"name": "b", "r": 1}]}}"#
        )
        .is_err());
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}],
                "entropy_lo": 9.0, "entropy_hi": 1.0}}"#
        )
        .is_err());
        // a variant without any merge description
        assert!(ServeFileConfig::parse(r#"{"policy": {"variants": [{"name": "a"}]}}"#).is_err());
        // typed fields reject wrong JSON types instead of defaulting
        assert!(ServeFileConfig::parse(
            r#"{"policy": {"variants": [{"name": "a", "r": 0}], "entropy_lo": "low"}}"#
        )
        .is_err());
        assert!(ServeFileConfig::parse("not json").is_err());
    }

    #[test]
    fn roundtrips_into_server_config() {
        let cfg = ServeFileConfig::parse(ServeFileConfig::example()).unwrap();
        let sc = cfg.into_server_config();
        assert_eq!(sc.max_queue, 4096);
        assert!(!sc.merge.is_off());
    }
}
