//! Minimal JSON parser/serializer (offline build: no serde_json).
//!
//! Covers the full JSON grammar the artifact manifests, weights headers and
//! experiment reports need: objects, arrays, strings (with escapes),
//! numbers, booleans, null.  Object key order is preserved on parse
//! (insertion order) and sorted on write for reproducible reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
    pub fn usize_list(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- construction helpers ----------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }
    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                        let end = (start + len).min(self.b.len());
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"name":"m","params":[{"name":"w","shape":[2,3],"dtype":"f32"}],
                    "meta":{"batch":8,"enc_tokens":[192,160]}}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.req("name").unwrap().as_str().unwrap(), "m");
        let p = &v.req("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.req("shape").unwrap().usize_list().unwrap(), vec![2, 3]);
        assert_eq!(
            v.req("meta").unwrap().req("enc_tokens").unwrap().usize_list().unwrap(),
            vec![192, 160]
        );
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"\\u00e9 é\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "é é");
    }

    #[test]
    fn nested_pretty_roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::arr(vec![Json::num(1.0), Json::Bool(true)])),
            ("b", Json::obj(vec![("c", Json::Null)])),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("12 34").is_err());
    }
}
