//! Small self-contained utilities: deterministic PRNG, stats, timing.
//!
//! The build is fully offline (vendored deps only), so randomness and
//! benchmark statistics are hand-rolled here instead of pulling `rand` /
//! `criterion`.

use std::any::Any;
use std::sync::{Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

/// Lock that shrugs off poisoning: used by the pool and the serving
/// stages, where a panicking task is caught and reported but must never
/// wedge the shared state behind a poisoned mutex.
#[inline]
pub fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Best-effort extraction of a panic payload's message.  `panic!("...")`
/// carries a `&str`, `panic!("{x}")` a `String`; anything else (a custom
/// payload) gets a placeholder rather than losing the event.
pub fn panic_message(payload: &(dyn Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "<non-string panic payload>"
    }
}

/// Join a thread, annotating a panic with its payload message instead of
/// discarding it (`join().map_err(|_| ...)` loses the reason the thread
/// died — the one fact needed to debug it).
pub fn join_annotated<T>(handle: JoinHandle<T>, what: &str) -> anyhow::Result<T> {
    handle
        .join()
        .map_err(|payload| anyhow::anyhow!("{what} panicked: {}", panic_message(&*payload)))
}

/// SplitMix64 PRNG — deterministic, seedable, good enough for synthetic
/// data generation and property-test case generation.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box–Muller sample
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (u1, u2) = (self.uniform().max(1e-12), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Fork a child RNG (stable under reordering of sibling forks).
    pub fn fork(&self, stream: u64) -> Rng {
        Rng::new(self.state ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }
}

/// Running summary statistics.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: usize,
    pub sum: f64,
    pub sum_sq: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.n as f64 - m * m).max(0.0)).sqrt()
    }
}

/// Percentile of a sample set (nearest-rank).
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let idx = ((p / 100.0) * (samples.len() - 1) as f64).round() as usize;
    samples[idx.min(samples.len() - 1)]
}

/// Time a closure `iters` times after `warmup` runs; returns per-iteration
/// wall-clock seconds (mean, std).  The poor man's criterion used by the
/// bench targets (offline build: no criterion crate available).
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    let samples = bench_samples(warmup, iters, &mut f);
    let mut st = Stats::new();
    for s in samples {
        st.push(s);
    }
    (st.mean(), st.std())
}

/// Like [`bench`] but returns the raw per-iteration samples (seconds), for
/// percentile reporting (`percentile`).
pub fn bench_samples<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn rng_normal_moments() {
        let mut r = Rng::new(11);
        let mut st = Stats::new();
        for _ in 0..50_000 {
            st.push(r.normal());
        }
        assert!(st.mean().abs() < 0.03, "mean {}", st.mean());
        assert!((st.std() - 1.0).abs() < 0.03, "std {}", st.std());
    }

    #[test]
    fn fork_streams_differ() {
        let r = Rng::new(1);
        let (mut a, mut b) = (r.fork(1), r.fork(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn panic_message_downcasts_common_payloads() {
        let join = |f: fn()| std::thread::spawn(f).join().unwrap_err();
        assert_eq!(panic_message(&*join(|| panic!("static str"))), "static str");
        assert_eq!(panic_message(&*join(|| panic!("{}", 41 + 1))), "42");
        assert_eq!(
            panic_message(&*join(|| std::panic::panic_any(7u32))),
            "<non-string panic payload>"
        );
    }

    #[test]
    fn join_annotated_keeps_the_payload() {
        let h = std::thread::spawn(|| panic!("boom at step {}", 3));
        let err = join_annotated(h, "worker thread").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("worker thread panicked"), "{msg}");
        assert!(msg.contains("boom at step 3"), "{msg}");
        let ok = std::thread::spawn(|| 5usize);
        assert_eq!(join_annotated(ok, "ok thread").unwrap(), 5);
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        // nearest-rank on 100 samples: p50 -> index round(0.5*99) = 50 -> 51
        assert_eq!(percentile(&mut v, 50.0), 51.0);
        assert_eq!(percentile(&mut v, 100.0), 100.0);
        assert_eq!(percentile(&mut v, 0.0), 1.0);
    }
}
