//! Rust reference implementation of the paper's token merging (§3).
//!
//! Mirrors the Layer-2 JAX semantics exactly (same A/B split, banded
//! matching, top-r selection, size-weighted averaging, order preservation,
//! slot maps) so that:
//!
//! * the coordinator's merge-policy planner can reason about schedules
//!   without touching the runtime,
//! * property tests can check invariants over millions of random cases
//!   cheaply, and
//! * integration tests can cross-validate the HLO artifacts' probes.
//!
//! Also hosts the analytic complexity model of eq. 2 and the speed-up
//! bound of appendix B.1.

/// Result of one merge step over `t` tokens of dim `d`.
#[derive(Clone, Debug)]
pub struct MergeResult {
    /// (t - r) * d merged tokens, temporal order preserved.
    pub tokens: Vec<f32>,
    /// token sizes (number of originals each token represents)
    pub sizes: Vec<f32>,
    /// original position -> output slot (length t)
    pub slot_map: Vec<usize>,
}

/// Cosine similarity between two d-vectors.
fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..a.len() {
        dot += a[i] as f64 * b[i] as f64;
        na += (a[i] as f64).powi(2);
        nb += (b[i] as f64).powi(2);
    }
    dot / (na.sqrt() * nb.sqrt() + 1e-8)
}

/// Bipartite soft matching under locality constraint `k` (paper eq. 1).
///
/// Tokens at even positions form subset A, odd positions subset B; for each
/// A-token the best B-match within the band `|i - j| < k` is found.
/// Returns (best_score, best_j) per A-token.
pub fn match_tokens(tokens: &[f32], t: usize, d: usize, k: usize) -> (Vec<f64>, Vec<usize>) {
    let te = t - (t % 2);
    let t2 = te / 2;
    let k = k.clamp(1, t2.max(1));
    let mut scores = vec![f64::NEG_INFINITY; t2];
    let mut best = vec![0usize; t2];
    for i in 0..t2 {
        let a = &tokens[(2 * i) * d..(2 * i + 1) * d];
        let lo = i.saturating_sub(k - 1);
        let hi = (i + k - 1).min(t2 - 1);
        for j in lo..=hi {
            let b = &tokens[(2 * j + 1) * d..(2 * j + 2) * d];
            let s = cosine(a, b);
            if s > scores[i] {
                scores[i] = s;
                best[i] = j;
            }
        }
    }
    (scores, best)
}

/// Merge the `r` most similar A-tokens into their matched B-tokens
/// (size-weighted average, order-preserving) — the Rust twin of
/// `python/compile/merging.py::merge_fixed_r`.
pub fn merge_fixed_r(tokens: &[f32], sizes: &[f32], t: usize, d: usize, r: usize, k: usize) -> MergeResult {
    assert_eq!(tokens.len(), t * d);
    assert_eq!(sizes.len(), t);
    let te = t - (t % 2);
    let t2 = te / 2;
    let r = r.min(t2);
    if r == 0 {
        return MergeResult {
            tokens: tokens.to_vec(),
            sizes: sizes.to_vec(),
            slot_map: (0..t).collect(),
        };
    }
    let (scores, best) = match_tokens(tokens, t, d, k);
    // top-r A tokens by score
    let mut order: Vec<usize> = (0..t2).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut merged = vec![false; t2];
    for &i in order.iter().take(r) {
        merged[i] = true;
    }
    // output slots for kept tokens, in temporal order
    let mut slot_map = vec![0usize; t];
    let mut slot = 0usize;
    let mut kept_slot = vec![usize::MAX; t];
    for p in 0..t {
        let is_merged_a = p % 2 == 0 && p < te && merged[p / 2];
        if !is_merged_a {
            kept_slot[p] = slot;
            slot_map[p] = slot;
            slot += 1;
        }
    }
    debug_assert_eq!(slot, t - r);
    for i in 0..t2 {
        if merged[i] {
            let partner = 2 * best[i] + 1;
            slot_map[2 * i] = kept_slot[partner];
        }
    }
    // size-weighted scatter-average
    let out_t = t - r;
    let mut num = vec![0.0f64; out_t * d];
    let mut den = vec![0.0f64; out_t];
    for p in 0..t {
        let s = slot_map[p];
        let w = sizes[p] as f64;
        den[s] += w;
        for j in 0..d {
            num[s * d + j] += tokens[p * d + j] as f64 * w;
        }
    }
    let mut out = vec![0.0f32; out_t * d];
    for s in 0..out_t {
        for j in 0..d {
            out[s * d + j] = (num[s * d + j] / den[s]) as f32;
        }
    }
    MergeResult {
        tokens: out,
        sizes: den.iter().map(|&x| x as f32).collect(),
        slot_map,
    }
}

/// Clone-to-neighbours unmerge: gather rows through the slot map.
pub fn unmerge(tokens: &[f32], d: usize, slot_map: &[usize]) -> Vec<f32> {
    let mut out = vec![0.0f32; slot_map.len() * d];
    for (p, &s) in slot_map.iter().enumerate() {
        out[p * d..(p + 1) * d].copy_from_slice(&tokens[s * d..(s + 1) * d]);
    }
    out
}

/// Dynamic merging (§5.5): merge pairs whose similarity exceeds the
/// threshold; returns (tokens', sizes', effective_token_count).
pub fn merge_dynamic(tokens: &[f32], sizes: &[f32], t: usize, d: usize, k: usize, threshold: f64) -> (MergeResult, usize) {
    let te = t - (t % 2);
    let t2 = te / 2;
    let (scores, _) = match_tokens(tokens, t, d, k);
    let r = scores.iter().filter(|&&s| s > threshold).count().min(t2);
    let res = merge_fixed_r(tokens, sizes, t, d, r, k);
    let eff = t - r;
    (res, eff)
}

// ---------------------------------------------------------------------------
// Analytic models

/// Similarity-computation complexity of local merging (paper eq. 2):
/// `t/2 + (k-1)(t-k)` pairwise scores; global merging (`k = t/2`) costs
/// `t^2/4`.
pub fn similarity_complexity(t: usize, k: usize) -> usize {
    let t2 = t / 2;
    let k = k.clamp(1, t2.max(1));
    if k >= t2 {
        t2 * t2
    } else {
        t2 + (k - 1) * (t - k)
    }
}

/// Upper bound on transformer speed-up from merging half the tokens per
/// layer (appendix B.1): `3 L 4^{L-1} / (4^L - 1)`.
pub fn speedup_bound(layers: u32) -> f64 {
    let l = layers as f64;
    3.0 * l * 4f64.powi(layers as i32 - 1) / (4f64.powi(layers as i32) - 1.0)
}

/// Static merge schedule (same rule as the Python side): token counts per
/// layer for fixed `r`, floor `q`.
pub fn merge_schedule(t: usize, r: usize, num_layers: usize, q: usize) -> Vec<usize> {
    let mut counts = vec![t];
    let mut cur = t;
    for _ in 0..num_layers {
        let even = cur - (cur % 2);
        let step = r.min(even / 2).min(cur.saturating_sub(q));
        cur -= step;
        counts.push(cur);
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_tokens(rng: &mut Rng, t: usize, d: usize) -> Vec<f32> {
        (0..t * d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn merge_shapes_and_mass() {
        let mut rng = Rng::new(1);
        for &(t, d, r, k) in &[(24usize, 8usize, 4usize, 1usize), (24, 8, 8, 3), (25, 4, 6, 12)] {
            let tokens = rand_tokens(&mut rng, t, d);
            let sizes = vec![1.0f32; t];
            let res = merge_fixed_r(&tokens, &sizes, t, d, r, k);
            assert_eq!(res.tokens.len(), (t - r) * d);
            assert_eq!(res.sizes.len(), t - r);
            let total: f32 = res.sizes.iter().sum();
            assert!((total - t as f32).abs() < 1e-3);
            // weighted token sum preserved
            for j in 0..d {
                let before: f64 = (0..t).map(|p| tokens[p * d + j] as f64).sum();
                let after: f64 = (0..t - r)
                    .map(|s| res.tokens[s * d + j] as f64 * res.sizes[s] as f64)
                    .sum();
                assert!((before - after).abs() < 1e-3, "axis {j}: {before} vs {after}");
            }
        }
    }

    #[test]
    fn causal_k1_merges_adjacent_only() {
        let mut rng = Rng::new(2);
        let (t, d) = (32, 4);
        let tokens = rand_tokens(&mut rng, t, d);
        let res = merge_fixed_r(&tokens, &vec![1.0; t], t, d, 8, 1);
        for s in 0..t - 8 {
            let sources: Vec<usize> =
                (0..t).filter(|&p| res.slot_map[p] == s).collect();
            let span = sources.iter().max().unwrap() - sources.iter().min().unwrap();
            assert!(span <= 1, "slot {s} merged non-adjacent positions {sources:?}");
        }
    }

    #[test]
    fn identical_tokens_merge_losslessly() {
        let (t, d) = (16, 4);
        let tokens: Vec<f32> = (0..t * d).map(|i| ((i % d) + 1) as f32).collect();
        let res = merge_fixed_r(&tokens, &vec![1.0; t], t, d, 8, 8);
        for s in 0..t - 8 {
            for j in 0..d {
                assert!((res.tokens[s * d + j] - (j + 1) as f32).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn unmerge_restores_length() {
        let mut rng = Rng::new(3);
        let (t, d) = (20, 6);
        let tokens = rand_tokens(&mut rng, t, d);
        let res = merge_fixed_r(&tokens, &vec![1.0; t], t, d, 5, 2);
        let um = unmerge(&res.tokens, d, &res.slot_map);
        assert_eq!(um.len(), t * d);
        // kept tokens whose slot holds only them are bit-identical
        for p in 0..t {
            let s = res.slot_map[p];
            if res.sizes[s] == 1.0 {
                assert_eq!(&um[p * d..(p + 1) * d], &tokens[p * d..(p + 1) * d]);
            }
        }
    }

    #[test]
    fn dynamic_threshold_extremes() {
        let mut rng = Rng::new(4);
        let (t, d) = (16, 4);
        let tokens = rand_tokens(&mut rng, t, d);
        let (res, eff) = merge_dynamic(&tokens, &vec![1.0; t], t, d, 1, 1.1);
        assert_eq!(eff, t);
        assert_eq!(res.tokens, tokens);
        let (_, eff) = merge_dynamic(&tokens, &vec![1.0; t], t, d, 1, -1.1);
        assert_eq!(eff, t - t / 2);
    }

    #[test]
    fn complexity_matches_eq2() {
        // k = 1 -> t/2 (linear); k = t/2 -> t^2/4 (quadratic)
        assert_eq!(similarity_complexity(192, 1), 96);
        assert_eq!(similarity_complexity(192, 96), 96 * 96);
        // eq. 2 formula spot check: t=100, k=5 -> 50 + 4*95 = 430
        assert_eq!(similarity_complexity(100, 5), 430);
        // monotone in k
        let mut prev = 0;
        for k in 1..=96 {
            let c = similarity_complexity(192, k);
            assert!(c >= prev);
            prev = c;
        }
    }

    #[test]
    fn speedup_bound_values() {
        // B.1: L=1 -> 1.0; grows with L; asymptote 3L/4 slope
        assert!((speedup_bound(1) - 1.0).abs() < 1e-9);
        assert!(speedup_bound(2) > 1.5 && speedup_bound(2) < 2.0);
        assert!(speedup_bound(10) > 7.0);
        for l in 1..12 {
            assert!(speedup_bound(l + 1) > speedup_bound(l));
        }
    }

    #[test]
    fn schedule_respects_floor() {
        let s = merge_schedule(96, 16, 4, 4);
        assert_eq!(s, vec![96, 80, 64, 48, 32]);
        let s = merge_schedule(10, 100, 4, 4);
        assert_eq!(*s.last().unwrap(), 4);
    }

    #[test]
    fn matching_respects_band() {
        let mut rng = Rng::new(5);
        let (t, d, k) = (40, 4, 3);
        let tokens = rand_tokens(&mut rng, t, d);
        let (_, best) = match_tokens(&tokens, t, d, k);
        for (i, &j) in best.iter().enumerate() {
            assert!((i as isize - j as isize).unsigned_abs() < k);
        }
    }
}
