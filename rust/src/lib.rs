//! `tomers` — token merging for time series transformers & state-space
//! models: a Rust serving/training coordinator over AOT-compiled JAX +
//! Pallas artifacts (PJRT).  Reproduction of Götz et al., ICML 2025.
//!
//! Layer map (DESIGN.md):
//! * L3 (this crate): coordinator (router/batcher/merge-policy), runtime
//!   (PJRT engine), training driver, evaluation, benchmark harness, and
//!   the substrates (signal processing, synthetic datasets, cost model,
//!   Rust merging reference).
//! * L2/L1 live in `python/compile/` and arrive here as HLO-text
//!   artifacts + manifests + weights (`make artifacts`).

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod eval;
pub mod json;
pub mod merging;
pub mod runtime;
pub mod signal;
pub mod tensor;
#[cfg(feature = "pjrt")]
pub mod train;
pub mod util;
