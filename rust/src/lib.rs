//! `tomers` — token merging for time series transformers & state-space
//! models: a Rust serving/training coordinator over AOT-compiled JAX +
//! Pallas artifacts (PJRT).  Reproduction of Götz et al., ICML 2025.
//!
//! Layer map (DESIGN.md §1):
//! * L3 (this crate): the typed merge API (`merging::MergeSpec` ->
//!   `merging::MergePlan`, DESIGN.md §2) over zero-allocation kernels,
//!   coordinator (router/batcher/merge-policy, streaming decode
//!   scheduler), the streaming session subsystem
//!   (`streaming::SessionManager`, DESIGN.md §9), runtime (PJRT engine +
//!   worker pool), training driver, evaluation, benchmark harness, and
//!   the substrates (signal processing, synthetic datasets, cost model,
//!   Rust merging reference).
//! * L4 (`net`, DESIGN.md §12): the sharded TCP serving front — wire
//!   framing + protocol, consistent-hash shard router, and N independent
//!   dual serve loops behind one acceptor.
//! * L2/L1 live in `python/compile/` and arrive here as HLO-text
//!   artifacts + manifests + weights (`make artifacts`).

// Lint posture for `cargo clippy -- -D warnings` (scripts/verify.sh):
// index-loop style is deliberate in the kernels (mirrors the math and the
// Python reference).  `unknown_lints` first so older clippy versions do
// not trip over newer lint names.  The historical crate-wide
// `too_many_arguments` allow is gone: merge configuration is a typed
// `MergeSpec`/`MergePlan` (merging::spec), and the only remaining wide
// signatures are the kernel innermost layer plus the serving composition
// root (`coordinator::serve_loop::run_serve_stages`), each with a scoped,
// justified allow.
#![allow(unknown_lints)]
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod eval;
pub mod json;
pub mod merging;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod signal;
pub mod streaming;
pub mod tensor;
#[cfg(feature = "pjrt")]
pub mod train;
pub mod util;
