//! `tomers` — token merging for time series transformers & state-space
//! models: a Rust serving/training coordinator over AOT-compiled JAX +
//! Pallas artifacts (PJRT).  Reproduction of Götz et al., ICML 2025.
//!
//! Layer map (DESIGN.md):
//! * L3 (this crate): coordinator (router/batcher/merge-policy), runtime
//!   (PJRT engine), training driver, evaluation, benchmark harness, and
//!   the substrates (signal processing, synthetic datasets, cost model,
//!   Rust merging reference).
//! * L2/L1 live in `python/compile/` and arrive here as HLO-text
//!   artifacts + manifests + weights (`make artifacts`).

// Lint posture for `cargo clippy -- -D warnings` (scripts/verify.sh):
// index-loop style is deliberate in the kernels (mirrors the math and the
// Python reference), and the merge entry points take the paper's full
// parameter tuple.  `unknown_lints` first so older clippy versions do not
// trip over newer lint names.
#![allow(unknown_lints)]
#![allow(clippy::too_many_arguments, clippy::needless_range_loop, clippy::manual_div_ceil)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod cost;
pub mod data;
pub mod eval;
pub mod json;
pub mod merging;
pub mod runtime;
pub mod signal;
pub mod tensor;
#[cfg(feature = "pjrt")]
pub mod train;
pub mod util;
