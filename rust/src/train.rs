//! Training driver: Rust runs the loop, the AOT `*_train` artifact runs
//! the fused fwd+bwd+Adam update.
//!
//! Artifact contract (manifest order):
//!   inputs  = [params..., m..., v..., step, x, y]
//!   outputs = [params'..., m'..., v'..., loss]
//! where for *chunked* artifacts (meta.chunk = K > 1) the data inputs are
//! stacked `x (K, b, ...)`, `y (K, b, ...)` and the loss output is `(K,)`:
//! the graph scans K optimiser steps per execution (EXPERIMENTS.md §Perf —
//! PJRT 0.5.1 returns root tuples as a single buffer, so device-resident
//! state is impossible; chunking amortises the mandatory host round-trip
//! over K steps instead).

use anyhow::{ensure, Result};

use crate::runtime::{Model, WeightStore};
use crate::tensor::Tensor;

pub struct TrainReport {
    pub losses: Vec<f64>,
    pub steps: usize,
    pub final_weights: WeightStore,
    pub seconds: f64,
}

/// Run up to `steps` optimiser steps, pulling batches from
/// `next_batch(step)`.  `on_log(step, loss)` returning `false` stops early
/// (at chunk granularity for chunked artifacts).
pub fn train_loop(
    model: &mut Model,
    init: &WeightStore,
    steps: usize,
    mut next_batch: impl FnMut(usize) -> (Tensor, Tensor),
    mut on_log: impl FnMut(usize, f64) -> bool,
) -> Result<TrainReport> {
    let n_params = model.manifest.params.len();
    ensure!(
        model.manifest.inputs.len() == 2 * n_params + 3,
        "not a train artifact: {} inputs for {} params",
        model.manifest.inputs.len(),
        n_params
    );
    ensure!(
        model.manifest.outputs.len() == 3 * n_params + 1,
        "not a train artifact: wrong output arity"
    );
    let chunk = model
        .manifest
        .meta
        .get("chunk")
        .and_then(|c| c.as_usize().ok())
        .unwrap_or(1)
        .max(1);

    // Host-side state in manifest param order.
    let mut params: Vec<Tensor> = model
        .manifest
        .params
        .iter()
        .map(|spec| init.get(&spec.name).cloned())
        .collect::<Result<_>>()?;
    let mut m_state: Vec<Tensor> = model
        .manifest
        .params
        .iter()
        .map(|spec| Tensor::zeros_f32(&spec.shape))
        .collect();
    let mut v_state = m_state.clone();

    let t0 = std::time::Instant::now();
    let mut losses = Vec::with_capacity(steps);
    let mut done = 0usize;
    'outer: while done < steps {
        // Assemble one (possibly chunked) execution.
        let (x, y) = if chunk == 1 {
            next_batch(done)
        } else {
            let mut xs = Vec::with_capacity(chunk);
            let mut ys = Vec::with_capacity(chunk);
            for k in 0..chunk {
                let (x, y) = next_batch(done + k);
                xs.push(x);
                ys.push(y);
            }
            (Tensor::stack(&xs)?, Tensor::stack(&ys)?)
        };
        model.set_weights_ordered(&params)?;
        let mut inputs = Vec::with_capacity(2 * n_params + 3);
        inputs.extend(m_state.iter().cloned());
        inputs.extend(v_state.iter().cloned());
        inputs.push(Tensor::scalar_f32(done as f32));
        inputs.push(x);
        inputs.push(y);
        let outs = model.execute(&inputs)?;
        params = outs[..n_params].to_vec();
        m_state = outs[n_params..2 * n_params].to_vec();
        v_state = outs[2 * n_params..3 * n_params].to_vec();
        let loss_out = outs[3 * n_params].f32s()?;
        // chunked artifacts quantize the step count up to a chunk multiple:
        // every loss in the chunk was computed, so all are recorded.
        let mut stop = false;
        for &loss in loss_out.iter().take(chunk) {
            losses.push(loss as f64);
            done += 1;
            if !on_log(done - 1, loss as f64) {
                stop = true;
            }
        }
        if stop || done >= steps {
            break 'outer;
        }
    }

    let mut final_weights = WeightStore::default();
    for (spec, t) in model.manifest.params.iter().zip(&params) {
        final_weights.insert(spec.name.clone(), t.clone());
    }
    // Leave the trained weights bound for immediate evaluation.
    model.set_weights_ordered(&params)?;
    Ok(TrainReport { losses, steps: done, final_weights, seconds: t0.elapsed().as_secs_f64() })
}

/// Simple early-stopping helper (patience on a smoothed loss).
pub struct EarlyStop {
    best: f64,
    since_best: usize,
    patience: usize,
}

impl EarlyStop {
    pub fn new(patience: usize) -> EarlyStop {
        EarlyStop { best: f64::INFINITY, since_best: 0, patience }
    }

    /// Feed a metric; returns `false` when patience is exhausted.
    pub fn keep_going(&mut self, metric: f64) -> bool {
        if metric < self.best - 1e-9 {
            self.best = metric;
            self.since_best = 0;
        } else {
            self.since_best += 1;
        }
        self.since_best <= self.patience
    }

    pub fn best(&self) -> f64 {
        self.best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn early_stop_triggers_after_patience() {
        let mut es = EarlyStop::new(2);
        assert!(es.keep_going(1.0));
        assert!(es.keep_going(0.9));
        assert!(es.keep_going(0.95)); // 1 since best
        assert!(es.keep_going(0.94)); // 2 since best
        assert!(!es.keep_going(0.96)); // 3 -> stop
        assert_eq!(es.best(), 0.9);
    }

    #[test]
    fn early_stop_resets_on_improvement() {
        let mut es = EarlyStop::new(1);
        assert!(es.keep_going(1.0));
        assert!(es.keep_going(1.1));
        assert!(es.keep_going(0.5)); // improvement resets
        assert!(es.keep_going(0.6));
        assert!(!es.keep_going(0.7));
    }
}
