//! [`StreamSession`]: one live stream — raw-observation ring plus
//! incremental causal merge state.
//!
//! A session's hot path is [`StreamSession::append`]: push the points
//! into the bounded raw ring (recent history for re-probing and
//! re-routing) and feed them through the
//! [`IncrementalMerge`](crate::merging::IncrementalMerge) state — O(n)
//! per `n` appended points, never a function of the stream's age.  The
//! decode path reads the merged representation's tail
//! ([`StreamSession::context_into`]) without touching raw history.

use std::time::Instant;

use anyhow::Result;

use crate::merging::{IncrementalMerge, MergeSpec};

/// Fixed-capacity ring of the most recent raw observations.
#[derive(Clone, Debug)]
pub struct RawRing {
    buf: Vec<f32>,
    capacity: usize,
    /// index of the oldest element (valid once `len == capacity`)
    head: usize,
    len: usize,
}

impl RawRing {
    pub fn new(capacity: usize) -> RawRing {
        RawRing { buf: vec![0.0; capacity.max(1)], capacity: capacity.max(1), head: 0, len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Push points, overwriting the oldest once full.
    pub fn push(&mut self, points: &[f32]) {
        for &p in points {
            if self.len < self.capacity {
                self.buf[(self.head + self.len) % self.capacity] = p;
                self.len += 1;
            } else {
                self.buf[self.head] = p;
                self.head = (self.head + 1) % self.capacity;
            }
        }
    }

    /// Copy the retained window, oldest first, into `out`.
    pub fn copy_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.len);
        for i in 0..self.len {
            out.push(self.buf[(self.head + i) % self.capacity]);
        }
    }
}

/// A long-lived stream of `d`-channel frames: bounded raw history +
/// incremental causal merged representation + decode-readiness
/// bookkeeping.  All frame counters (`appended`, `since_*`, readiness)
/// count *frames*, not scalars, so a multivariate session becomes
/// decode-ready at the same cadence a univariate one does.
#[derive(Debug)]
pub struct StreamSession {
    pub id: u64,
    merge: IncrementalMerge,
    /// scalar ring holding `raw_window * d` values — pushes are whole
    /// frames (multiples of `d`) and the capacity is a multiple of `d`,
    /// so frame boundaries stay aligned under wraparound
    ring: RawRing,
    /// total frames ever appended (outlives the ring)
    appended: u64,
    /// frames since the last spectral probe
    since_probe: usize,
    /// frames since the last decode step served this session
    since_new: usize,
    /// monotonic sequence at which the session crossed `min_new`
    /// (None = not ready); drives FIFO-fair decode scheduling
    ready_since: Option<u64>,
    /// wall-clock twin of `ready_since`: when the oldest currently
    /// unserved point arrived (drives the partial-batch flush deadline)
    ready_at: Option<Instant>,
    /// wall-clock of the last append/decode (TTL eviction)
    pub last_touch: Instant,
    /// monotonic touch sequence (LRU eviction, no clock reads)
    pub touch_seq: u64,
    /// regime changes this session went through
    reroutes: u32,
    /// consecutive faulted decode steps (reset on a successful decode;
    /// the manager quarantines the session past its budget)
    fault_count: u32,
    /// frames consumed by the last decode step — restorable by
    /// [`StreamSession::restore_window`] when that step faults
    last_window: usize,
}

impl StreamSession {
    /// A fresh session of `d`-channel frames merging under `spec`
    /// (derived by the manager from the admission probe), retaining
    /// `raw_window` raw frames.
    pub fn new(
        id: u64,
        spec: MergeSpec,
        d: usize,
        raw_window: usize,
        now: Instant,
    ) -> Result<StreamSession> {
        Ok(StreamSession {
            id,
            merge: IncrementalMerge::new(spec, d)?,
            ring: RawRing::new(raw_window.max(1) * d.max(1)),
            appended: 0,
            since_probe: 0,
            since_new: 0,
            ready_since: None,
            ready_at: None,
            last_touch: now,
            touch_seq: 0,
            reroutes: 0,
            fault_count: 0,
            last_window: 0,
        })
    }

    /// The session's current merge spec.
    pub fn spec(&self) -> &MergeSpec {
        self.merge.spec()
    }

    /// The incremental merge state (read-only).
    pub fn merge(&self) -> &IncrementalMerge {
        &self.merge
    }

    /// Channels per frame (token dimensionality).
    pub fn d(&self) -> usize {
        self.merge.d()
    }

    /// Total frames appended over the session's lifetime.
    pub fn appended(&self) -> u64 {
        self.appended
    }

    /// Frames appended since the last probe (manager-internal cadence).
    pub fn since_probe(&self) -> usize {
        self.since_probe
    }

    /// Regime changes (re-routes) so far.
    pub fn reroutes(&self) -> u32 {
        self.reroutes
    }

    /// Merged tokens currently held.
    pub fn merged_len(&self) -> usize {
        self.merge.len()
    }

    /// The retained raw window, oldest first (re-probe / re-route input).
    pub fn raw_window_into(&self, out: &mut Vec<f32>) {
        self.ring.copy_into(out);
    }

    /// Append observations (`points.len()` must be a whole number of
    /// `d`-channel frames — the manager rejects ragged appends before
    /// calling): ring + incremental merge, O(points).  `max_merged`
    /// bounds the merged representation (front-trimmed).
    pub fn append(&mut self, points: &[f32], max_merged: usize, now: Instant, seq: u64) {
        let frames = points.len() / self.merge.d();
        debug_assert_eq!(points.len() % self.merge.d(), 0, "ragged append reached the session");
        self.ring.push(points);
        self.merge.append(points);
        self.merge.trim_front(max_merged);
        self.appended += frames as u64;
        self.since_probe += frames;
        self.since_new += frames;
        self.last_touch = now;
        self.touch_seq = seq;
        // an empty append is a touch (keep-alive), not unserved data — it
        // must not date the FIFO/flush-deadline keys
        if frames > 0 && self.ready_since.is_none() {
            self.ready_since = Some(seq);
            self.ready_at = Some(now);
        }
    }

    /// Whether a decode step should include this session: at least
    /// `min_new` unserved frames.
    pub fn is_ready(&self, min_new: usize) -> bool {
        self.since_new >= min_new
    }

    /// The touch sequence at which this session first accumulated
    /// unserved points (FIFO decode fairness key).
    pub fn ready_since(&self) -> Option<u64> {
        self.ready_since
    }

    /// Wall-clock arrival of the oldest unserved point (the decode
    /// scheduler's flush-deadline key).
    pub fn ready_at(&self) -> Option<Instant> {
        self.ready_at
    }

    /// Mark the session served by a decode step.  The consumed window is
    /// remembered so a faulted step can restore it
    /// ([`StreamSession::restore_window`]).  The fault count is *not*
    /// touched here — a step's fate is unknown at assembly time; the
    /// manager clears it via [`StreamSession::decode_succeeded`] when the
    /// step's buffer comes back clean.
    pub fn mark_decoded(&mut self, now: Instant, seq: u64) {
        self.last_window = self.since_new;
        self.since_new = 0;
        self.ready_since = None;
        self.ready_at = None;
        self.last_touch = now;
        self.touch_seq = seq;
    }

    /// A decode step containing this session completed normally: the
    /// consecutive-fault count resets (the budget is for *consecutive*
    /// faults; sporadic recovered faults must not accumulate into an
    /// eviction over a long-lived session).
    pub fn decode_succeeded(&mut self) {
        self.fault_count = 0;
    }

    /// Restore the window consumed by the last (faulted) decode step so
    /// the next step retries it, and count the fault.  Returns the
    /// consecutive-fault count, which the manager checks against the
    /// session's fault budget.  Idempotent per decode: a second call
    /// without an intervening [`StreamSession::mark_decoded`] restores
    /// nothing more (the window is already back).
    pub fn restore_window(&mut self, now: Instant, seq: u64) -> u32 {
        self.since_new += self.last_window;
        self.last_window = 0;
        if self.since_new > 0 && self.ready_since.is_none() {
            self.ready_since = Some(seq);
            self.ready_at = Some(now);
        }
        self.last_touch = now;
        self.touch_seq = seq;
        self.fault_count += 1;
        self.fault_count
    }

    /// Consecutive faulted decode steps (0 after any successful one).
    pub fn fault_count(&self) -> u32 {
        self.fault_count
    }

    /// Assemble the decode input row: the last `size_row.len()` merged
    /// tokens right-aligned into `row` (`m * d` interleaved values) with
    /// one size per token in `size_row` (padding sizes 0 — the size-array
    /// form that lets sessions at different fill levels share one batch).
    /// Returns the real-token fill.
    pub fn context_into(&self, row: &mut [f32], size_row: &mut [f32]) -> usize {
        self.merge.context_tail_into(row, size_row)
    }

    /// Switch the session to a new merge spec (regime change): the merged
    /// history is rebuilt by replaying `window` — the retained raw window
    /// the caller already materialized via
    /// [`StreamSession::raw_window_into`] (the manager's re-probe path
    /// has it in hand, so replay never re-copies the ring) — so the new
    /// regime's representation covers exactly what the ring still holds.
    pub fn reroute(&mut self, spec: MergeSpec, max_merged: usize, window: &[f32]) -> Result<()> {
        let mut fresh = IncrementalMerge::new(spec, self.merge.d())?;
        fresh.append(window);
        fresh.trim_front(max_merged);
        self.merge = fresh;
        self.reroutes += 1;
        Ok(())
    }

    /// Reset the probe cadence counter (manager calls this after probing).
    pub fn probe_done(&mut self) {
        self.since_probe = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::MergeSpec;

    fn causal(th: f64) -> MergeSpec {
        MergeSpec::dynamic(th, 1).with_causal()
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut r = RawRing::new(4);
        r.push(&[1.0, 2.0, 3.0]);
        let mut out = Vec::new();
        r.copy_into(&mut out);
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
        r.push(&[4.0, 5.0, 6.0]);
        r.copy_into(&mut out);
        assert_eq!(out, vec![3.0, 4.0, 5.0, 6.0]);
        assert_eq!(r.len(), 4);
        // pushing more than capacity in one call keeps the newest tail
        r.push(&[7.0, 8.0, 9.0, 10.0, 11.0]);
        r.copy_into(&mut out);
        assert_eq!(out, vec![8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn readiness_follows_min_new() {
        let now = Instant::now();
        let mut s = StreamSession::new(1, causal(1.5), 1, 64, now).unwrap();
        assert!(!s.is_ready(4));
        s.append(&[1.0, 2.0, 3.0], 1024, now, 1);
        assert!(!s.is_ready(4));
        s.append(&[4.0], 1024, now, 2);
        assert!(s.is_ready(4));
        assert_eq!(s.ready_since(), Some(1), "readiness dates from the first unserved point");
        s.mark_decoded(now, 3);
        assert!(!s.is_ready(4));
        assert_eq!(s.ready_since(), None);
        // an empty append is a keep-alive touch: it must not date the
        // FIFO key or the flush deadline ahead of real data
        s.append(&[], 1024, now, 4);
        assert_eq!(s.ready_since(), None, "empty append must not look like unserved data");
        assert!(s.ready_at().is_none());
        assert_eq!(s.touch_seq, 4, "but it does refresh the TTL/LRU touch");
        s.append(&[5.0], 1024, now, 5);
        assert_eq!(s.ready_since(), Some(5), "readiness dates from the first real point");
    }

    #[test]
    fn reroute_replays_the_ring() {
        let now = Instant::now();
        // threshold 1.5: nothing merges, merged rep == raw history
        let mut s = StreamSession::new(2, causal(1.5), 1, 8, now).unwrap();
        for i in 0..20 {
            s.append(&[i as f32], 1024, now, i);
        }
        assert_eq!(s.merged_len(), 20);
        // reroute to threshold 0.0 (merge everything similar): the new
        // state covers exactly the ring's 8 retained points (the caller
        // materializes the window; reroute replays it without re-copying)
        let mut scratch = Vec::new();
        s.raw_window_into(&mut scratch);
        s.reroute(causal(0.0), 1024, &scratch).unwrap();
        assert_eq!(s.merge().raw_len(), 8);
        assert_eq!(s.reroutes(), 1);
        // monotone ramp: adjacent cosine = 1 > 0 ⇒ all 4 pairs merge
        assert_eq!(s.merged_len(), 4);
    }

    #[test]
    fn append_is_bounded_by_max_merged() {
        let now = Instant::now();
        let mut s = StreamSession::new(3, causal(1.5), 1, 16, now).unwrap();
        for i in 0..100 {
            s.append(&[i as f32, (i + 1) as f32], 10, now, i);
            assert!(s.merged_len() <= 10);
        }
        assert_eq!(s.appended(), 200);
    }

    #[test]
    fn restore_window_reverses_mark_decoded() {
        let now = Instant::now();
        let mut s = StreamSession::new(5, causal(1.5), 1, 64, now).unwrap();
        s.append(&[1.0, 2.0, 3.0, 4.0, 5.0], 1024, now, 1);
        assert!(s.is_ready(4));
        s.mark_decoded(now, 2);
        assert!(!s.is_ready(4));
        // the step faulted: the 5-frame window comes back, readiness too
        assert_eq!(s.restore_window(now, 3), 1);
        assert!(s.is_ready(4));
        assert_eq!(s.ready_since(), Some(3));
        // idempotent per decode: a duplicate restore adds nothing
        assert_eq!(s.restore_window(now, 4), 2, "but the fault still counts");
        assert_eq!(s.fault_count(), 2);
        // consecutive-fault accounting resets only on a *completed* step
        s.mark_decoded(now, 5);
        assert_eq!(s.fault_count(), 2, "assembly alone must not reset the count");
        s.decode_succeeded();
        assert_eq!(s.fault_count(), 0);
        // restored frames merge with newly appended ones
        s.append(&[6.0], 1024, now, 6);
        s.restore_window(now, 7);
        assert!(s.is_ready(4), "5 restored + 1 new frames ready again");
    }

    #[test]
    fn multivariate_sessions_count_frames_not_scalars() {
        let now = Instant::now();
        let mut s = StreamSession::new(4, causal(1.5), 3, 8, now).unwrap();
        assert_eq!(s.d(), 3);
        // 2 frames of 3 channels = 6 scalars
        s.append(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 1024, now, 1);
        assert_eq!(s.appended(), 2, "readiness cadence counts frames");
        assert!(!s.is_ready(3));
        s.append(&[7.0, 8.0, 9.0], 1024, now, 2);
        assert!(s.is_ready(3));
        // the ring retains raw_window *frames* (8 * 3 scalars)
        for i in 0..20 {
            s.append(&[i as f32; 3], 1024, now, 3 + i as u64);
        }
        let mut window = Vec::new();
        s.raw_window_into(&mut window);
        assert_eq!(window.len(), 8 * 3);
        assert_eq!(&window[21..24], &[19.0, 19.0, 19.0]);
        // decode rows carry m*d values with one size per frame
        let (mut row, mut sz) = (vec![0.0f32; 4 * 3], vec![0.0f32; 4]);
        let fill = s.context_into(&mut row, &mut sz);
        assert_eq!(fill, 4);
        assert_eq!(&row[9..12], &[19.0, 19.0, 19.0]);
        assert!(sz.iter().all(|&x| x > 0.0));
    }
}
