//! [`SessionManager`]: the bounded session table.
//!
//! * **Admission** — a new session's merge spec is derived from the
//!   spectral predictors (paper §6.2, table 4): entropy of the initial
//!   context, measured through the serving layer's bounded-prefix
//!   memoized [`EntropyCache`], mapped through the
//!   [`StreamPolicy`](super::StreamPolicy) ladder.  The memo pays off
//!   on replayed admission contexts (retries, reconnects); *re-probes*
//!   analyze a sliding window whose bytes change between probes, so
//!   they bypass the cache entirely (a lookup would always miss while
//!   its insertion evicts the reusable admission memos) and pay one
//!   direct bounded-prefix FFT — amortized to negligible by the
//!   `reprobe_every` cadence, which is the actual cost control there.
//! * **Bounded capacity** — admitting past `max_sessions` evicts the
//!   least-recently-touched session (monotonic touch sequence, no clock
//!   reads on the hot path); idle sessions past `session_ttl` are evicted
//!   by [`SessionManager::evict_expired`].  Under churn the table and the
//!   per-session rings are the only state, so memory stays bounded by
//!   `max_sessions * (raw_window + max_merged)` floats (asserted in
//!   `tests/streaming_sessions.rs`).
//! * **Re-probing** — every `reprobe_every` appended points a session's
//!   retained raw window is re-probed; a changed spec re-routes the
//!   session (its merged history is rebuilt from the window, counting a
//!   regime change).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::session::StreamSession;
use super::StreamingConfig;
use crate::coordinator::policy::EntropyCache;

/// Counters the manager accumulates; snapshot into the serving metrics
/// via [`SessionManager::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamStats {
    pub admitted: u64,
    pub evicted_capacity: u64,
    pub evicted_ttl: u64,
    pub reroutes: u64,
    pub probes: u64,
    pub appended_points: u64,
}

/// Outcome of one [`SessionManager::append`] call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AppendOutcome {
    /// a re-probe ran on this append
    pub probed: bool,
    /// the probe changed the session's merge spec (regime change)
    pub rerouted: bool,
}

/// Bounded table of live [`StreamSession`]s.  See the module docs.
pub struct SessionManager {
    cfg: StreamingConfig,
    sessions: HashMap<u64, StreamSession>,
    /// admission-context memo only — re-probes go around it (see
    /// [`SessionManager::append`]), so reconnect/retry memos are not
    /// evicted by sliding-window churn
    entropy: EntropyCache,
    /// leading samples a probe analyzes (flat FFT cost; shared between
    /// the admission cache and the direct re-probe path)
    probe_prefix: usize,
    /// monotonic touch sequence (LRU order + FIFO decode fairness)
    seq: u64,
    stats: StreamStats,
    /// reusable probe/replay buffer
    scratch: Vec<f32>,
}

impl SessionManager {
    pub fn new(cfg: StreamingConfig) -> Result<SessionManager> {
        cfg.validate()?;
        // Bounded-prefix cap: flat probe cost however long the admission
        // context is.  Floor 256 so the achievable entropy (~log2(n/2)
        // bits) clears the default ladder's top band even when the raw
        // window is configured tiny; ceiling keeps the probe FFT cheap.
        let prefix_cap = cfg.raw_window.clamp(256, 16384);
        let capacity = cfg.max_sessions.min(4096);
        Ok(SessionManager {
            cfg,
            sessions: HashMap::new(),
            entropy: EntropyCache::new(capacity, prefix_cap),
            probe_prefix: prefix_cap,
            seq: 0,
            stats: StreamStats::default(),
            scratch: Vec::new(),
        })
    }

    pub fn config(&self) -> &StreamingConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    pub fn session(&self, id: u64) -> Option<&StreamSession> {
        self.sessions.get(&id)
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Admit a new session: probe the initial context, derive its merge
    /// spec, evict (TTL first, then LRU) if the table is full, then
    /// append the initial points.  Errs on a duplicate id.
    pub fn admit(&mut self, id: u64, initial: &[f32], now: Instant) -> Result<()> {
        ensure!(!self.sessions.contains_key(&id), "session {id} already admitted");
        self.evict_expired(now);
        while self.sessions.len() >= self.cfg.max_sessions {
            let lru = self
                .sessions
                .values()
                .min_by_key(|s| s.touch_seq)
                .map(|s| s.id)
                .expect("non-empty table");
            self.sessions.remove(&lru);
            self.stats.evicted_capacity += 1;
        }
        let entropy = self.entropy.entropy(initial);
        self.stats.probes += 1;
        let spec = self.cfg.policy.spec_for(entropy);
        let mut session = StreamSession::new(id, spec, self.cfg.raw_window, now)?;
        let seq = self.next_seq();
        if !initial.is_empty() {
            session.append(initial, self.cfg.max_merged, now, seq);
            self.stats.appended_points += initial.len() as u64;
        } else {
            session.touch_seq = seq;
        }
        session.probe_done();
        self.sessions.insert(id, session);
        self.stats.admitted += 1;
        Ok(())
    }

    /// Append observations to a session (admitting it first if unknown —
    /// the streaming intake path).  Re-probes every
    /// [`StreamingConfig::reprobe_every`] points and re-routes on a
    /// regime change.
    pub fn append(&mut self, id: u64, points: &[f32], now: Instant) -> Result<AppendOutcome> {
        if !self.sessions.contains_key(&id) {
            self.admit(id, points, now)?;
            return Ok(AppendOutcome::default());
        }
        let seq = self.next_seq();
        let SessionManager { cfg, sessions, probe_prefix, stats, scratch, .. } = self;
        let session = sessions.get_mut(&id).expect("checked above");
        session.append(points, cfg.max_merged, now, seq);
        stats.appended_points += points.len() as u64;
        let mut outcome = AppendOutcome::default();
        if session.since_probe() >= cfg.reprobe_every {
            outcome.probed = true;
            stats.probes += 1;
            session.raw_window_into(scratch);
            // Direct bounded-prefix entropy, NOT the cache: a sliding
            // window's bytes differ from every previous probe, so a
            // cache lookup would always miss while its insertion evicts
            // the reusable admission memos.  Cost is one prefix FFT per
            // `reprobe_every` points — the cadence is the cost control.
            let prefix = &scratch[..scratch.len().min(*probe_prefix)];
            let e = crate::signal::spectral_entropy(prefix);
            let spec = cfg.policy.spec_for(e);
            if &spec != session.spec() {
                session.reroute(spec, cfg.max_merged, scratch)?;
                stats.reroutes += 1;
                outcome.rerouted = true;
            }
            session.probe_done();
        }
        Ok(outcome)
    }

    /// Evict sessions idle past the TTL; returns how many went.
    pub fn evict_expired(&mut self, now: Instant) -> usize {
        let ttl = self.cfg.session_ttl;
        let before = self.sessions.len();
        self.sessions.retain(|_, s| now.duration_since(s.last_touch) < ttl);
        let evicted = before - self.sessions.len();
        self.stats.evicted_ttl += evicted as u64;
        evicted
    }

    /// Number of decode-ready sessions (count only — no allocation or
    /// ordering; the scheduler polls this every few milliseconds).
    pub fn ready_count(&self) -> usize {
        let min_new = self.cfg.min_new;
        self.sessions.values().filter(|s| s.is_ready(min_new)).count()
    }

    /// Wall-clock arrival of the oldest unserved point across all ready
    /// sessions — the scheduler's partial-batch flush deadline.  `None`
    /// when nothing is ready.
    pub fn oldest_ready_at(&self) -> Option<Instant> {
        let min_new = self.cfg.min_new;
        self.sessions
            .values()
            .filter(|s| s.is_ready(min_new))
            .filter_map(|s| s.ready_at())
            .min()
    }

    /// Collect up to `max` decode-ready sessions, FIFO by the sequence at
    /// which each first accumulated unserved points — a hot session
    /// cannot starve one that has been waiting longer.
    pub fn take_ready(&self, max: usize, out: &mut Vec<u64>) {
        out.clear();
        let min_new = self.cfg.min_new;
        let mut ready: Vec<(u64, u64)> = self
            .sessions
            .values()
            .filter(|s| s.is_ready(min_new))
            .map(|s| (s.ready_since().expect("ready implies a since-seq"), s.id))
            .collect();
        ready.sort_unstable();
        out.extend(ready.into_iter().take(max).map(|(_, id)| id));
    }

    /// Assemble one decode row for a session (delegates to
    /// [`StreamSession::context_into`]).  An unknown id — impossible when
    /// the id came from [`SessionManager::take_ready`] under the same
    /// borrow — zeroes the row and reports fill 0, so a pool-parallel
    /// slab fill never panics mid-batch.
    pub fn context_fill(&self, id: u64, row: &mut [f32], size_row: &mut [f32]) -> usize {
        match self.sessions.get(&id) {
            Some(s) => s.context_into(row, size_row),
            None => {
                row.fill(0.0);
                size_row.fill(0.0);
                0
            }
        }
    }

    /// Mark sessions served by a completed decode step.
    pub fn mark_decoded(&mut self, ids: &[u64], now: Instant) {
        let seq = self.next_seq();
        for id in ids {
            if let Some(s) = self.sessions.get_mut(id) {
                s.mark_decoded(now, seq);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::time::Duration;

    fn cfg(max_sessions: usize) -> StreamingConfig {
        StreamingConfig {
            max_sessions,
            session_ttl: Duration::from_secs(3600),
            reprobe_every: 64,
            raw_window: 128,
            max_merged: 256,
            min_new: 4,
            ..StreamingConfig::default()
        }
    }

    fn noise(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn admission_derives_spec_from_entropy() {
        let mut m = SessionManager::new(cfg(8)).unwrap();
        let now = Instant::now();
        // clean sine: low entropy -> conservative band (off by default)
        let sine: Vec<f32> = (0..128)
            .map(|i| (2.0 * std::f64::consts::PI * 4.0 * i as f64 / 128.0).sin() as f32)
            .collect();
        m.admit(1, &sine, now).unwrap();
        assert!(m.session(1).unwrap().spec().is_off());
        // noise: high entropy -> aggressive causal dynamic
        let mut rng = Rng::new(5);
        m.admit(2, &noise(&mut rng, 128), now).unwrap();
        let spec = m.session(2).unwrap().spec().clone();
        assert!(!spec.is_off());
        assert!(spec.causal && spec.k == 1);
        assert!(m.admit(1, &sine, now).is_err(), "duplicate admission");
    }

    #[test]
    fn capacity_evicts_lru() {
        let mut m = SessionManager::new(cfg(3)).unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(7);
        for id in 0..3 {
            m.admit(id, &noise(&mut rng, 32), now).unwrap();
        }
        // touch 0 so 1 becomes the LRU
        m.append(0, &[1.0], now).unwrap();
        m.admit(99, &noise(&mut rng, 32), now).unwrap();
        assert_eq!(m.len(), 3);
        assert!(m.session(1).is_none(), "LRU session must be the one evicted");
        assert!(m.session(0).is_some() && m.session(99).is_some());
        assert_eq!(m.stats().evicted_capacity, 1);
    }

    #[test]
    fn ttl_evicts_idle_sessions() {
        let mut m = SessionManager::new(StreamingConfig {
            session_ttl: Duration::from_millis(5),
            ..cfg(8)
        })
        .unwrap();
        let t0 = Instant::now();
        let mut rng = Rng::new(9);
        m.admit(1, &noise(&mut rng, 16), t0).unwrap();
        assert_eq!(m.evict_expired(t0), 0);
        assert_eq!(m.evict_expired(t0 + Duration::from_millis(10)), 1);
        assert!(m.is_empty());
        assert_eq!(m.stats().evicted_ttl, 1);
    }

    #[test]
    fn reprobe_reroutes_on_regime_change() {
        let mut m = SessionManager::new(StreamingConfig {
            reprobe_every: 64,
            raw_window: 128,
            ..cfg(4)
        })
        .unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(11);
        // admitted on noise: aggressive merging
        m.admit(1, &noise(&mut rng, 128), now).unwrap();
        assert!(!m.session(1).unwrap().spec().is_off());
        // regime change: feed a pure sine until the window is clean
        let sine: Vec<f32> = (0..64)
            .map(|i| (2.0 * std::f64::consts::PI * 2.0 * i as f64 / 64.0).sin() as f32)
            .collect();
        let mut rerouted = false;
        for _ in 0..4 {
            rerouted |= m.append(1, &sine, now).unwrap().rerouted;
        }
        assert!(rerouted, "a clean window must re-route the session");
        assert!(m.session(1).unwrap().spec().is_off());
        assert!(m.stats().reroutes >= 1);
        // the rebuilt state covers the retained window only
        assert!(m.session(1).unwrap().merge().raw_len() <= 128);
    }

    #[test]
    fn take_ready_is_fifo_fair() {
        let mut m = SessionManager::new(cfg(8)).unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(13);
        for id in [10, 20, 30] {
            m.admit(id, &noise(&mut rng, 8), now).unwrap();
        }
        // all ready (admission appended 8 >= min_new 4); FIFO = admission order
        let mut ids = Vec::new();
        m.take_ready(2, &mut ids);
        assert_eq!(ids, vec![10, 20]);
        m.mark_decoded(&ids, now);
        m.take_ready(8, &mut ids);
        assert_eq!(ids, vec![30]);
        // 30 decoded; now 10 appends again and becomes the only ready one
        m.mark_decoded(&[30], now);
        m.append(10, &noise(&mut rng, 4), now).unwrap();
        m.take_ready(8, &mut ids);
        assert_eq!(ids, vec![10]);
    }
}
