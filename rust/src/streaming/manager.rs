//! [`SessionManager`]: the bounded session table.
//!
//! * **Admission** — a new session's merge spec is derived from the
//!   spectral predictors (paper §6.2, table 4): entropy of the initial
//!   context, measured through the serving layer's bounded-prefix
//!   memoized [`EntropyCache`], mapped through the
//!   [`StreamPolicy`](super::StreamPolicy) ladder.  The memo pays off
//!   on replayed admission contexts (retries, reconnects); *re-probes*
//!   analyze a sliding window whose bytes change between probes, so
//!   they bypass the cache entirely (a lookup would always miss while
//!   its insertion evicts the reusable admission memos) and pay one
//!   direct bounded-prefix FFT — amortized to negligible by the
//!   `reprobe_every` cadence, which is the actual cost control there.
//! * **Bounded capacity** — admitting past `max_sessions` evicts the
//!   least-recently-touched session (monotonic touch sequence, no clock
//!   reads on the hot path); idle sessions past `session_ttl` are evicted
//!   by [`SessionManager::evict_expired`].  Under churn the table and the
//!   per-session rings are the only state, so memory stays bounded by
//!   `max_sessions * (raw_window + max_merged) * d` floats (asserted in
//!   `tests/streaming_sessions.rs`).
//! * **Re-probing** — every `reprobe_every` appended points a session's
//!   retained raw window is re-probed; a changed spec re-routes the
//!   session (its merged history is rebuilt from the window, counting a
//!   regime change).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::session::StreamSession;
use super::StreamingConfig;
use crate::coordinator::policy::EntropyCache;

/// Counters the manager accumulates; snapshot into the serving metrics
/// via [`SessionManager::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamStats {
    pub admitted: u64,
    pub evicted_capacity: u64,
    pub evicted_ttl: u64,
    pub reroutes: u64,
    pub probes: u64,
    /// appended frames (a `d`-channel frame counts once)
    pub appended_points: u64,
    /// windows restored after a faulted decode step (DESIGN.md §10)
    pub requeued_windows: u64,
    /// sessions evicted for exhausting their consecutive-fault budget
    pub quarantined: u64,
}

/// Outcome of one [`SessionManager::append`] call.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AppendOutcome {
    /// a re-probe ran on this append
    pub probed: bool,
    /// the probe changed the session's merge spec (regime change)
    pub rerouted: bool,
}

/// Bounded table of live [`StreamSession`]s.  See the module docs.
///
/// **Multivariate sessions** (the homogeneous-`d` design, DESIGN.md §9):
/// the manager's [`StreamingConfig::d`] fixes one channel count for every
/// session it admits, matching the serving artifact's shape — so every
/// decode batch is homogeneous in `d` by construction, and an append
/// whose length is not a whole number of `d`-channel frames is rejected
/// with an error (never silently reinterpreted).  Spectral probes reduce
/// a multivariate window to one series by averaging channels per frame
/// before the entropy FFT.
pub struct SessionManager {
    cfg: StreamingConfig,
    sessions: HashMap<u64, StreamSession>,
    /// admission-context memo only — re-probes go around it (see
    /// [`SessionManager::append`]), so reconnect/retry memos are not
    /// evicted by sliding-window churn
    entropy: EntropyCache,
    /// leading frames a probe analyzes (flat FFT cost; shared between
    /// the admission cache and the direct re-probe path)
    probe_prefix: usize,
    /// monotonic touch sequence (LRU order + FIFO decode fairness)
    seq: u64,
    stats: StreamStats,
    /// reusable probe/replay buffer (interleaved frames)
    scratch: Vec<f32>,
    /// reusable channel-reduced probe series (`d > 1` only)
    reduced: Vec<f32>,
}

/// Average the channels of each `d`-channel frame into one value — the
/// univariate reduction the spectral probe analyzes for multivariate
/// sessions (`d == 1` is the identity copy).
fn reduce_channels(interleaved: &[f32], d: usize, out: &mut Vec<f32>) {
    out.clear();
    if d == 1 {
        out.extend_from_slice(interleaved);
        return;
    }
    out.reserve(interleaved.len() / d);
    for frame in interleaved.chunks_exact(d) {
        out.push(frame.iter().sum::<f32>() / d as f32);
    }
}

impl SessionManager {
    pub fn new(cfg: StreamingConfig) -> Result<SessionManager> {
        cfg.validate()?;
        // Bounded-prefix cap: flat probe cost however long the admission
        // context is.  Floor 256 so the achievable entropy (~log2(n/2)
        // bits) clears the default ladder's top band even when the raw
        // window is configured tiny; ceiling keeps the probe FFT cheap.
        // Like `EntropyCache::for_policy` on the batch side, the cap is
        // additionally sized to the *configured* ladder: the top band cut
        // needs log2(prefix/2) bits of headroom, else a custom
        // high-entropy band would be silently unreachable and aggressive
        // merging would never engage.
        let n = cfg.policy.thresholds.len();
        let top_cut = if n > 1 {
            cfg.policy.entropy_lo
                + (cfg.policy.entropy_hi - cfg.policy.entropy_lo) * (n - 1) as f64 / n as f64
        } else {
            0.0
        };
        // need log2(prefix/2) > top_cut, with ~1.5 bits of headroom
        let need = (top_cut + 1.5).exp2().ceil() as usize * 2;
        let prefix_cap = cfg.raw_window.clamp(256, 16384).max(need.min(16384));
        if need > 16384 {
            eprintln!(
                "WARN: stream policy top entropy cut {top_cut:.1} bits needs a \
                 {need}-sample probe, capped at 16384 (max achievable ~{:.1} bits) — \
                 the most aggressive threshold band may be unreachable; lower the cut",
                (16384f64 / 2.0).log2()
            );
        } else if need > cfg.raw_window && n > 1 {
            // the ladder-sized prefix only helps the *admission* probe
            // (its context can be arbitrarily long); a re-probe analyzes
            // at most the retained ring, so a top band beyond the
            // window's achievable entropy gets re-routed out of at the
            // first re-probe however noisy the signal is
            eprintln!(
                "WARN: stream policy top entropy cut {top_cut:.1} bits needs ~{need} \
                 samples, but re-probes analyze at most raw_window = {} frames \
                 (~{:.1} bits achievable) — sessions admitted into the top band will \
                 be re-routed out of it at their first re-probe; raise raw_window or \
                 lower the cut",
                cfg.raw_window,
                (cfg.raw_window as f64 / 2.0).log2()
            );
        }
        let capacity = cfg.max_sessions.min(4096);
        Ok(SessionManager {
            cfg,
            sessions: HashMap::new(),
            entropy: EntropyCache::new(capacity, prefix_cap),
            probe_prefix: prefix_cap,
            seq: 0,
            stats: StreamStats::default(),
            scratch: Vec::new(),
            reduced: Vec::new(),
        })
    }

    pub fn config(&self) -> &StreamingConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    /// Merge-efficiency gauge over the whole table: total raw tokens
    /// appended vs output tokens produced (trimmed included) across every
    /// live session — what `Metrics::set_stream_tokens` snapshots.
    pub fn merge_totals(&self) -> (u64, u64) {
        let mut raw = 0u64;
        let mut merged = 0u64;
        for s in self.sessions.values() {
            raw += s.merge().raw_len() as u64;
            merged += s.merge().output_len() as u64;
        }
        (raw, merged)
    }

    pub fn session(&self, id: u64) -> Option<&StreamSession> {
        self.sessions.get(&id)
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Admit a new session: probe the initial context, derive its merge
    /// spec, evict (TTL first, then LRU) if the table is full, then
    /// append the initial points.  Errs on a duplicate id or on an
    /// `initial` that is not a whole number of `d`-channel frames.
    pub fn admit(&mut self, id: u64, initial: &[f32], now: Instant) -> Result<()> {
        ensure!(!self.sessions.contains_key(&id), "session {id} already admitted");
        let d = self.cfg.d;
        ensure!(
            initial.len() % d == 0,
            "session {id}: {} values is not a whole number of {d}-channel frames \
             (this serving process runs homogeneous d = {d} sessions)",
            initial.len()
        );
        self.evict_expired(now);
        while self.sessions.len() >= self.cfg.max_sessions {
            let lru = self
                .sessions
                .values()
                .min_by_key(|s| s.touch_seq)
                .map(|s| s.id)
                .expect("non-empty table");
            self.sessions.remove(&lru);
            self.stats.evicted_capacity += 1;
        }
        let entropy = if d == 1 {
            self.entropy.entropy(initial)
        } else {
            // probe the channel-mean series; the memo still pays off on
            // replayed admission contexts (same bytes -> same reduction)
            let SessionManager { entropy, reduced, .. } = self;
            reduce_channels(initial, d, reduced);
            entropy.entropy(&reduced[..])
        };
        self.stats.probes += 1;
        let spec = self.cfg.policy.spec_for(entropy);
        let mut session = StreamSession::new(id, spec, d, self.cfg.raw_window, now)?;
        let seq = self.next_seq();
        if !initial.is_empty() {
            session.append(initial, self.cfg.max_merged, now, seq);
            self.stats.appended_points += (initial.len() / d) as u64;
        } else {
            session.touch_seq = seq;
        }
        session.probe_done();
        self.sessions.insert(id, session);
        self.stats.admitted += 1;
        Ok(())
    }

    /// Append observations to a session (admitting it first if unknown —
    /// the streaming intake path).  Errs when `points` is not a whole
    /// number of `d`-channel frames.  Re-probes every
    /// [`StreamingConfig::reprobe_every`] frames and re-routes on a
    /// regime change.
    pub fn append(&mut self, id: u64, points: &[f32], now: Instant) -> Result<AppendOutcome> {
        if !self.sessions.contains_key(&id) {
            self.admit(id, points, now)?;
            return Ok(AppendOutcome::default());
        }
        let d = self.cfg.d;
        ensure!(
            points.len() % d == 0,
            "session {id}: {} values is not a whole number of {d}-channel frames \
             (this serving process runs homogeneous d = {d} sessions)",
            points.len()
        );
        let seq = self.next_seq();
        let SessionManager { cfg, sessions, probe_prefix, stats, scratch, reduced, .. } = self;
        let session = sessions.get_mut(&id).expect("checked above");
        session.append(points, cfg.max_merged, now, seq);
        stats.appended_points += (points.len() / d) as u64;
        let mut outcome = AppendOutcome::default();
        if session.since_probe() >= cfg.reprobe_every {
            outcome.probed = true;
            stats.probes += 1;
            session.raw_window_into(scratch);
            // Direct bounded-prefix entropy, NOT the cache: a sliding
            // window's bytes differ from every previous probe, so a
            // cache lookup would always miss while its insertion evicts
            // the reusable admission memos.  Cost is one prefix FFT per
            // `reprobe_every` frames — the cadence is the cost control.
            let series: &[f32] = if d == 1 {
                &scratch[..]
            } else {
                reduce_channels(scratch, d, reduced);
                &reduced[..]
            };
            let prefix = &series[..series.len().min(*probe_prefix)];
            let e = crate::signal::spectral_entropy(prefix);
            let spec = cfg.policy.spec_for(e);
            if &spec != session.spec() {
                // replay the window already materialized above — reroute
                // does not re-copy the ring
                session.reroute(spec, cfg.max_merged, &scratch[..])?;
                stats.reroutes += 1;
                outcome.rerouted = true;
            }
            session.probe_done();
        }
        Ok(outcome)
    }

    /// Evict sessions idle past the TTL; returns how many went.
    pub fn evict_expired(&mut self, now: Instant) -> usize {
        let ttl = self.cfg.session_ttl;
        let before = self.sessions.len();
        self.sessions.retain(|_, s| now.duration_since(s.last_touch) < ttl);
        let evicted = before - self.sessions.len();
        self.stats.evicted_ttl += evicted as u64;
        evicted
    }

    /// Number of decode-ready sessions (count only — no allocation or
    /// ordering; the scheduler polls this every few milliseconds).
    pub fn ready_count(&self) -> usize {
        let min_new = self.cfg.min_new;
        self.sessions.values().filter(|s| s.is_ready(min_new)).count()
    }

    /// Wall-clock arrival of the oldest unserved point across all ready
    /// sessions — the scheduler's partial-batch flush deadline.  `None`
    /// when nothing is ready.
    pub fn oldest_ready_at(&self) -> Option<Instant> {
        let min_new = self.cfg.min_new;
        self.sessions
            .values()
            .filter(|s| s.is_ready(min_new))
            .filter_map(|s| s.ready_at())
            .min()
    }

    /// Collect up to `max` decode-ready sessions, FIFO by the sequence at
    /// which each first accumulated unserved points — a hot session
    /// cannot starve one that has been waiting longer.
    pub fn take_ready(&self, max: usize, out: &mut Vec<u64>) {
        out.clear();
        let min_new = self.cfg.min_new;
        let mut ready: Vec<(u64, u64)> = self
            .sessions
            .values()
            .filter(|s| s.is_ready(min_new))
            .map(|s| (s.ready_since().expect("ready implies a since-seq"), s.id))
            .collect();
        ready.sort_unstable();
        out.extend(ready.into_iter().take(max).map(|(_, id)| id));
    }

    /// Assemble one decode row for a session: `row` holds
    /// `size_row.len() * d` interleaved values, `size_row` one size per
    /// token (delegates to [`StreamSession::context_into`]).  An unknown
    /// id — impossible when the id came from
    /// [`SessionManager::take_ready`] under the same borrow — zeroes the
    /// row and reports fill 0, so a pool-parallel slab fill never panics
    /// mid-batch.
    pub fn context_fill(&self, id: u64, row: &mut [f32], size_row: &mut [f32]) -> usize {
        match self.sessions.get(&id) {
            Some(s) => s.context_into(row, size_row),
            None => {
                row.fill(0.0);
                size_row.fill(0.0);
                0
            }
        }
    }

    /// Mark sessions served by a completed decode step.
    pub fn mark_decoded(&mut self, ids: &[u64], now: Instant) {
        let seq = self.next_seq();
        for id in ids {
            if let Some(s) = self.sessions.get_mut(id) {
                s.mark_decoded(now, seq);
            }
        }
    }

    /// A decode step carrying these sessions faulted after retries: make
    /// each session's last window pending again so a later step re-serves
    /// it (the windows were consumed at assembly by
    /// [`SessionManager::mark_decoded`]).  A session whose *consecutive*
    /// fault count reaches `budget` is quarantined — evicted, so a
    /// poisoned context cannot fault every step it lands in forever
    /// (`budget` 0 disables quarantine).  Returns
    /// `(requeued, quarantined)`.
    pub fn requeue_after_fault(
        &mut self,
        ids: &[u64],
        budget: u32,
        now: Instant,
    ) -> (usize, usize) {
        let seq = self.next_seq();
        let mut requeued = 0usize;
        let mut quarantined = 0usize;
        for id in ids {
            let Some(s) = self.sessions.get_mut(id) else { continue };
            let faults = s.restore_window(now, seq);
            if budget > 0 && faults >= budget {
                self.sessions.remove(id);
                quarantined += 1;
                self.stats.quarantined += 1;
            } else {
                requeued += 1;
                self.stats.requeued_windows += 1;
            }
        }
        (requeued, quarantined)
    }

    /// A decode step carrying these sessions completed cleanly: reset
    /// their consecutive-fault counts.  Fed back from the step-buffer
    /// harvest (not at assembly time — a step's fate is unknown then, and
    /// resetting early would let an always-faulting session escape its
    /// quarantine budget).
    pub fn decode_succeeded(&mut self, ids: &[u64]) {
        for id in ids {
            if let Some(s) = self.sessions.get_mut(id) {
                s.decode_succeeded();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::time::Duration;

    fn cfg(max_sessions: usize) -> StreamingConfig {
        StreamingConfig {
            max_sessions,
            session_ttl: Duration::from_secs(3600),
            reprobe_every: 64,
            raw_window: 128,
            max_merged: 256,
            min_new: 4,
            ..StreamingConfig::default()
        }
    }

    fn noise(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn admission_derives_spec_from_entropy() {
        let mut m = SessionManager::new(cfg(8)).unwrap();
        let now = Instant::now();
        // clean sine: low entropy -> conservative band (off by default)
        let sine: Vec<f32> = (0..128)
            .map(|i| (2.0 * std::f64::consts::PI * 4.0 * i as f64 / 128.0).sin() as f32)
            .collect();
        m.admit(1, &sine, now).unwrap();
        assert!(m.session(1).unwrap().spec().is_off());
        // noise: high entropy -> aggressive causal dynamic
        let mut rng = Rng::new(5);
        m.admit(2, &noise(&mut rng, 128), now).unwrap();
        let spec = m.session(2).unwrap().spec().clone();
        assert!(!spec.is_off());
        assert!(spec.causal && spec.k == 1);
        assert!(m.admit(1, &sine, now).is_err(), "duplicate admission");
    }

    #[test]
    fn capacity_evicts_lru() {
        let mut m = SessionManager::new(cfg(3)).unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(7);
        for id in 0..3 {
            m.admit(id, &noise(&mut rng, 32), now).unwrap();
        }
        // touch 0 so 1 becomes the LRU
        m.append(0, &[1.0], now).unwrap();
        m.admit(99, &noise(&mut rng, 32), now).unwrap();
        assert_eq!(m.len(), 3);
        assert!(m.session(1).is_none(), "LRU session must be the one evicted");
        assert!(m.session(0).is_some() && m.session(99).is_some());
        assert_eq!(m.stats().evicted_capacity, 1);
    }

    #[test]
    fn ttl_evicts_idle_sessions() {
        let mut m = SessionManager::new(StreamingConfig {
            session_ttl: Duration::from_millis(5),
            ..cfg(8)
        })
        .unwrap();
        let t0 = Instant::now();
        let mut rng = Rng::new(9);
        m.admit(1, &noise(&mut rng, 16), t0).unwrap();
        assert_eq!(m.evict_expired(t0), 0);
        assert_eq!(m.evict_expired(t0 + Duration::from_millis(10)), 1);
        assert!(m.is_empty());
        assert_eq!(m.stats().evicted_ttl, 1);
    }

    #[test]
    fn reprobe_reroutes_on_regime_change() {
        let mut m = SessionManager::new(StreamingConfig {
            reprobe_every: 64,
            raw_window: 128,
            ..cfg(4)
        })
        .unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(11);
        // admitted on noise: aggressive merging
        m.admit(1, &noise(&mut rng, 128), now).unwrap();
        assert!(!m.session(1).unwrap().spec().is_off());
        // regime change: feed a pure sine until the window is clean
        let sine: Vec<f32> = (0..64)
            .map(|i| (2.0 * std::f64::consts::PI * 2.0 * i as f64 / 64.0).sin() as f32)
            .collect();
        let mut rerouted = false;
        for _ in 0..4 {
            rerouted |= m.append(1, &sine, now).unwrap().rerouted;
        }
        assert!(rerouted, "a clean window must re-route the session");
        assert!(m.session(1).unwrap().spec().is_off());
        assert!(m.stats().reroutes >= 1);
        // the rebuilt state covers the retained window only
        assert!(m.session(1).unwrap().merge().raw_len() <= 128);
        // the table-wide merge gauge sums that session's counters
        let (raw, merged) = m.merge_totals();
        assert_eq!(raw, m.session(1).unwrap().merge().raw_len() as u64);
        assert!(merged >= 1 && merged <= raw, "raw={raw} merged={merged}");
    }

    #[test]
    fn probe_prefix_clears_the_configured_ladder() {
        use crate::streaming::StreamPolicy;
        // default ladder (cuts at 4.5/6.0 bits): the prefix must give the
        // top band headroom beyond the raw_window floor
        let m = SessionManager::new(cfg(4)).unwrap();
        assert!(m.probe_prefix >= 256);
        assert!(
            (m.probe_prefix as f64 / 2.0).log2() > 6.0,
            "prefix {} cannot reach the default top band",
            m.probe_prefix
        );
        // a custom high-entropy ladder (top cut 9.0 bits) forces a bigger
        // probe window than raw_window alone would pick — without this, a
        // validating config would silently never engage its top band
        let hot = StreamingConfig {
            raw_window: 256,
            policy: StreamPolicy {
                entropy_lo: 6.0,
                entropy_hi: 12.0,
                thresholds: vec![1.1, 0.8],
            },
            ..cfg(4)
        };
        let m = SessionManager::new(hot).unwrap();
        assert!(
            (m.probe_prefix as f64 / 2.0).log2() > 9.0,
            "prefix {} cannot reach the configured 9-bit cut",
            m.probe_prefix
        );
    }

    #[test]
    fn multivariate_manager_rejects_ragged_frames() {
        // homogeneous-d design: the manager runs one d for every session
        let mut m = SessionManager::new(StreamingConfig { d: 3, ..cfg(4) }).unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(15);
        // 8 frames x 3 channels admits cleanly
        m.admit(1, &noise(&mut rng, 24), now).unwrap();
        assert_eq!(m.session(1).unwrap().d(), 3);
        assert_eq!(m.session(1).unwrap().appended(), 8);
        assert_eq!(m.stats().appended_points, 8, "stats count frames, not scalars");
        // a ragged append (not a multiple of d) is an error, not a
        // silent reinterpretation — on admission and on append alike
        let err = m.admit(2, &noise(&mut rng, 10), now).unwrap_err();
        assert!(err.to_string().contains("3-channel"), "{err}");
        assert!(m.session(2).is_none());
        assert!(m.append(1, &noise(&mut rng, 7), now).is_err());
        assert_eq!(m.session(1).unwrap().appended(), 8, "ragged append must not land");
        // whole frames keep flowing
        m.append(1, &noise(&mut rng, 6), now).unwrap();
        assert_eq!(m.session(1).unwrap().appended(), 10);
    }

    #[test]
    fn multivariate_reprobe_reduces_channels() {
        let mut m = SessionManager::new(StreamingConfig {
            d: 2,
            reprobe_every: 32,
            raw_window: 64,
            ..cfg(4)
        })
        .unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(16);
        // noisy admission in both channels -> aggressive causal merging
        m.admit(1, &noise(&mut rng, 128), now).unwrap();
        assert!(!m.session(1).unwrap().spec().is_off());
        // regime change: both channels turn into the same clean sine, so
        // the channel-mean probe series is clean too and re-routes to Off
        let mut rerouted = false;
        for round in 0..4 {
            let frames: Vec<f32> = (0..32)
                .flat_map(|i| {
                    let t = (round * 32 + i) as f64;
                    let v = (2.0 * std::f64::consts::PI * t / 32.0).sin() as f32;
                    [v, v]
                })
                .collect();
            rerouted |= m.append(1, &frames, now).unwrap().rerouted;
        }
        assert!(rerouted, "a clean multivariate window must re-route");
        assert!(m.session(1).unwrap().spec().is_off());
    }

    #[test]
    fn requeue_after_fault_restores_readiness_and_quarantines() {
        let mut m = SessionManager::new(cfg(8)).unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(21);
        for id in [1, 2] {
            m.admit(id, &noise(&mut rng, 8), now).unwrap();
        }
        let mut ids = Vec::new();
        m.take_ready(8, &mut ids);
        assert_eq!(ids, vec![1, 2]);
        m.mark_decoded(&ids, now);
        assert_eq!(m.ready_count(), 0, "windows consumed at assembly");
        // the step faults: both windows come back, sessions ready again
        let (requeued, quarantined) = m.requeue_after_fault(&[1, 2], 3, now);
        assert_eq!((requeued, quarantined), (2, 0));
        assert_eq!(m.ready_count(), 2, "restored windows are decode-ready");
        assert_eq!(m.stats().requeued_windows, 2);
        // session 1 keeps faulting (assemble -> fault), session 2 succeeds
        m.mark_decoded(&[1, 2], now);
        m.decode_succeeded(&[2]);
        m.requeue_after_fault(&[1], 3, now);
        m.mark_decoded(&[1], now);
        // third consecutive fault for 1 hits the budget: quarantined
        let (requeued, quarantined) = m.requeue_after_fault(&[1], 3, now);
        assert_eq!((requeued, quarantined), (0, 1));
        assert!(m.session(1).is_none(), "quarantined session must be evicted");
        assert!(m.session(2).is_some(), "clean session unaffected");
        assert_eq!(m.stats().quarantined, 1);
        // unknown ids are ignored, budget 0 disables quarantine
        assert_eq!(m.requeue_after_fault(&[99], 3, now), (0, 0));
        m.take_ready(8, &mut ids);
        m.mark_decoded(&ids, now);
        for _ in 0..10 {
            m.requeue_after_fault(&[2], 0, now);
            m.mark_decoded(&[2], now);
        }
        assert!(m.session(2).is_some(), "budget 0 must never quarantine");
    }

    #[test]
    fn take_ready_is_fifo_fair() {
        let mut m = SessionManager::new(cfg(8)).unwrap();
        let now = Instant::now();
        let mut rng = Rng::new(13);
        for id in [10, 20, 30] {
            m.admit(id, &noise(&mut rng, 8), now).unwrap();
        }
        // all ready (admission appended 8 >= min_new 4); FIFO = admission order
        let mut ids = Vec::new();
        m.take_ready(2, &mut ids);
        assert_eq!(ids, vec![10, 20]);
        m.mark_decoded(&ids, now);
        m.take_ready(8, &mut ids);
        assert_eq!(ids, vec![30]);
        // 30 decoded; now 10 appends again and becomes the only ready one
        m.mark_decoded(&[30], now);
        m.append(10, &noise(&mut rng, 4), now).unwrap();
        m.take_ready(8, &mut ids);
        assert_eq!(ids, vec![10]);
    }
}
