//! [`StreamPolicy`]: spectral entropy → causal merge threshold.
//!
//! The batch serving policy (`coordinator::policy::MergePolicy`) routes a
//! request to a *compiled variant* by spectral entropy.  A stream session
//! has no per-request artifact choice — its knob is the causal
//! dynamic-merge threshold of its incremental state (paper §5.5 under the
//! causal restriction).  The mapping follows the same table-4 logic:
//! noisy, high-entropy series tolerate aggressive merging (low
//! threshold), clean series should merge conservatively or not at all.

use anyhow::{ensure, Result};

use crate::merging::MergeSpec;

/// An entropy ladder over causal merge thresholds.
///
/// `thresholds[i]` applies to the i-th entropy band of the uniform
/// partition of `[entropy_lo, entropy_hi]` (same arithmetic as
/// `MergePolicy::uniform`); entries must be **non-increasing** (higher
/// entropy never merges less aggressively).  A threshold above `1.0`
/// (the cosine ceiling) means "never merge" and is compiled to
/// [`MergeSpec::off`] outright, so such sessions skip score computation
/// entirely.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamPolicy {
    pub entropy_lo: f64,
    pub entropy_hi: f64,
    /// causal dynamic-merge threshold per entropy band, most conservative
    /// first; length = number of bands (>= 1)
    pub thresholds: Vec<f64>,
}

impl Default for StreamPolicy {
    /// Three bands: clean series off, mid conservative, noisy aggressive.
    fn default() -> StreamPolicy {
        StreamPolicy {
            entropy_lo: 3.0,
            entropy_hi: 7.5,
            thresholds: vec![1.1, 0.95, 0.8],
        }
    }
}

impl StreamPolicy {
    pub fn validate(&self) -> Result<()> {
        ensure!(
            !self.thresholds.is_empty(),
            "stream policy: thresholds must not be empty"
        );
        ensure!(
            self.entropy_lo.is_finite() && self.entropy_hi.is_finite(),
            "stream policy: entropy bounds must be finite"
        );
        ensure!(
            self.entropy_lo < self.entropy_hi,
            "stream policy: entropy_lo must be < entropy_hi"
        );
        for (i, &th) in self.thresholds.iter().enumerate() {
            ensure!(
                th.is_finite() && th >= 0.0,
                "stream policy: thresholds[{i}] must be finite and >= 0, got {th}"
            );
        }
        ensure!(
            self.thresholds.windows(2).all(|w| w[0] >= w[1]),
            "stream policy: thresholds must be non-increasing (higher entropy \
             must not merge less aggressively)"
        );
        // every reachable spec must validate (off or causal dynamic)
        for &th in &self.thresholds {
            Self::spec_for_threshold(th).validate()?;
        }
        Ok(())
    }

    /// Entropy band index for a measured entropy (same uniform-cut
    /// arithmetic as `MergePolicy::uniform` + `decision_for`).
    pub fn band_for(&self, entropy: f64) -> usize {
        let n = self.thresholds.len();
        let mut idx = 0;
        for i in 1..n {
            let cut = self.entropy_lo + (self.entropy_hi - self.entropy_lo) * i as f64 / n as f64;
            if entropy >= cut {
                idx = i;
            }
        }
        idx
    }

    /// The causal merge spec a session at this entropy should run.
    pub fn spec_for(&self, entropy: f64) -> MergeSpec {
        Self::spec_for_threshold(self.thresholds[self.band_for(entropy)])
    }

    fn spec_for_threshold(th: f64) -> MergeSpec {
        if th > 1.0 {
            MergeSpec::off()
        } else {
            MergeSpec::dynamic(th, 1).with_causal()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::MergeMode;

    #[test]
    fn default_ladder_validates_and_orders() {
        let p = StreamPolicy::default();
        p.validate().unwrap();
        // below the range: most conservative band = off
        assert!(p.spec_for(0.0).is_off());
        // above the range: most aggressive band
        match p.spec_for(12.0).mode {
            MergeMode::Dynamic { threshold } => assert_eq!(threshold, 0.8),
            m => panic!("unexpected mode {m:?}"),
        }
        // every reachable spec is causal (or off) and valid
        for e in [0.0, 4.0, 5.0, 6.0, 7.0, 9.0] {
            let spec = p.spec_for(e);
            spec.validate().unwrap();
            assert!(spec.is_off() || (spec.causal && spec.k == 1));
        }
    }

    #[test]
    fn band_cuts_match_merge_policy_arithmetic() {
        let p = StreamPolicy {
            entropy_lo: 2.0,
            entropy_hi: 8.0,
            thresholds: vec![1.1, 0.9, 0.7],
        };
        // cuts at 4.0 and 6.0
        assert_eq!(p.band_for(3.9), 0);
        assert_eq!(p.band_for(4.0), 1);
        assert_eq!(p.band_for(5.9), 1);
        assert_eq!(p.band_for(6.0), 2);
    }

    #[test]
    fn rejects_bad_ladders() {
        let mut p = StreamPolicy::default();
        p.thresholds = vec![];
        assert!(p.validate().is_err());
        p.thresholds = vec![0.5, 0.9]; // increasing = less merge at higher entropy
        assert!(p.validate().is_err());
        p.thresholds = vec![f64::NAN];
        assert!(p.validate().is_err());
        p.thresholds = vec![-0.1];
        assert!(p.validate().is_err());
        p = StreamPolicy { entropy_lo: 5.0, entropy_hi: 5.0, ..StreamPolicy::default() };
        assert!(p.validate().is_err());
    }
}
