//! Streaming decode subsystem: long-lived per-user sessions with
//! incremental causal merging (DESIGN.md §9).
//!
//! The batch serving path (`coordinator/`) answers one-shot requests over
//! fully materialized contexts.  Forecasting-as-a-service traffic is not
//! one-shot: a session appends observations forever and asks for rolling
//! predictions.  Recomputing the merged context per request costs O(t·d)
//! per append; this subsystem keeps the paper's *causal* merged
//! representation as running state instead, so appending `n` points costs
//! O(n·d) ([`crate::merging::IncrementalMerge`], bit-for-bit equal to a
//! full recompute).
//!
//! * [`session`]  — [`StreamSession`]: a bounded ring of recent raw
//!   observations plus the incremental merge state, decode-readiness
//!   bookkeeping and context-row assembly.
//! * [`manager`]  — [`SessionManager`]: bounded session table with
//!   LRU/TTL eviction; derives each session's
//!   [`MergeSpec`](crate::merging::MergeSpec) from the spectral
//!   predictors at admission and re-probes every
//!   [`StreamingConfig::reprobe_every`] appends, re-routing the session
//!   when the regime changes.
//! * [`probe`]    — [`StreamPolicy`]: the spectral-entropy → causal merge
//!   threshold ladder (the streaming analogue of
//!   [`crate::coordinator::MergePolicy`]'s variant routing).
//!
//! The decode-step scheduler that continuously batches ready sessions
//! into the staged serving pipeline lives in `coordinator::stream` (it
//! needs the pool/metrics/pipeline machinery); this module stays
//! dependency-light so the session substrate is testable alone.

pub mod manager;
pub mod probe;
pub mod session;

pub use manager::{SessionManager, StreamStats};
pub use probe::StreamPolicy;
pub use session::StreamSession;

use std::time::Duration;

use anyhow::{ensure, Result};

/// Configuration of the streaming subsystem (the `"streaming"` block of
/// the serving config — see `config.rs` for the JSON form and
/// `ServeFileConfig::example()`).
#[derive(Clone, Debug, PartialEq)]
pub struct StreamingConfig {
    /// session-table capacity; admitting past it evicts the
    /// least-recently-touched session
    pub max_sessions: usize,
    /// sessions idle longer than this are evicted
    pub session_ttl: Duration,
    /// appended frames between spectral re-probes of a session (regime
    /// detection)
    pub reprobe_every: usize,
    /// raw observation frames retained per session (ring buffer
    /// capacity); also the window a re-probe analyzes and a re-route
    /// replays
    pub raw_window: usize,
    /// merged tokens retained per session (front-trimmed beyond this)
    pub max_merged: usize,
    /// new frames a session must accumulate to become decode-ready
    pub min_new: usize,
    /// channels per frame (token dimensionality `d`).  One `d` per
    /// serving process — the homogeneous-`d` design (DESIGN.md §9): every
    /// session shares the artifact's channel count, so every decode batch
    /// is homogeneous by construction and appends whose length is not a
    /// whole number of `d`-channel frames are rejected at intake.
    pub d: usize,
    /// entropy → merge-threshold ladder
    pub policy: StreamPolicy,
    /// artifact variant that executes stream decode steps under
    /// `tomers serve` (`None` = the policy's first variant).  Ignored by
    /// the offline demos, which use a synthetic device.
    pub variant: Option<String>,
}

impl Default for StreamingConfig {
    fn default() -> StreamingConfig {
        StreamingConfig {
            max_sessions: 1024,
            session_ttl: Duration::from_secs(60),
            reprobe_every: 256,
            raw_window: 1024,
            max_merged: 4096,
            min_new: 16,
            d: 1,
            policy: StreamPolicy::default(),
            variant: None,
        }
    }
}

impl StreamingConfig {
    /// Field-naming validation, mirroring [`crate::merging::MergeSpec`]'s
    /// validate-once discipline.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.max_sessions >= 1, "streaming: max_sessions must be >= 1");
        ensure!(
            self.session_ttl > Duration::ZERO,
            "streaming: session_ttl must be positive"
        );
        ensure!(self.reprobe_every >= 1, "streaming: reprobe_every must be >= 1");
        ensure!(
            self.raw_window >= 2,
            "streaming: raw_window must hold at least one pair (>= 2)"
        );
        ensure!(self.max_merged >= 1, "streaming: max_merged must be >= 1");
        ensure!(self.min_new >= 1, "streaming: min_new must be >= 1");
        ensure!(self.d >= 1, "streaming: d (channels per frame) must be >= 1");
        if let Some(v) = &self.variant {
            ensure!(!v.is_empty(), "streaming: variant must not be empty when given");
        }
        self.policy.validate()
    }
}
