//! The sharded serving front (DESIGN.md §12): N self-contained dual
//! serve loops behind one TCP acceptor.
//!
//! Thread topology for `shards = N` with `C` live connections:
//!
//! ```text
//!            acceptor ──spawns──► C connection readers (+ C writers)
//!                                        │ route by ShardRouter
//!                  ┌─────────────────────┴──────────────────────┐
//!            shard 0 …                                     shard N-1
//!            intake thread (batching)                      intake thread
//!            exec thread (run_serve_stages                 exec thread
//!              = device + both prep stages)
//! ```
//!
//! Every shard owns its full serving state — session table, delivery
//! outboxes, metrics, bounded intake — and shards share **nothing**: an
//! id's shard is a pure function of the id ([`ShardRouter`]), so there is
//! no routing table to lock and no cross-shard rebalancing to get wrong.
//!
//! **Backpressure is fail-fast on the wire.**  In-process, the server
//! signals overload by dropping the response sender; over TCP a dropped
//! sender is indistinguishable from a hang, so overload answers with a
//! terminal `Failed("backpressure: …")` forecast response (stream appends
//! get an error frame).  Every request still reaches exactly one terminal
//! response — the wire realisation of the `ForecastOutcome` liveness
//! contract.
//!
//! **Drain order on shutdown** (each step gates the next, every handle
//! joined via [`join_annotated`]): stop accepting → connection threads
//! exit (50 ms read timeout polls the flag) → the last [`ShardPorts`]
//! clone drops, closing every shard's intake channels → each intake
//! flushes its remaining batches (so queued requests reach terminal
//! outcomes), drops its jobs channel and the dual loop winds down through
//! the fault-tolerant close paths → per-shard metrics merge into one
//! process report ([`merged_report`]).

use std::collections::BTreeMap;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::frame::{write_frame, FrameDecoder};
use super::protocol::{self, Request, Response};
use super::router::ShardRouter;
use super::NetConfig;
use crate::coordinator::batcher;
use crate::coordinator::metrics::{merged_json, merged_report, sum_delivery};
use crate::coordinator::pipeline::Pending;
use crate::coordinator::serve_loop::SERVE_QUEUE_DEPTH;
use crate::coordinator::stream::DecodeStep;
use crate::coordinator::{
    run_serve_stages, BatcherConfig, DeliveryMonitor, DeliveryStats, DynamicBatcher,
    EntropyCache, FaultContext, FaultPolicy, ForecastOutcome, ForecastRequest, ForecastResponse,
    MergePolicy, Metrics, PrepJob, ReadyBatch, StreamEvent, VariantMeta,
};
use crate::json::Json;
use crate::merging::MergeSpec;
use crate::obs::{recorder, ObsConfig, Stage};
use crate::runtime::pool::WorkerPool;
use crate::streaming::StreamingConfig;
use crate::util::{join_annotated, lock_ignore_poison as lock};

/// Everything one shard needs to stand up its dual serve loop — the
/// per-loop slice of [`crate::coordinator::ServerConfig`].  Cloned per
/// shard: each gets its own policy/meta copies, never shared references.
#[derive(Clone)]
pub struct ShardSpec {
    /// merge-rate routing policy (each shard runs its own entropy cache)
    pub policy: MergePolicy,
    /// batch geometry per variant
    pub metas: BTreeMap<String, VariantMeta>,
    /// host premerge for over-length contexts
    pub merge: MergeSpec,
    /// prep-stage parallelism for `run_serve_stages`
    pub prep_slots: usize,
    /// stream decode geometry
    pub stream_meta: VariantMeta,
    /// streaming subsystem config (session table, probe cadence, …)
    pub stream_cfg: StreamingConfig,
    /// batching flush deadline
    pub max_wait: Duration,
    /// bound on pending requests per shard — the intake channel depth
    /// *and* the batcher's global bound
    pub max_queue: usize,
    /// fault tolerance: retries/deadlines/quarantine + delivery bounds
    pub faults: FaultPolicy,
    /// observability: trace-ring/sampling settings and histogram bounds
    /// (the `"obs"` config block; defaults are always-on with negligible
    /// overhead — see `benches/obs.rs`)
    pub obs: ObsConfig,
}

/// A shard's client-facing side: what connection threads route into.
/// Dropping the last clone closes the shard's intake channels, which is
/// exactly the drain signal the shard's threads wind down on.
#[derive(Clone)]
pub struct ShardPorts {
    /// bounded forecast intake (`try_send` = wire backpressure)
    pub forecast_tx: SyncSender<Pending>,
    /// bounded stream-append intake
    pub event_tx: SyncSender<StreamEvent>,
    /// the shard's delivery outboxes (collect/ack served directly)
    pub delivery: Arc<Mutex<DeliveryMonitor>>,
    /// the shard's metrics (reports + wire-level rejection accounting)
    pub metrics: Arc<Mutex<Metrics>>,
}

/// A shard's server-owned side: joined on shutdown.
pub struct ShardRuntime {
    /// the intake thread; joins the exec thread internally, so joining
    /// this joins the whole shard
    intake: JoinHandle<Result<()>>,
    metrics: Arc<Mutex<Metrics>>,
    delivery: Arc<Mutex<DeliveryMonitor>>,
}

/// Answer a forecast that the shard cannot queue with a terminal
/// `Failed` — the wire's fail-fast backpressure contract.
fn reject_forecast(
    shard: usize,
    metrics: &Arc<Mutex<Metrics>>,
    req: ForecastRequest,
    t0: Instant,
    rtx: mpsc::Sender<ForecastResponse>,
) {
    {
        let mut m = lock(metrics);
        m.record_rejected();
        m.record_failed(1);
    }
    let _ = rtx.send(ForecastResponse {
        id: req.id,
        forecast: Vec::new(),
        variant: String::new(),
        latency: t0.elapsed().as_secs_f64(),
        batch_size: 0,
        outcome: ForecastOutcome::Failed(format!("backpressure: shard {shard} intake full")),
    });
}

/// Stand up one self-contained shard: an intake thread (routing +
/// deadline-ordered batching, the `coordinator::server` idiom) feeding an
/// exec thread that runs the dual serve loop with the given synthetic or
/// real device closures.  Returns the client-facing ports and the
/// join-side runtime.
pub fn spawn_shard<XB, XS>(
    index: usize,
    spec: ShardSpec,
    pool: &'static WorkerPool,
    execute_batch: XB,
    execute_stream: XS,
) -> Result<(ShardPorts, ShardRuntime)>
where
    XB: FnMut(&mut ReadyBatch) -> Result<Vec<Vec<f32>>> + Send + 'static,
    XS: FnMut(&mut DecodeStep) -> Result<Vec<Vec<f32>>> + Send + 'static,
{
    let ShardSpec {
        policy,
        metas,
        merge,
        prep_slots,
        stream_meta,
        stream_cfg,
        max_wait,
        max_queue,
        faults: fault_policy,
        obs,
    } = spec;
    fault_policy.validate()?;
    obs.validate()?;
    let delivery = Arc::new(Mutex::new(DeliveryMonitor::new(
        fault_policy.outbox_cap,
        fault_policy.forecast_ttl,
    )));
    let metrics = Arc::new(Mutex::new(Metrics::with_obs(&obs)));
    let faults = FaultContext::new(fault_policy);
    let (forecast_tx, forecast_rx) = sync_channel::<Pending>(max_queue);
    let (event_tx, event_rx) = sync_channel::<StreamEvent>(max_queue);
    let (jobs_tx, jobs_rx) = sync_channel::<PrepJob>(SERVE_QUEUE_DEPTH);

    // Exec thread: the dual serve loop — device closures plus both prep
    // stages; rolling forecasts land in this shard's delivery monitor
    // with a periodic TTL sweep (the coordinator::server cadence).
    let exec_metrics = Arc::clone(&metrics);
    let exec_delivery = Arc::clone(&delivery);
    let exec_faults = faults.clone();
    let exec_metas = metas.clone();
    let ttl = exec_faults.policy.forecast_ttl;
    let expire_every = (ttl / 4).max(Duration::from_millis(50));
    let exec = thread::Builder::new()
        .name(format!("tomers-shard{index}-exec"))
        .spawn(move || -> Result<()> {
            let mut last_expire = Instant::now();
            run_serve_stages(
                jobs_rx,
                event_rx,
                exec_metas,
                merge,
                prep_slots,
                stream_meta,
                stream_cfg,
                pool,
                exec_metrics,
                exec_faults,
                execute_batch,
                execute_stream,
                move |session, forecast| {
                    let now = Instant::now();
                    let mut d = lock(&exec_delivery);
                    d.offer(session, forecast, now);
                    if now.duration_since(last_expire) >= expire_every {
                        d.expire(now);
                        last_expire = now;
                    }
                },
            )
        })
        .map_err(|e| anyhow!("spawning shard {index} exec thread: {e}"))?;

    // Intake thread: entropy routing + deadline-ordered batching, same
    // shape as coordinator::server's intake, except overload answers a
    // terminal Failed (see the module docs) instead of dropping senders.
    let intake_metrics = Arc::clone(&metrics);
    let intake = thread::Builder::new()
        .name(format!("tomers-shard{index}-intake"))
        .spawn(move || -> Result<()> {
            let mut queues: BTreeMap<(String, usize), DynamicBatcher<Pending>> = BTreeMap::new();
            let mut total_pending = 0usize;
            let mut entropy_cache = EntropyCache::for_policy(4096, &policy);
            let ordered_variants = policy.variant_names();
            'serve: loop {
                let now = Instant::now();
                let timeout = queues
                    .values()
                    .filter_map(|q| q.next_deadline(now))
                    .min()
                    .unwrap_or(Duration::from_millis(50));
                match forecast_rx.recv_timeout(timeout) {
                    Ok((req, t0, rtx)) => {
                        let t_in = Instant::now();
                        let decision = policy.decide_cached(&mut entropy_cache, &req.context);
                        recorder().record(
                            req.id,
                            Stage::Intake,
                            index,
                            t_in,
                            t_in.elapsed(),
                            req.context.len() as u32,
                        );
                        lock(&intake_metrics)
                            .record_route(&decision.variant.name, decision.entropy);
                        let mut name = decision.variant.name;
                        {
                            let tracker = lock(&faults.tracker);
                            if tracker.is_quarantined(&name) {
                                if let Some(alt) = tracker.fallback(&ordered_variants, &name) {
                                    lock(&intake_metrics).record_downgrade(&name, alt);
                                    name = alt.to_string();
                                }
                            }
                        }
                        let capacity = metas
                            .get(&name)
                            .map(|meta| meta.capacity)
                            .expect("policy names a loaded variant");
                        if total_pending >= max_queue {
                            reject_forecast(index, &intake_metrics, req, t0, rtx);
                        } else {
                            let q = queues
                                .entry((name, req.context.len()))
                                .or_insert_with(|| {
                                    DynamicBatcher::new(BatcherConfig {
                                        capacity,
                                        max_wait,
                                        max_queue,
                                    })
                                });
                            match q.push((req, t0, rtx)) {
                                Ok(()) => total_pending += 1,
                                Err((req, t0, rtx)) => {
                                    reject_forecast(index, &intake_metrics, req, t0, rtx);
                                }
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
                let now = Instant::now();
                for ((variant, _len), batch) in batcher::drain_ready(&mut queues, now) {
                    total_pending -= batch.len();
                    if jobs_tx.send(PrepJob { variant, batch }).is_err() {
                        break 'serve;
                    }
                }
                queues.retain(|_, q| !q.is_empty());
            }
            // Drain: the intake channel closed (shutdown) — flush every
            // still-pending request so each reaches a terminal outcome
            // before the stages wind down.
            for ((variant, _len), mut q) in std::mem::take(&mut queues) {
                while !q.is_empty() {
                    let batch = q.drain_batch();
                    if jobs_tx.send(PrepJob { variant: variant.clone(), batch }).is_err() {
                        break;
                    }
                }
            }
            drop(jobs_tx); // unwinds prep + execute
            join_annotated(exec, "shard exec thread")?
        })
        .map_err(|e| anyhow!("spawning shard {index} intake thread: {e}"))?;

    Ok((
        ShardPorts {
            forecast_tx,
            event_tx,
            delivery: Arc::clone(&delivery),
            metrics: Arc::clone(&metrics),
        },
        ShardRuntime { intake, metrics, delivery },
    ))
}

/// TTL-sweep every shard's outboxes, fold the ledgers into the per-shard
/// metrics, and return the merged process report plus the summed delivery
/// ledger (identity-preserving — see [`sum_delivery`]).
pub fn process_report(ports: &[ShardPorts]) -> (String, DeliveryStats) {
    let now = Instant::now();
    for p in ports {
        let stats = {
            let mut d = lock(&p.delivery);
            d.expire(now);
            d.stats()
        };
        lock(&p.metrics).set_delivery(stats);
    }
    let guards: Vec<_> = ports.iter().map(|p| lock(&p.metrics)).collect();
    let refs: Vec<&Metrics> = guards.iter().map(|g| &**g).collect();
    let text = merged_report(&refs);
    let delivery = refs
        .iter()
        .filter_map(|m| m.delivery())
        .fold(DeliveryStats::default(), sum_delivery);
    (text, delivery)
}

/// TTL-sweep every shard's outboxes (like [`process_report`]) and return
/// the merged structured metrics — per-shard objects plus the exact
/// histogram-merged total ([`merged_json`]) — for the `"metrics"` wire
/// request and the Prometheus formatter.
pub fn process_metrics_json(ports: &[ShardPorts]) -> Json {
    let now = Instant::now();
    for p in ports {
        let stats = {
            let mut d = lock(&p.delivery);
            d.expire(now);
            d.stats()
        };
        lock(&p.metrics).set_delivery(stats);
    }
    let guards: Vec<_> = ports.iter().map(|p| lock(&p.metrics)).collect();
    let refs: Vec<&Metrics> = guards.iter().map(|g| &**g).collect();
    merged_json(&refs)
}

/// The running sharded server: joinable from the thread that called
/// [`serve_net`].  Call [`shutdown`](NetServerHandle::shutdown) to drain
/// (see the module docs for the order) — dropping the handle without it
/// leaves the listener running.
pub struct NetServerHandle {
    addr: SocketAddr,
    flag: Arc<AtomicBool>,
    ports: Arc<Vec<ShardPorts>>,
    acceptor: JoinHandle<()>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    shards: Vec<ShardRuntime>,
    closed: Arc<AtomicUsize>,
}

impl NetServerHandle {
    /// The bound listen address (resolves port 0 to the ephemeral pick).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections fully served and closed so far (drives the CLI's
    /// `--exit-after`).
    pub fn connections_closed(&self) -> usize {
        self.closed.load(Ordering::Relaxed)
    }

    /// Graceful drain; returns the merged process report.  Every thread
    /// the server spawned is joined here — acceptor, connections, then
    /// each shard (whose intake joins its exec internally).
    pub fn shutdown(self) -> Result<String> {
        let NetServerHandle { addr: _, flag, ports, acceptor, conns, shards, closed: _ } = self;
        flag.store(true, Ordering::Relaxed);
        join_annotated(acceptor, "net acceptor thread")?;
        for conn in std::mem::take(&mut *lock(&conns)) {
            join_annotated(conn, "net connection thread")?;
        }
        // last ports clone: shard intake channels close and the drain
        // cascade runs (module docs)
        drop(ports);
        let mut reports = Vec::with_capacity(shards.len());
        for (i, rt) in shards.into_iter().enumerate() {
            join_annotated(rt.intake, "shard intake thread")
                .with_context(|| format!("shard {i}"))??;
            let stats = {
                let mut d = lock(&rt.delivery);
                d.expire(Instant::now());
                d.stats()
            };
            lock(&rt.metrics).set_delivery(stats);
            reports.push(rt.metrics);
        }
        let guards: Vec<_> = reports.iter().map(|m| lock(m)).collect();
        let refs: Vec<&Metrics> = guards.iter().map(|g| &**g).collect();
        Ok(merged_report(&refs))
    }
}

/// Bind `cfg.addr` and serve `cfg.shards` independent dual serve loops
/// behind it.  `batch_device(i)` / `stream_device(i)` build shard `i`'s
/// device closures (so tests and `serve-net` seed per-shard fault plans);
/// each shard gets a clone of `spec`.
pub fn serve_net<MB, MS, XB, XS>(
    cfg: &NetConfig,
    spec: &ShardSpec,
    pool: &'static WorkerPool,
    mut batch_device: MB,
    mut stream_device: MS,
) -> Result<NetServerHandle>
where
    MB: FnMut(usize) -> XB,
    MS: FnMut(usize) -> XS,
    XB: FnMut(&mut ReadyBatch) -> Result<Vec<Vec<f32>>> + Send + 'static,
    XS: FnMut(&mut DecodeStep) -> Result<Vec<Vec<f32>>> + Send + 'static,
{
    cfg.validate()?;
    spec.obs.validate()?;
    spec.obs.apply();
    let router = Arc::new(ShardRouter::new(cfg.shards)?);
    let mut ports = Vec::with_capacity(cfg.shards);
    let mut shards = Vec::with_capacity(cfg.shards);
    for i in 0..cfg.shards {
        let (p, rt) = spawn_shard(i, spec.clone(), pool, batch_device(i), stream_device(i))?;
        ports.push(p);
        shards.push(rt);
    }
    let ports = Arc::new(ports);

    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding net listener on {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let flag = Arc::new(AtomicBool::new(false));
    let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let closed = Arc::new(AtomicUsize::new(0));
    let live = Arc::new(AtomicUsize::new(0));

    let a_ports = Arc::clone(&ports);
    let a_flag = Arc::clone(&flag);
    let a_conns = Arc::clone(&conns);
    let a_closed = Arc::clone(&closed);
    let max_conns = cfg.max_conns;
    let max_frame_bytes = cfg.max_frame_bytes;
    let acceptor = thread::Builder::new()
        .name("tomers-net-accept".into())
        .spawn(move || {
            while !a_flag.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        if live.load(Ordering::Relaxed) >= max_conns {
                            // over the cap: error frame + close, never queue
                            let _ = stream.set_nonblocking(false);
                            let reply = protocol::response_to_json(&Response::Error {
                                context: "accept".into(),
                                reason: format!("connection limit {max_conns} reached"),
                            })
                            .to_string();
                            let mut s = stream;
                            let _ = write_frame(&mut s, &reply, max_frame_bytes);
                            continue;
                        }
                        live.fetch_add(1, Ordering::Relaxed);
                        let c_ports = Arc::clone(&a_ports);
                        let c_router = Arc::clone(&router);
                        let c_flag = Arc::clone(&a_flag);
                        let c_live = Arc::clone(&live);
                        let c_closed = Arc::clone(&a_closed);
                        let spawned = thread::Builder::new()
                            .name("tomers-net-conn".into())
                            .spawn(move || {
                                handle_conn(stream, &c_ports, &c_router, max_frame_bytes, &c_flag);
                                c_live.fetch_sub(1, Ordering::Relaxed);
                                c_closed.fetch_add(1, Ordering::Relaxed);
                            });
                        match spawned {
                            Ok(handle) => lock(&a_conns).push(handle),
                            Err(_) => {
                                live.fetch_sub(1, Ordering::Relaxed);
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(5));
                    }
                    // transient accept errors (per-connection resets):
                    // keep accepting
                    Err(_) => thread::sleep(Duration::from_millis(5)),
                }
            }
        })
        .map_err(|e| anyhow!("spawning net acceptor: {e}"))?;

    Ok(NetServerHandle { addr, flag, ports, acceptor, conns, shards, closed })
}

/// Serialize one response frame onto the shared write half.  Write errors
/// are swallowed: an abruptly-disconnected peer must not take the server
/// down, and its session outboxes survive for reconnect-collect.
fn send_reply(stream: &Arc<Mutex<TcpStream>>, max_frame_bytes: usize, resp: &Response) {
    let payload = protocol::response_to_json(resp).to_string();
    let mut s = lock(stream);
    let _ = write_frame(&mut *s, &payload, max_frame_bytes);
}

/// One connection: a reader thread (this function) decoding frames and
/// routing them, plus a writer thread fanning terminal forecast responses
/// back.  Both serialize frames under one write-half mutex so frames
/// never interleave.
fn handle_conn(
    stream: TcpStream,
    ports: &Arc<Vec<ShardPorts>>,
    router: &Arc<ShardRouter>,
    max_frame_bytes: usize,
    shutdown: &Arc<AtomicBool>,
) {
    let _ = stream.set_nonblocking(false);
    // the read timeout doubles as the shutdown poll cadence
    if stream.set_read_timeout(Some(Duration::from_millis(50))).is_err() {
        return;
    }
    let Ok(write_half) = stream.try_clone() else { return };
    let write_half = Arc::new(Mutex::new(write_half));

    // Terminal forecast responses arrive whenever their batch executes,
    // on the shard's exec thread — a dedicated writer drains them so the
    // reader keeps decoding while batches are in flight.
    let (resp_tx, resp_rx) = mpsc::channel::<ForecastResponse>();
    let w_stream = Arc::clone(&write_half);
    let w_router = Arc::clone(router);
    let writer = thread::spawn(move || {
        for resp in resp_rx.iter() {
            let shard = w_router.shard_for(resp.id);
            let payload =
                protocol::response_to_json(&protocol::forecast_response(&resp, shard))
                    .to_string();
            let mut s = lock(&w_stream);
            let _ = write_frame(&mut *s, &payload, max_frame_bytes);
        }
    });

    let mut dec = FrameDecoder::new(max_frame_bytes);
    let mut stream = stream;
    let mut buf = [0u8; 4096];
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break;
        }
        match stream.read(&mut buf) {
            Ok(0) => break, // clean EOF (or truncated stream — same close)
            Ok(n) => {
                if let Err(e) = dec.push(&buf[..n]) {
                    // framing errors (oversized header, bad UTF-8) lose
                    // byte-stream sync: report and close this connection
                    send_reply(
                        &write_half,
                        max_frame_bytes,
                        &Response::Error { context: "framing".into(), reason: format!("{e:#}") },
                    );
                    break;
                }
                while let Some(payload) = dec.next() {
                    handle_frame(&payload, ports, router, &write_half, &resp_tx, max_frame_bytes);
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(_) => break,
        }
    }
    // writer exits once every in-flight request's sender resolves —
    // batches already queued keep flushing on their max_wait deadline
    drop(resp_tx);
    let _ = writer.join();
}

/// Decode + route one request frame.  Malformed JSON in a well-framed
/// payload answers an error frame and keeps the connection alive — only
/// framing-level violations close it.
fn handle_frame(
    payload: &str,
    ports: &[ShardPorts],
    router: &ShardRouter,
    stream: &Arc<Mutex<TcpStream>>,
    resp_tx: &mpsc::Sender<ForecastResponse>,
    max_frame_bytes: usize,
) {
    let req = match protocol::parse_request(payload) {
        Ok(r) => r,
        Err(e) => {
            send_reply(
                stream,
                max_frame_bytes,
                &Response::Error { context: "parse".into(), reason: format!("{e:#}") },
            );
            return;
        }
    };
    match req {
        Request::Forecast { id, context } => {
            let shard = router.shard_for(id);
            let pending: Pending =
                (ForecastRequest { id, context }, Instant::now(), resp_tx.clone());
            match ports[shard].forecast_tx.try_send(pending) {
                Ok(()) => {}
                Err(TrySendError::Full((req, t0, rtx))) => {
                    reject_forecast(shard, &ports[shard].metrics, req, t0, rtx);
                }
                Err(TrySendError::Disconnected(_)) => send_reply(
                    stream,
                    max_frame_bytes,
                    &Response::Error {
                        context: "forecast".into(),
                        reason: format!("shard {shard} is down"),
                    },
                ),
            }
        }
        Request::Append { session, points } => {
            let shard = router.shard_for(session);
            match ports[shard].event_tx.try_send(StreamEvent::Append { session, points }) {
                Ok(()) => {
                    send_reply(stream, max_frame_bytes, &Response::Appended { session, shard });
                }
                Err(TrySendError::Full(_)) => send_reply(
                    stream,
                    max_frame_bytes,
                    &Response::Error {
                        context: "append".into(),
                        reason: format!("backpressure: shard {shard} stream intake full"),
                    },
                ),
                Err(TrySendError::Disconnected(_)) => send_reply(
                    stream,
                    max_frame_bytes,
                    &Response::Error {
                        context: "append".into(),
                        reason: format!("shard {shard} is down"),
                    },
                ),
            }
        }
        Request::Collect { session } => {
            let shard = router.shard_for(session);
            let entries = lock(&ports[shard].delivery).collect(session);
            send_reply(
                stream,
                max_frame_bytes,
                &Response::Collected { session, shard, entries },
            );
        }
        Request::Ack { session, upto } => {
            let shard = router.shard_for(session);
            let count = lock(&ports[shard].delivery).ack(session, upto, Instant::now());
            send_reply(stream, max_frame_bytes, &Response::Acked { session, shard, count });
        }
        Request::Report => {
            let (text, delivery) = process_report(ports);
            send_reply(stream, max_frame_bytes, &Response::Report { text, delivery });
        }
        Request::Metrics => {
            let metrics = process_metrics_json(ports);
            send_reply(stream, max_frame_bytes, &Response::Metrics { metrics });
        }
    }
}
