//! Consistent-hash routing of session/request ids onto shards
//! (DESIGN.md §12).
//!
//! Each shard contributes [`VNODES_PER_SHARD`] virtual points on a
//! 64-bit hash ring; an id is hashed with the SplitMix64 finalizer (the
//! same mixer as [`crate::util::Rng`]) and owned by the first ring point
//! clockwise from it.  Properties the serving fabric relies on:
//!
//! * **deterministic & platform-independent** — pure integer mixing, no
//!   `RandomState`; the same id maps to the same shard in every process,
//!   pinned by golden values cross-checked against the Python
//!   transliteration (`scripts/crosscheck_net.py`);
//! * **stable under shard-count change** — growing N shards to N+1
//!   moves only the keys the new shard's vnodes capture (≈1/(N+1) of
//!   the space), not a full reshuffle like `id % N` would;
//! * **stateless** — connection threads route without consulting the
//!   shards, so there is no routing table to lock or rebalance.

use anyhow::{ensure, Result};

/// Virtual ring points per shard: enough that the expected load
/// imbalance between shards stays within a few percent, small enough
/// that building and searching the ring is trivial.
pub const VNODES_PER_SHARD: usize = 64;

/// SplitMix64 finalizer (`util::Rng`'s output stage): the ring's point
/// hash and the id hash.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Consistent-hash ring over `shards` shards; see the module docs.
#[derive(Clone, Debug)]
pub struct ShardRouter {
    /// `(ring point, shard)` sorted by point
    ring: Vec<(u64, u32)>,
    shards: usize,
}

impl ShardRouter {
    pub fn new(shards: usize) -> Result<ShardRouter> {
        Self::with_vnodes(shards, VNODES_PER_SHARD)
    }

    /// Ring with an explicit vnode count (tests shrink it to probe
    /// imbalance; serving always uses [`VNODES_PER_SHARD`]).
    pub fn with_vnodes(shards: usize, vnodes: usize) -> Result<ShardRouter> {
        ensure!(shards >= 1, "a shard router needs at least one shard");
        ensure!(vnodes >= 1, "a shard router needs at least one vnode per shard");
        let mut ring = Vec::with_capacity(shards * vnodes);
        for shard in 0..shards as u64 {
            for vnode in 0..vnodes as u64 {
                // distinct, order-free point stream per (shard, vnode):
                // mix a shard stream key with the vnode index
                let point = mix64(mix64(shard) ^ vnode.wrapping_mul(0xA24B_AED4_963E_E407));
                ring.push((point, shard as u32));
            }
        }
        ring.sort_unstable();
        Ok(ShardRouter { ring, shards })
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The shard owning `id` (a session or request id): first ring point
    /// at or clockwise-after `mix64(id)`, wrapping at the top.
    pub fn shard_for(&self, id: u64) -> usize {
        let h = mix64(id);
        let idx = self.ring.partition_point(|&(point, _)| point < h);
        self.ring[idx % self.ring.len()].1 as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_owns_everything() {
        let r = ShardRouter::new(1).unwrap();
        for id in 0..1000 {
            assert_eq!(r.shard_for(id), 0);
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let a = ShardRouter::new(4).unwrap();
        let b = ShardRouter::new(4).unwrap();
        for id in 0..10_000 {
            assert_eq!(a.shard_for(id), b.shard_for(id));
        }
    }

    /// Golden routing pins, cross-checked bit-for-bit by the Python
    /// transliteration in `scripts/crosscheck_net.py` — a silent change
    /// to the mixer or ring construction would reshuffle every session
    /// onto a different shard's `SessionManager`/`DeliveryMonitor`
    /// mid-deployment, so the assignment is part of the wire contract.
    #[test]
    fn hash_stability_golden_pins() {
        let ids: [u64; 8] = [0, 1, 2, 3, 7, 42, 1_000_003, u64::MAX >> 13];
        let got: Vec<Vec<usize>> = [2usize, 3, 4]
            .iter()
            .map(|&n| {
                let r = ShardRouter::new(n).unwrap();
                ids.iter().map(|&id| r.shard_for(id)).collect()
            })
            .collect();
        let expect: [[usize; 8]; 3] = [
            [0, 1, 0, 1, 1, 1, 0, 0],
            [0, 1, 0, 2, 2, 1, 2, 2],
            [3, 1, 0, 2, 2, 1, 3, 2],
        ];
        for (row, (g, e)) in got.iter().zip(expect.iter()).enumerate() {
            assert_eq!(g.as_slice(), e.as_slice(), "shards={}", row + 2);
        }
    }

    #[test]
    fn mixer_golden_pins() {
        // splitmix64 finalizer reference values (shared with util::Rng's
        // output stage and the Python transliteration)
        assert_eq!(mix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(mix64(1), 0x910A_2DEC_8902_5CC1);
        assert_eq!(mix64(0xDEAD_BEEF), 0x4ADF_B90F_68C9_EB9B);
    }

    #[test]
    fn distribution_is_roughly_balanced() {
        let r = ShardRouter::new(4).unwrap();
        let mut counts = [0usize; 4];
        for id in 0..40_000u64 {
            counts[r.shard_for(id)] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            // 4 shards x 64 vnodes: expect 10k +- a few thousand each
            assert!((4_000..=20_000).contains(&c), "shard {s} got {c} of 40k ids");
        }
    }

    #[test]
    fn growth_moves_a_bounded_fraction() {
        // consistent hashing's point: adding a shard must not reshuffle
        // the world.  With id % N, ~3/4 of ids would move from N=3 to 4.
        let before = ShardRouter::new(3).unwrap();
        let after = ShardRouter::new(4).unwrap();
        let moved = (0..40_000u64)
            .filter(|&id| before.shard_for(id) != after.shard_for(id))
            .count();
        assert!(
            moved < 40_000 / 2,
            "{moved} of 40k ids moved when growing 3 -> 4 shards (expected ~1/4)"
        );
    }

    #[test]
    fn rejects_degenerate_configs() {
        assert!(ShardRouter::new(0).is_err());
        assert!(ShardRouter::with_vnodes(2, 0).is_err());
    }
}
