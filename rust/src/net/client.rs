//! Blocking wire client: what `tomers client` and the loopback tests
//! drive the sharded front with.
//!
//! One [`NetClient`] wraps one TCP connection.  Requests are written as
//! frames ([`super::frame`]); responses are decoded as they arrive, in
//! server order — which is **not** request order once forecasts are in
//! flight (terminal forecast responses land whenever their batch
//! executes, interleaved with the synchronous replies).  Callers that
//! pipeline therefore tally responses by type/id rather than zipping them
//! against requests.

use std::io::Read;
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::frame::{write_frame, FrameDecoder};
use super::protocol::{parse_response, request_to_json, Request, Response};

/// A blocking connection to a `serve-net` front.
pub struct NetClient {
    stream: TcpStream,
    dec: FrameDecoder,
    max_frame_bytes: usize,
}

impl NetClient {
    /// Connect once.
    pub fn connect(addr: &str, max_frame_bytes: usize) -> Result<NetClient> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        Ok(NetClient { stream, dec: FrameDecoder::new(max_frame_bytes), max_frame_bytes })
    }

    /// Connect with bounded retries — the smoke gate starts the client
    /// while the server is still binding its listener.
    pub fn connect_retry(addr: &str, max_frame_bytes: usize, attempts: usize) -> Result<NetClient> {
        let mut last = None;
        for i in 0..attempts.max(1) {
            match NetClient::connect(addr, max_frame_bytes) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    last = Some(e);
                    if i + 1 < attempts {
                        thread::sleep(Duration::from_millis(50 << i.min(4)));
                    }
                }
            }
        }
        Err(last.expect("at least one attempt").context(format!(
            "connecting to {addr} ({attempts} attempts)"
        )))
    }

    /// Bound how long [`recv`](Self::recv) blocks waiting for bytes
    /// (`None` = forever).
    pub fn set_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout).context("setting read timeout")
    }

    /// Write one request frame.
    pub fn send(&mut self, req: &Request) -> Result<()> {
        let payload = request_to_json(req).to_string();
        write_frame(&mut self.stream, &payload, self.max_frame_bytes)
            .context("writing request frame")
    }

    /// Block until the next response frame (server order, not request
    /// order — see the module docs).
    pub fn recv(&mut self) -> Result<Response> {
        loop {
            if let Some(payload) = self.dec.next() {
                return parse_response(&payload);
            }
            let mut buf = [0u8; 4096];
            let n = self.stream.read(&mut buf).context("reading response frame")?;
            if n == 0 {
                if self.dec.mid_frame() {
                    bail!("server closed the connection mid-frame");
                }
                bail!("server closed the connection");
            }
            self.dec.push(&buf[..n])?;
        }
    }

    /// `send` + `recv` for strictly synchronous exchanges (collect, ack,
    /// report).  Only valid when no forecast responses are in flight on
    /// this connection — an in-flight terminal response would be returned
    /// here instead.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.send(req)?;
        self.recv()
    }
}
