//! Wire framing: length-prefixed line-JSON frames (DESIGN.md §12).
//!
//! A frame is a 4-byte big-endian length followed by exactly that many
//! bytes of UTF-8 JSON (one logical line — the compact `Json::to_string`
//! form contains no raw newlines).  The length prefix makes partial
//! reads unambiguous (no scanning for delimiters inside string escapes)
//! and lets the receiver enforce its memory bound **before** allocating:
//! a header declaring more than `max_frame_bytes` is rejected on sight,
//! so a hostile or broken peer cannot make a connection thread reserve
//! an arbitrary buffer.
//!
//! Two consumption styles share the same state machine:
//!
//! * [`FrameDecoder`] — incremental: feed whatever `read` returned
//!   (`push`), pop completed frames (`next`).  The server's connection
//!   threads use this under a read timeout so a blocked socket never
//!   wedges a partial frame, and the unit tests drive it byte-by-byte
//!   to pin reassembly across arbitrary read boundaries.
//! * [`write_frame`] — blocking write of one frame, used by both sides.

use std::collections::VecDeque;
use std::io::Write;

use anyhow::{bail, ensure, Result};

/// Default per-frame payload bound (the `"net"."max_frame_bytes"` config
/// default): generous for forecast contexts, small enough that a
/// per-connection buffer is never a memory event.
pub const DEFAULT_MAX_FRAME_BYTES: usize = 1 << 20;

/// Length-prefix header size (u32, big-endian).
pub const FRAME_HEADER_BYTES: usize = 4;

/// Write one frame: 4-byte big-endian length + the UTF-8 payload.
/// Callers pass the same `max_frame_bytes` they accept, so an oversized
/// *outgoing* frame fails loudly at the sender instead of poisoning the
/// peer's connection.
pub fn write_frame(w: &mut impl Write, payload: &str, max_frame_bytes: usize) -> Result<()> {
    ensure!(!payload.is_empty(), "refusing to send an empty frame");
    ensure!(
        payload.len() <= max_frame_bytes,
        "frame payload of {} bytes exceeds max_frame_bytes = {max_frame_bytes}",
        payload.len()
    );
    let len = (payload.len() as u32).to_be_bytes();
    w.write_all(&len)?;
    w.write_all(payload.as_bytes())?;
    w.flush()?;
    Ok(())
}

/// Incremental frame reassembler with a hard payload bound; see the
/// module docs.  After an error (oversized or zero-length header, bad
/// UTF-8) the byte stream has lost framing sync, so the connection must
/// be closed — the decoder stays poisoned and keeps erroring.
#[derive(Debug)]
pub struct FrameDecoder {
    max_frame_bytes: usize,
    /// partial length prefix (big-endian accumulation)
    header: [u8; FRAME_HEADER_BYTES],
    header_len: usize,
    /// expected payload length once the header is complete
    need: Option<usize>,
    payload: Vec<u8>,
    ready: VecDeque<String>,
    poisoned: bool,
}

impl FrameDecoder {
    pub fn new(max_frame_bytes: usize) -> FrameDecoder {
        FrameDecoder {
            max_frame_bytes: max_frame_bytes.max(1),
            header: [0; FRAME_HEADER_BYTES],
            header_len: 0,
            need: None,
            payload: Vec::new(),
            ready: VecDeque::new(),
            poisoned: false,
        }
    }

    /// Feed bytes as they arrived off the socket.  Completed frames are
    /// queued for [`next`](Self::next); a framing violation (length 0 or
    /// beyond the bound, invalid UTF-8) errors **before** any payload
    /// allocation for that frame and poisons the decoder.
    pub fn push(&mut self, mut chunk: &[u8]) -> Result<()> {
        ensure!(!self.poisoned, "frame decoder poisoned by an earlier framing error");
        while !chunk.is_empty() {
            match self.need {
                None => {
                    let take = (FRAME_HEADER_BYTES - self.header_len).min(chunk.len());
                    self.header[self.header_len..self.header_len + take]
                        .copy_from_slice(&chunk[..take]);
                    self.header_len += take;
                    chunk = &chunk[take..];
                    if self.header_len == FRAME_HEADER_BYTES {
                        let len = u32::from_be_bytes(self.header) as usize;
                        if len == 0 || len > self.max_frame_bytes {
                            self.poisoned = true;
                            bail!(
                                "frame header declares {len} bytes — outside \
                                 (0, max_frame_bytes = {}]",
                                self.max_frame_bytes
                            );
                        }
                        self.need = Some(len);
                        self.header_len = 0;
                    }
                }
                Some(len) => {
                    let take = (len - self.payload.len()).min(chunk.len());
                    self.payload.extend_from_slice(&chunk[..take]);
                    chunk = &chunk[take..];
                    if self.payload.len() == len {
                        let bytes = std::mem::take(&mut self.payload);
                        self.need = None;
                        match String::from_utf8(bytes) {
                            Ok(s) => self.ready.push_back(s),
                            Err(_) => {
                                self.poisoned = true;
                                bail!("frame payload is not valid UTF-8");
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Pop the next completed frame payload, if any.
    pub fn next(&mut self) -> Option<String> {
        self.ready.pop_front()
    }

    /// Whether a frame is mid-reassembly (useful for "clean EOF" checks:
    /// EOF with `mid_frame()` is a truncated stream, not a close).
    pub fn mid_frame(&self) -> bool {
        self.header_len > 0 || self.need.is_some()
    }

    /// Bytes currently buffered for the in-progress frame — by
    /// construction `<= max_frame_bytes`; the bound test asserts the
    /// backing capacity too.
    pub fn buffered(&self) -> usize {
        self.header_len + self.payload.len()
    }

    /// Capacity of the payload buffer (for the no-allocation-on-reject
    /// test: a rejected oversized header must leave this untouched).
    pub fn payload_capacity(&self) -> usize {
        self.payload.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_bytes(payload: &str) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, payload, DEFAULT_MAX_FRAME_BYTES).unwrap();
        out
    }

    #[test]
    fn roundtrip_one_frame() {
        let mut dec = FrameDecoder::new(1024);
        dec.push(&frame_bytes(r#"{"type":"report"}"#)).unwrap();
        assert_eq!(dec.next().as_deref(), Some(r#"{"type":"report"}"#));
        assert!(dec.next().is_none());
        assert!(!dec.mid_frame());
    }

    #[test]
    fn partial_frames_across_reads() {
        // byte-by-byte delivery: reassembly must be boundary-agnostic
        let mut bytes = frame_bytes(r#"{"type":"collect","session":7}"#);
        bytes.extend(frame_bytes(r#"{"type":"ack","session":7,"upto":3}"#));
        let mut dec = FrameDecoder::new(1024);
        let mut got = Vec::new();
        for b in bytes {
            dec.push(&[b]).unwrap();
            while let Some(f) = dec.next() {
                got.push(f);
            }
        }
        assert_eq!(
            got,
            vec![
                r#"{"type":"collect","session":7}"#.to_string(),
                r#"{"type":"ack","session":7,"upto":3}"#.to_string(),
            ]
        );
        // ragged split straddling a header boundary
        let bytes = frame_bytes("[1,2,3]");
        let mut dec = FrameDecoder::new(1024);
        dec.push(&bytes[..3]).unwrap();
        assert!(dec.mid_frame() && dec.next().is_none());
        dec.push(&bytes[3..6]).unwrap();
        dec.push(&bytes[6..]).unwrap();
        assert_eq!(dec.next().as_deref(), Some("[1,2,3]"));
    }

    #[test]
    fn multiple_frames_in_one_read() {
        let mut bytes = Vec::new();
        for i in 0..5 {
            bytes.extend(frame_bytes(&format!("[{i}]")));
        }
        let mut dec = FrameDecoder::new(64);
        dec.push(&bytes).unwrap();
        let got: Vec<String> = std::iter::from_fn(|| dec.next()).collect();
        assert_eq!(got, vec!["[0]", "[1]", "[2]", "[3]", "[4]"]);
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut dec = FrameDecoder::new(64);
        // header declares 16 MiB; the decoder must reject on the header
        // alone, never reserving the declared payload
        let header = ((16u32) << 20).to_be_bytes();
        let err = dec.push(&header).unwrap_err();
        assert!(err.to_string().contains("max_frame_bytes"), "{err}");
        assert_eq!(dec.payload_capacity(), 0, "rejected frame must not allocate");
        // the decoder is poisoned: framing sync is unrecoverable
        assert!(dec.push(b"x").is_err());
    }

    #[test]
    fn zero_length_frame_rejected() {
        let mut dec = FrameDecoder::new(64);
        assert!(dec.push(&0u32.to_be_bytes()).is_err());
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut dec = FrameDecoder::new(64);
        let mut bytes = 2u32.to_be_bytes().to_vec();
        bytes.extend([0xff, 0xfe]);
        assert!(dec.push(&bytes).is_err());
        assert!(dec.push(b"x").is_err(), "poisoned after the framing error");
    }

    #[test]
    fn writer_rejects_oversized_and_empty_payloads() {
        let mut out = Vec::new();
        assert!(write_frame(&mut out, "", 64).is_err());
        assert!(write_frame(&mut out, &"x".repeat(65), 64).is_err());
        assert!(out.is_empty(), "rejected frames must write nothing");
        write_frame(&mut out, "ok", 64).unwrap();
        assert_eq!(out.len(), FRAME_HEADER_BYTES + 2);
    }

    #[test]
    fn buffered_stays_within_bound() {
        let mut dec = FrameDecoder::new(32);
        let bytes = frame_bytes(&"a".repeat(32));
        // feed all but the last byte: buffered payload is at its max
        dec.push(&bytes[..bytes.len() - 1]).unwrap();
        assert!(dec.buffered() <= 32 + FRAME_HEADER_BYTES);
        dec.push(&bytes[bytes.len() - 1..]).unwrap();
        assert_eq!(dec.next().unwrap().len(), 32);
    }
}
