//! Layer-4 network front: a sharded TCP serving fabric over the
//! coordinator (DESIGN.md §12).
//!
//! The coordinator serves one process-internal dual loop
//! ([`crate::coordinator::serve_loop`]); this layer puts a wire and a
//! shard fabric in front of it, dependency-free over `std::net`:
//!
//! * `frame`    — length-prefixed line-JSON framing with a hard
//!   per-frame memory bound (`max_frame_bytes`, enforced before
//!   allocation).
//! * `protocol` — the frame vocabulary: forecast / append / collect /
//!   ack / report / metrics requests and their terminal responses, parsed
//!   with the config system's unknown-key-rejection strictness.
//! * `router`   — [`ShardRouter`]: consistent-hashes session/request ids
//!   onto shards via a splitmix64 vnode ring; deterministic across
//!   processes (golden-pinned and cross-checked by
//!   `scripts/crosscheck_net.py`).
//! * `server`   — N self-contained shards (each its own dual serve loop,
//!   device thread, session table, `DeliveryMonitor`, bounded intake)
//!   behind one acceptor; fail-fast backpressure on the wire; graceful
//!   drain merging per-shard metrics into one process report.
//! * `client`   — the blocking loopback driver `tomers client` uses.
//!
//! There is deliberately **no cross-shard rebalancing**: an id's shard is
//! a pure function of the id, so shards share nothing (no routing table,
//! no cross-shard locks) and the fabric scales to N device threads.

pub mod client;
pub mod frame;
pub mod protocol;
pub mod router;
pub mod server;

use anyhow::{ensure, Result};

pub use client::NetClient;
pub use frame::{write_frame, FrameDecoder, DEFAULT_MAX_FRAME_BYTES, FRAME_HEADER_BYTES};
pub use protocol::{
    forecast_response, parse_request, parse_response, request_to_json, response_to_json,
    Request, Response,
};
pub use router::{mix64, ShardRouter, VNODES_PER_SHARD};
pub use server::{
    process_metrics_json, process_report, serve_net, spawn_shard, NetServerHandle,
    ShardPorts, ShardSpec,
};

/// The `"net"` config block (parsed by [`crate::config::net_from_json`]):
/// how `tomers serve-net` exposes the shard fabric.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetConfig {
    /// independent serve-loop shards (= device threads)
    pub shards: usize,
    /// listen address; port 0 picks an ephemeral port (tests, loopback
    /// smoke gates)
    pub addr: String,
    /// concurrent connection cap — excess connects get an error frame
    /// and are closed, never queued
    pub max_conns: usize,
    /// per-frame payload bound, enforced on both sides before allocation
    pub max_frame_bytes: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            shards: 2,
            addr: "127.0.0.1:0".to_string(),
            max_conns: 64,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
        }
    }
}

impl NetConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.shards >= 1, "net.shards must be >= 1");
        ensure!(!self.addr.is_empty(), "net.addr must not be empty");
        ensure!(self.max_conns >= 1, "net.max_conns must be >= 1");
        ensure!(
            self.max_frame_bytes >= 64,
            "net.max_frame_bytes must be >= 64 (error frames need room)"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_validates() {
        NetConfig::default().validate().unwrap();
    }

    #[test]
    fn degenerate_configs_rejected() {
        for cfg in [
            NetConfig { shards: 0, ..NetConfig::default() },
            NetConfig { addr: String::new(), ..NetConfig::default() },
            NetConfig { max_conns: 0, ..NetConfig::default() },
            NetConfig { max_frame_bytes: 8, ..NetConfig::default() },
        ] {
            assert!(cfg.validate().is_err(), "{cfg:?} must be rejected");
        }
    }
}
