//! Frame vocabulary: the JSON payloads carried by [`frame`](super::frame)
//! frames, parsed with the same strictness discipline as the config
//! system (`config.rs`): **unknown keys are rejected at every level**,
//! a wrong-typed value is an error, and every request frame yields
//! exactly one response frame — the wire realisation of the in-process
//! terminal-outcome contract (`ForecastOutcome`).
//!
//! Requests (client → server), dispatched on `"type"`:
//!
//! ```json
//! {"type": "forecast", "id": 7, "context": [0.1, 0.2]}
//! {"type": "append",   "session": 3, "points": [0.5, 0.5]}
//! {"type": "collect",  "session": 3}
//! {"type": "ack",      "session": 3, "upto": 11}
//! {"type": "report"}
//! ```
//!
//! Responses (server → client): `"forecast"` (terminal, with
//! `"outcome"` of `delivered | deadline_exceeded | failed` and the
//! serving shard), `"appended"`, `"collected"` (the unacked outbox,
//! oldest first), `"acked"`, `"report"` (merged text + summed delivery
//! ledger) and `"error"` (per-connection: malformed input or wire
//! backpressure — never a process fault).

use anyhow::{bail, ensure, Context, Result};

use crate::config::reject_unknown_keys;
use crate::coordinator::{DeliveryStats, ForecastOutcome, ForecastResponse};
use crate::json::Json;

/// A decoded client request frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// one-shot forecast over a materialized context
    Forecast { id: u64, context: Vec<f32> },
    /// stream observations for a session (whole `d`-channel frames)
    Append { session: u64, points: Vec<f32> },
    /// fetch the session's unacked forecasts (at-least-once)
    Collect { session: u64 },
    /// retire the session's forecasts with `seq <= upto`
    Ack { session: u64, upto: u64 },
    /// merged per-shard metrics report
    Report,
    /// merged per-shard structured metrics (JSON; the machine-readable
    /// twin of `Report` — see `Metrics::to_json` / `merged_json`)
    Metrics,
}

/// A decoded server response frame.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// terminal outcome of one forecast request
    Forecast {
        id: u64,
        outcome: ForecastOutcome,
        forecast: Vec<f32>,
        variant: String,
        latency_ms: f64,
        batch_size: usize,
        shard: usize,
    },
    /// the append was accepted into the shard's bounded intake
    Appended { session: u64, shard: usize },
    /// the session's unacked forecasts, oldest first
    Collected { session: u64, shard: usize, entries: Vec<(u64, Vec<f32>)> },
    /// how many forecasts the ack retired
    Acked { session: u64, shard: usize, count: usize },
    /// merged metrics text + the summed delivery ledger
    Report { text: String, delivery: DeliveryStats },
    /// merged structured metrics: `{"shards": [...], "total": {...}}`
    /// (`coordinator::merged_json`); carried opaque so new telemetry
    /// fields never need a wire change
    Metrics { metrics: Json },
    /// per-connection error: what failed (`context`) and why
    Error { context: String, reason: String },
}

fn get_u64(v: &Json, key: &str, path: &str) -> Result<u64> {
    let n = v.req(key).with_context(|| format!("{path}: missing {key:?}"))?.as_f64()?;
    ensure!(
        n.fract() == 0.0 && (0.0..=9.007_199_254_740_992e15).contains(&n),
        "{path}: {key} must be a non-negative integer"
    );
    Ok(n as u64)
}

fn get_f32s(v: &Json, key: &str, path: &str) -> Result<Vec<f32>> {
    v.req(key)
        .with_context(|| format!("{path}: missing {key:?}"))?
        .as_arr()?
        .iter()
        .map(|x| Ok(x.as_f64()? as f32))
        .collect()
}

fn f32s_json(values: &[f32]) -> Json {
    Json::arr(values.iter().map(|&x| Json::num(x as f64)).collect())
}

/// Parse one request frame payload; see the module docs for the grammar.
pub fn parse_request(text: &str) -> Result<Request> {
    let v = Json::parse(text).context("request frame is not valid JSON")?;
    let ty = v.req("type").context("request frame: missing \"type\"")?.as_str()?.to_string();
    match ty.as_str() {
        "forecast" => {
            reject_unknown_keys(&v, "\"forecast\" frame", &["type", "id", "context"])?;
            Ok(Request::Forecast {
                id: get_u64(&v, "id", "\"forecast\" frame")?,
                context: get_f32s(&v, "context", "\"forecast\" frame")?,
            })
        }
        "append" => {
            reject_unknown_keys(&v, "\"append\" frame", &["type", "session", "points"])?;
            Ok(Request::Append {
                session: get_u64(&v, "session", "\"append\" frame")?,
                points: get_f32s(&v, "points", "\"append\" frame")?,
            })
        }
        "collect" => {
            reject_unknown_keys(&v, "\"collect\" frame", &["type", "session"])?;
            Ok(Request::Collect { session: get_u64(&v, "session", "\"collect\" frame")? })
        }
        "ack" => {
            reject_unknown_keys(&v, "\"ack\" frame", &["type", "session", "upto"])?;
            Ok(Request::Ack {
                session: get_u64(&v, "session", "\"ack\" frame")?,
                upto: get_u64(&v, "upto", "\"ack\" frame")?,
            })
        }
        "report" => {
            reject_unknown_keys(&v, "\"report\" frame", &["type"])?;
            Ok(Request::Report)
        }
        "metrics" => {
            reject_unknown_keys(&v, "\"metrics\" frame", &["type"])?;
            Ok(Request::Metrics)
        }
        other => bail!(
            "unknown request type {other:?} — accepted: forecast | append | collect | \
             ack | report | metrics"
        ),
    }
}

/// Serialize one request frame payload (the client half).
pub fn request_to_json(req: &Request) -> Json {
    match req {
        Request::Forecast { id, context } => Json::obj(vec![
            ("type", Json::str("forecast")),
            ("id", Json::num(*id as f64)),
            ("context", f32s_json(context)),
        ]),
        Request::Append { session, points } => Json::obj(vec![
            ("type", Json::str("append")),
            ("session", Json::num(*session as f64)),
            ("points", f32s_json(points)),
        ]),
        Request::Collect { session } => Json::obj(vec![
            ("type", Json::str("collect")),
            ("session", Json::num(*session as f64)),
        ]),
        Request::Ack { session, upto } => Json::obj(vec![
            ("type", Json::str("ack")),
            ("session", Json::num(*session as f64)),
            ("upto", Json::num(*upto as f64)),
        ]),
        Request::Report => Json::obj(vec![("type", Json::str("report"))]),
        Request::Metrics => Json::obj(vec![("type", Json::str("metrics"))]),
    }
}

/// The `"outcome"` wire word for a terminal [`ForecastOutcome`].
fn outcome_word(outcome: &ForecastOutcome) -> &'static str {
    match outcome {
        ForecastOutcome::Delivered => "delivered",
        ForecastOutcome::DeadlineExceeded => "deadline_exceeded",
        ForecastOutcome::Failed(_) => "failed",
    }
}

/// Wrap a served [`ForecastResponse`] (plus the shard that served it)
/// into its wire frame.
pub fn forecast_response(resp: &ForecastResponse, shard: usize) -> Response {
    Response::Forecast {
        id: resp.id,
        outcome: resp.outcome.clone(),
        forecast: resp.forecast.clone(),
        variant: resp.variant.clone(),
        latency_ms: resp.latency * 1e3,
        batch_size: resp.batch_size,
        shard,
    }
}

/// Serialize one response frame payload (the server half).
pub fn response_to_json(resp: &Response) -> Json {
    match resp {
        Response::Forecast { id, outcome, forecast, variant, latency_ms, batch_size, shard } => {
            let mut pairs = vec![
                ("type", Json::str("forecast")),
                ("id", Json::num(*id as f64)),
                ("outcome", Json::str(outcome_word(outcome))),
            ];
            if let ForecastOutcome::Failed(reason) = outcome {
                pairs.push(("reason", Json::str(reason.clone())));
            }
            pairs.extend([
                ("forecast", f32s_json(forecast)),
                ("variant", Json::str(variant.clone())),
                ("latency_ms", Json::num(*latency_ms)),
                ("batch_size", Json::num(*batch_size as f64)),
                ("shard", Json::num(*shard as f64)),
            ]);
            Json::obj(pairs)
        }
        Response::Appended { session, shard } => Json::obj(vec![
            ("type", Json::str("appended")),
            ("session", Json::num(*session as f64)),
            ("shard", Json::num(*shard as f64)),
        ]),
        Response::Collected { session, shard, entries } => Json::obj(vec![
            ("type", Json::str("collected")),
            ("session", Json::num(*session as f64)),
            ("shard", Json::num(*shard as f64)),
            (
                "entries",
                Json::arr(
                    entries
                        .iter()
                        .map(|(seq, forecast)| {
                            Json::obj(vec![
                                ("seq", Json::num(*seq as f64)),
                                ("forecast", f32s_json(forecast)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        Response::Acked { session, shard, count } => Json::obj(vec![
            ("type", Json::str("acked")),
            ("session", Json::num(*session as f64)),
            ("shard", Json::num(*shard as f64)),
            ("count", Json::num(*count as f64)),
        ]),
        Response::Report { text, delivery } => Json::obj(vec![
            ("type", Json::str("report")),
            ("text", Json::str(text.clone())),
            ("enqueued", Json::num(delivery.enqueued as f64)),
            ("acked", Json::num(delivery.acked as f64)),
            ("redelivered", Json::num(delivery.redelivered as f64)),
            ("expired_undelivered", Json::num(delivery.expired_undelivered as f64)),
            ("dropped_overflow", Json::num(delivery.dropped_overflow as f64)),
            ("pending", Json::num(delivery.pending as f64)),
        ]),
        Response::Metrics { metrics } => Json::obj(vec![
            ("type", Json::str("metrics")),
            ("metrics", metrics.clone()),
        ]),
        Response::Error { context, reason } => Json::obj(vec![
            ("type", Json::str("error")),
            ("context", Json::str(context.clone())),
            ("reason", Json::str(reason.clone())),
        ]),
    }
}

/// Parse one response frame payload (the client half).
pub fn parse_response(text: &str) -> Result<Response> {
    let v = Json::parse(text).context("response frame is not valid JSON")?;
    let ty = v.req("type").context("response frame: missing \"type\"")?.as_str()?.to_string();
    match ty.as_str() {
        "forecast" => {
            reject_unknown_keys(
                &v,
                "\"forecast\" response",
                &[
                    "type",
                    "id",
                    "outcome",
                    "reason",
                    "forecast",
                    "variant",
                    "latency_ms",
                    "batch_size",
                    "shard",
                ],
            )?;
            let outcome = match v.req("outcome")?.as_str()? {
                "delivered" => ForecastOutcome::Delivered,
                "deadline_exceeded" => ForecastOutcome::DeadlineExceeded,
                "failed" => ForecastOutcome::Failed(match v.get("reason") {
                    Some(r) => r.as_str()?.to_string(),
                    None => String::new(),
                }),
                other => bail!("unknown forecast outcome {other:?}"),
            };
            Ok(Response::Forecast {
                id: get_u64(&v, "id", "\"forecast\" response")?,
                outcome,
                forecast: get_f32s(&v, "forecast", "\"forecast\" response")?,
                variant: v.req("variant")?.as_str()?.to_string(),
                latency_ms: v.req("latency_ms")?.as_f64()?,
                batch_size: v.req("batch_size")?.as_usize()?,
                shard: v.req("shard")?.as_usize()?,
            })
        }
        "appended" => {
            reject_unknown_keys(&v, "\"appended\" response", &["type", "session", "shard"])?;
            Ok(Response::Appended {
                session: get_u64(&v, "session", "\"appended\" response")?,
                shard: v.req("shard")?.as_usize()?,
            })
        }
        "collected" => {
            reject_unknown_keys(
                &v,
                "\"collected\" response",
                &["type", "session", "shard", "entries"],
            )?;
            let mut entries = Vec::new();
            for (i, e) in v.req("entries")?.as_arr()?.iter().enumerate() {
                let path = format!("\"collected\" entries[{i}]");
                reject_unknown_keys(e, &path, &["seq", "forecast"])?;
                entries.push((get_u64(e, "seq", &path)?, get_f32s(e, "forecast", &path)?));
            }
            Ok(Response::Collected {
                session: get_u64(&v, "session", "\"collected\" response")?,
                shard: v.req("shard")?.as_usize()?,
                entries,
            })
        }
        "acked" => {
            reject_unknown_keys(
                &v,
                "\"acked\" response",
                &["type", "session", "shard", "count"],
            )?;
            Ok(Response::Acked {
                session: get_u64(&v, "session", "\"acked\" response")?,
                shard: v.req("shard")?.as_usize()?,
                count: v.req("count")?.as_usize()?,
            })
        }
        "report" => {
            reject_unknown_keys(
                &v,
                "\"report\" response",
                &[
                    "type",
                    "text",
                    "enqueued",
                    "acked",
                    "redelivered",
                    "expired_undelivered",
                    "dropped_overflow",
                    "pending",
                ],
            )?;
            Ok(Response::Report {
                text: v.req("text")?.as_str()?.to_string(),
                delivery: DeliveryStats {
                    enqueued: get_u64(&v, "enqueued", "\"report\" response")?,
                    acked: get_u64(&v, "acked", "\"report\" response")?,
                    redelivered: get_u64(&v, "redelivered", "\"report\" response")?,
                    expired_undelivered: get_u64(
                        &v,
                        "expired_undelivered",
                        "\"report\" response",
                    )?,
                    dropped_overflow: get_u64(&v, "dropped_overflow", "\"report\" response")?,
                    pending: get_u64(&v, "pending", "\"report\" response")?,
                },
            })
        }
        "metrics" => {
            reject_unknown_keys(&v, "\"metrics\" response", &["type", "metrics"])?;
            Ok(Response::Metrics {
                metrics: v.req("metrics").context("\"metrics\" response")?.clone(),
            })
        }
        "error" => {
            reject_unknown_keys(&v, "\"error\" response", &["type", "context", "reason"])?;
            Ok(Response::Error {
                context: v.req("context")?.as_str()?.to_string(),
                reason: v.req("reason")?.as_str()?.to_string(),
            })
        }
        other => bail!("unknown response type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: Request) {
        let text = request_to_json(&req).to_string();
        assert_eq!(parse_request(&text).unwrap(), req, "{text}");
    }

    fn roundtrip_response(resp: Response) {
        let text = response_to_json(&resp).to_string();
        assert_eq!(parse_response(&text).unwrap(), resp, "{text}");
    }

    #[test]
    fn request_roundtrips() {
        roundtrip_request(Request::Forecast { id: 7, context: vec![0.25, -1.5, 3.375] });
        roundtrip_request(Request::Append { session: 3, points: vec![0.5, 0.125] });
        roundtrip_request(Request::Collect { session: u64::MAX >> 12 });
        roundtrip_request(Request::Ack { session: 3, upto: 11 });
        roundtrip_request(Request::Report);
        roundtrip_request(Request::Metrics);
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_response(Response::Forecast {
            id: 9,
            outcome: ForecastOutcome::Delivered,
            forecast: vec![1.0, 2.5],
            variant: "v".into(),
            latency_ms: 12.5,
            batch_size: 4,
            shard: 1,
        });
        roundtrip_response(Response::Forecast {
            id: 10,
            outcome: ForecastOutcome::Failed("backpressure: shard 0 intake full".into()),
            forecast: vec![],
            variant: String::new(),
            latency_ms: 0.5,
            batch_size: 0,
            shard: 0,
        });
        roundtrip_response(Response::Appended { session: 3, shard: 1 });
        roundtrip_response(Response::Collected {
            session: 3,
            shard: 1,
            entries: vec![(0, vec![1.0]), (1, vec![2.0, 3.0])],
        });
        roundtrip_response(Response::Acked { session: 3, shard: 1, count: 2 });
        roundtrip_response(Response::Report {
            text: "served=1\n".into(),
            delivery: DeliveryStats {
                enqueued: 10,
                acked: 4,
                redelivered: 1,
                expired_undelivered: 2,
                dropped_overflow: 1,
                pending: 3,
            },
        });
        roundtrip_response(Response::Error { context: "parse".into(), reason: "bad".into() });
        roundtrip_response(Response::Metrics {
            metrics: Json::obj(vec![
                ("shards", Json::arr(vec![Json::obj(vec![("served", Json::num(3.0))])])),
                ("total", Json::obj(vec![("served", Json::num(3.0))])),
            ]),
        });
    }

    #[test]
    fn unknown_keys_rejected_at_every_level() {
        let err = parse_request(r#"{"type":"collect","session":1,"sesion":2}"#).unwrap_err();
        assert!(err.to_string().contains("sesion"), "{err}");
        let err = parse_request(r#"{"type":"forecast","id":1,"context":[1],"prio":9}"#)
            .unwrap_err();
        assert!(err.to_string().contains("prio"), "{err}");
        // nested: a collected entry with a stray key
        let err = parse_response(
            r#"{"type":"collected","session":1,"shard":0,
                "entries":[{"seq":0,"forecast":[1],"extra":true}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("extra"), "{err}");
    }

    #[test]
    fn malformed_and_mistyped_frames_rejected() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"type":"warp"}"#).is_err());
        assert!(parse_request(r#"{"type":"collect","session":"three"}"#).is_err());
        assert!(parse_request(r#"{"type":"ack","session":1,"upto":-3}"#).is_err());
        assert!(parse_request(r#"{"type":"ack","session":1,"upto":1.5}"#).is_err());
        assert!(parse_response(r#"{"type":"forecast","id":1,"outcome":"maybe",
            "forecast":[],"variant":"v","latency_ms":1,"batch_size":1,"shard":0}"#)
            .is_err());
    }

    #[test]
    fn forecast_wrapper_carries_shard_and_reason() {
        let resp = ForecastResponse {
            id: 4,
            forecast: vec![],
            variant: "v".into(),
            latency: 0.002,
            batch_size: 2,
            outcome: ForecastOutcome::Failed("injected fault #3".into()),
        };
        let wire = forecast_response(&resp, 1);
        let text = response_to_json(&wire).to_string();
        assert!(text.contains("\"shard\": 1") || text.contains("\"shard\":1"), "{text}");
        match parse_response(&text).unwrap() {
            Response::Forecast { outcome: ForecastOutcome::Failed(r), shard, .. } => {
                assert_eq!(r, "injected fault #3");
                assert_eq!(shard, 1);
            }
            other => panic!("wrong decode: {other:?}"),
        }
    }
}
