//! PJRT execution engine: loads HLO-text artifacts, binds weights, runs.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin).  One `Engine` owns the
//! client; each `Model` owns a compiled executable plus its weights
//! pre-staged as device buffers, so the request hot path does exactly one
//! host->device transfer per *input* batch and none for weights.
//!
//! Interchange is HLO text (`HloModuleProto::from_text_file`) — see
//! `python/compile/aot.py` for why serialized protos are rejected.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};
use xla::{ElementType, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::manifest::Manifest;
use super::weights::WeightStore;
use crate::tensor::Tensor;

pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
}

impl Engine {
    /// CPU PJRT engine over an artifact directory.
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Engine> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { client: client.clone(), dir: artifact_dir.into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Names of all artifacts present in the directory.
    pub fn available(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if let Some(name) = path.file_name().and_then(|n| n.to_str()) {
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// Load + compile one artifact (no weights bound yet).
    pub fn load(&self, name: &str) -> Result<Model> {
        let manifest = Manifest::load(&self.dir.join(format!("{name}.json")))?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Model { manifest, exe, client: self.client.clone(), weight_bufs: Vec::new() })
    }

    /// Load an artifact and bind its identity's weights file from the
    /// artifact directory (`<identity>.weights.bin`).
    pub fn load_with_weights(&self, name: &str) -> Result<Model> {
        let mut model = self.load(name)?;
        let identity = name.split("__").next().unwrap_or(name);
        let ws = WeightStore::load(&self.dir.join(format!("{identity}.weights.bin")))?;
        model.bind_weights(&ws)?;
        Ok(model)
    }

    pub fn tensor_to_buffer(&self, t: &Tensor) -> Result<PjRtBuffer> {
        let buf = match t {
            Tensor::F32 { shape, data } => {
                self.client.buffer_from_host_buffer::<f32>(data, shape, None)
            }
            Tensor::I32 { shape, data } => {
                self.client.buffer_from_host_buffer::<i32>(data, shape, None)
            }
        };
        buf.map_err(|e| anyhow!("host->device transfer: {e:?}"))
    }
}

pub struct Model {
    pub manifest: Manifest,
    exe: PjRtLoadedExecutable,
    client: PjRtClient,
    weight_bufs: Vec<PjRtBuffer>,
}

impl Model {
    /// Stage weights on device in manifest parameter order, validating
    /// every shape against the manifest.
    pub fn bind_weights(&mut self, ws: &WeightStore) -> Result<()> {
        let mut bufs = Vec::with_capacity(self.manifest.params.len());
        for spec in &self.manifest.params {
            let t = ws
                .get(&spec.name)
                .with_context(|| format!("binding weights for {}", self.manifest.name))?;
            ensure!(
                t.shape() == spec.shape.as_slice(),
                "weight {} shape {:?} != manifest {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
            let buf = match t {
                Tensor::F32 { shape, data } => {
                    self.client.buffer_from_host_buffer::<f32>(data, shape, None)
                }
                Tensor::I32 { shape, data } => {
                    self.client.buffer_from_host_buffer::<i32>(data, shape, None)
                }
            }
            .map_err(|e| anyhow!("staging weight {}: {e:?}", spec.name))?;
            bufs.push(buf);
        }
        self.weight_bufs = bufs;
        Ok(())
    }

    pub fn has_weights(&self) -> bool {
        !self.weight_bufs.is_empty() || self.manifest.params.is_empty()
    }

    /// Execute with data inputs in manifest input order; returns output
    /// tensors in manifest output order.
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        ensure!(self.has_weights(), "{}: weights not bound", self.manifest.name);
        ensure!(
            inputs.len() == self.manifest.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.manifest.name,
            self.manifest.inputs.len(),
            inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&self.manifest.inputs) {
            ensure!(
                t.shape() == spec.shape.as_slice() && t.dtype() == spec.dtype,
                "{}: input {} got {:?}/{} want {:?}/{}",
                self.manifest.name,
                spec.name,
                t.shape(),
                t.dtype(),
                spec.shape,
                spec.dtype
            );
        }
        let mut input_bufs = Vec::with_capacity(inputs.len());
        for t in inputs {
            let buf = match t {
                Tensor::F32 { shape, data } => {
                    self.client.buffer_from_host_buffer::<f32>(data, shape, None)
                }
                Tensor::I32 { shape, data } => {
                    self.client.buffer_from_host_buffer::<i32>(data, shape, None)
                }
            }
            .map_err(|e| anyhow!("input transfer: {e:?}"))?;
            input_bufs.push(buf);
        }
        let mut args: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(input_bufs.iter());

        let results = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.manifest.name))?;
        ensure!(!results.is_empty() && !results[0].is_empty(), "empty execution result");

        let mut outputs = Vec::new();
        if results[0].len() == 1 {
            // single tuple buffer (return_tuple=True lowering)
            let lit = results[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result: {e:?}"))?;
            let parts = untuple(lit)?;
            for part in parts {
                outputs.push(literal_to_tensor(&part)?);
            }
        } else {
            for buf in &results[0] {
                let lit = buf.to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
                outputs.push(literal_to_tensor(&lit)?);
            }
        }
        ensure!(
            outputs.len() == self.manifest.outputs.len(),
            "{}: got {} outputs, manifest says {}",
            self.manifest.name,
            outputs.len(),
            self.manifest.outputs.len()
        );
        Ok(outputs)
    }

    /// Buffer-level execute for device-resident pipelines (the training
    /// hot path): takes borrowed device buffers in full argument order
    /// (params first, then data inputs) and returns the raw output
    /// buffers without any host transfer.  Requires the artifact to have
    /// been lowered with untupled outputs (aot.py does this) so PJRT
    /// splits the root tuple into one buffer per output.
    pub fn execute_buffers(&self, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let results = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.manifest.name))?;
        ensure!(!results.is_empty(), "empty execution result");
        Ok(results.into_iter().next().unwrap())
    }

    /// Stage a host tensor as a device buffer on this model's client.
    pub fn stage(&self, t: &Tensor) -> Result<PjRtBuffer> {
        let buf = match t {
            Tensor::F32 { shape, data } => {
                self.client.buffer_from_host_buffer::<f32>(data, shape, None)
            }
            Tensor::I32 { shape, data } => {
                self.client.buffer_from_host_buffer::<i32>(data, shape, None)
            }
        };
        buf.map_err(|e| anyhow!("host->device transfer: {e:?}"))
    }

    /// Fetch one device buffer back to a host tensor.
    pub fn fetch(&self, buf: &PjRtBuffer) -> Result<Tensor> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("fetch: {e:?}"))?;
        literal_to_tensor(&lit)
    }

    /// The staged weight buffers (manifest param order).
    pub fn weight_buffers(&self) -> &[PjRtBuffer] {
        &self.weight_bufs
    }

    /// Read current weights back as a store keyed by manifest param names
    /// (used after training to persist updated parameters).
    pub fn weights_to_store(&self) -> Result<WeightStore> {
        let mut ws = WeightStore::default();
        for (spec, buf) in self.manifest.params.iter().zip(&self.weight_bufs) {
            let lit = buf.to_literal_sync().map_err(|e| anyhow!("fetch weight: {e:?}"))?;
            ws.insert(spec.name.clone(), literal_to_tensor(&lit)?);
        }
        Ok(ws)
    }

    /// Replace the staged weights from tensors in manifest param order
    /// (the training loop's update path).
    pub fn set_weights_ordered(&mut self, tensors: &[Tensor]) -> Result<()> {
        ensure!(tensors.len() == self.manifest.params.len(), "weight count mismatch");
        let mut bufs = Vec::with_capacity(tensors.len());
        for (t, spec) in tensors.iter().zip(&self.manifest.params) {
            ensure!(t.shape() == spec.shape.as_slice(), "weight {} shape", spec.name);
            let buf = match t {
                Tensor::F32 { shape, data } => {
                    self.client.buffer_from_host_buffer::<f32>(data, shape, None)
                }
                Tensor::I32 { shape, data } => {
                    self.client.buffer_from_host_buffer::<i32>(data, shape, None)
                }
            }
            .map_err(|e| anyhow!("staging weight: {e:?}"))?;
            bufs.push(buf);
        }
        self.weight_bufs = bufs;
        Ok(())
    }
}

fn untuple(lit: Literal) -> Result<Vec<Literal>> {
    let shape = lit.shape().map_err(|e| anyhow!("shape: {e:?}"))?;
    if shape.is_tuple() {
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    } else {
        Ok(vec![lit])
    }
}

pub fn literal_to_tensor(lit: &Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("array shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        ElementType::F32 => {
            let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?;
            Tensor::from_f32(&dims, data)
        }
        ElementType::S32 => {
            let data = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?;
            Tensor::from_i32(&dims, data)
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}
