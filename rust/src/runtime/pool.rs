//! [`WorkerPool`]: the shared work-stealing runtime behind every host-side
//! parallel stage (batched merging, the serving prep stage, benches).
//!
//! PR 1 parallelized `merge_batch` with a per-call `std::thread::scope`
//! fan-out: every merge paid a full thread spawn + join per worker, which
//! is both latency (~50-100us per spawn) and noise under serving load.
//! This pool spawns its threads **once** and reuses them forever:
//!
//! * **persistent workers** — `workers` threads spawned at construction,
//!   parked on a condvar when idle.  [`WorkerPool::spawned_threads`]
//!   counts lifetime spawns so benches/tests can assert the steady state
//!   performs **zero** thread spawns (the pool's whole point).
//! * **per-worker deques with stealing** — tasks are pushed round-robin
//!   onto one deque per worker; a worker pops its own deque from the
//!   front and steals from the back of its siblings when empty
//!   ([`WorkerPool::steals`] counts those).  Independent chunky tasks
//!   (the merge workload) therefore balance themselves without a central
//!   queue bottleneck.
//! * **scoped `run`** — [`WorkerPool::run`] accepts non-`'static` closures
//!   (borrowing slabs/scratches from the caller's stack, exactly like
//!   `thread::scope`) and blocks until every task completed.  The caller
//!   *helps*: it executes its own batch's still-queued tasks instead of
//!   sleeping, so `run` makes progress even when all workers are busy
//!   with other batches (concurrent `run`s from several threads are
//!   fine — the serving prep stage and ad-hoc callers share one pool).
//! * **panic propagation without poisoning** — a panicking task is caught
//!   on the worker, the first payload is re-thrown from `run` on the
//!   calling thread, and the pool (workers, queues, counters, other
//!   tasks of the same batch) keeps working: a bad batch cannot wedge
//!   the serving process.
//!
//! One process-wide pool is available via [`WorkerPool::global`] (sized by
//! [`WorkerPool::init_global`] before first use — the CLI's
//! `--merge-workers` flag — or `available_parallelism` by default); the
//! merging layer's convenience entry points and the serving executor use
//! it so the whole process shares one set of threads.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

use crate::util::lock_ignore_poison as lock;

/// Type-erased view of one `run` call's task set: `execute(i)` runs task
/// `i` exactly once and returns `true` when it was the batch's last task.
trait TaskSource: Sync {
    fn execute(&self, index: usize) -> bool;
}

/// One `run` call's tasks plus its completion/panic state.  Lives on the
/// calling thread's stack; workers reach it through an erased pointer
/// that `run` guarantees outlives every queued task (it blocks until
/// `remaining` hits zero, and `remaining` is the last field a worker
/// touches).
struct Batch<F> {
    tasks: Vec<Mutex<Option<F>>>,
    remaining: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>,
}

impl<F: FnOnce() + Send> TaskSource for Batch<F> {
    fn execute(&self, index: usize) -> bool {
        let task = lock(&self.tasks[index]).take();
        if let Some(f) = task {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
                let mut slot = lock(&self.panic);
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        }
        // AcqRel: publishes the task's writes to the caller that observes 0.
        self.remaining.fetch_sub(1, Ordering::AcqRel) == 1
    }
}

/// A queued task: erased batch pointer + task index.
///
/// SAFETY: the pointer is only dereferenced while the owning `run` call is
/// still blocked (see `Batch`), so sending it across threads is sound.
struct TaskRef {
    source: *const (dyn TaskSource + 'static),
    index: usize,
}

unsafe impl Send for TaskRef {}

struct Shared {
    /// one deque per worker; `run` distributes round-robin
    queues: Vec<Mutex<VecDeque<TaskRef>>>,
    /// queued-but-not-yet-popped tasks (drives worker wakeup)
    pending: AtomicUsize,
    shutdown: AtomicBool,
    /// workers park here when every deque is empty
    sleep_mx: Mutex<()>,
    sleep_cv: Condvar,
    /// `run` callers park here until their batch completes
    done_mx: Mutex<()>,
    done_cv: Condvar,
    spawned: AtomicU64,
    steals: AtomicU64,
    executed: AtomicU64,
}

fn find_task(shared: &Shared, me: usize) -> Option<TaskRef> {
    let w = shared.queues.len();
    for off in 0..w {
        let qi = (me + off) % w;
        let task = {
            let mut q = lock(&shared.queues[qi]);
            // own deque from the front (submission order), steals from the
            // back (the classic work-stealing split).
            if off == 0 {
                q.pop_front()
            } else {
                q.pop_back()
            }
        };
        if let Some(task) = task {
            shared.pending.fetch_sub(1, Ordering::SeqCst);
            if off != 0 {
                shared.steals.fetch_add(1, Ordering::Relaxed);
            }
            return Some(task);
        }
    }
    None
}

fn worker_loop(shared: Arc<Shared>, me: usize) {
    loop {
        if let Some(task) = find_task(&shared, me) {
            // SAFETY: the batch outlives the task (see `Batch`).
            let done = unsafe { (*task.source).execute(task.index) };
            shared.executed.fetch_add(1, Ordering::Relaxed);
            if done {
                // Lock + notify so a caller between its `remaining` check
                // and `wait` cannot miss the wakeup.
                let _g = lock(&shared.done_mx);
                shared.done_cv.notify_all();
            }
            continue;
        }
        let mut g = lock(&shared.sleep_mx);
        loop {
            if shared.pending.load(Ordering::SeqCst) > 0 {
                break; // drain before honoring shutdown
            }
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            g = shared.sleep_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Persistent work-stealing thread pool.  See the module docs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// A pool with `workers` persistent threads (clamped to at least 1).
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            queues: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            pending: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            sleep_mx: Mutex::new(()),
            sleep_cv: Condvar::new(),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
            spawned: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            executed: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|w| {
                let sh = Arc::clone(&shared);
                shared.spawned.fetch_add(1, Ordering::SeqCst);
                thread::Builder::new()
                    .name(format!("tomers-pool-{w}"))
                    .spawn(move || worker_loop(sh, w))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, handles, workers }
    }

    /// A pool sized to the machine (`available_parallelism`).
    pub fn with_default_parallelism() -> WorkerPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        WorkerPool::new(n)
    }

    /// The process-wide shared pool, created on first use (machine-sized
    /// unless [`WorkerPool::init_global`] ran first).
    pub fn global() -> &'static WorkerPool {
        GLOBAL_POOL.get_or_init(WorkerPool::with_default_parallelism)
    }

    /// Size the process-wide pool before anything uses it.  Returns `false`
    /// (and changes nothing) if the global pool already exists — worker
    /// count is a process-startup decision, not a reconfigurable knob.
    pub fn init_global(workers: usize) -> bool {
        if GLOBAL_POOL.get().is_some() {
            return false;
        }
        GLOBAL_POOL.set(WorkerPool::new(workers)).is_ok()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Lifetime thread spawns.  Equals [`WorkerPool::workers`] forever —
    /// the zero-spawns-after-warmup invariant benches and tests assert.
    pub fn spawned_threads(&self) -> u64 {
        self.shared.spawned.load(Ordering::SeqCst)
    }

    /// Tasks taken from a sibling's deque (lifetime).
    pub fn steals(&self) -> u64 {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Tasks executed (lifetime), including caller-helped ones.
    pub fn tasks_executed(&self) -> u64 {
        self.shared.executed.load(Ordering::Relaxed)
    }

    /// Run a set of independent tasks to completion, `thread::scope`-style:
    /// the closures may borrow from the caller's stack, and `run` returns
    /// only after every task finished.  If any task panicked, the first
    /// payload is re-thrown here (after all tasks completed), and the pool
    /// remains fully usable.
    ///
    /// A single task runs inline on the caller — no queueing, no
    /// synchronization — so the degenerate case costs nothing.
    pub fn run<'scope, F>(&self, tasks: Vec<F>)
    where
        F: FnOnce() + Send + 'scope,
    {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        if n == 1 {
            let mut tasks = tasks;
            (tasks.pop().expect("n == 1"))();
            return;
        }
        let batch = Batch {
            tasks: tasks.into_iter().map(|f| Mutex::new(Some(f))).collect(),
            remaining: AtomicUsize::new(n),
            panic: Mutex::new(None),
        };
        let erased: *const (dyn TaskSource + 'scope) = &batch;
        // SAFETY: lifetime erasure only.  Every queued TaskRef is consumed
        // before `batch.remaining` reaches zero, and this function does not
        // return (nor unwind — nothing below panics) until it does, so no
        // dereference can outlive `batch` or the `'scope` borrows inside.
        let erased: *const (dyn TaskSource + 'static) =
            unsafe { std::mem::transmute(erased) };
        // Count BEFORE pushing: an awake worker popping a just-pushed task
        // must never fetch_sub below zero (usize wrap would make parked
        // workers busy-spin on `pending > 0`).  The transient over-count
        // only costs a failed scan.
        self.shared.pending.fetch_add(n, Ordering::SeqCst);
        for (i, q) in (0..n).map(|i| (i, i % self.workers)) {
            lock(&self.shared.queues[q]).push_back(TaskRef { source: erased, index: i });
        }
        {
            let _g = lock(&self.shared.sleep_mx);
            self.shared.sleep_cv.notify_all();
        }
        // Help: run our own still-queued tasks instead of blocking.
        while let Some(task) = self.pop_own(erased) {
            // SAFETY: `batch` is alive (we are inside `run`).
            unsafe { (*task.source).execute(task.index) };
            self.shared.executed.fetch_add(1, Ordering::Relaxed);
        }
        // Wait for tasks already claimed by workers.
        {
            let mut g = lock(&self.shared.done_mx);
            while batch.remaining.load(Ordering::Acquire) != 0 {
                g = self.shared.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
        }
        if let Some(payload) = lock(&batch.panic).take() {
            resume_unwind(payload);
        }
    }

    /// Pop a queued task belonging to `source` (the caller-help path; other
    /// batches' tasks are left for the workers).
    fn pop_own(&self, source: *const (dyn TaskSource + 'static)) -> Option<TaskRef> {
        for q in &self.shared.queues {
            let task = {
                let mut q = lock(q);
                q.iter()
                    .position(|t| std::ptr::eq(t.source as *const (), source as *const ()))
                    .and_then(|pos| q.remove(pos))
            };
            if let Some(task) = task {
                self.shared.pending.fetch_sub(1, Ordering::SeqCst);
                return Some(task);
            }
        }
        None
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let _g = lock(&self.shared.sleep_mx);
            self.shared.sleep_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

static GLOBAL_POOL: OnceLock<WorkerPool> = OnceLock::new();

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_all_tasks_with_stack_borrows() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u64; 64];
        let tasks: Vec<_> = data
            .chunks_mut(7)
            .enumerate()
            .map(|(c, chunk)| {
                move || {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = (c * 100 + i) as u64;
                    }
                }
            })
            .collect();
        pool.run(tasks);
        for (p, &v) in data.iter().enumerate() {
            assert_eq!(v, ((p / 7) * 100 + p % 7) as u64, "slot {p}");
        }
    }

    #[test]
    fn empty_and_single_task_fast_paths() {
        let pool = WorkerPool::new(2);
        pool.run(Vec::<fn()>::new());
        let hit = AtomicUsize::new(0);
        pool.run(vec![|| {
            hit.fetch_add(1, Ordering::SeqCst);
        }]);
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn panic_propagates_and_poisons_nothing() {
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send>> = vec![
                Box::new(|| {
                    done.fetch_add(1, Ordering::SeqCst);
                }),
                Box::new(|| panic!("task boom")),
                Box::new(|| {
                    done.fetch_add(1, Ordering::SeqCst);
                }),
            ];
            pool.run(tasks);
        }));
        assert!(result.is_err(), "panic must reach the caller");
        // sibling tasks of the panicking batch still ran
        assert_eq!(done.load(Ordering::SeqCst), 2);
        // and the pool is not poisoned: later batches run normally
        let after = AtomicUsize::new(0);
        pool.run(
            (0..16)
                .map(|_| {
                    || {
                        after.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(after.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn zero_thread_spawns_after_warmup() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.spawned_threads(), 4);
        for round in 0..50 {
            let count = AtomicUsize::new(0);
            pool.run(
                (0..9)
                    .map(|_| {
                        || {
                            count.fetch_add(1, Ordering::SeqCst);
                        }
                    })
                    .collect::<Vec<_>>(),
            );
            assert_eq!(count.load(Ordering::SeqCst), 9, "round {round}");
            assert_eq!(pool.spawned_threads(), 4, "round {round}: pool spawned a thread");
        }
        assert!(pool.tasks_executed() >= 450);
    }

    #[test]
    fn concurrent_runs_share_the_pool() {
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    for _ in 0..10 {
                        pool.run(
                            (0..8)
                                .map(|_| {
                                    || {
                                        total.fetch_add(1, Ordering::SeqCst);
                                    }
                                })
                                .collect::<Vec<_>>(),
                        );
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 3 * 10 * 8);
    }

    #[test]
    fn global_pool_is_shared_and_stable() {
        let a = WorkerPool::global() as *const WorkerPool;
        let b = WorkerPool::global() as *const WorkerPool;
        assert_eq!(a, b);
        // init after first use is rejected
        assert!(!WorkerPool::init_global(1));
        assert!(WorkerPool::global().workers() >= 1);
    }
}
