//! Runtime layer: PJRT engine, artifact manifests, weight store, and the
//! shared host-side worker pool.
//!
//! `Engine` (engine.rs) compiles HLO-text artifacts produced by
//! `python/compile/aot.py` on the PJRT CPU client and executes them with
//! weights staged as device buffers.  `Manifest` (manifest.rs) is the
//! Python<->Rust contract; `WeightStore` (weights.rs) the weight format.
//! `WorkerPool` (pool.rs) is the persistent work-stealing pool every
//! host-side parallel stage (batched merging, serving prep) runs on.

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod pool;
pub mod weights;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, Model};
pub use manifest::{Manifest, TensorSpec};
pub use pool::WorkerPool;
pub use weights::WeightStore;
