//! Runtime layer: PJRT engine, artifact manifests, weight store.
//!
//! `Engine` (engine.rs) compiles HLO-text artifacts produced by
//! `python/compile/aot.py` on the PJRT CPU client and executes them with
//! weights staged as device buffers.  `Manifest` (manifest.rs) is the
//! Python<->Rust contract; `WeightStore` (weights.rs) the weight format.

pub mod engine;
pub mod manifest;
pub mod weights;

pub use engine::{Engine, Model};
pub use manifest::{Manifest, TensorSpec};
pub use weights::WeightStore;
