//! Runtime layer: PJRT engine, artifact manifests, weight store.
//!
//! `Engine` (engine.rs) compiles HLO-text artifacts produced by
//! `python/compile/aot.py` on the PJRT CPU client and executes them with
//! weights staged as device buffers.  `Manifest` (manifest.rs) is the
//! Python<->Rust contract; `WeightStore` (weights.rs) the weight format.

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod weights;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, Model};
pub use manifest::{Manifest, TensorSpec};
pub use weights::WeightStore;
