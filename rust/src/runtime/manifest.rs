//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  One JSON per HLO artifact listing the exact flattened
//! parameter order (params first, then data inputs), output specs, the
//! model config, and experiment metadata (token counts per layer, batch).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::json::Json;
use crate::merging::MergeSpec;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn parse(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.usize_list()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub family: String,
    pub params: Vec<TensorSpec>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub config: Json,
    pub meta: Json,
    /// The typed [`MergeSpec`] realized inside the artifact (optional —
    /// older manifests predate it).  Serialized in the same JSON dialect
    /// as the serving config's `merge` blocks
    /// ([`crate::config::merge_spec_to_json`]), with the same
    /// unknown-key rejection, so `Variant.spec` can be read from the
    /// artifact instead of declared by hand.
    pub merge_spec: Option<MergeSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing manifest {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req(key)?.as_arr()?.iter().map(TensorSpec::parse).collect()
        };
        let merge_spec = v
            .get("merge_spec")
            .map(|s| crate::config::merge_spec_from_json(s, "manifest \"merge_spec\""))
            .transpose()?;
        let m = Manifest {
            name: v.req("name")?.as_str()?.to_string(),
            family: v.req("family")?.as_str()?.to_string(),
            params: specs("params")?,
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            config: v.req("config")?.clone(),
            meta: v.req("meta")?.clone(),
            merge_spec,
        };
        ensure!(!m.outputs.is_empty(), "manifest has no outputs");
        Ok(m)
    }

    /// Batch size baked into the artifact (from meta).
    pub fn batch(&self) -> usize {
        self.meta.get("batch").and_then(|b| b.as_usize().ok()).unwrap_or(1)
    }

    /// Per-layer encoder token counts (merge schedule), if present.
    pub fn enc_tokens(&self) -> Option<Vec<usize>> {
        self.meta
            .get("enc_tokens")
            .or_else(|| self.meta.get("tokens"))
            .and_then(|t| t.usize_list().ok())
    }

    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).and_then(|v| v.as_usize().ok())
    }

    pub fn config_str(&self, key: &str) -> Option<&str> {
        self.config.get(key).and_then(|v| v.as_str().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "fc_transformer_L2__r16", "family": "forecast",
      "config": {"arch": "transformer", "m": 192, "p": 96, "r_enc": 16},
      "params": [{"name": "enc/0/attn/wq/w", "shape": [64, 64], "dtype": "f32"}],
      "inputs": [{"name": "x", "shape": [8, 192, 7], "dtype": "f32"}],
      "outputs": [{"name": "out0", "shape": [8, 96, 7], "dtype": "f32"}],
      "meta": {"batch": 8, "enc_tokens": [192, 176, 160]}
    }"#;

    #[test]
    fn parses_fields() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "fc_transformer_L2__r16");
        assert_eq!(m.params[0].elements(), 64 * 64);
        assert_eq!(m.inputs[0].shape, vec![8, 192, 7]);
        assert_eq!(m.batch(), 8);
        assert_eq!(m.enc_tokens().unwrap(), vec![192, 176, 160]);
        assert_eq!(m.config_usize("m"), Some(192));
        assert_eq!(m.config_str("arch"), Some("transformer"));
        assert!(m.merge_spec.is_none(), "merge_spec is optional for older manifests");
    }

    /// A manifest carrying a `merge_spec` block: parsed through the same
    /// strict parser as the serving config, and round-trippable through
    /// `config::merge_spec_to_json` without loss.
    #[test]
    fn merge_spec_round_trips_through_manifest_json() {
        use crate::merging::{Accum, MergeSpec};
        let specs = vec![
            MergeSpec::off(),
            MergeSpec::single(16, 8),
            MergeSpec::fixed_r(vec![8, 8], 2).with_accum(Accum::F32),
            MergeSpec::dynamic(0.9, 1).with_causal(),
        ];
        for spec in specs {
            let block = crate::config::merge_spec_to_json(&spec).to_string();
            let text = SAMPLE.replacen(
                "\"meta\":",
                &format!("\"merge_spec\": {block}, \"meta\":"),
                1,
            );
            let m = Manifest::parse(&text).unwrap_or_else(|e| panic!("{block}: {e:#}"));
            assert_eq!(m.merge_spec, Some(spec), "{block}");
        }
    }

    #[test]
    fn merge_spec_rejects_unknown_and_invalid_keys() {
        // unknown key inside the block, named in the error
        let bad = SAMPLE.replacen(
            "\"meta\":",
            "\"merge_spec\": {\"mode\": \"fixed\", \"rate\": 16}, \"meta\":",
            1,
        );
        let err = Manifest::parse(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("rate"), "{err:#}");
        // a key the mode would never read is an error too
        let bad = SAMPLE.replacen(
            "\"meta\":",
            "\"merge_spec\": {\"mode\": \"off\", \"k\": 4}, \"meta\":",
            1,
        );
        assert!(Manifest::parse(&bad).is_err());
        // invalid specs (k = 0) are rejected at parse time
        let bad = SAMPLE.replacen(
            "\"meta\":",
            "\"merge_spec\": {\"mode\": \"fixed\", \"r\": 4, \"k\": 0}, \"meta\":",
            1,
        );
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_outputs() {
        let bad = SAMPLE.replace(
            r#""outputs": [{"name": "out0", "shape": [8, 96, 7], "dtype": "f32"}]"#,
            r#""outputs": []"#,
        );
        assert!(Manifest::parse(&bad).is_err());
    }
}
