//! Safetensors-lite weight store (mirror of `python/compile/formats.py`).
//!
//! Layout: `u64 LE header-length | JSON header | raw data`.  The header
//! maps tensor name -> {dtype, shape, data_offsets}.  Names use the
//! tree-flatten path convention (`enc/0/attn/wq/w`) so they bind 1:1 to
//! manifest param entries.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::json::Json;
use crate::tensor::Tensor;

#[derive(Clone, Debug, Default)]
pub struct WeightStore {
    pub tensors: BTreeMap<String, Tensor>,
}

impl WeightStore {
    pub fn load(path: &Path) -> Result<WeightStore> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening weights {}", path.display()))?;
        let mut len_buf = [0u8; 8];
        f.read_exact(&mut len_buf)?;
        let hlen = u64::from_le_bytes(len_buf) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
        let mut data = Vec::new();
        f.read_to_end(&mut data)?;

        let mut tensors = BTreeMap::new();
        for (name, spec) in header.as_obj()? {
            let shape = spec.req("shape")?.usize_list()?;
            let offs = spec.req("data_offsets")?.usize_list()?;
            ensure!(offs.len() == 2 && offs[1] <= data.len(), "bad offsets for {name}");
            let bytes = &data[offs[0]..offs[1]];
            let t = match spec.req("dtype")?.as_str()? {
                "f32" => {
                    ensure!(bytes.len() % 4 == 0, "misaligned f32 data for {name}");
                    let vals: Vec<f32> = bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    Tensor::from_f32(&shape, vals)?
                }
                "i32" => {
                    let vals: Vec<i32> = bytes
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    Tensor::from_i32(&shape, vals)?
                }
                other => bail!("unsupported dtype {other} for {name}"),
            };
            tensors.insert(name.clone(), t);
        }
        Ok(WeightStore { tensors })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut header = BTreeMap::new();
        let mut blobs: Vec<&[u8]> = Vec::new();
        let mut raw: Vec<Vec<u8>> = Vec::new();
        let mut offset = 0usize;
        for (name, t) in &self.tensors {
            let bytes: Vec<u8> = match t {
                Tensor::F32 { data, .. } => {
                    data.iter().flat_map(|v| v.to_le_bytes()).collect()
                }
                Tensor::I32 { data, .. } => {
                    data.iter().flat_map(|v| v.to_le_bytes()).collect()
                }
            };
            header.insert(
                name.clone(),
                Json::obj(vec![
                    ("dtype", Json::str(t.dtype())),
                    ("shape", Json::arr(t.shape().iter().map(|&d| Json::num(d as f64)).collect())),
                    (
                        "data_offsets",
                        Json::arr(vec![Json::num(offset as f64), Json::num((offset + bytes.len()) as f64)]),
                    ),
                ]),
            );
            offset += bytes.len();
            raw.push(bytes);
        }
        for b in &raw {
            blobs.push(b);
        }
        let hjson = Json::Obj(header).to_string();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating weights {}", path.display()))?;
        f.write_all(&(hjson.len() as u64).to_le_bytes())?;
        f.write_all(hjson.as_bytes())?;
        for b in blobs {
            f.write_all(b)?;
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("weight {name:?} not found"))
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.tensors.insert(name.into(), t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("tomers_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let mut ws = WeightStore::default();
        ws.insert("a/w", Tensor::from_f32(&[2, 2], vec![1.0, -2.5, 3.25, 0.0]).unwrap());
        ws.insert("b/ids", Tensor::from_i32(&[3], vec![7, -9, 11]).unwrap());
        ws.save(&path).unwrap();
        let rt = WeightStore::load(&path).unwrap();
        assert_eq!(rt.tensors.len(), 2);
        assert_eq!(rt.get("a/w").unwrap(), ws.get("a/w").unwrap());
        assert_eq!(rt.get("b/ids").unwrap(), ws.get("b/ids").unwrap());
        assert!(rt.get("missing").is_err());
    }
}
