//! Serving loop: an executor thread owning the PJRT engine and the loaded
//! merge-rate variants, fed by a request channel.
//!
//! PJRT handles are not `Send`, so the engine, executables and weight
//! buffers all live on the executor thread — the standard topology for a
//! single-accelerator serving process.  Clients hold a cheap cloneable
//! handle; each request carries its own response channel.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::policy::EntropyCache;
use super::{ForecastRequest, ForecastResponse, ServerConfig};
use crate::runtime::Engine;
use crate::tensor::Tensor;

enum Msg {
    Request(ForecastRequest, Instant, mpsc::Sender<ForecastResponse>),
    Report(mpsc::Sender<String>),
    Shutdown,
}

/// Client handle: submit forecasts to the executor thread.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Blocking forecast call.
    pub fn forecast(&self, request: ForecastRequest) -> Result<ForecastResponse> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(request, Instant::now(), rtx))
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("request dropped (backpressure or shutdown)"))
    }

    /// Fire-and-forget submit; the response arrives on the returned channel.
    pub fn submit(&self, request: ForecastRequest) -> Result<mpsc::Receiver<ForecastResponse>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(request, Instant::now(), rtx))
            .map_err(|_| anyhow!("server stopped"))?;
        Ok(rrx)
    }

    pub fn metrics_report(&self) -> Result<String> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Report(rtx)).map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server stopped"))
    }
}

pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<thread::JoinHandle<Result<()>>>,
}

impl ServerHandle {
    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        match self.join.take() {
            Some(j) => j.join().map_err(|_| anyhow!("server thread panicked"))?,
            None => Ok(()),
        }
    }
}

type PendingReq = (ForecastRequest, Instant, mpsc::Sender<ForecastResponse>);

/// Spawn the serving thread.  Loads every variant named by the policy and
/// binds its weights before accepting requests.
pub fn serve(config: ServerConfig) -> Result<ServerHandle> {
    let (tx, rx) = mpsc::channel::<Msg>();
    let cfg = config.clone();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
    let join = thread::spawn(move || -> Result<()> {
        let engine = match Engine::new(&cfg.artifact_dir) {
            Ok(e) => e,
            Err(e) => {
                let _ = ready_tx.send(Err(anyhow!("engine: {e}")));
                return Err(e);
            }
        };
        let mut models = BTreeMap::new();
        let mut queues: BTreeMap<String, DynamicBatcher<PendingReq>> = BTreeMap::new();
        for name in cfg.policy.variant_names() {
            match engine.load_with_weights(&name) {
                Ok(m) => {
                    let capacity = m.manifest.batch();
                    models.insert(name.clone(), m);
                    queues.insert(
                        name.clone(),
                        DynamicBatcher::new(BatcherConfig {
                            capacity,
                            max_wait: cfg.max_wait,
                            max_queue: cfg.max_queue,
                        }),
                    );
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(anyhow!("loading {name}: {e}")));
                    return Err(e);
                }
            }
        }
        let _ = ready_tx.send(Ok(()));
        let mut metrics = Metrics::new();
        // Routing statistic cache: the full-context FFT per request is the
        // hottest non-model cost on the executor thread.  Entropy is
        // computed on a bounded prefix (sized to the policy's top
        // threshold so every variant stays reachable) and memoized by
        // context hash, so repeated/replayed contexts route for the cost
        // of one hash.
        let mut entropy_cache = EntropyCache::for_policy(4096, &cfg.policy);

        loop {
            // Poll with a timeout tight enough to honour flush deadlines.
            let now = Instant::now();
            let timeout = queues
                .values()
                .filter_map(|q| q.next_deadline(now))
                .min()
                .unwrap_or(Duration::from_millis(50));
            match rx.recv_timeout(timeout) {
                Ok(Msg::Request(req, t0, rtx)) => {
                    let decision = cfg.policy.decide_cached(&mut entropy_cache, &req.context);
                    let q = queues
                        .get_mut(&decision.variant.name)
                        .expect("policy names a loaded variant");
                    if q.push((req, t0, rtx)).is_err() {
                        metrics.record_rejected();
                        // dropping rtx signals rejection to the client
                    }
                }
                Ok(Msg::Report(rtx)) => {
                    let _ = rtx.send(metrics.report());
                }
                Ok(Msg::Shutdown) => break,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            // Flush every ready queue.
            let now = Instant::now();
            for (name, q) in queues.iter_mut() {
                while q.ready(now) {
                    let batch = q.drain_batch();
                    let model = &models[name];
                    if let Err(e) = run_batch(model, name, batch, &mut metrics) {
                        eprintln!("batch execution failed on {name}: {e}");
                    }
                }
            }
        }
        Ok(())
    });
    ready_rx
        .recv()
        .map_err(|_| anyhow!("server thread died during startup"))??;
    Ok(ServerHandle { tx, join: Some(join) })
}

fn run_batch(
    model: &crate::runtime::Model,
    variant: &str,
    batch: Vec<PendingReq>,
    metrics: &mut Metrics,
) -> Result<()> {
    let capacity = model.manifest.batch();
    let m = model.manifest.inputs[0].shape[1];
    let n = batch.len();
    anyhow::ensure!(n > 0 && n <= capacity, "bad batch size {n}");
    // Pad short batches by repeating the last context (discarded below).
    let mut xs = Vec::with_capacity(capacity * m);
    for (req, _, _) in &batch {
        anyhow::ensure!(req.context.len() == m, "context length {} != {m}", req.context.len());
        xs.extend_from_slice(&req.context);
    }
    for _ in n..capacity {
        let last = &batch[n - 1].0.context;
        xs.extend_from_slice(last);
    }
    let x = Tensor::from_f32(&[capacity, m], xs)?;
    let outputs = model.execute(&[x])?;
    // chronos family: out0 = logits (b, p, vocab), out1 = scales (b,)
    let vocab = model.manifest.config_usize("vocab").unwrap_or(0);
    let forecasts = if vocab > 0 {
        let clip = model
            .manifest
            .config
            .get("clip")
            .and_then(|c| c.as_f64().ok())
            .unwrap_or(15.0);
        crate::eval::chronos_dequantize(&outputs[0], &outputs[1], vocab, clip)?
    } else {
        outputs[0].clone()
    };
    let mut latencies = Vec::with_capacity(n);
    for (i, (req, t0, rtx)) in batch.into_iter().enumerate() {
        let latency = t0.elapsed().as_secs_f64();
        latencies.push(latency);
        let row = forecasts.row_f32(i)?.to_vec();
        let _ = rtx.send(ForecastResponse {
            id: req.id,
            forecast: row,
            variant: variant.to_string(),
            latency,
            batch_size: n,
        });
    }
    metrics.record_batch(variant, n, &latencies);
    Ok(())
}
