//! Serving front-end: intake thread + staged prep/execute pipeline.
//!
//! Three threads serve a process (see `pipeline` for the stage core):
//!
//! * **intake** — owns the client channel, routes each request through the
//!   merge policy, batches per variant, and flushes ready batches **in
//!   deadline order** (`batcher::drain_ready`) into the prep stage.  A
//!   bounded job channel pushes back on intake when the device falls
//!   behind.
//! * **prep** — spawned by `pipeline::run_stages`: pads the input slab and
//!   premerges over-length contexts on the shared `WorkerPool` while the
//!   previous batch executes (double-buffered slabs).
//! * **execute** — owns the PJRT engine, executables and weight buffers
//!   (PJRT handles are not `Send`, so all device work lives on this one
//!   thread — the standard topology for a single-accelerator serving
//!   process), runs `model.execute`, dequantizes and responds.
//!
//! Clients hold a cheap cloneable handle; each request carries its own
//! response channel.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::{self, BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::pipeline::{self, Pending, PrepJob, ReadyBatch, VariantMeta};
use super::policy::EntropyCache;
use super::{ForecastRequest, ForecastResponse, ServerConfig};
use crate::runtime::pool::WorkerPool;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::util::lock_ignore_poison;

/// Depth of the intake -> prep job channel: enough to keep prep busy, small
/// enough that backpressure reaches the batcher quickly.
const PREP_QUEUE_DEPTH: usize = 2;

enum Msg {
    Request(ForecastRequest, Instant, mpsc::Sender<ForecastResponse>),
    Report(mpsc::Sender<String>),
    Shutdown,
}

/// Client handle: submit forecasts to the serving threads.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Blocking forecast call.
    pub fn forecast(&self, request: ForecastRequest) -> Result<ForecastResponse> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(request, Instant::now(), rtx))
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("request dropped (backpressure or shutdown)"))
    }

    /// Fire-and-forget submit; the response arrives on the returned channel.
    pub fn submit(&self, request: ForecastRequest) -> Result<mpsc::Receiver<ForecastResponse>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(request, Instant::now(), rtx))
            .map_err(|_| anyhow!("server stopped"))?;
        Ok(rrx)
    }

    pub fn metrics_report(&self) -> Result<String> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Report(rtx)).map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server stopped"))
    }
}

pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<thread::JoinHandle<Result<()>>>,
}

impl ServerHandle {
    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        match self.join.take() {
            Some(j) => j.join().map_err(|_| anyhow!("server thread panicked"))?,
            None => Ok(()),
        }
    }
}

/// Spawn the serving threads.  The execute thread loads every variant
/// named by the policy and binds its weights before intake accepts
/// requests.
pub fn serve(config: ServerConfig) -> Result<ServerHandle> {
    // The batch server does not drive stream sessions yet (the streaming
    // scheduler is wired via `tomers stream` / `run_stream_stages`); say
    // so loudly rather than letting a configured block silently do
    // nothing.
    if config.streaming.is_some() {
        eprintln!(
            "WARN: the \"streaming\" config block is not yet wired into `tomers serve` — \
             it only takes effect under `tomers stream` (see DESIGN.md §9)"
        );
    }
    // The pool is process-wide; size it here if the config asks and the
    // pool does not exist yet.
    if config.merge_workers > 0 {
        WorkerPool::init_global(config.merge_workers);
    }
    let pool = WorkerPool::global();
    if config.merge_workers > 0 && pool.workers() != config.merge_workers {
        eprintln!(
            "WARN: merge_workers={} requested but the process pool already runs {} workers",
            config.merge_workers,
            pool.workers()
        );
    }

    let (tx, rx) = mpsc::channel::<Msg>();
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(PREP_QUEUE_DEPTH);
    let (ready_tx, ready_rx) = mpsc::channel::<Result<BTreeMap<String, VariantMeta>>>();

    // Execute thread: owns the engine; prep is spawned inside run_stages.
    let exec_cfg = config.clone();
    let exec_metrics = Arc::clone(&metrics);
    let exec = thread::Builder::new()
        .name("tomers-exec".into())
        .spawn(move || -> Result<()> {
            let engine = match Engine::new(&exec_cfg.artifact_dir) {
                Ok(e) => e,
                Err(e) => {
                    let _ = ready_tx.send(Err(anyhow!("engine: {e}")));
                    return Err(e);
                }
            };
            let mut models = BTreeMap::new();
            let mut metas = BTreeMap::new();
            for name in exec_cfg.policy.variant_names() {
                match engine.load_with_weights(&name) {
                    Ok(m) => {
                        let meta = VariantMeta {
                            capacity: m.manifest.batch(),
                            m: m.manifest.inputs[0].shape[1],
                        };
                        metas.insert(name.clone(), meta);
                        models.insert(name, m);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("loading {name}: {e}")));
                        return Err(e);
                    }
                }
            }
            let _ = ready_tx.send(Ok(metas.clone()));
            pipeline::run_stages(
                jobs_rx,
                metas,
                exec_cfg.merge.clone(),
                pool.workers(),
                pool,
                exec_metrics,
                |ready| execute_ready(&models, ready),
            )
        })
        .map_err(|e| anyhow!("spawning execute thread: {e}"))?;

    let metas = ready_rx
        .recv()
        .map_err(|_| anyhow!("execute thread died during startup"))??;

    // Intake thread: routing + deadline-ordered batching.
    let cfg = config;
    let intake_metrics = metrics;
    let join = thread::Builder::new()
        .name("tomers-intake".into())
        .spawn(move || -> Result<()> {
            // Queues are keyed by (variant, context length): prep requires
            // a batch to be length-uniform (one premerge schedule per
            // batch), so mixing lengths in one queue would reject whole
            // batches as ragged.  Queues appear lazily as lengths show up
            // and are evicted once drained, so the map stays bounded by the
            // lengths currently pending; `total_pending` keeps max_queue a
            // *global* bound (per-queue limits alone would multiply it by
            // the number of distinct lengths).
            let mut queues: BTreeMap<(String, usize), DynamicBatcher<Pending>> = BTreeMap::new();
            let mut total_pending = 0usize;
            // Routing statistic cache: the full-context FFT per request is
            // the hottest non-model cost on the intake thread.  Entropy is
            // computed on a bounded prefix and memoized by context hash
            // (see policy.rs).
            let mut entropy_cache = EntropyCache::for_policy(4096, &cfg.policy);
            'serve: loop {
                // Poll with a timeout tight enough to honour flush deadlines.
                let now = Instant::now();
                let timeout = queues
                    .values()
                    .filter_map(|q| q.next_deadline(now))
                    .min()
                    .unwrap_or(Duration::from_millis(50));
                match rx.recv_timeout(timeout) {
                    Ok(Msg::Request(req, t0, rtx)) => {
                        let decision = cfg.policy.decide_cached(&mut entropy_cache, &req.context);
                        let name = decision.variant.name;
                        let capacity = metas
                            .get(&name)
                            .map(|meta| meta.capacity)
                            .expect("policy names a loaded variant");
                        if total_pending >= cfg.max_queue {
                            lock_ignore_poison(&intake_metrics).record_rejected();
                            // dropping rtx signals rejection to the client
                        } else {
                            let q = queues
                                .entry((name, req.context.len()))
                                .or_insert_with(|| {
                                    DynamicBatcher::new(BatcherConfig {
                                        capacity,
                                        max_wait: cfg.max_wait,
                                        max_queue: cfg.max_queue,
                                    })
                                });
                            match q.push((req, t0, rtx)) {
                                Ok(()) => total_pending += 1,
                                Err(_) => {
                                    lock_ignore_poison(&intake_metrics).record_rejected();
                                }
                            }
                        }
                    }
                    Ok(Msg::Report(rtx)) => {
                        let _ = rtx.send(lock_ignore_poison(&intake_metrics).report());
                    }
                    Ok(Msg::Shutdown) => break,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
                // Flush ready queues, oldest pending request first, into
                // the prep stage (blocking send = backpressure).
                let now = Instant::now();
                for ((variant, _len), batch) in batcher::drain_ready(&mut queues, now) {
                    total_pending -= batch.len();
                    if jobs_tx.send(PrepJob { variant, batch }).is_err() {
                        // stages stopped (execute error) — surface it below
                        break 'serve;
                    }
                }
                // drop drained-empty queues so the map (and the poll scan)
                // stays bounded by the lengths actually in flight
                queues.retain(|_, q| !q.is_empty());
            }
            drop(jobs_tx); // unwinds prep + execute
            match exec.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow!("execute thread panicked")),
            }
        })
        .map_err(|e| anyhow!("spawning intake thread: {e}"))?;
    Ok(ServerHandle { tx, join: Some(join) })
}

/// The device stage: execute one prepped batch and return a forecast row
/// per real request.  The slab is moved into the host tensor and reclaimed
/// afterwards (no per-batch copy — the recycled buffer round-trips through
/// the tensor).
fn execute_ready(
    models: &BTreeMap<String, crate::runtime::Model>,
    ready: &mut ReadyBatch,
) -> Result<Vec<Vec<f32>>> {
    let model = models
        .get(&ready.variant)
        .ok_or_else(|| anyhow!("no model for variant {}", ready.variant))?;
    let capacity = model.manifest.batch();
    let m = model.manifest.inputs[0].shape[1];
    anyhow::ensure!(
        ready.slab.len() == capacity * m,
        "slab {} != ({capacity}, {m})",
        ready.slab.len()
    );
    let x = Tensor::from_f32(&[capacity, m], std::mem::take(&mut ready.slab))?;
    let result = model.execute(std::slice::from_ref(&x));
    // reclaim the buffer for the recycle channel, whatever execute did
    if let Tensor::F32 { data, .. } = x {
        ready.slab = data;
    }
    let outputs = result?;
    // chronos family: out0 = logits (b, p, vocab), out1 = scales (b,)
    let vocab = model.manifest.config_usize("vocab").unwrap_or(0);
    let forecasts = if vocab > 0 {
        let clip = model
            .manifest
            .config
            .get("clip")
            .and_then(|c| c.as_f64().ok())
            .unwrap_or(15.0);
        crate::eval::chronos_dequantize(&outputs[0], &outputs[1], vocab, clip)?
    } else {
        outputs[0].clone()
    };
    (0..ready.rows).map(|i| Ok(forecasts.row_f32(i)?.to_vec())).collect()
}
