//! Serving front-end: intake thread + staged prep/execute pipeline, with
//! stream sessions multiplexed onto the same device thread when a
//! `"streaming"` block is configured.
//!
//! Three threads serve a batch-only process (see `pipeline` for the stage
//! core):
//!
//! * **intake** — owns the client channel, routes each request through the
//!   merge policy, batches per variant, and flushes ready batches **in
//!   deadline order** (`batcher::drain_ready`) into the prep stage.  A
//!   bounded job channel pushes back on intake when the device falls
//!   behind.
//! * **prep** — spawned by `pipeline::run_stages`: pads the input slab and
//!   premerges over-length contexts on the shared `WorkerPool` while the
//!   previous batch executes (double-buffered slabs).
//! * **execute** — owns the PJRT engine, executables and weight buffers
//!   (PJRT handles are not `Send`, so all device work lives on this one
//!   thread — the standard topology for a single-accelerator serving
//!   process), runs `model.execute`, dequantizes and responds.
//!
//! With a `"streaming"` block a **fourth** thread joins (the stream prep
//! stage) and the execute thread runs `serve_loop::run_serve_stages`
//! instead: batch slabs and stream decode steps arrive tagged on one
//! ready channel and share the device, the `WorkerPool` and the metrics
//! (DESIGN.md §9).  The stream intake is bounded by `max_queue` like the
//! batch queue — appends fail fast under backpressure instead of
//! buffering unbounded events.  Startup *fails* when the block names no
//! loaded streaming-capable artifact — a configured block can never be a
//! silent no-op.
//!
//! At startup the execute thread reconciles each variant's declared merge
//! spec with its loaded artifact's `Manifest.merge_spec`
//! ([`MergePolicy::prefer_manifest_specs`]): the manifest wins by default
//! (one log line per artifact says which source won), the
//! `"spec_source": "config"` escape hatch forces the declaration.
//!
//! Clients hold a cheap cloneable handle; each request carries its own
//! response channel and always receives a **terminal** response
//! ([`super::ForecastOutcome`]) — a device fault or a missed deadline
//! answers with an error outcome, never a silently dropped channel.
//! Stream clients hold a [`StreamClient`] from
//! [`ServerHandle::stream_client`]; rolling forecasts land in a
//! per-session bounded outbox ([`DeliveryMonitor`]) read through
//! [`StreamClient::collect`] and retired with [`StreamClient::ack`]
//! (at-least-once delivery, DESIGN.md §10).

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use super::batcher::{self, BatcherConfig, DynamicBatcher};
use super::delivery::DeliveryMonitor;
use super::faults::FaultContext;
use super::metrics::Metrics;
use super::pipeline::{self, Pending, PrepJob, ReadyBatch, VariantMeta};
use super::policy::{EntropyCache, MergePolicy};
use super::serve_loop;
use super::stream::{DecodeStep, StreamEvent};
use super::{ForecastRequest, ForecastResponse, ServerConfig};
use crate::merging::MergeSpec;
use crate::runtime::pool::WorkerPool;
use crate::runtime::{Engine, Model};
use crate::tensor::Tensor;
use crate::util::{join_annotated, lock_ignore_poison};

/// Depth of the intake -> prep job channel: enough to keep prep busy, small
/// enough that backpressure reaches the batcher quickly.
const PREP_QUEUE_DEPTH: usize = 2;

enum Msg {
    Request(ForecastRequest, Instant, mpsc::Sender<ForecastResponse>),
    Report(mpsc::Sender<String>),
    Shutdown,
}

/// Client handle: submit forecasts to the serving threads.
#[derive(Clone)]
pub struct Client {
    tx: mpsc::Sender<Msg>,
}

impl Client {
    /// Blocking forecast call.
    pub fn forecast(&self, request: ForecastRequest) -> Result<ForecastResponse> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(request, Instant::now(), rtx))
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("request dropped (backpressure or shutdown)"))
    }

    /// Fire-and-forget submit; the response arrives on the returned channel.
    pub fn submit(&self, request: ForecastRequest) -> Result<mpsc::Receiver<ForecastResponse>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Msg::Request(request, Instant::now(), rtx))
            .map_err(|_| anyhow!("server stopped"))?;
        Ok(rrx)
    }

    pub fn metrics_report(&self) -> Result<String> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Msg::Report(rtx)).map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server stopped"))
    }
}

/// Stream-session handle: append observation frames to a session (the
/// session is admitted on first sight).  Rolling forecasts accumulate in
/// the session's bounded outbox; [`StreamClient::collect`] reads them and
/// [`StreamClient::ack`] retires them (at-least-once: uncollected or
/// unacked forecasts are redelivered, DESIGN.md §10).
///
/// The intake is **bounded** (`max_queue` pending events, mirroring the
/// batch path's queue bound): when the device falls behind and the
/// buffer fills, [`StreamClient::append`] fails fast with a
/// backpressure error instead of queueing unbounded memory — the caller
/// retries or sheds.
#[derive(Clone)]
pub struct StreamClient {
    tx: mpsc::SyncSender<StreamEvent>,
    /// channels per frame of this serving process (homogeneous-`d`)
    d: usize,
    delivery: Arc<Mutex<DeliveryMonitor>>,
}

impl StreamClient {
    /// Channels per frame this serving process accepts.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Append `points` (a whole number of `d`-channel interleaved frames
    /// for the configured streaming `d`) to `session`.  A ragged length
    /// errs **here**, at the caller — the prep thread would only be able
    /// to log it, invisibly to the client.  Errs without blocking when
    /// the bounded intake is full (backpressure).
    pub fn append(&self, session: u64, points: Vec<f32>) -> Result<()> {
        ensure!(
            points.len() % self.d == 0,
            "session {session}: {} values is not a whole number of {}-channel frames \
             (this serving process runs homogeneous d = {} sessions)",
            points.len(),
            self.d,
            self.d
        );
        self.tx.try_send(StreamEvent::Append { session, points }).map_err(|e| match e {
            mpsc::TrySendError::Full(_) => {
                anyhow!("stream intake full (max_queue events pending) — backpressure, retry")
            }
            mpsc::TrySendError::Disconnected(_) => anyhow!("stream serving stopped"),
        })
    }

    /// Every unacked rolling forecast for `session`, oldest first, as
    /// `(seq, forecast)`.  Entries stay queued (and are redelivered by a
    /// later collect) until [`StreamClient::ack`]ed.
    pub fn collect(&self, session: u64) -> Vec<(u64, Vec<f32>)> {
        lock_ignore_poison(&self.delivery).collect(session)
    }

    /// Retire `session`'s forecasts up to and including `upto`; returns
    /// how many were acked.
    pub fn ack(&self, session: u64, upto: u64) -> usize {
        lock_ignore_poison(&self.delivery).ack(session, upto, Instant::now())
    }
}

pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<thread::JoinHandle<Result<()>>>,
    stream_tx: Option<mpsc::SyncSender<StreamEvent>>,
    /// channels per frame of the streaming subsystem (handed to clients)
    stream_d: usize,
    /// per-session forecast outboxes (shared with the execute thread's
    /// deliver closure); `None` without a `"streaming"` block
    delivery: Option<Arc<Mutex<DeliveryMonitor>>>,
}

impl ServerHandle {
    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone() }
    }

    /// A stream-session client (`None` when no `"streaming"` block is
    /// configured).  All clones must be dropped before [`Self::shutdown`]
    /// can wind the stream prep stage down.
    pub fn stream_client(&self) -> Option<StreamClient> {
        match (&self.stream_tx, &self.delivery) {
            (Some(tx), Some(delivery)) => Some(StreamClient {
                tx: tx.clone(),
                d: self.stream_d,
                delivery: Arc::clone(delivery),
            }),
            _ => None,
        }
    }

    /// The delivery monitor behind the stream outboxes — for accounting
    /// checks (pending depth, stats) outside a [`StreamClient`].  `None`
    /// when streaming is unconfigured.
    pub fn delivery_monitor(&self) -> Option<Arc<Mutex<DeliveryMonitor>>> {
        self.delivery.as_ref().map(Arc::clone)
    }

    pub fn shutdown(mut self) -> Result<()> {
        // Close the stream intake first so the stream prep stage flushes
        // its ready sessions and exits (the dual loop ends only when both
        // input channels are closed).
        self.stream_tx = None;
        self.delivery = None;
        let _ = self.tx.send(Msg::Shutdown);
        match self.join.take() {
            Some(j) => join_annotated(j, "server thread")?,
            None => Ok(()),
        }
    }
}

/// Spawn the serving threads.  The execute thread loads every variant
/// named by the policy, binds its weights, reconciles each variant's
/// merge spec against its manifest, and — when streaming is configured —
/// resolves the stream-decode artifact before intake accepts requests.
pub fn serve(config: ServerConfig) -> Result<ServerHandle> {
    // The pool is process-wide; size it here if the config asks and the
    // pool does not exist yet.
    if config.merge_workers > 0 {
        WorkerPool::init_global(config.merge_workers);
    }
    let pool = WorkerPool::global();
    if config.merge_workers > 0 && pool.workers() != config.merge_workers {
        eprintln!(
            "WARN: merge_workers={} requested but the process pool already runs {} workers",
            config.merge_workers,
            pool.workers()
        );
    }

    config.faults.validate()?;
    let has_streaming = config.streaming.is_some();
    let stream_d = config.streaming.as_ref().map(|s| s.d).unwrap_or(1);
    let (tx, rx) = mpsc::channel::<Msg>();
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    // fault policy + the variant quarantine tracker, shared between the
    // execute stage (records faults) and the intake (routes around
    // quarantined variants)
    let faults = FaultContext::new(config.faults.clone());
    let (jobs_tx, jobs_rx) = mpsc::sync_channel::<PrepJob>(PREP_QUEUE_DEPTH);
    // startup handshake: metas + the manifest-reconciled routing policy
    type Startup = (BTreeMap<String, VariantMeta>, MergePolicy);
    let (ready_tx, ready_rx) = mpsc::channel::<Result<Startup>>();
    // stream plumbing (created unconditionally; the batch-only path drops
    // its ends so nothing dangles).  The event channel is bounded by the
    // same max_queue as the batch intake: when the device falls behind,
    // StreamClient::append fails fast instead of buffering unbounded
    // events behind a blocked stream-prep thread.
    let (ev_tx, ev_rx) = mpsc::sync_channel::<StreamEvent>(config.max_queue.max(1));
    // per-session bounded outboxes for rolling forecasts (replaces the
    // old fire-and-forget forecast channel)
    let delivery = Arc::new(Mutex::new(DeliveryMonitor::new(
        config.faults.outbox_cap,
        config.faults.forecast_ttl,
    )));

    // Execute thread: owns the engine; prep stages are spawned inside
    // run_stages / run_serve_stages.
    let exec_cfg = config.clone();
    let exec_metrics = Arc::clone(&metrics);
    let exec_faults = faults.clone();
    let exec_delivery = Arc::clone(&delivery);
    let exec = thread::Builder::new()
        .name("tomers-exec".into())
        .spawn(move || -> Result<()> {
            let engine = match Engine::new(&exec_cfg.artifact_dir) {
                Ok(e) => e,
                Err(e) => {
                    let _ = ready_tx.send(Err(anyhow!("engine: {e}")));
                    return Err(e);
                }
            };
            let mut models = BTreeMap::new();
            let mut metas = BTreeMap::new();
            for name in exec_cfg.policy.variant_names() {
                match engine.load_with_weights(&name) {
                    Ok(m) => {
                        let meta = VariantMeta {
                            capacity: m.manifest.batch(),
                            m: m.manifest.inputs[0].shape[1],
                        };
                        metas.insert(name.clone(), meta);
                        models.insert(name, m);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("loading {name}: {e}")));
                        return Err(e);
                    }
                }
            }
            // The loader prefers each artifact's Manifest.merge_spec over
            // the config's variant declaration (default; the
            // "spec_source": "config" escape hatch flips it) — one loud
            // line per artifact names which source won.
            let mut policy = exec_cfg.policy.clone();
            let manifest_specs: BTreeMap<String, MergeSpec> = models
                .iter()
                .filter_map(|(n, m)| m.manifest.merge_spec.clone().map(|s| (n.clone(), s)))
                .collect();
            for resolution in
                policy.prefer_manifest_specs(&manifest_specs, exec_cfg.prefer_manifest_spec)
            {
                eprintln!("INFO: {resolution}");
            }
            match exec_cfg.streaming.clone() {
                Some(scfg) => {
                    // Streaming serve: resolve the decode artifact (a
                    // startup error when none is capable), then drive
                    // batch + stream work through one device thread.
                    let manifests: BTreeMap<String, &crate::runtime::Manifest> =
                        models.iter().map(|(n, m)| (n.clone(), &m.manifest)).collect();
                    let art =
                        match serve_loop::resolve_stream_artifact(&manifests, &policy, &scfg) {
                            Ok(a) => a,
                            Err(e) => {
                                let _ = ready_tx.send(Err(anyhow!("{e:#}")));
                                return Err(e);
                            }
                        };
                    drop(manifests);
                    eprintln!(
                        "INFO: streaming decode wired: variant {} (capacity {}, m {}, d {}{})",
                        art.variant,
                        art.meta.capacity,
                        art.meta.m,
                        scfg.d,
                        if art.size_aware { ", size-aware" } else { "" },
                    );
                    let _ = ready_tx.send(Ok((metas.clone(), policy)));
                    let stream_model =
                        models.get(&art.variant).expect("resolved from this map");
                    // forecasts land in the session's bounded outbox;
                    // expiry runs time-gated off the same closure so a
                    // collector-less process still bounds its memory
                    let ttl = exec_cfg.faults.forecast_ttl;
                    let expire_every = (ttl / 4).max(Duration::from_millis(50));
                    let mut last_expire = Instant::now();
                    serve_loop::run_serve_stages(
                        jobs_rx,
                        ev_rx,
                        metas,
                        exec_cfg.merge.clone(),
                        pool.workers(),
                        art.meta.clone(),
                        scfg,
                        pool,
                        exec_metrics,
                        exec_faults,
                        |ready| execute_ready(&models, ready),
                        |step| execute_stream_step(stream_model, art.size_aware, step),
                        move |session, forecast| {
                            let now = Instant::now();
                            let mut d = lock_ignore_poison(&exec_delivery);
                            d.offer(session, forecast, now);
                            if now.duration_since(last_expire) >= expire_every {
                                d.expire(now);
                                last_expire = now;
                            }
                        },
                    )
                }
                None => {
                    drop(ev_rx);
                    drop(exec_delivery);
                    let _ = ready_tx.send(Ok((metas.clone(), policy)));
                    pipeline::run_stages(
                        jobs_rx,
                        metas,
                        exec_cfg.merge.clone(),
                        pool.workers(),
                        pool,
                        exec_metrics,
                        exec_faults,
                        |ready| execute_ready(&models, ready),
                    )
                }
            }
        })
        .map_err(|e| anyhow!("spawning execute thread: {e}"))?;

    let (metas, policy) = ready_rx
        .recv()
        .map_err(|_| anyhow!("execute thread died during startup"))??;

    // Intake thread: routing + deadline-ordered batching.
    let cfg = config;
    let intake_metrics = metrics;
    let intake_faults = faults;
    let intake_delivery = has_streaming.then(|| Arc::clone(&delivery));
    // graceful-degradation order: the policy lists variants by increasing
    // merge rate, so walking left from a quarantined variant reaches
    // cheaper (less merged, more conservative) artifacts first
    let ordered_variants = policy.variant_names();
    let join = thread::Builder::new()
        .name("tomers-intake".into())
        .spawn(move || -> Result<()> {
            // Queues are keyed by (variant, context length): prep requires
            // a batch to be length-uniform (one premerge schedule per
            // batch), so mixing lengths in one queue would reject whole
            // batches as ragged.  Queues appear lazily as lengths show up
            // and are evicted once drained, so the map stays bounded by the
            // lengths currently pending; `total_pending` keeps max_queue a
            // *global* bound (per-queue limits alone would multiply it by
            // the number of distinct lengths).
            let mut queues: BTreeMap<(String, usize), DynamicBatcher<Pending>> = BTreeMap::new();
            let mut total_pending = 0usize;
            // Routing statistic cache: the full-context FFT per request is
            // the hottest non-model cost on the intake thread.  Entropy is
            // computed on a bounded prefix and memoized by context hash
            // (see policy.rs).  The policy is the manifest-reconciled one
            // the execute thread sent back at startup.
            let mut entropy_cache = EntropyCache::for_policy(4096, &policy);
            'serve: loop {
                // Poll with a timeout tight enough to honour flush deadlines.
                let now = Instant::now();
                let timeout = queues
                    .values()
                    .filter_map(|q| q.next_deadline(now))
                    .min()
                    .unwrap_or(Duration::from_millis(50));
                match rx.recv_timeout(timeout) {
                    Ok(Msg::Request(req, t0, rtx)) => {
                        let t_in = Instant::now();
                        let decision = policy.decide_cached(&mut entropy_cache, &req.context);
                        crate::obs::recorder().record(
                            req.id,
                            crate::obs::Stage::Intake,
                            0,
                            t_in,
                            t_in.elapsed(),
                            req.context.len() as u32,
                        );
                        lock_ignore_poison(&intake_metrics)
                            .record_route(&decision.variant.name, decision.entropy);
                        let mut name = decision.variant.name;
                        // graceful degradation: route around a quarantined
                        // variant (repeated device faults) instead of
                        // feeding it more requests to fail
                        {
                            let tracker = lock_ignore_poison(&intake_faults.tracker);
                            if tracker.is_quarantined(&name) {
                                if let Some(alt) = tracker.fallback(&ordered_variants, &name) {
                                    lock_ignore_poison(&intake_metrics)
                                        .record_downgrade(&name, alt);
                                    name = alt.to_string();
                                }
                            }
                        }
                        let capacity = metas
                            .get(&name)
                            .map(|meta| meta.capacity)
                            .expect("policy names a loaded variant");
                        if total_pending >= cfg.max_queue {
                            lock_ignore_poison(&intake_metrics).record_rejected();
                            // dropping rtx signals rejection to the client
                        } else {
                            let q = queues
                                .entry((name, req.context.len()))
                                .or_insert_with(|| {
                                    DynamicBatcher::new(BatcherConfig {
                                        capacity,
                                        max_wait: cfg.max_wait,
                                        max_queue: cfg.max_queue,
                                    })
                                });
                            match q.push((req, t0, rtx)) {
                                Ok(()) => total_pending += 1,
                                Err(_) => {
                                    lock_ignore_poison(&intake_metrics).record_rejected();
                                }
                            }
                        }
                    }
                    Ok(Msg::Report(rtx)) => {
                        // fold the delivery-monitor counters in (and run a
                        // TTL sweep) so the report reflects the outboxes
                        if let Some(delivery) = &intake_delivery {
                            let stats = {
                                let mut d = lock_ignore_poison(delivery);
                                d.expire(Instant::now());
                                d.stats()
                            };
                            lock_ignore_poison(&intake_metrics).set_delivery(stats);
                        }
                        let _ = rtx.send(lock_ignore_poison(&intake_metrics).report());
                    }
                    Ok(Msg::Shutdown) => break,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
                // Flush ready queues, oldest pending request first, into
                // the prep stage (blocking send = backpressure).
                let now = Instant::now();
                for ((variant, _len), batch) in batcher::drain_ready(&mut queues, now) {
                    total_pending -= batch.len();
                    if jobs_tx.send(PrepJob { variant, batch }).is_err() {
                        // stages stopped (execute error) — surface it below
                        break 'serve;
                    }
                }
                // drop drained-empty queues so the map (and the poll scan)
                // stays bounded by the lengths actually in flight
                queues.retain(|_, q| !q.is_empty());
            }
            drop(jobs_tx); // unwinds prep + execute
            join_annotated(exec, "execute thread")?
        })
        .map_err(|e| anyhow!("spawning intake thread: {e}"))?;
    Ok(ServerHandle {
        tx,
        join: Some(join),
        stream_tx: has_streaming.then_some(ev_tx),
        stream_d,
        delivery: has_streaming.then_some(delivery),
    })
}

/// The device stage: execute one prepped batch and return a forecast row
/// per real request.  The slab is moved into the host tensor and reclaimed
/// afterwards (no per-batch copy — the recycled buffer round-trips through
/// the tensor).
fn execute_ready(
    models: &BTreeMap<String, Model>,
    ready: &mut ReadyBatch,
) -> Result<Vec<Vec<f32>>> {
    let model = models
        .get(&ready.variant)
        .ok_or_else(|| anyhow!("no model for variant {}", ready.variant))?;
    let capacity = model.manifest.batch();
    let m = model.manifest.inputs[0].shape[1];
    ensure!(
        ready.slab.len() == capacity * m,
        "slab {} != ({capacity}, {m})",
        ready.slab.len()
    );
    let x = Tensor::from_f32(&[capacity, m], std::mem::take(&mut ready.slab))?;
    let result = model.execute(std::slice::from_ref(&x));
    // reclaim the buffer for the recycle channel, whatever execute did
    if let Tensor::F32 { data, .. } = x {
        ready.slab = data;
    }
    forecast_rows(model, result?, ready.rows)
}

/// The streaming device stage: execute one decode step — values slab
/// always, the size array too when the artifact is size-aware — and
/// return one rolling forecast per real session row.  Both buffers
/// round-trip through the host tensors so the recycle channel keeps its
/// zero-copy steady state.
fn execute_stream_step(
    model: &Model,
    size_aware: bool,
    step: &mut DecodeStep,
) -> Result<Vec<Vec<f32>>> {
    let in0 = &model.manifest.inputs[0];
    ensure!(
        step.slab.len() == in0.elements(),
        "stream slab {} values != artifact input {:?}",
        step.slab.len(),
        in0.shape
    );
    let mut inputs = Vec::with_capacity(2);
    inputs.push(Tensor::from_f32(&in0.shape, std::mem::take(&mut step.slab))?);
    if size_aware {
        let in1 = &model.manifest.inputs[1];
        ensure!(
            step.sizes.len() == in1.elements(),
            "stream size array {} values != artifact input {:?}",
            step.sizes.len(),
            in1.shape
        );
        inputs.push(Tensor::from_f32(&in1.shape, std::mem::take(&mut step.sizes))?);
    }
    let result = model.execute(&inputs);
    // reclaim the buffers for the recycle channel, whatever execute did
    if size_aware {
        if let Some(Tensor::F32 { data, .. }) = inputs.pop() {
            step.sizes = data;
        }
    }
    if let Some(Tensor::F32 { data, .. }) = inputs.pop() {
        step.slab = data;
    }
    forecast_rows(model, result?, step.rows)
}

/// Post-process device outputs into one forecast row per real request:
/// chronos-family artifacts dequantize (out0 = logits, out1 = scales),
/// everything else returns out0's rows directly.
fn forecast_rows(model: &Model, outputs: Vec<Tensor>, rows: usize) -> Result<Vec<Vec<f32>>> {
    let vocab = model.manifest.config_usize("vocab").unwrap_or(0);
    let forecasts = if vocab > 0 {
        let clip = model
            .manifest
            .config
            .get("clip")
            .and_then(|c| c.as_f64().ok())
            .unwrap_or(15.0);
        crate::eval::chronos_dequantize(&outputs[0], &outputs[1], vocab, clip)?
    } else {
        outputs[0].clone()
    };
    (0..rows).map(|i| Ok(forecasts.row_f32(i)?.to_vec())).collect()
}
