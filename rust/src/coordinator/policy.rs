//! Merge-policy planner: serving-level dynamic merging.
//!
//! The paper shows (§6.2, table 4) that spectral entropy of the input
//! predicts how much merging a series tolerates: high-entropy/noisy series
//! gain quality from aggressive merging (adaptive low-pass filtering),
//! low-entropy series should be merged conservatively.  The planner turns
//! that observation into a routing rule: per request, compute the
//! statistic and select the compiled merge-rate variant — a static-shape
//! realisation of §5.5 per-batch dynamic merging (DESIGN.md §3b).

use crate::signal;

/// A selectable artifact variant: merge rate + artifact name suffix.
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    pub name: String,
    pub r: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct PolicyDecision {
    pub variant: Variant,
    pub entropy: f64,
}

/// Entropy-threshold policy over an ordered set of variants.
#[derive(Clone, Debug)]
pub struct MergePolicy {
    /// variants ordered by increasing r (first = no merging)
    pub variants: Vec<Variant>,
    /// entropy thresholds between consecutive variants (len = variants-1)
    pub thresholds: Vec<f64>,
}

impl MergePolicy {
    /// Policy with uniform thresholds over [lo, hi] entropy bits.
    pub fn uniform(variants: Vec<Variant>, lo: f64, hi: f64) -> MergePolicy {
        let n = variants.len();
        let thresholds = (1..n)
            .map(|i| lo + (hi - lo) * i as f64 / n as f64)
            .collect();
        MergePolicy { variants, thresholds }
    }

    /// Fixed policy: always the same variant (for ablations/benchmarks).
    pub fn fixed(variant: Variant) -> MergePolicy {
        MergePolicy { variants: vec![variant], thresholds: vec![] }
    }

    /// Decide the variant for a request context.
    pub fn decide(&self, context: &[f32]) -> PolicyDecision {
        let entropy = signal::spectral_entropy(context);
        let mut idx = 0;
        for (i, &th) in self.thresholds.iter().enumerate() {
            if entropy >= th {
                idx = i + 1;
            }
        }
        PolicyDecision { variant: self.variants[idx].clone(), entropy }
    }

    pub fn variant_names(&self) -> Vec<String> {
        self.variants.iter().map(|v| v.name.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn variants() -> Vec<Variant> {
        vec![
            Variant { name: "chronos_s__r0".into(), r: 0 },
            Variant { name: "chronos_s__r32".into(), r: 32 },
            Variant { name: "chronos_s__r128".into(), r: 128 },
        ]
    }

    #[test]
    fn low_entropy_input_gets_conservative_merging() {
        let policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        // pure sine: very low spectral entropy
        let clean: Vec<f32> = (0..512)
            .map(|i| (2.0 * std::f64::consts::PI * 8.0 * i as f64 / 512.0).sin() as f32)
            .collect();
        let d = policy.decide(&clean);
        assert_eq!(d.variant.r, 0, "entropy={}", d.entropy);
    }

    #[test]
    fn high_entropy_input_gets_aggressive_merging() {
        let policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        let mut rng = Rng::new(5);
        let noisy: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let d = policy.decide(&noisy);
        assert_eq!(d.variant.r, 128, "entropy={}", d.entropy);
    }

    #[test]
    fn fixed_policy_ignores_input() {
        let policy = MergePolicy::fixed(Variant { name: "x".into(), r: 64 });
        let d = policy.decide(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(d.variant.r, 64);
    }

    #[test]
    fn thresholds_partition_monotonically() {
        let policy = MergePolicy::uniform(variants(), 0.0, 9.0);
        assert_eq!(policy.thresholds.len(), 2);
        assert!(policy.thresholds[0] < policy.thresholds[1]);
    }
}
