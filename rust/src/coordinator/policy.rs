//! Merge-policy planner: serving-level dynamic merging.
//!
//! The paper shows (§6.2, table 4) that spectral entropy of the input
//! predicts how much merging a series tolerates: high-entropy/noisy series
//! gain quality from aggressive merging (adaptive low-pass filtering),
//! low-entropy series should be merged conservatively.  The planner turns
//! that observation into a routing rule: per request, compute the
//! statistic and select the compiled merge-rate variant — a static-shape
//! realisation of §5.5 per-batch dynamic merging (DESIGN.md §3b).

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::fmt;

use crate::merging::MergeSpec;
use crate::signal;

/// A selectable artifact variant: the artifact name plus the typed
/// [`MergeSpec`] realized inside it.  Variants can differ in any spec
/// dimension — merge rate, mode, locality `k` — not just `r`; the policy
/// only requires them to be ordered by aggressiveness
/// ([`Variant::r`]).
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    pub name: String,
    pub spec: MergeSpec,
}

impl Variant {
    pub fn new(name: impl Into<String>, spec: MergeSpec) -> Variant {
        Variant { name: name.into(), spec }
    }

    /// The conventional serving variant: a single fixed-`r` merge step at
    /// the default locality ([`MergeSpec::DEFAULT_K`]); `r == 0` means no
    /// merging.
    pub fn fixed(name: impl Into<String>, r: usize) -> Variant {
        let spec = if r == 0 {
            MergeSpec::off()
        } else {
            MergeSpec::single(r, MergeSpec::DEFAULT_K)
        };
        Variant::new(name, spec)
    }

    /// Total merged pairs of the variant's spec (the aggressiveness
    /// ordering key; 0 for off/dynamic variants).
    pub fn r(&self) -> usize {
        self.spec.total_r()
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct PolicyDecision {
    pub variant: Variant,
    pub entropy: f64,
}

/// Entropy-threshold policy over an ordered set of variants.
#[derive(Clone, Debug)]
pub struct MergePolicy {
    /// variants ordered by increasing r (first = no merging)
    pub variants: Vec<Variant>,
    /// entropy thresholds between consecutive variants (len = variants-1)
    pub thresholds: Vec<f64>,
}

impl MergePolicy {
    /// Policy with uniform thresholds over [lo, hi] entropy bits.
    pub fn uniform(variants: Vec<Variant>, lo: f64, hi: f64) -> MergePolicy {
        let n = variants.len();
        let thresholds = (1..n)
            .map(|i| lo + (hi - lo) * i as f64 / n as f64)
            .collect();
        MergePolicy { variants, thresholds }
    }

    /// Fixed policy: always the same variant (for ablations/benchmarks).
    pub fn fixed(variant: Variant) -> MergePolicy {
        MergePolicy { variants: vec![variant], thresholds: vec![] }
    }

    /// Decide the variant for a request context (uncached: one full-length
    /// FFT per call — see [`MergePolicy::decide_cached`] for the serving
    /// hot path).
    pub fn decide(&self, context: &[f32]) -> PolicyDecision {
        self.decision_for(signal::spectral_entropy(context))
    }

    /// Decide using a memoized, bounded-prefix entropy (the executor-thread
    /// hot path).  Identical thresholds; the only difference is where the
    /// entropy number comes from.
    pub fn decide_cached(&self, cache: &mut EntropyCache, context: &[f32]) -> PolicyDecision {
        self.decision_for(cache.entropy(context))
    }

    fn decision_for(&self, entropy: f64) -> PolicyDecision {
        let mut idx = 0;
        for (i, &th) in self.thresholds.iter().enumerate() {
            if entropy >= th {
                idx = i + 1;
            }
        }
        PolicyDecision { variant: self.variants[idx].clone(), entropy }
    }

    pub fn variant_names(&self) -> Vec<String> {
        self.variants.iter().map(|v| v.name.clone()).collect()
    }

    /// Reconcile each variant's declared spec with the spec its loaded
    /// artifact manifest carries (`Manifest.merge_spec`), keyed by
    /// variant name.  By default the **manifest wins** — the artifact is
    /// the ground truth for what was actually compiled into it, and a
    /// config declaration that disagrees is at best stale; pass
    /// `prefer_manifest = false` (the `"spec_source": "config"` escape
    /// hatch) to force the config's declaration instead, e.g. while
    /// migrating mislabeled artifacts.
    ///
    /// Returns one [`SpecResolution`] per variant that has a manifest
    /// spec (variants without one always keep their declaration), so the
    /// caller can log which source won for every routed artifact.  Note
    /// the entropy bands still follow the variant *list order* — a
    /// manifest spec that changes a variant's aggressiveness does not
    /// re-sort the ladder.
    pub fn prefer_manifest_specs(
        &mut self,
        manifest_specs: &BTreeMap<String, MergeSpec>,
        prefer_manifest: bool,
    ) -> Vec<SpecResolution> {
        let mut resolutions = Vec::new();
        for variant in &mut self.variants {
            let Some(manifest) = manifest_specs.get(&variant.name) else {
                continue;
            };
            let declared = variant.spec.clone();
            let source = if prefer_manifest { SpecSource::Manifest } else { SpecSource::Config };
            if prefer_manifest {
                variant.spec = manifest.clone();
            }
            resolutions.push(SpecResolution {
                variant: variant.name.clone(),
                source,
                declared,
                manifest: manifest.clone(),
            });
        }
        resolutions
    }
}

/// Which side won a [`MergePolicy::prefer_manifest_specs`] reconciliation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecSource {
    /// the artifact manifest's `merge_spec` (the default)
    Manifest,
    /// the config file's variant declaration (`"spec_source": "config"`)
    Config,
}

/// The outcome of reconciling one variant's spec sources — [`fmt::Display`]
/// renders the loud per-variant log line the server emits at startup.
#[derive(Clone, Debug, PartialEq)]
pub struct SpecResolution {
    /// variant (artifact) name
    pub variant: String,
    /// which source won
    pub source: SpecSource,
    /// what the config declared
    pub declared: MergeSpec,
    /// what the artifact manifest carries
    pub manifest: MergeSpec,
}

impl SpecResolution {
    /// The spec the policy routes with after reconciliation.
    pub fn chosen(&self) -> &MergeSpec {
        match self.source {
            SpecSource::Manifest => &self.manifest,
            SpecSource::Config => &self.declared,
        }
    }

    /// Whether the two sources disagreed (the interesting case to log).
    pub fn disagreed(&self) -> bool {
        self.declared != self.manifest
    }
}

impl fmt::Display for SpecResolution {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let (winner, note) = match self.source {
            SpecSource::Manifest => ("manifest merge_spec", "default"),
            SpecSource::Config => ("config declaration", "forced by spec_source=\"config\""),
        };
        if self.disagreed() {
            write!(
                f,
                "variant {}: {winner} wins ({note}) — using {:?} (manifest carries {:?}, \
                 config declared {:?})",
                self.variant,
                self.chosen().mode,
                self.manifest.mode,
                self.declared.mode,
            )
        } else {
            write!(
                f,
                "variant {}: {winner} wins ({note}) — manifest and config agree on {:?}",
                self.variant,
                self.chosen().mode,
            )
        }
    }
}

/// FNV-1a over the raw f32 bit patterns — cheap, deterministic, and exact
/// (no float tolerance: a cache hit means the bytes were identical).
pub fn hash_context(context: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &x in context {
        for b in x.to_bits().to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Memoized spectral-entropy provider for the merge-policy planner.
///
/// The serving executor thread runs one `decide` per incoming request, so
/// the statistic must stay far below one model execution.  Two cost
/// levers (`cargo bench --bench policy` measures both):
///
/// * **bounded prefix** — entropy is computed over at most `prefix_cap`
///   leading samples, so the FFT cost is flat in the request length.  For
///   contexts no longer than the cap this is *exactly*
///   [`MergePolicy::decide`]; longer contexts read a lower absolute
///   entropy than full-length analysis would (spectral entropy grows with
///   window size, ceiling `log2(n/2)` bits), so the cap must be sized to
///   the policy's top threshold — use [`EntropyCache::for_policy`], which
///   does that arithmetic, rather than guessing a cap.
/// * **memoization** — entropy is cached by FNV-1a hash of the prefix
///   bytes with FIFO eviction, so replayed/retried contexts cost one hash.
#[derive(Clone, Debug)]
pub struct EntropyCache {
    capacity: usize,
    prefix_cap: usize,
    map: HashMap<u64, f64>,
    fifo: VecDeque<u64>,
    hits: u64,
    misses: u64,
}

impl EntropyCache {
    /// `capacity` cached entries (0 disables memoization), entropy over at
    /// most `prefix_cap` leading samples.
    pub fn new(capacity: usize, prefix_cap: usize) -> EntropyCache {
        EntropyCache {
            capacity,
            prefix_cap: prefix_cap.max(1),
            map: HashMap::new(),
            fifo: VecDeque::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// A cache whose prefix cap is sized so the achievable entropy range
    /// (`~log2(prefix/2)` bits for a one-sided spectrum) comfortably
    /// clears the policy's highest threshold — otherwise the most
    /// aggressive variants would be unreachable no matter how noisy the
    /// input.  Floor 512, ceiling 16384 samples; a top threshold above
    /// ~12.5 bits cannot be honored within the ceiling (the prefix FFT
    /// would no longer be cheap), so that misconfiguration is reported
    /// loudly instead of silently routing around the top variant.
    pub fn for_policy(capacity: usize, policy: &MergePolicy) -> EntropyCache {
        let top = policy.thresholds.iter().cloned().fold(0.0f64, f64::max);
        // need log2(prefix/2) > top, with ~1.5 bits of headroom
        let need = (top + 1.5).exp2().ceil() as usize * 2;
        let cap = need.clamp(512, 16384);
        if need > cap {
            eprintln!(
                "WARN: policy top entropy threshold {top:.1} bits needs a {need}-sample \
                 prefix, capped at {cap} (max achievable ~{:.1} bits) — the most \
                 aggressive variant may be unreachable; lower the threshold",
                (cap as f64 / 2.0).log2()
            );
        }
        EntropyCache::new(capacity, cap)
    }

    /// The slice actually analyzed: the first `min(len, prefix_cap)`
    /// samples.  No power-of-two truncation — `signal::fft` handles
    /// arbitrary lengths (Bluestein), and using the full available window
    /// keeps `decide_cached` identical to `decide` for short contexts and
    /// free of routing discontinuities at power-of-two boundaries.
    fn prefix<'a>(&self, context: &'a [f32]) -> &'a [f32] {
        &context[..context.len().min(self.prefix_cap)]
    }

    /// Memoized bounded-prefix spectral entropy.
    pub fn entropy(&mut self, context: &[f32]) -> f64 {
        let prefix = self.prefix(context);
        if prefix.is_empty() {
            return 0.0;
        }
        if self.capacity == 0 {
            return signal::spectral_entropy(prefix);
        }
        let key = hash_context(prefix);
        if let Some(&e) = self.map.get(&key) {
            self.hits += 1;
            return e;
        }
        let e = signal::spectral_entropy(prefix);
        self.misses += 1;
        if self.map.len() >= self.capacity {
            if let Some(old) = self.fifo.pop_front() {
                self.map.remove(&old);
            }
        }
        self.map.insert(key, e);
        self.fifo.push_back(key);
        e
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn variants() -> Vec<Variant> {
        vec![
            Variant::fixed("chronos_s__r0", 0),
            Variant::fixed("chronos_s__r32", 32),
            Variant::fixed("chronos_s__r128", 128),
        ]
    }

    #[test]
    fn low_entropy_input_gets_conservative_merging() {
        let policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        // pure sine: very low spectral entropy
        let clean: Vec<f32> = (0..512)
            .map(|i| (2.0 * std::f64::consts::PI * 8.0 * i as f64 / 512.0).sin() as f32)
            .collect();
        let d = policy.decide(&clean);
        assert_eq!(d.variant.r(), 0, "entropy={}", d.entropy);
        assert!(d.variant.spec.is_off());
    }

    #[test]
    fn high_entropy_input_gets_aggressive_merging() {
        let policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        let mut rng = Rng::new(5);
        let noisy: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let d = policy.decide(&noisy);
        assert_eq!(d.variant.r(), 128, "entropy={}", d.entropy);
    }

    #[test]
    fn fixed_policy_ignores_input() {
        let policy = MergePolicy::fixed(Variant::fixed("x", 64));
        let d = policy.decide(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(d.variant.r(), 64);
    }

    #[test]
    fn variants_can_differ_in_mode_and_k() {
        use crate::merging::MergeSpec;
        // a mixed-mode variant set: off / tight-k fixed / dynamic
        let policy = MergePolicy::uniform(
            vec![
                Variant::fixed("x__r0", 0),
                Variant::new("x__r32k1", MergeSpec::single(32, 1).with_causal()),
                Variant::new("x__dyn", MergeSpec::dynamic(0.9, 16)),
            ],
            2.0,
            7.0,
        );
        for v in &policy.variants {
            assert!(v.spec.validate().is_ok(), "{}", v.name);
        }
        assert_eq!(policy.variants[1].spec.k, 1);
        assert!(matches!(
            policy.variants[2].spec.mode,
            crate::merging::MergeMode::Dynamic { .. }
        ));
    }

    #[test]
    fn manifest_specs_win_by_default_config_wins_when_forced() {
        use crate::merging::{MergeMode, MergeSpec};
        let manifest_specs: BTreeMap<String, MergeSpec> = [
            // r32's artifact disagrees with its declaration
            ("chronos_s__r32".to_string(), MergeSpec::dynamic(0.9, 1).with_causal()),
            // r128's artifact agrees
            ("chronos_s__r128".to_string(), MergeSpec::single(128, MergeSpec::DEFAULT_K)),
        ]
        .into();

        // default: the manifest is the ground truth
        let mut policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        let res = policy.prefer_manifest_specs(&manifest_specs, true);
        assert_eq!(res.len(), 2, "one resolution per manifest-spec variant");
        assert!(res.iter().all(|r| r.source == SpecSource::Manifest));
        assert!(
            matches!(policy.variants[1].spec.mode, MergeMode::Dynamic { .. }),
            "the routed spec must be the manifest's"
        );
        assert_eq!(policy.variants[2].spec.total_r(), 128);
        // r0 has no manifest spec: declaration kept, no resolution
        assert!(policy.variants[0].spec.is_off());
        let r32 = res.iter().find(|r| r.variant == "chronos_s__r32").unwrap();
        assert!(r32.disagreed());
        assert!(format!("{r32}").contains("manifest merge_spec wins"), "{r32}");
        let r128 = res.iter().find(|r| r.variant == "chronos_s__r128").unwrap();
        assert!(!r128.disagreed());

        // escape hatch: the config declaration is forced
        let mut policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        let res = policy.prefer_manifest_specs(&manifest_specs, false);
        assert!(res.iter().all(|r| r.source == SpecSource::Config));
        assert_eq!(policy.variants[1].spec.total_r(), 32, "declaration must survive");
        let r32 = res.iter().find(|r| r.variant == "chronos_s__r32").unwrap();
        assert_eq!(r32.chosen(), &r32.declared);
        assert!(format!("{r32}").contains("spec_source"), "{r32}");
    }

    #[test]
    fn thresholds_partition_monotonically() {
        let policy = MergePolicy::uniform(variants(), 0.0, 9.0);
        assert_eq!(policy.thresholds.len(), 2);
        assert!(policy.thresholds[0] < policy.thresholds[1]);
    }

    #[test]
    fn cached_decide_matches_uncached_within_prefix_cap() {
        let policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        let mut cache = EntropyCache::new(64, 512);
        let mut rng = Rng::new(17);
        // any length <= the cap analyzes the identical slice, including
        // awkward non-power-of-two lengths (Bluestein FFT path)
        for n in [512usize, 500, 511, 257, 96] {
            let ctx: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let a = policy.decide(&ctx);
            let b = policy.decide_cached(&mut cache, &ctx);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn for_policy_sizes_prefix_to_top_threshold() {
        // uniform(3.0, 7.5) over 3 variants puts thresholds at 4.5 and
        // 6.0 bits; log2(512/2) = 8 already clears 6.0, so the floor holds
        let policy = MergePolicy::uniform(variants(), 3.0, 7.5);
        let cache = EntropyCache::for_policy(16, &policy);
        assert_eq!(cache.prefix_cap, 512);
        assert!((cache.prefix_cap as f64 / 2.0).log2() > policy.thresholds[1]);
        // a policy whose top threshold is ~9.7 bits gets a bigger window
        let hot = MergePolicy::uniform(variants(), 3.0, 13.0);
        let big = EntropyCache::for_policy(16, &hot);
        assert!(big.prefix_cap > 512, "prefix {}", big.prefix_cap);
        assert!((big.prefix_cap as f64 / 2.0).log2() > hot.thresholds[1]);
        // single-variant policy (no thresholds) falls back to the floor
        let fixed = MergePolicy::fixed(Variant::fixed("x", 0));
        assert_eq!(EntropyCache::for_policy(16, &fixed).prefix_cap, 512);
    }

    #[test]
    fn cache_hits_on_repeated_contexts() {
        let policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        let mut cache = EntropyCache::new(64, 512);
        let mut rng = Rng::new(18);
        let ctx: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let first = policy.decide_cached(&mut cache, &ctx);
        assert_eq!(cache.misses(), 1);
        for _ in 0..5 {
            let again = policy.decide_cached(&mut cache, &ctx);
            assert_eq!(again, first);
        }
        assert_eq!(cache.hits(), 5);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cache_evicts_fifo_beyond_capacity() {
        let mut cache = EntropyCache::new(2, 512);
        let mut rng = Rng::new(19);
        for _ in 0..5 {
            let ctx: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            let _ = cache.entropy(&ctx);
        }
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.misses(), 5);
    }

    /// Streaming reuse: a session's context grows by appends but its
    /// *head* is stable, and the cache analyzes the bounded leading
    /// prefix — so once the stream outgrows the cap, every further
    /// `decide_cached` is one hash + one memo hit, never an FFT.
    #[test]
    fn growing_prefix_hits_the_bounded_memo() {
        let policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        let mut cache = EntropyCache::new(64, 256);
        let mut rng = Rng::new(21);
        let mut stream: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let first = policy.decide_cached(&mut cache, &stream);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 0);
        // 10 appends, each growing the stream past the prefix cap: the
        // analyzed slice is bytewise identical every time
        for _ in 0..10 {
            stream.extend((0..32).map(|_| rng.normal() as f32));
            let again = policy.decide_cached(&mut cache, &stream);
            assert_eq!(again, first, "a stable head must route stably");
        }
        assert_eq!(cache.hits(), 10, "every post-growth decision must be a memo hit");
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    /// Eviction at capacity is a cost lever, never a semantics lever:
    /// decisions after arbitrary churn equal the uncached policy.
    #[test]
    fn eviction_at_capacity_does_not_change_decisions() {
        let policy = MergePolicy::uniform(variants(), 2.0, 7.0);
        let mut cache = EntropyCache::new(2, 256);
        let mut rng = Rng::new(22);
        let streams: Vec<Vec<f32>> =
            (0..5).map(|_| (0..200).map(|_| rng.normal() as f32).collect()).collect();
        // two interleaved passes: capacity 2 against 5 streams guarantees
        // every entry is evicted and recomputed at least once
        for _ in 0..2 {
            for ctx in &streams {
                let cached = policy.decide_cached(&mut cache, ctx);
                assert_eq!(cached, policy.decide(ctx), "eviction changed a decision");
            }
        }
        assert_eq!(cache.len(), 2, "cache stayed at capacity");
        assert_eq!(cache.misses(), 10, "full churn: every lookup recomputed");
    }

    #[test]
    fn prefix_caps_long_contexts() {
        let mut cache = EntropyCache::new(4, 512);
        let mut rng = Rng::new(20);
        let ctx: Vec<f32> = (0..700).map(|_| rng.normal() as f32).collect();
        // 700 samples capped to the 512 prefix: same slice, cache hit
        let e_700 = cache.entropy(&ctx);
        let e_512 = cache.entropy(&ctx[..512]);
        assert_eq!(e_700, e_512);
        assert_eq!(cache.hits(), 1);
        // empty context is a safe no-op, not a panic
        assert_eq!(cache.entropy(&[]), 0.0);
        // ordering is preserved on awkward (non-power-of-two) lengths:
        // noise still reads higher than a sine
        let clean: Vec<f32> = (0..500)
            .map(|i| (2.0 * std::f64::consts::PI * 8.0 * i as f64 / 500.0).sin() as f32)
            .collect();
        let noisy: Vec<f32> = (0..500).map(|_| rng.normal() as f32).collect();
        assert!(cache.entropy(&noisy) > cache.entropy(&clean) + 2.0);
    }
}
