//! Serving metrics: latency distribution, throughput, batch occupancy,
//! per-variant routing counts.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::percentile;

#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latencies: Vec<f64>,
    batch_sizes: Vec<usize>,
    per_variant: BTreeMap<String, usize>,
    rejected: usize,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            latencies: Vec::new(),
            batch_sizes: Vec::new(),
            per_variant: BTreeMap::new(),
            rejected: 0,
        }
    }

    pub fn record_batch(&mut self, variant: &str, batch: usize, latencies: &[f64]) {
        self.batch_sizes.push(batch);
        self.latencies.extend_from_slice(latencies);
        *self.per_variant.entry(variant.to_string()).or_insert(0) += latencies.len();
    }

    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    pub fn served(&self) -> usize {
        self.latencies.len()
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    pub fn throughput(&self) -> f64 {
        self.served() as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn latency_percentiles(&self) -> (f64, f64, f64) {
        let mut l = self.latencies.clone();
        (
            percentile(&mut l, 50.0),
            percentile(&mut l, 95.0),
            percentile(&mut l, 99.0),
        )
    }

    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    pub fn per_variant(&self) -> &BTreeMap<String, usize> {
        &self.per_variant
    }

    pub fn report(&self) -> String {
        let (p50, p95, p99) = self.latency_percentiles();
        let mut s = format!(
            "served={} rejected={} throughput={:.1}/s p50={:.1}ms p95={:.1}ms p99={:.1}ms occupancy={:.2}\n",
            self.served(),
            self.rejected,
            self.throughput(),
            p50 * 1e3,
            p95 * 1e3,
            p99 * 1e3,
            self.mean_batch_occupancy(),
        );
        for (v, n) in &self.per_variant {
            s.push_str(&format!("  {v}: {n}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new();
        m.record_batch("v1", 4, &[0.010, 0.012, 0.011, 0.013]);
        m.record_batch("v2", 2, &[0.020, 0.022]);
        m.record_rejected();
        assert_eq!(m.served(), 6);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.per_variant()["v1"], 4);
        assert!((m.mean_batch_occupancy() - 3.0).abs() < 1e-12);
        let (p50, p95, p99) = m.latency_percentiles();
        assert!(p50 <= p95 && p95 <= p99);
        assert!(m.report().contains("v2: 2"));
    }
}
